//! Bench harness regenerating the paper's fig5 (see
//! `rust/src/experiments/fig5.rs` for the claims checked and
//! DESIGN.md for the experiment index). Scale via GNND_SCALE=quick|standard|full.
fn main() {
    let scale = gnnd::experiments::Scale::from_env();
    eprintln!("running fig5 at {scale:?} scale (GNND_SCALE to change)");
    gnnd::experiments::fig5::run(scale);
}
