//! Bench harness regenerating the paper's fig7 (see
//! `rust/src/experiments/fig7.rs` for the claims checked and
//! DESIGN.md for the experiment index). Scale via GNND_SCALE=quick|standard|full.
fn main() {
    let scale = gnnd::experiments::Scale::from_env();
    eprintln!("running fig7 at {scale:?} scale (GNND_SCALE to change)");
    gnnd::experiments::fig7::run(scale);
}
