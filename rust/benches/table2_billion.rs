//! Bench harness regenerating the paper's table2 (see
//! `rust/src/experiments/table2.rs` for the claims checked and
//! DESIGN.md for the experiment index). Scale via GNND_SCALE=quick|standard|full.
fn main() {
    let scale = gnnd::experiments::Scale::from_env();
    eprintln!("running table2 at {scale:?} scale (GNND_SCALE to change)");
    gnnd::experiments::table2::run(scale);
}
