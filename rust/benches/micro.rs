//! Micro benchmarks of the hot paths (the §Perf instrumentation):
//!
//! * native distance kernels (L2 / IP throughput);
//! * one cross-matching batch: native vs PJRT-pallas vs PJRT-jnp — the
//!   L1 ablation (tiled Pallas kernel vs plain-XLA reference inside the
//!   same artifact shape) plus host-oracle reference;
//! * sampling and selective-update phases in isolation;
//! * end-to-end per-iteration cost at a fixed n.
//!
//! Criterion is not in the vendored dependency set, so this is a plain
//! harness: warmup + timed reps, median-of-batches ns/op.

use gnnd::config::Metric;
use gnnd::dataset::synth;
use gnnd::gnnd::engine::{Batch, CrossmatchEngine, NativeEngine};
use gnnd::gnnd::sample::parallel_sample;
use gnnd::gnnd::GnndParams;
use gnnd::graph::{concurrent::ConcurrentGraph, KnnGraph, EMPTY};
use gnnd::runtime::{artifacts_available, Manifest, PjrtEngine};
use gnnd::util::rng::Rng;
use gnnd::util::timer::Timer;

fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..reps.div_ceil(10).max(1) {
        f();
    }
    let mut times = Vec::new();
    for _ in 0..5 {
        let t = Timer::start();
        for _ in 0..reps {
            f();
        }
        times.push(t.secs() / reps as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = times[times.len() / 2];
    let (val, unit) = if med < 1e-6 {
        (med * 1e9, "ns")
    } else if med < 1e-3 {
        (med * 1e6, "us")
    } else if med < 1.0 {
        (med * 1e3, "ms")
    } else {
        (med, "s ")
    };
    println!("{name:<46} {val:>9.2} {unit}/op");
    med
}

fn mk_batch(ds: &gnnd::Dataset, rows: usize, s: usize, seed: u64) -> (Vec<u32>, Vec<u32>, Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let mut new_ids = Vec::with_capacity(rows * s);
    let mut old_ids = Vec::with_capacity(rows * s);
    for _ in 0..rows * s {
        new_ids.push(rng.below(ds.len()) as u32);
        old_ids.push(rng.below(ds.len()) as u32);
    }
    let gn: Vec<i32> = new_ids.iter().map(|&x| if x == EMPTY { -1 } else { x as i32 }).collect();
    let go: Vec<i32> = old_ids.iter().map(|&x| if x == EMPTY { -1 } else { x as i32 }).collect();
    (new_ids, old_ids, gn, go)
}

fn main() {
    println!("== micro benches (hot paths) ==");
    let ds = synth::sift_like(20_000, 0xBEEF);

    // ---- L3 native distance kernels ----
    {
        let a = ds.vec(0).to_vec();
        let b = ds.vec(1).to_vec();
        let mut acc = 0f32;
        bench("distance: l2_sq d=128", 100_000, || {
            acc += gnnd::distance::l2_sq(&a, &b);
        });
        bench("distance: dot d=128", 100_000, || {
            acc += gnnd::distance::dot(&a, &b);
        });
        std::hint::black_box(acc);
    }

    // ---- one crossmatch batch (B=64, S=32, d=128) ----
    let rows = 64;
    let s = 32;
    let (new_ids, old_ids, gn, go) = mk_batch(&ds, rows, s, 1);
    let batch = Batch { s, rows, new_ids: &new_ids, old_ids: &old_ids, groups_new: &gn, groups_old: &go };
    bench("crossmatch: native (64x32, d=128)", 50, || {
        std::hint::black_box(NativeEngine.crossmatch(&ds, &batch).unwrap());
    });

    if artifacts_available("artifacts") {
        let pjrt = PjrtEngine::load("artifacts", s, ds.d, Metric::L2).expect("pjrt engine");
        println!("   [pjrt artifact: {}]", pjrt.artifact().name);
        bench("crossmatch: pjrt pallas (64x32, d=128)", 10, || {
            std::hint::black_box(pjrt.crossmatch(&ds, &batch).unwrap());
        });
        // jnp twin — the L1 Pallas-vs-plain-XLA ablation
        if let Ok(manifest) = Manifest::load("artifacts") {
            if let Ok(meta) = manifest.by_name("crossmatch_s32_d128_l2_jnp") {
                let jnp = PjrtEngine::load_artifact("artifacts", meta).expect("jnp engine");
                bench("crossmatch: pjrt jnp-ref (64x32, d=128)", 10, || {
                    std::hint::black_box(jnp.crossmatch(&ds, &batch).unwrap());
                });
            }
        }
    } else {
        println!("crossmatch: pjrt SKIPPED (run `make artifacts`)");
    }

    // ---- sampling phase ----
    {
        let mut rng = Rng::new(3);
        let mut g = KnnGraph::random_init(&ds, 32, &mut rng);
        bench("sampling: parallel_sample n=20k k=32 p=16", 5, || {
            std::hint::black_box(parallel_sample(&mut g, 16, gnnd::util::num_threads()));
        });
    }

    // ---- selective update (segmented vs single-lock) ----
    for (name, width) in [("update: segmented insert", 32usize), ("update: single-lock insert", usize::MAX)] {
        let mut g = KnnGraph::empty(20_000, 64);
        let mut rng = Rng::new(4);
        let pairs: Vec<(usize, u32, f32)> = (0..10_000)
            .map(|_| (rng.below(1_000), rng.below(20_000) as u32, rng.f32()))
            .collect();
        let cg = ConcurrentGraph::new(&mut g, width);
        let mut i = 0;
        bench(name, 20_000, || {
            let (u, v, d) = pairs[i % pairs.len()];
            i += 1;
            cg.insert(u, v, d);
        });
    }

    // ---- one full GNND iteration at n=20k ----
    {
        let params = GnndParams::default().with_k(32).with_p(16).with_iters(1);
        bench("gnnd: full iteration n=20k k=32 p=16 (native)", 1, || {
            std::hint::black_box(gnnd::gnnd::build(&ds, &params).unwrap());
        });
    }
    println!("== done ==");
}
