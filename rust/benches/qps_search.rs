//! Serving benchmark: build a GNND graph at GNND_SCALE and sweep the
//! search subsystem's `ef` knob, printing the recall-vs-QPS operating
//! curve (QPS, p50/p95/p99 latency, recall@10) — the closed-loop
//! counterpart of the construction-side fig benches.
//!
//! ```bash
//! cargo bench --bench qps_search                 # standard scale
//! GNND_SCALE=quick cargo bench --bench qps_search
//! GNND_THREADS=8 cargo bench --bench qps_search
//! ```

use gnnd::dataset::synth;
use gnnd::gnnd::GnndParams;
use gnnd::search::serve::{self, ServeConfig};
use gnnd::search::{EntryStrategy, SearchParams};
use gnnd::util::timer::Timer;

fn main() {
    let scale = gnnd::experiments::Scale::from_env();
    let n = scale.n_base();
    eprintln!("running qps_search at {scale:?} scale (GNND_SCALE to change): n={n}");

    let ds = synth::sift_like(n, 0x5EBE);
    let t = Timer::start();
    let graph = gnnd::gnnd::build(&ds, &GnndParams::default()).expect("gnnd build");
    eprintln!("graph built in {:.1}s (k={})", t.secs(), graph.k());

    let cfg = ServeConfig {
        k: 10,
        ef_sweep: vec![8, 16, 32, 64, 128, 256],
        n_queries: 2_000.min(n),
        distinct_queries: 1_000.min(n),
        threads: 0,
        params: SearchParams::default().with_entries(EntryStrategy::KMeans, 16),
        ..Default::default()
    };
    let report = serve::run_sweep(&ds, &graph, &cfg).expect("serve sweep");
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
}
