//! Serving benchmark: build a GNND graph at GNND_SCALE and sweep the
//! search subsystem's `ef` knob, printing the recall-vs-QPS operating
//! curve (QPS, p50/p95/p99 latency, recall@10) — the closed-loop
//! counterpart of the construction-side fig benches. A second sweep
//! serves the same corpus split into 4 shards through the out-of-core
//! pipeline + `ShardedIndex`, so monolithic-vs-sharded QPS is tracked
//! over time; a third serves the shards under a residency budget that
//! fits ~50% of the store (LRU faulting, residency counters printed);
//! a fourth serves the same budget at *block* granularity (paged shard
//! files, partial reads — bytes_read vs total payload printed), and a
//! fifth compares sequential vs parallel scatter
//! (`search_threads`, now a persistent pool) at a single serve worker,
//! where per-query latency is the whole story. Hierarchy sweeps rerun
//! the monolithic and sharded configurations with coarse-to-fine entry
//! descent (+ adaptive `route_slack` shard pruning on the sharded
//! ones) — flat-vs-hierarchy at equal ef is the entry-quality story,
//! and those curves are additionally dumped machine-readable to
//! `BENCH_8.json` at the repo root (recall@10 / qps / hops /
//! dist_evals / probe_mean per sweep point). A product-quantized
//! sweep (`--pq-m d/8`, per-query ADC lookup tables, exact f32
//! rerank) joins the f32 and scalar-quant curves in `BENCH_10.json`,
//! which also records each configuration's vector payload bytes, the
//! `simd` feature state and per-kernel dispatch-vs-scalar
//! micro-throughput. A final *open-loop*
//! sweep probes the monolithic index's closed-loop capacity, then
//! offers 60% and 150% of it on a seeded Poisson schedule — the
//! underloaded point shows queue delays near zero, the overloaded one
//! trips the overload flag and shows the queueing tail the closed
//! loop structurally cannot see. Last, the monolithic index is served
//! over loopback TCP behind the `gnnd serve` front end at coalescing
//! windows {0, 200, 1000}µs — network-vs-in-process QPS at identical
//! recall is the cost of the wire, and the window sweep the batching
//! payback.
//!
//! ```bash
//! cargo bench --bench qps_search                 # standard scale
//! GNND_SCALE=quick cargo bench --bench qps_search
//! GNND_THREADS=8 cargo bench --bench qps_search
//! ```

use gnnd::dataset::synth;
use gnnd::gnnd::{GnndParams, NativeEngine};
use gnnd::merge::outofcore::{
    build_out_of_core, pq_quantize_store, quantize_store, OutOfCoreConfig, ResidencyMode,
    ShardCompression, ShardStore,
};
use gnnd::metrics::Report;
use gnnd::search::serve::{self, ServeConfig};
use gnnd::search::server::{RemoteIndex, Server, ServerConfig};
use gnnd::search::sharded::ShardedIndex;
use gnnd::search::{EntryStrategy, SearchIndex, SearchParams};
use gnnd::util::json::Json;
use gnnd::util::timer::Timer;

/// Reduce one sweep report to the `BENCH_8.json` point list: the
/// operating-curve columns only (`recall@<k>` renamed to `recall` so
/// downstream tooling doesn't need to know k).
fn bench8_points(r: &Report) -> Json {
    let keep = ["ef", "qps", "recall", "hops", "dist_evals", "rerank_evals", "probe_mean"];
    let rows = r
        .rows
        .iter()
        .map(|row| {
            let mut o = Json::obj();
            for (name, v) in &row.cols {
                let key = if name.starts_with("recall@") {
                    "recall"
                } else {
                    name.as_str()
                };
                if keep.contains(&key) {
                    o = o.set(key, *v);
                }
            }
            o
        })
        .collect();
    Json::Arr(rows)
}

fn main() {
    let scale = gnnd::experiments::Scale::from_env();
    let n = scale.n_base();
    eprintln!("running qps_search at {scale:?} scale (GNND_SCALE to change): n={n}");

    let ds = synth::sift_like(n, 0x5EBE);
    let t = Timer::start();
    let graph = gnnd::gnnd::build(&ds, &GnndParams::default()).expect("gnnd build");
    eprintln!("graph built in {:.1}s (k={})", t.secs(), graph.k());

    let cfg = ServeConfig {
        k: 10,
        ef_sweep: vec![16, 32, 64, 128, 256],
        n_queries: 2_000.min(n),
        distinct_queries: 1_000.min(n),
        threads: 0,
        params: SearchParams::default().with_entries(EntryStrategy::KMeans, 16),
        ..Default::default()
    };
    let index = SearchIndex::new(&ds, &graph, cfg.params.clone()).expect("search index");
    let report = serve::run_sweep_on(&index, &ds, &cfg).expect("serve sweep");
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    let mut bench8 = vec![("mono-kmeans16", report)];
    // BENCH_10.json rows: (tag, vector payload bytes, sweep points) for
    // the precision story — f32 vs scalar-quant vs product-quantized
    let mut bench10: Vec<(&str, usize, Json)> = Vec::new();

    // ---- monolithic hierarchy entries: the same graph seeded by a
    // coarse-to-fine descent instead of fixed k-means entries — equal-ef
    // hops and dist_evals against the sweep above are the entry-quality
    // story BENCH_8.json records ----
    let cfg_mono_hier = ServeConfig {
        params: SearchParams::default().with_entries(EntryStrategy::Hierarchy, 16),
        ..cfg.clone()
    };
    let mono_hier =
        SearchIndex::new(&ds, &graph, cfg_mono_hier.params.clone()).expect("hierarchy index");
    let mut ds_mono_hier = ds.clone();
    ds_mono_hier.name = format!("{} hierarchy", ds.name);
    let report =
        serve::run_sweep_on(&mono_hier, &ds_mono_hier, &cfg_mono_hier).expect("hierarchy sweep");
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    bench8.push(("mono-hierarchy16", report));
    drop(mono_hier);

    // ---- sharded variant: same corpus, 4 out-of-core shards ----
    let dir = std::env::temp_dir().join(format!("gnnd-qps-shards-{}", std::process::id()));
    let ooc = OutOfCoreConfig { shards: 4, workers: 2, params: GnndParams::default() };
    let t = Timer::start();
    let (_g, stats) = build_out_of_core(&ds, &dir, &ooc, &NativeEngine).expect("ooc build");
    eprintln!(
        "sharded build in {:.1}s ({} merges over {} rounds)",
        t.secs(),
        stats.merges,
        stats.rounds
    );
    let sharded = ShardedIndex::open(&dir, cfg.params.clone(), 0).expect("sharded index");
    // distinct corpus name => distinct report title => distinct JSON
    // file, so the monolithic curve above is not overwritten
    let mut ds_sharded = ds.clone();
    ds_sharded.name = format!("{} sharded", ds.name);
    let report = serve::run_sweep_on(&sharded, &ds_sharded, &cfg).expect("sharded sweep");
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    bench10.push(("f32-flat", n * ds.d * 4, bench8_points(&report)));
    bench8.push(("sharded-flat", report));
    drop(sharded);

    // ---- budget-constrained variant: ~50% of the store resident ----
    // probe the 2 nearest of 4 shards so the per-query pinned set fits
    // the budget; shards fault in and out through the LRU cache
    let manifest = ShardStore::new(&dir)
        .and_then(|s| s.load_manifest())
        .expect("shard manifest");
    let budget = manifest.estimated_resident_bytes() / 2;
    let tight = ShardedIndex::open_with(&dir, cfg.params.clone(), 2, budget, 1)
        .expect("budget-constrained index");
    let mut ds_tight = ds.clone();
    ds_tight.name = format!("{} sharded budget50", ds.name);
    let report = serve::run_sweep_on(&tight, &ds_tight, &cfg).expect("budget sweep");
    tight.store().evict_to_budget(); // shed the last queries' released pins
    let res = tight.residency();
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    println!("residency at budget 50%: {}", res.to_json());
    drop(tight);

    // ---- block-residency variant: same 50% budget, but enforced over
    // 64 KiB blocks of all shards instead of whole shards — queries
    // page in only the rows their walks visit (bytes_read vs the
    // store's total payload is the partial-read story), results are
    // bit-identical to every other configuration ----
    let paged = ShardedIndex::open_with_residency(
        &dir,
        cfg.params.clone(),
        2,
        budget,
        1,
        ResidencyMode::block(),
    )
    .expect("block-residency index");
    let mut ds_paged = ds.clone();
    ds_paged.name = format!("{} sharded block50", ds.name);
    let report = serve::run_sweep_on(&paged, &ds_paged, &cfg).expect("block sweep");
    let res = paged.residency();
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    println!("residency at block-granular budget 50%: {}", res.to_json());
    drop(paged);

    // ---- quantized variant: same 50% budget at block granularity,
    // but the vector payload is u8 scalar-quantized codes (4x more
    // rows per block of budget) with the f32 shards as the
    // exact-rerank source (`rerank=4`) — recall vs the two f32 curves
    // above is the quantization story, the rerank_evals column the
    // extra exact work it costs ----
    let t = Timer::start();
    quantize_store(&dir).expect("quantize shard store");
    eprintln!("quantized shard store in {:.1}s", t.secs());
    let qstore = ShardStore::with_options(&dir, budget, ResidencyMode::block(), true)
        .expect("quantized store");
    let quant = ShardedIndex::from_store(qstore, cfg.params.clone().with_rerank(4), 2, 1)
        .expect("quantized index");
    let cfg_quant = ServeConfig { params: cfg.params.clone().with_rerank(4), ..cfg.clone() };
    let mut ds_quant = ds.clone();
    ds_quant.name = format!("{} sharded quant50 rerank4", ds.name);
    let report = serve::run_sweep_on(&quant, &ds_quant, &cfg_quant).expect("quantized sweep");
    let res = quant.residency();
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    println!("residency at quantized block budget 50%: {}", res.to_json());
    bench10.push(("scalar-rerank4", n * ds.d, bench8_points(&report)));
    drop(quant);

    // ---- product-quantized variant: same budget/granularity/rerank,
    // but each row is m = d/8 PQ codes scored through a per-query ADC
    // lookup table (m table gathers per distance) — 4x less payload
    // than even the u8 codes, with the same f32 shards as the
    // exact-rerank source. Recall vs the scalar curve above is the PQ
    // story BENCH_10.json records ----
    let t = Timer::start();
    let pq_m = (ds.d / 8).max(1);
    let pp = pq_quantize_store(&dir, pq_m).expect("pq-quantize shard store");
    eprintln!("pq-quantized shard store (m={}) in {:.1}s", pp.m(), t.secs());
    let pstore =
        ShardStore::with_compression(&dir, budget, ResidencyMode::block(), ShardCompression::Pq)
            .expect("pq store");
    let pq_idx = ShardedIndex::from_store(pstore, cfg.params.clone().with_rerank(4), 2, 1)
        .expect("pq index");
    let cfg_pq = ServeConfig { params: cfg.params.clone().with_rerank(4), ..cfg.clone() };
    let mut ds_pq = ds.clone();
    ds_pq.name = format!("{} sharded pq50 rerank4", ds.name);
    let report = serve::run_sweep_on(&pq_idx, &ds_pq, &cfg_pq).expect("pq sweep");
    let res = pq_idx.residency();
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    println!("residency at pq block budget 50%: {}", res.to_json());
    // payload: m code bytes per row plus one copy of the shared
    // codebooks (256 centroids x d floats; every shard stores the
    // same fitted code space)
    bench10.push(("pq-rerank4", n * pq_m + 1024 * ds.d + 4 * pq_m, bench8_points(&report)));
    drop(pq_idx);

    // ---- hierarchy entries + adaptive routing over the same shards:
    // per-shard `hier_<s>.bin` sidecars (built on this first open,
    // loaded byte-identically afterwards) seed every probed shard's
    // beam near the query, and `route_slack = 1.2` prunes shards whose
    // best routing centroid is > 1.2x the nearest shard's score — vs
    // the probe-all sharded sweep above, recall holds while hops,
    // dist_evals and probe_mean drop ----
    let hier_params = SearchParams::default()
        .with_entries(EntryStrategy::Hierarchy, 16)
        .with_route_slack(1.2);
    let cfg_hier = ServeConfig { params: hier_params.clone(), ..cfg.clone() };
    let hier = ShardedIndex::open(&dir, hier_params.clone(), 0).expect("hierarchy sharded index");
    let mut ds_hier = ds.clone();
    ds_hier.name = format!("{} sharded hier slack1.2", ds.name);
    let report = serve::run_sweep_on(&hier, &ds_hier, &cfg_hier).expect("hierarchy sharded sweep");
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    bench8.push(("sharded-hier-slack1.2", report));
    drop(hier);

    // ---- quantized + hierarchy + routing: the descent, the slack
    // cutoff and the u8 code path compose — same budget/rerank as the
    // quant50 sweep above, hierarchy sidecars reused from the f32 open
    let qstore = ShardStore::with_options(&dir, budget, ResidencyMode::block(), true)
        .expect("quantized store");
    let quant_hier = ShardedIndex::from_store(qstore, hier_params.clone().with_rerank(4), 2, 1)
        .expect("quantized hierarchy index");
    let cfg_qh = ServeConfig { params: hier_params.clone().with_rerank(4), ..cfg.clone() };
    let mut ds_qh = ds.clone();
    ds_qh.name = format!("{} sharded quant50 hier rerank4", ds.name);
    let report = serve::run_sweep_on(&quant_hier, &ds_qh, &cfg_qh).expect("quantized hier sweep");
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    bench8.push(("quant50-hier-slack1.2", report));
    drop(quant_hier);

    // ---- sequential vs parallel scatter at 1 serve worker ----
    // with a single closed-loop worker, QPS is per-query latency:
    // fanning the probed shards across 4 scatter threads must beat the
    // sequential scatter at identical recall (results are bit-equal)
    let cfg_lat = ServeConfig {
        ef_sweep: vec![32, 128],
        n_queries: 500.min(n),
        distinct_queries: 250.min(n),
        threads: 1,
        ..cfg.clone()
    };
    for (tag, search_threads) in [("scatter-seq", 1usize), ("scatter-par4", 4usize)] {
        let index = ShardedIndex::open_with(&dir, cfg.params.clone(), 0, 0, search_threads)
            .expect("scatter index");
        let mut ds_tag = ds.clone();
        ds_tag.name = format!("{} sharded {tag}", ds.name);
        let report = serve::run_sweep_on(&index, &ds_tag, &cfg_lat).expect("scatter sweep");
        match report.save_json("results") {
            Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
            Err(e) => println!("{}\n[save failed: {e}]", report.render()),
        }
    }
    std::fs::remove_dir_all(dir).ok();

    // ---- open-loop arrival sweep over the monolithic index ----
    // probe capacity closed-loop at ef=64, then offer fractions of it
    // on a seeded Poisson schedule: under load the achieved rate
    // tracks the offered rate and queue delays stay near zero; past
    // capacity the overload flag trips and the queue-delay tail is the
    // whole latency story
    let stream = serve::sample_queries(&ds, 500.min(n), cfg.k, cfg.seed);
    let probe_cfg = ServeConfig {
        ef_sweep: vec![64],
        n_queries: 1_000.min(n),
        distinct_queries: 500.min(n),
        ..cfg.clone()
    };
    let capacity = serve::run_point(&index, &stream, &probe_cfg, 64).qps;
    eprintln!("closed-loop capacity at ef=64: {capacity:.0} qps");
    for (tag, frac) in [("underload-0.6x", 0.6), ("overload-1.5x", 1.5)] {
        let open_cfg = ServeConfig { arrival_rate: capacity * frac, ..probe_cfg.clone() };
        let s = serve::run_point(&index, &stream, &open_cfg, 64);
        println!(
            "open-loop {tag}: offered {:.0} qps, achieved {:.0} qps, service p50 {:.3} ms, \
             queue p50 {:.3} ms, queue p99 {:.3} ms, overload={}",
            s.offered_rate, s.qps, s.p50_ms, s.queue_p50_ms, s.queue_p99_ms, s.overload
        );
    }
    // the saved open-loop operating curve (underload, so every ef
    // point is comparable to the closed-loop curve above)
    let open_cfg = ServeConfig {
        ef_sweep: vec![32, 128],
        arrival_rate: capacity * 0.6,
        n_queries: 1_000.min(n),
        distinct_queries: 500.min(n),
        ..cfg.clone()
    };
    let mut ds_open = ds.clone();
    ds_open.name = format!("{} open-loop poisson", ds.name);
    let report = serve::run_sweep_on(&index, &ds_open, &open_cfg).expect("open-loop sweep");
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }

    // ---- loopback TCP serving: the same monolithic index behind the
    // `gnnd serve` front end, swept through a `RemoteIndex` client at
    // three coalescing windows. Framing + the loopback hop cost QPS
    // against the in-process curve above; a wider window claws some
    // back by folding concurrent requests into one executor pass ----
    for window_us in [0u64, 200, 1000] {
        let scfg = ServerConfig { coalesce_window_us: window_us, ..ServerConfig::default() };
        let srv = Server::bind("127.0.0.1:0", scfg).expect("bind loopback server");
        let addr = srv.local_addr().expect("server addr").to_string();
        let handle = srv.handle().expect("server handle");
        crossbeam_utils::thread::scope(|s| {
            s.builder()
                .name("bench-server".into())
                .spawn(|_| srv.run(&index).expect("server run"))
                .expect("spawn server");
            let remote = RemoteIndex::connect(&addr).expect("connect to loopback server");
            let mut ds_net = ds.clone();
            ds_net.name = format!("{} tcp window{window_us}us", ds.name);
            let net_cfg = ServeConfig {
                ef_sweep: vec![32, 128],
                n_queries: 1_000.min(n),
                distinct_queries: 500.min(n),
                threads: 4,
                ..cfg.clone()
            };
            let report = serve::run_sweep_on(&remote, &ds_net, &net_cfg).expect("tcp sweep");
            match report.save_json("results") {
                Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
                Err(e) => println!("{}\n[save failed: {e}]", report.render()),
            }
            drop(remote); // close the pooled connections before shutdown
            handle.shutdown();
        })
        .expect("server scope");
    }

    // ---- BENCH_8.json: the flat-vs-hierarchy operating curves above,
    // machine-readable at the repo root — the PR 8 artifact a driver
    // (or a human) diffs without scraping the tables ----
    let mut sweeps = Json::obj();
    for (tag, r) in &bench8 {
        sweeps = sweeps.set(tag, bench8_points(r));
    }
    let out = Json::obj()
        .set("bench", "qps_search")
        .set("scale", format!("{scale:?}"))
        .set("n", n)
        .set("k", cfg.k)
        .set("sweeps", sweeps);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_8.json");
    match std::fs::write(path, out.to_string()) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => println!("[BENCH_8.json save failed: {e}]"),
    }

    // ---- kernel micro-throughput: the dispatch path (AVX2/NEON when
    // built with --features simd and the CPU has them, scalar
    // otherwise) vs the forced-scalar reference on serving-shaped
    // buffers — the per-kernel speedup recorded next to the
    // end-to-end precision sweeps ----
    use std::hint::black_box;
    let d = ds.d;
    let av: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
    let bv: Vec<f32> = (0..d).map(|i| (i as f32 * 0.53).cos()).collect();
    let au: Vec<u8> = (0..d).map(|i| (i * 37 % 251) as u8).collect();
    let bu: Vec<u8> = (0..d).map(|i| (i * 53 % 251) as u8).collect();
    let lut: Vec<f32> = (0..pq_m * 256).map(|i| i as f32 * 1e-3).collect();
    let codes: Vec<u8> = (0..pq_m).map(|i| (i * 97 % 256) as u8).collect();
    let iters: usize = 2_000_000;
    let mut time = |f: &mut dyn FnMut() -> f64| {
        let t = Timer::start();
        let mut acc = 0.0f64;
        for _ in 0..iters {
            acc += f();
        }
        black_box(acc);
        iters as f64 / t.secs()
    };
    use gnnd::distance as dk;
    type Kernel<'a> = Box<dyn FnMut() -> f64 + 'a>;
    let mut cases: Vec<(&str, Kernel<'_>, Kernel<'_>)> = vec![
        (
            "l2_sq",
            Box::new(|| dk::l2_sq(black_box(&av), black_box(&bv)) as f64),
            Box::new(|| dk::l2_sq_scalar(black_box(&av), black_box(&bv)) as f64),
        ),
        (
            "dot",
            Box::new(|| dk::dot(black_box(&av), black_box(&bv)) as f64),
            Box::new(|| dk::dot_scalar(black_box(&av), black_box(&bv)) as f64),
        ),
        (
            "l2_sq_u8",
            Box::new(|| dk::l2_sq_u8(black_box(&au), black_box(&bu)) as f64),
            Box::new(|| dk::l2_sq_u8_scalar(black_box(&au), black_box(&bu)) as f64),
        ),
        (
            "pq_lut_sum",
            Box::new(|| dk::pq_lut_sum(black_box(&lut), black_box(&codes)) as f64),
            Box::new(|| dk::pq_lut_sum_scalar(black_box(&lut), black_box(&codes)) as f64),
        ),
    ];
    let mut kernels = Json::obj();
    for (name, dispatch, scalar) in cases.iter_mut() {
        let disp = time(dispatch.as_mut());
        let scal = time(scalar.as_mut());
        println!(
            "kernel {name}: dispatch {:.1} Mops, scalar {:.1} Mops ({:.2}x)",
            disp / 1e6,
            scal / 1e6,
            disp / scal
        );
        kernels = kernels.set(
            *name,
            Json::obj()
                .set("dispatch_mops", disp / 1e6)
                .set("scalar_mops", scal / 1e6)
                .set("speedup", disp / scal),
        );
    }

    // ---- BENCH_10.json: the precision sweeps (f32 / scalar-quant /
    // product-quantized, each with its vector payload bytes) plus the
    // kernel table — the PR 10 artifact a driver diffs to see the
    // recall/qps/footprint trade and the simd win in one file ----
    let mut sweeps10 = Json::obj();
    for (tag, bytes, points) in bench10 {
        sweeps10 = sweeps10
            .set(tag, Json::obj().set("dataset_bytes", bytes).set("points", points));
    }
    let out = Json::obj()
        .set("bench", "qps_search")
        .set("scale", format!("{scale:?}"))
        .set("n", n)
        .set("d", ds.d)
        .set("k", cfg.k)
        .set("pq_m", pq_m)
        .set("simd", cfg!(feature = "simd"))
        .set("sweeps", sweeps10)
        .set("kernels", kernels);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_10.json");
    match std::fs::write(path, out.to_string()) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => println!("[BENCH_10.json save failed: {e}]"),
    }
}
