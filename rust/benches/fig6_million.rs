//! Bench harness regenerating the paper's fig6 (see
//! `rust/src/experiments/fig6.rs` for the claims checked and
//! DESIGN.md for the experiment index). Scale via GNND_SCALE=quick|standard|full.
fn main() {
    let scale = gnnd::experiments::Scale::from_env();
    eprintln!("running fig6 at {scale:?} scale (GNND_SCALE to change)");
    gnnd::experiments::fig6::run(scale);
}
