//! Vector storage backends: the seam between *data structures*
//! ([`Dataset`](super::Dataset), [`crate::graph::KnnGraph`]) and *where
//! their rows physically live*.
//!
//! Two backends implement the same row-access contract:
//!
//! * [`VectorStore::Owned`] — a flat in-memory `Vec<f32>`, the backing
//!   every construction path (GNND, merge, benches) uses. Row access
//!   is a slice borrow; nothing here costs anything new.
//! * [`VectorStore::Paged`] — file-backed rows fetched on demand in
//!   fixed-size **blocks** via `FileExt::read_at` (pure std: the
//!   offline dependency closure has no `memmap2`/`libc`, so paging —
//!   not mmap — is the portable mechanism). Blocks land in a shared
//!   [`BlockCache`] with LRU eviction under a byte budget, so a beam
//!   search that touches a few hundred rows of a shard reads a few
//!   hundred rows' worth of blocks — never the whole file.
//!
//! The cache is *shared across stores* (one per
//! [`ShardStore`](crate::merge::outofcore::ShardStore)): the byte
//! budget is enforced over the blocks of **all** open shards at once,
//! which is what lets a `--memory-budget` smaller than a single shard
//! still serve correctly — a configuration the whole-shard residency
//! cache of PR 3 could not express.
//!
//! Admission is gated by a two-visit [`Doorkeeper`]: when inserting a
//! block would force an eviction, a key seen for the *first* time is
//! served but **not cached** (the fetch result still goes back to the
//! caller) — only a second visit within the doorkeeper's window admits
//! it. A scan-shaped probe stream larger than the budget therefore no
//! longer evicts the hot set; rejected admissions are counted and
//! surface in `ResidencyStats`.

use std::collections::HashMap;
use std::fs::File;
#[cfg(unix)]
use std::os::unix::fs::FileExt;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::config::Metric;
use crate::graph::Neighbor;

/// Default block payload size (64 KiB): large enough that sequential
/// walks amortize the syscall, small enough that a budget of a few MB
/// still holds a useful working set. Overridable per store
/// (`--block-size` at the CLI).
pub const DEFAULT_BLOCK_BYTES: usize = 64 * 1024;

/// Nominal resident cost of a paged handle (file descriptor + path +
/// struct) — what a paged [`super::Dataset`] / graph reports as its own
/// footprint; its blocks are accounted by the shared [`BlockCache`].
pub const PAGED_HANDLE_BYTES: usize = 512;

/// One decoded cache block: a contiguous run of rows, already parsed
/// from its on-disk little-endian layout into the in-memory element
/// type, so row access after a cache hit costs a slice index — no
/// per-access decode.
pub enum Block {
    /// Dataset rows: `block_rows * d` floats.
    F32(Vec<f32>),
    /// Quantized dataset rows: `block_rows * d` u8 codes — 4x more rows
    /// per byte of cache budget than [`Block::F32`].
    U8(Vec<u8>),
    /// Graph rows: `block_rows * k` neighbor entries (flag bit and
    /// EMPTY sentinel already decoded).
    Neigh(Vec<Neighbor>),
}

impl Block {
    /// In-memory byte cost — the unit the cache budget is accounted in
    /// (the decoded form, mirroring how the shard-granular cache
    /// accounts resident shards).
    pub fn mem_bytes(&self) -> usize {
        match self {
            Block::F32(v) => v.len() * std::mem::size_of::<f32>(),
            Block::U8(v) => v.len(),
            Block::Neigh(v) => v.len() * std::mem::size_of::<Neighbor>(),
        }
    }
}

/// Decode a raw `.dsb` v2 block payload (little-endian f32 rows).
pub(crate) fn decode_f32_block(bytes: &[u8]) -> Block {
    Block::F32(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}

/// Decode a raw quantized `.dsb` block payload (u8 code rows — the
/// on-disk and in-memory forms coincide).
pub(crate) fn decode_u8_block(bytes: &[u8]) -> Block {
    Block::U8(bytes.to_vec())
}

/// Two-visit admission gate: a fixed-capacity recently-seen key set
/// (two rotating generations, so "recently" ages out in O(1) without
/// per-entry timestamps). `admit` answers "was this key seen in the
/// current or previous generation?" and records it either way — the
/// TinyLFU doorkeeper reduced to its cheapest useful form.
#[derive(Debug)]
pub(crate) struct Doorkeeper {
    cur: std::collections::HashSet<u64>,
    prev: std::collections::HashSet<u64>,
    cap: usize,
}

impl Default for Doorkeeper {
    fn default() -> Self {
        Doorkeeper::new(1024)
    }
}

impl Doorkeeper {
    pub(crate) fn new(cap: usize) -> Self {
        Doorkeeper { cur: Default::default(), prev: Default::default(), cap: cap.max(8) }
    }

    /// True iff `key` was seen recently (second visit within the
    /// window). Records the key regardless, rotating generations when
    /// the current one fills.
    pub(crate) fn admit(&mut self, key: u64) -> bool {
        if self.cur.contains(&key) || self.prev.contains(&key) {
            return true;
        }
        if self.cur.len() >= self.cap {
            self.prev = std::mem::take(&mut self.cur);
        }
        self.cur.insert(key);
        false
    }
}

/// Counters of a [`BlockCache`], merged into
/// [`crate::merge::outofcore::ResidencyStats`] by serve-time tooling.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BlockCacheStats {
    /// Block requests served from cache.
    pub hits: u64,
    /// Blocks fetched from disk (= misses, including re-fetches of
    /// blocks the doorkeeper declined to admit).
    pub fetches: u64,
    pub evictions: u64,
    /// Fetched blocks the doorkeeper declined to cache.
    pub rejected_admissions: u64,
    /// Disk bytes actually read by block fetches.
    pub bytes_read: u64,
    pub resident_blocks: usize,
    pub resident_bytes: usize,
    pub peak_resident_bytes: usize,
    /// Configured budget (0 = unbounded).
    pub budget_bytes: usize,
    /// Target block payload size.
    pub block_bytes: usize,
}

struct BlockSlot {
    data: Arc<Block>,
    bytes: usize,
    last_used: u64,
}

#[derive(Default)]
struct BlockCacheInner {
    blocks: HashMap<(u64, usize), BlockSlot>,
    tick: u64,
    hits: u64,
    fetches: u64,
    evictions: u64,
    rejected_admissions: u64,
    bytes_read: u64,
    resident_bytes: usize,
    peak_resident_bytes: usize,
    door: Option<Doorkeeper>,
    next_store: u64,
}

/// A byte-budgeted LRU cache of decoded file blocks, shared by every
/// [`PagedRows`] of one shard store. Keys are `(store_id, block)`;
/// blocks are never pinned — an access clones the block's `Arc`,
/// releases the lock, and reads, so eviction can always make progress
/// and a budget smaller than one shard (even smaller than one block)
/// stays correct: the fetched block is handed to the caller whether or
/// not it was admitted.
pub struct BlockCache {
    budget_bytes: usize,
    block_bytes: usize,
    inner: Mutex<BlockCacheInner>,
    tele: BlockTele,
}

impl BlockCache {
    /// `budget_bytes = 0` means unbounded (every fetched block stays).
    pub fn new(budget_bytes: usize, block_bytes: usize) -> Arc<BlockCache> {
        // floor of 1: tiny block sizes are legal (tests use row-sized
        // blocks); stores clamp to at least one row per block anyway
        let block_bytes = block_bytes.max(1);
        let mut inner = BlockCacheInner::default();
        if budget_bytes > 0 {
            // window ~4x the blocks the budget can hold: long enough
            // that a hot block's second visit lands inside it, short
            // enough that a scan ages out instead of accumulating
            let cap = (4 * budget_bytes / block_bytes).max(64);
            inner.door = Some(Doorkeeper::new(cap));
        }
        Arc::new(BlockCache {
            budget_bytes,
            block_bytes,
            inner: Mutex::new(inner),
            tele: BlockTele::new(),
        })
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Target payload bytes per block (stores derive their row-aligned
    /// `block_rows` from this).
    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    /// Allocate a fresh store id (cache keys are namespaced per store,
    /// so re-opening a file never aliases stale blocks).
    fn register(&self) -> u64 {
        let mut c = self.inner.lock().unwrap();
        c.next_store += 1;
        c.next_store
    }

    /// Drop every cached block of one store (a shard file was saved
    /// over: its old blocks are garbage the budget should not carry).
    pub(crate) fn forget_store(&self, store_id: u64) {
        let mut c = self.inner.lock().unwrap();
        let stale: Vec<(u64, usize)> =
            c.blocks.keys().filter(|(s, _)| *s == store_id).copied().collect();
        for key in stale {
            if let Some(slot) = c.blocks.remove(&key) {
                c.resident_bytes -= slot.bytes;
                c.evictions += 1;
                self.tele.evictions.inc();
            }
        }
        self.tele.resident_bytes.set(c.resident_bytes as i64);
    }

    /// The block under `key`, fetching via `fetch` on a miss (`fetch`
    /// returns the decoded block plus the disk bytes it read, and runs
    /// with the cache lock *released* — concurrent misses on different
    /// blocks overlap their I/O; a rare duplicate fetch of the same
    /// block is benign and both copies are counted as fetches).
    fn get(
        &self,
        key: (u64, usize),
        fetch: impl FnOnce() -> crate::Result<(Block, usize)>,
    ) -> crate::Result<Arc<Block>> {
        {
            let mut c = self.inner.lock().unwrap();
            c.tick += 1;
            let tick = c.tick;
            if let Some(slot) = c.blocks.get_mut(&key) {
                slot.last_used = tick;
                c.hits += 1;
                tls_block_hit();
                self.tele.hits.inc();
                return Ok(Arc::clone(&slot.data));
            }
        }
        let (block, disk_bytes) = fetch()?;
        let bytes = block.mem_bytes();
        let data = Arc::new(block);
        let mut c = self.inner.lock().unwrap();
        c.fetches += 1;
        c.bytes_read += disk_bytes as u64;
        tls_block_fetch();
        self.tele.fetches.inc();
        self.tele.bytes_read.add(disk_bytes as u64);
        c.tick += 1;
        let tick = c.tick;
        if let Some(slot) = c.blocks.get_mut(&key) {
            // another thread fetched the same block while we read disk:
            // serve the cached copy, drop ours
            slot.last_used = tick;
            return Ok(Arc::clone(&slot.data));
        }
        let fits = self.budget_bytes == 0 || c.resident_bytes + bytes <= self.budget_bytes;
        let admit = fits
            || match &mut c.door {
                Some(door) => door.admit(block_key_hash(key)),
                None => true,
            };
        if admit {
            c.resident_bytes += bytes;
            c.peak_resident_bytes = c.peak_resident_bytes.max(c.resident_bytes);
            c.blocks.insert(key, BlockSlot { data: Arc::clone(&data), bytes, last_used: tick });
            if self.budget_bytes > 0 {
                while c.resident_bytes > self.budget_bytes && c.blocks.len() > 1 {
                    let victim = c
                        .blocks
                        .iter()
                        .min_by_key(|(_, s)| s.last_used)
                        .map(|(&k, _)| k);
                    let Some(v) = victim else { break };
                    if let Some(slot) = c.blocks.remove(&v) {
                        c.resident_bytes -= slot.bytes;
                        c.evictions += 1;
                        self.tele.evictions.inc();
                    }
                }
            }
            self.tele.resident_bytes.set(c.resident_bytes as i64);
        } else {
            c.rejected_admissions += 1;
            self.tele.rejected_admissions.inc();
        }
        Ok(data)
    }

    /// Snapshot of the counters.
    pub fn stats(&self) -> BlockCacheStats {
        let c = self.inner.lock().unwrap();
        BlockCacheStats {
            hits: c.hits,
            fetches: c.fetches,
            evictions: c.evictions,
            rejected_admissions: c.rejected_admissions,
            bytes_read: c.bytes_read,
            resident_blocks: c.blocks.len(),
            resident_bytes: c.resident_bytes,
            peak_resident_bytes: c.peak_resident_bytes,
            budget_bytes: self.budget_bytes,
            block_bytes: self.block_bytes,
        }
    }
}

/// Mix a `(store, block)` key into the doorkeeper's u64 key space.
fn block_key_hash((store, block): (u64, usize)) -> u64 {
    store.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (block as u64)
}

thread_local! {
    /// Block-cache activity of *this thread*, bumped on every
    /// [`BlockCache::get`] regardless of tracing. A shard walk runs
    /// entirely on one thread, so a before/after read pair brackets
    /// exactly that shard's block traffic — the per-shard
    /// `block_fetches`/`block_hits` of a query trace, attributed
    /// without plumbing a context handle through the row accessors.
    static TLS_BLOCK: std::cell::Cell<(u64, u64)> = const { std::cell::Cell::new((0, 0)) };
}

/// This thread's cumulative `(hits, fetches)` across all block caches.
/// Monotone; callers diff two reads to attribute a code region.
pub fn thread_block_counters() -> (u64, u64) {
    TLS_BLOCK.with(|c| c.get())
}

fn tls_block_hit() {
    TLS_BLOCK.with(|c| {
        let (h, f) = c.get();
        c.set((h + 1, f));
    });
}

fn tls_block_fetch() {
    TLS_BLOCK.with(|c| {
        let (h, f) = c.get();
        c.set((h, f + 1));
    });
}

/// Global-registry mirrors of the block-cache counters. The
/// authoritative counts stay in [`BlockCacheInner`] under its mutex
/// (and keep feeding `ResidencyStats`); these handles make the same
/// events visible live through [`crate::telemetry::global`] snapshots
/// mid-run. Handles are resolved once per cache, not per access.
struct BlockTele {
    hits: Arc<crate::telemetry::Counter>,
    fetches: Arc<crate::telemetry::Counter>,
    evictions: Arc<crate::telemetry::Counter>,
    rejected_admissions: Arc<crate::telemetry::Counter>,
    bytes_read: Arc<crate::telemetry::Counter>,
    resident_bytes: Arc<crate::telemetry::Gauge>,
}

impl BlockTele {
    fn new() -> Self {
        let g = crate::telemetry::global();
        BlockTele {
            hits: g.counter("block_cache.hits"),
            fetches: g.counter("block_cache.fetches"),
            evictions: g.counter("block_cache.evictions"),
            rejected_admissions: g.counter("block_cache.rejected_admissions"),
            bytes_read: g.counter("block_cache.bytes_read"),
            resident_bytes: g.gauge("block_cache.resident_bytes"),
        }
    }
}

/// File-backed fixed-stride rows served block-at-a-time through a
/// shared [`BlockCache`]. Cloning shares the file handle and the cache
/// namespace (a clone sees the same cached blocks).
#[derive(Clone)]
pub struct PagedRows {
    file: Arc<File>,
    path: Arc<PathBuf>,
    /// Byte offset of row 0 in the file (just past the header).
    data_off: u64,
    rows: usize,
    /// On-disk bytes per row.
    row_stride: usize,
    /// Decoded elements per row (d floats, or k neighbors).
    elems_per_row: usize,
    /// Rows per block (block-aligned on row boundaries; the last block
    /// of a file is short).
    block_rows: usize,
    store_id: u64,
    cache: Arc<BlockCache>,
    decode: fn(&[u8]) -> Block,
}

impl std::fmt::Debug for PagedRows {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedRows")
            .field("path", &self.path)
            .field("rows", &self.rows)
            .field("row_stride", &self.row_stride)
            .field("block_rows", &self.block_rows)
            .finish()
    }
}

impl PagedRows {
    /// Wrap an already-validated file region (callers — the `.dsb` /
    /// `.knng` v2 readers — have parsed the header and checked the
    /// file length against `rows * row_stride`, so block reads can
    /// never run off the end of an intact file).
    pub(crate) fn new(
        file: File,
        path: PathBuf,
        data_off: u64,
        rows: usize,
        row_stride: usize,
        elems_per_row: usize,
        cache: &Arc<BlockCache>,
        decode: fn(&[u8]) -> Block,
    ) -> Self {
        assert!(row_stride > 0 && elems_per_row > 0);
        PagedRows {
            file: Arc::new(file),
            path: Arc::new(path),
            data_off,
            rows,
            row_stride,
            elems_per_row,
            block_rows: (cache.block_bytes() / row_stride).max(1),
            store_id: cache.register(),
            cache: Arc::clone(cache),
            decode,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub(crate) fn store_id(&self) -> u64 {
        self.store_id
    }

    pub(crate) fn cache(&self) -> &Arc<BlockCache> {
        &self.cache
    }

    /// The block holding row `i` plus the row's element offset inside
    /// it. Fetch failures panic: the file validated at open, so a
    /// failed `read_at` means the store was truncated or deleted
    /// underneath a live reader — the same unrecoverable condition the
    /// sharded query path panics on (`pin_handle`).
    fn row_block(&self, i: usize) -> (Arc<Block>, usize) {
        assert!(i < self.rows, "row {i} out of range ({} rows)", self.rows);
        let b = i / self.block_rows;
        let block = self
            .cache
            .get((self.store_id, b), || {
                let start_row = b * self.block_rows;
                let rows = self.block_rows.min(self.rows - start_row);
                let nbytes = rows * self.row_stride;
                let mut buf = vec![0u8; nbytes];
                read_exact_at(
                    &self.file,
                    &mut buf,
                    self.data_off + (start_row * self.row_stride) as u64,
                )
                .map_err(|e| anyhow::anyhow!("read block {b} of {:?}: {e}", self.path))?;
                Ok(((self.decode)(&buf), nbytes))
            })
            .unwrap_or_else(|e| {
                panic!("{:?} unreadable mid-serve (store truncated or deleted?): {e:#}", self.path)
            });
        (block, (i % self.block_rows) * self.elems_per_row)
    }

    /// Borrow row `i` as floats for the duration of `f` (the block's
    /// `Arc` keeps the data alive across any concurrent eviction).
    /// Panics if this store does not hold f32 rows.
    pub fn with_f32_row<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        let (block, start) = self.row_block(i);
        match &*block {
            Block::F32(v) => f(&v[start..start + self.elems_per_row]),
            _ => unreachable!("f32 row access on a non-f32 store"),
        }
    }

    /// Borrow row `i` as u8 codes for the duration of `f`. Panics if
    /// this store does not hold quantized rows.
    pub fn with_u8_row<R>(&self, i: usize, f: impl FnOnce(&[u8]) -> R) -> R {
        let (block, start) = self.row_block(i);
        match &*block {
            Block::U8(v) => f(&v[start..start + self.elems_per_row]),
            _ => unreachable!("u8 row access on a non-quantized store"),
        }
    }

    /// Append row `i`'s live neighbor prefix to `out`. Panics if this
    /// store does not hold neighbor rows.
    pub fn neighbors_into(&self, i: usize, out: &mut Vec<Neighbor>) {
        let (block, start) = self.row_block(i);
        match &*block {
            Block::Neigh(v) => out.extend(
                v[start..start + self.elems_per_row]
                    .iter()
                    .take_while(|e| !e.is_empty())
                    .copied(),
            ),
            _ => unreachable!("neighbor row access on a non-neighbor store"),
        }
    }
}

#[cfg(unix)]
fn read_exact_at(file: &File, buf: &mut [u8], off: u64) -> std::io::Result<()> {
    file.read_exact_at(buf, off)
}

#[cfg(not(unix))]
fn read_exact_at(file: &File, buf: &mut [u8], off: u64) -> std::io::Result<()> {
    // non-unix fallback: a try_clone shares the underlying cursor, so
    // concurrent seek+read pairs must be serialized process-wide or
    // one thread's read lands at another's offset (windows has
    // seek_read, but this crate only targets unix in CI; keep the
    // fallback portable-std and rare-path simple)
    use std::io::{Read, Seek, SeekFrom};
    static SEEK_READ_LOCK: Mutex<()> = Mutex::new(());
    let _serialized = SEEK_READ_LOCK.lock().unwrap();
    let mut f = file.try_clone()?;
    f.seek(SeekFrom::Start(off))?;
    f.read_exact(buf)
}

/// Per-dimension scalar-quantization parameters: dimension `j` of a
/// row `x` encodes as `round((x[j] - offset[j]) / scale[j])` clamped to
/// `[0, 255]`, and decodes as `offset[j] + scale[j] * code`. For a row
/// inside the fitted min/max box the round-trip error per dimension is
/// at most `scale[j] / 2` — the bound the property suite asserts.
///
/// The same per-dimension affine codebook shape as the IVF-PQ
/// baseline's coarse quantizer, reduced to one u8 code per dimension
/// (no subspace clustering), so a quantized row is exactly `d` bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: Vec<f32>,
    pub offset: Vec<f32>,
}

impl QuantParams {
    pub fn d(&self) -> usize {
        self.scale.len()
    }

    /// Encode one f32 row into `out` (cleared first).
    pub fn encode_into(&self, row: &[f32], out: &mut Vec<u8>) {
        debug_assert_eq!(row.len(), self.d());
        out.clear();
        out.extend(row.iter().zip(&self.scale).zip(&self.offset).map(|((&x, &s), &o)| {
            // s > 0 by construction (QuantFitter::finish)
            ((x - o) / s).round().clamp(0.0, 255.0) as u8
        }));
    }

    /// Decode one code row into `out` (cleared first).
    pub fn decode_into(&self, codes: &[u8], out: &mut Vec<f32>) {
        debug_assert_eq!(codes.len(), self.d());
        out.clear();
        out.extend(
            codes
                .iter()
                .zip(&self.scale)
                .zip(&self.offset)
                .map(|((&c, &s), &o)| o + s * c as f32),
        );
    }

    /// In-memory footprint of the sidecar itself.
    pub fn mem_bytes(&self) -> usize {
        (self.scale.len() + self.offset.len()) * std::mem::size_of::<f32>()
    }
}

/// Streaming per-dimension min/max accumulator for fitting
/// [`QuantParams`] without materializing the corpus: `observe` every
/// row (of every shard, for a sharded store — one shared code space
/// keeps cross-shard code distances comparable), then `finish`.
pub struct QuantFitter {
    min: Vec<f32>,
    max: Vec<f32>,
    rows: usize,
}

impl QuantFitter {
    pub fn new(d: usize) -> Self {
        QuantFitter { min: vec![f32::INFINITY; d], max: vec![f32::NEG_INFINITY; d], rows: 0 }
    }

    pub fn observe(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.min.len());
        for (j, &x) in row.iter().enumerate() {
            self.min[j] = self.min[j].min(x);
            self.max[j] = self.max[j].max(x);
        }
        self.rows += 1;
    }

    /// Fitted parameters. A constant (or never-observed) dimension gets
    /// `scale = 1`, which encodes every value to code 0 and decodes it
    /// back exactly (`offset` carries the constant).
    pub fn finish(self) -> QuantParams {
        let scale = self
            .min
            .iter()
            .zip(&self.max)
            .map(|(&lo, &hi)| {
                let s = (hi - lo) / 255.0;
                if s > 0.0 && s.is_finite() {
                    s
                } else {
                    1.0
                }
            })
            .collect();
        let offset = self.min.iter().map(|&lo| if lo.is_finite() { lo } else { 0.0 }).collect();
        QuantParams { scale, offset }
    }
}

/// Where a quantized store's u8 code rows live.
#[derive(Clone, Debug)]
pub(crate) enum QuantCodes {
    Owned(Vec<u8>),
    Paged(PagedRows),
}

/// Full-precision rows kept alongside a quantized store for the exact
/// rerank phase. Paged is the serving form (rows fault in through the
/// block cache, so rerank reads only the `rerank * k` rows it scores);
/// Owned is the in-memory convenience (`--quantize` on a monolithic
/// search).
#[derive(Clone, Debug)]
pub(crate) enum ExactRows {
    Owned(Vec<f32>),
    Paged(PagedRows),
}

/// A scalar-quantized vector backing: u8 code rows plus the
/// [`QuantParams`] sidecar, with optional full-precision [`ExactRows`]
/// for rerank. The beam phase scores candidates in code space (L2) or
/// against dequantized codes (inner product) — 1 byte per dimension of
/// row traffic either way.
#[derive(Clone, Debug)]
pub(crate) struct QuantStore {
    pub(crate) d: usize,
    pub(crate) params: Arc<QuantParams>,
    pub(crate) codes: QuantCodes,
    pub(crate) exact: Option<ExactRows>,
}

impl QuantStore {
    pub(crate) fn rows(&self) -> usize {
        match &self.codes {
            QuantCodes::Owned(v) => v.len() / self.d,
            QuantCodes::Paged(p) => p.rows(),
        }
    }

    /// Borrow row `i`'s codes for the duration of `f`.
    pub(crate) fn with_codes<R>(&self, i: usize, f: impl FnOnce(&[u8]) -> R) -> R {
        match &self.codes {
            QuantCodes::Owned(v) => f(&v[i * self.d..(i + 1) * self.d]),
            QuantCodes::Paged(p) => p.with_u8_row(i, f),
        }
    }

    /// Dequantize row `i` into `out` (cleared first).
    pub(crate) fn decode_row_into(&self, i: usize, out: &mut Vec<f32>) {
        let params = &self.params;
        self.with_codes(i, |codes| params.decode_into(codes, out));
    }

    /// Approximate (beam-phase) distance of row `i` to the query. L2
    /// runs the integer kernel against the pre-encoded query codes
    /// (`qcodes`, from [`QuantParams::encode_into`]) — the value is in
    /// code space, comparable only within one code space. Inner-product
    /// metrics dequantize on the fly against the f32 query
    /// ([`crate::distance::dot_dequant`]).
    pub(crate) fn dist_to(&self, metric: Metric, i: usize, q: &[f32], qcodes: &[u8]) -> f32 {
        match metric.kernel_metric() {
            Metric::L2 => self.with_codes(i, |row| crate::distance::l2_sq_u8(row, qcodes) as f32),
            Metric::Ip => {
                let p = &self.params;
                self.with_codes(i, |row| -crate::distance::dot_dequant(row, q, &p.scale, &p.offset))
            }
            Metric::Cosine => unreachable!("kernel_metric lowers cosine"),
        }
    }

    /// Full-precision distance of row `i` to the query, for the rerank
    /// phase: exact rows when attached, else the dequantized row (still
    /// metric-unit, just carrying the quantization error) via `buf`.
    pub(crate) fn rerank_dist_to(
        &self,
        metric: Metric,
        i: usize,
        q: &[f32],
        buf: &mut Vec<f32>,
    ) -> f32 {
        match &self.exact {
            Some(ExactRows::Owned(v)) => {
                crate::distance::distance(metric, &v[i * self.d..(i + 1) * self.d], q)
            }
            Some(ExactRows::Paged(p)) => {
                p.with_f32_row(i, |row| crate::distance::distance(metric, row, q))
            }
            None => {
                self.decode_row_into(i, buf);
                crate::distance::distance(metric, buf, q)
            }
        }
    }

    /// In-memory footprint: codes (owned) or handle (paged), plus the
    /// params sidecar and the exact-rows attachment.
    pub(crate) fn resident_bytes(&self) -> usize {
        let codes = match &self.codes {
            QuantCodes::Owned(v) => v.len(),
            QuantCodes::Paged(_) => PAGED_HANDLE_BYTES,
        };
        let exact = match &self.exact {
            Some(ExactRows::Owned(v)) => v.len() * std::mem::size_of::<f32>(),
            Some(ExactRows::Paged(_)) => PAGED_HANDLE_BYTES,
            None => 0,
        };
        codes + self.params.mem_bytes() + exact
    }

    pub(crate) fn codes_store_id(&self) -> Option<u64> {
        match &self.codes {
            QuantCodes::Paged(p) => Some(p.store_id()),
            QuantCodes::Owned(_) => None,
        }
    }

    pub(crate) fn exact_store_id(&self) -> Option<u64> {
        match &self.exact {
            Some(ExactRows::Paged(p)) => Some(p.store_id()),
            _ => None,
        }
    }
}

/// Lloyd rounds when fitting PQ codebooks — enough to converge the
/// per-subspace quantizers on the bounded sample `kmeans::train` uses.
const PQ_KMEANS_ITERS: usize = 12;

/// Product-quantization parameters: `m` subquantizers, each a
/// (≤)256-entry k-means codebook over its contiguous slice of the
/// dimensions, so a row encodes to `m` bytes (one centroid id per
/// subspace). Subspace `sub` covers `dsub = d / m` dimensions starting
/// at `sub * dsub`; the last subspace absorbs the remainder — the same
/// split as the IVF-PQ baseline ([`crate::baselines::ivfpq`]).
///
/// Queries never decode rows in the beam phase: [`Self::build_lut`]
/// precomputes the m×256 asymmetric-distance table once per query, and
/// each candidate costs `m` table lookups
/// ([`crate::distance::pq_lut_sum`]). PQ distances are distances to the
/// *reconstructed* row, so they are in metric units (unlike the
/// code-space values of [`QuantParams`]) — but still approximate, which
/// is what the exact rerank phase corrects.
#[derive(Clone, Debug, PartialEq)]
pub struct PqParams {
    d: usize,
    m: usize,
    dsub: usize,
    /// Fitted centroid count per subquantizer (k-means clamps k to the
    /// training-row count, so small fits yield < 256). Codes never
    /// reference slots past it.
    ksub: Vec<u32>,
    /// `256 * d` floats, subspace-contiguous: subquantizer `sub` of
    /// width `w` owns `256*lo(sub) .. 256*(lo(sub)+w)`, centroids
    /// packed `[c][w]`; slots past `ksub[sub]` are zero padding.
    centroids: Vec<f32>,
}

impl PqParams {
    pub fn d(&self) -> usize {
        self.d
    }

    /// Subquantizer count = encoded bytes per row.
    pub fn m(&self) -> usize {
        self.m
    }

    /// `(start dimension, width)` of subspace `sub`.
    #[inline]
    fn sub_bounds(&self, sub: usize) -> (usize, usize) {
        let lo = sub * self.dsub;
        let w = if sub + 1 == self.m { self.d - lo } else { self.dsub };
        (lo, w)
    }

    /// Centroid `c` of subquantizer `sub`.
    #[inline]
    fn centroid(&self, sub: usize, c: usize) -> &[f32] {
        let (lo, w) = self.sub_bounds(sub);
        let base = crate::distance::PQ_KSUB * lo + c * w;
        &self.centroids[base..base + w]
    }

    /// Fit `m` per-subspace codebooks on `data` (`n` rows × `d`,
    /// row-major) with the k-means substrate the IVF-PQ baseline uses.
    pub fn fit(data: &[f32], d: usize, m: usize, seed: u64, threads: usize) -> crate::Result<Self> {
        anyhow::ensure!(d > 0 && m > 0 && m <= d, "pq needs 1 <= m <= d (m={m}, d={d})");
        let n = data.len() / d;
        anyhow::ensure!(n > 0, "pq fit needs at least one training row");
        let dsub = d / m;
        let mut centroids = vec![0f32; crate::distance::PQ_KSUB * d];
        let mut ksub = Vec::with_capacity(m);
        let mut sub_rows: Vec<f32> = Vec::new();
        let mut params = PqParams { d, m, dsub, ksub: Vec::new(), centroids: Vec::new() };
        for sub in 0..m {
            let (lo, w) = params.sub_bounds(sub);
            sub_rows.clear();
            sub_rows.reserve(n * w);
            for r in 0..n {
                sub_rows.extend_from_slice(&data[r * d + lo..r * d + lo + w]);
            }
            let book = crate::baselines::kmeans::train(
                &sub_rows,
                w,
                crate::distance::PQ_KSUB,
                PQ_KMEANS_ITERS,
                Metric::L2,
                seed ^ (sub as u64 + 1),
                threads,
            );
            for c in 0..book.k {
                let base = crate::distance::PQ_KSUB * lo + c * w;
                centroids[base..base + w].copy_from_slice(book.centroid(c));
            }
            ksub.push(book.k as u32);
        }
        params.ksub = ksub;
        params.centroids = centroids;
        Ok(params)
    }

    /// Reassemble from persisted parts (the `.dsb` PQ reader).
    pub(crate) fn from_parts(
        d: usize,
        m: usize,
        ksub: Vec<u32>,
        centroids: Vec<f32>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(d > 0 && m > 0 && m <= d, "pq header: 1 <= m <= d violated (m={m}, d={d})");
        anyhow::ensure!(ksub.len() == m, "pq header: {} ksub words, want {m}", ksub.len());
        anyhow::ensure!(
            ksub.iter().all(|&k| (1..=crate::distance::PQ_KSUB as u32).contains(&k)),
            "pq header: ksub out of 1..=256"
        );
        anyhow::ensure!(
            centroids.len() == crate::distance::PQ_KSUB * d,
            "pq codebooks: {} floats, want {}",
            centroids.len(),
            crate::distance::PQ_KSUB * d
        );
        Ok(PqParams { d, m, dsub: d / m, ksub, centroids })
    }

    /// Persisted parts, mirroring [`Self::from_parts`].
    pub(crate) fn parts(&self) -> (&[u32], &[f32]) {
        (&self.ksub, &self.centroids)
    }

    /// Encode one f32 row into `out` (cleared first): nearest centroid
    /// per subspace, squared-L2 assignment like
    /// [`Codebook::assign`](crate::baselines::kmeans::Codebook::assign).
    pub fn encode_into(&self, row: &[f32], out: &mut Vec<u8>) {
        debug_assert_eq!(row.len(), self.d);
        out.clear();
        for sub in 0..self.m {
            let (lo, w) = self.sub_bounds(sub);
            let rv = &row[lo..lo + w];
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..self.ksub[sub] as usize {
                let dist = crate::distance::l2_sq(rv, self.centroid(sub, c));
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            out.push(best.1 as u8);
        }
    }

    /// Reconstruct one code row into `out` (cleared first).
    pub fn decode_into(&self, codes: &[u8], out: &mut Vec<f32>) {
        debug_assert_eq!(codes.len(), self.m);
        out.clear();
        for (sub, &c) in codes.iter().enumerate() {
            out.extend_from_slice(self.centroid(sub, c as usize));
        }
    }

    /// Fill the query's m×256 asymmetric-distance table: entry
    /// `[sub * 256 + c]` is the metric distance contribution of
    /// subspace `sub` when the candidate's code there is `c`, so
    /// [`crate::distance::pq_lut_sum`] over a code row equals the
    /// metric distance to the reconstructed row. Slots past
    /// `ksub[sub]` are +inf (never referenced by intact codes; a
    /// corrupt code ranks last instead of winning with 0).
    pub fn build_lut(&self, metric: Metric, q: &[f32], lut: &mut Vec<f32>) {
        debug_assert_eq!(q.len(), self.d);
        lut.clear();
        lut.resize(self.m * crate::distance::PQ_KSUB, f32::INFINITY);
        for sub in 0..self.m {
            let (lo, w) = self.sub_bounds(sub);
            let qsub = &q[lo..lo + w];
            for c in 0..self.ksub[sub] as usize {
                lut[sub * crate::distance::PQ_KSUB + c] =
                    crate::distance::distance(metric, qsub, self.centroid(sub, c));
            }
        }
    }

    /// In-memory footprint of the codebook sidecar.
    pub fn mem_bytes(&self) -> usize {
        self.centroids.len() * std::mem::size_of::<f32>()
            + self.ksub.len() * std::mem::size_of::<u32>()
    }
}

/// A product-quantized vector backing: m-byte code rows plus the
/// [`PqParams`] codebooks, with optional full-precision [`ExactRows`]
/// for rerank. The beam phase scores candidates via the per-query LUT
/// ([`crate::distance::pq_lut_sum`]) — m bytes of row traffic and m
/// table gathers per candidate, against d bytes and a d-wide integer
/// dot for scalar quantization.
#[derive(Clone, Debug)]
pub(crate) struct PqStore {
    pub(crate) d: usize,
    pub(crate) params: Arc<PqParams>,
    /// m-byte rows (the [`QuantCodes`] container is code-width
    /// agnostic: paged stores carry `elems_per_row = m`).
    pub(crate) codes: QuantCodes,
    pub(crate) exact: Option<ExactRows>,
}

impl PqStore {
    pub(crate) fn rows(&self) -> usize {
        match &self.codes {
            QuantCodes::Owned(v) => v.len() / self.params.m(),
            QuantCodes::Paged(p) => p.rows(),
        }
    }

    /// Borrow row `i`'s m-byte codes for the duration of `f`.
    pub(crate) fn with_codes<R>(&self, i: usize, f: impl FnOnce(&[u8]) -> R) -> R {
        let m = self.params.m();
        match &self.codes {
            QuantCodes::Owned(v) => f(&v[i * m..(i + 1) * m]),
            QuantCodes::Paged(p) => p.with_u8_row(i, f),
        }
    }

    /// Reconstruct row `i` into `out` (cleared first).
    pub(crate) fn decode_row_into(&self, i: usize, out: &mut Vec<f32>) {
        let params = &self.params;
        self.with_codes(i, |codes| params.decode_into(codes, out));
    }

    /// Approximate (beam-phase) distance of row `i` to the query whose
    /// ADC table is `lut` (from [`PqParams::build_lut`]) — metric
    /// units, distance to the reconstructed row.
    pub(crate) fn dist_to_lut(&self, i: usize, lut: &[f32]) -> f32 {
        self.with_codes(i, |codes| crate::distance::pq_lut_sum(lut, codes))
    }

    /// Full-precision distance of row `i` to the query, for the rerank
    /// phase: exact rows when attached, else the reconstructed row
    /// (still metric-unit, carrying the quantization error) via `buf`.
    pub(crate) fn rerank_dist_to(
        &self,
        metric: Metric,
        i: usize,
        q: &[f32],
        buf: &mut Vec<f32>,
    ) -> f32 {
        match &self.exact {
            Some(ExactRows::Owned(v)) => {
                crate::distance::distance(metric, &v[i * self.d..(i + 1) * self.d], q)
            }
            Some(ExactRows::Paged(p)) => {
                p.with_f32_row(i, |row| crate::distance::distance(metric, row, q))
            }
            None => {
                self.decode_row_into(i, buf);
                crate::distance::distance(metric, buf, q)
            }
        }
    }

    /// In-memory footprint: codes (owned) or handle (paged), plus the
    /// codebook sidecar and the exact-rows attachment.
    pub(crate) fn resident_bytes(&self) -> usize {
        let codes = match &self.codes {
            QuantCodes::Owned(v) => v.len(),
            QuantCodes::Paged(_) => PAGED_HANDLE_BYTES,
        };
        let exact = match &self.exact {
            Some(ExactRows::Owned(v)) => v.len() * std::mem::size_of::<f32>(),
            Some(ExactRows::Paged(_)) => PAGED_HANDLE_BYTES,
            None => 0,
        };
        codes + self.params.mem_bytes() + exact
    }

    pub(crate) fn codes_store_id(&self) -> Option<u64> {
        match &self.codes {
            QuantCodes::Paged(p) => Some(p.store_id()),
            QuantCodes::Owned(_) => None,
        }
    }

    pub(crate) fn exact_store_id(&self) -> Option<u64> {
        match &self.exact {
            Some(ExactRows::Paged(p)) => Some(p.store_id()),
            _ => None,
        }
    }
}

/// Where a data structure's rows live: fully in memory, paged from
/// disk through a [`BlockCache`], scalar-quantized u8 codes (owned or
/// paged) with the [`QuantParams`] sidecar, or product-quantized
/// m-byte codes with the [`PqParams`] codebooks.
#[derive(Clone, Debug)]
pub enum VectorStore {
    Owned(Vec<f32>),
    Paged(PagedRows),
    Quantized(Box<QuantStore>),
    Pq(Box<PqStore>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_rows(path: &std::path::Path, rows: usize, d: usize) -> Vec<f32> {
        let data: Vec<f32> = (0..rows * d).map(|x| x as f32 * 0.5 - 3.0).collect();
        let mut f = File::create(path).unwrap();
        for x in &data {
            f.write_all(&x.to_le_bytes()).unwrap();
        }
        data
    }

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "gnnd-store-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ))
    }

    fn open_paged(path: &std::path::Path, rows: usize, d: usize, cache: &Arc<BlockCache>) -> PagedRows {
        PagedRows::new(
            File::open(path).unwrap(),
            path.to_path_buf(),
            0,
            rows,
            d * 4,
            d,
            cache,
            decode_f32_block,
        )
    }

    #[test]
    fn paged_rows_match_owned_across_block_boundaries() {
        // d = 3 (stride 12) with 40-byte blocks -> 3 rows per block and
        // a short tail block: exercises first/last row of every block
        // and a block size d does not divide.
        let (rows, d) = (10usize, 3usize);
        let path = tmpfile("boundary");
        let data = write_rows(&path, rows, d);
        let cache = BlockCache::new(0, 40);
        let paged = open_paged(&path, rows, d, &cache);
        assert_eq!(paged.block_rows, 3);
        for i in 0..rows {
            paged.with_f32_row(i, |row| {
                assert_eq!(row, &data[i * d..(i + 1) * d], "row {i}");
            });
        }
        let s = cache.stats();
        assert_eq!(s.fetches, 4, "10 rows over 3-row blocks = 4 blocks");
        assert_eq!(s.hits, rows as u64 - 4);
        assert_eq!(s.bytes_read, (rows * d * 4) as u64);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let (rows, d) = (32usize, 4usize); // stride 16
        let path = tmpfile("lru");
        write_rows(&path, rows, d);
        // blocks of 2 rows (32B payload -> 32B mem); budget = 2 blocks
        let cache = BlockCache::new(64, 32);
        let paged = open_paged(&path, rows, d, &cache);
        assert_eq!(paged.block_rows, 2);
        paged.with_f32_row(0, |_| ());
        paged.with_f32_row(2, |_| ());
        let s = cache.stats();
        assert_eq!((s.fetches, s.resident_blocks), (2, 2));
        assert!(s.resident_bytes <= 64);
        // third distinct block with a full cache: first visit rejected
        paged.with_f32_row(4, |_| ());
        let s = cache.stats();
        assert_eq!(s.rejected_admissions, 1);
        assert_eq!(s.resident_blocks, 2, "first-visit block must not evict the set");
        // second visit admits (and evicts the LRU block 0)
        paged.with_f32_row(4, |_| ());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.resident_blocks, 2);
        assert!(s.resident_bytes <= 64);
        // block 2 stayed hot through the scan
        let hits_before = cache.stats().hits;
        paged.with_f32_row(2, |_| ());
        assert_eq!(cache.stats().hits, hits_before + 1);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn scan_larger_than_budget_does_not_evict_hot_set() {
        let (rows, d) = (64usize, 4usize);
        let path = tmpfile("scan");
        write_rows(&path, rows, d);
        let cache = BlockCache::new(64, 32); // 2-row blocks, 2-block budget
        let paged = open_paged(&path, rows, d, &cache);
        // warm the hot set
        paged.with_f32_row(0, |_| ());
        paged.with_f32_row(2, |_| ());
        // scan 20 distinct cold blocks, each visited once
        for i in (8..48).step_by(2) {
            paged.with_f32_row(i, |_| ());
        }
        let s = cache.stats();
        assert_eq!(s.evictions, 0, "one-shot scan must not evict: {s:?}");
        assert!(s.rejected_admissions >= 20);
        // the hot set is still resident
        let hits = s.hits;
        paged.with_f32_row(0, |_| ());
        paged.with_f32_row(2, |_| ());
        assert_eq!(cache.stats().hits, hits + 2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn unbounded_cache_admits_everything() {
        let (rows, d) = (16usize, 4usize);
        let path = tmpfile("unbounded");
        write_rows(&path, rows, d);
        let cache = BlockCache::new(0, 32);
        let paged = open_paged(&path, rows, d, &cache);
        for i in 0..rows {
            paged.with_f32_row(i, |_| ());
        }
        let s = cache.stats();
        assert_eq!(s.rejected_admissions, 0);
        assert_eq!(s.evictions, 0);
        assert_eq!(s.resident_blocks, 8);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn forget_store_drops_only_that_namespace() {
        let (rows, d) = (8usize, 4usize);
        let p1 = tmpfile("forget1");
        let p2 = tmpfile("forget2");
        write_rows(&p1, rows, d);
        write_rows(&p2, rows, d);
        let cache = BlockCache::new(0, 64);
        let a = open_paged(&p1, rows, d, &cache);
        let b = open_paged(&p2, rows, d, &cache);
        a.with_f32_row(0, |_| ());
        b.with_f32_row(0, |_| ());
        assert_eq!(cache.stats().resident_blocks, 2);
        cache.forget_store(a.store_id());
        assert_eq!(cache.stats().resident_blocks, 1);
        // b's block survived
        let hits = cache.stats().hits;
        b.with_f32_row(0, |_| ());
        assert_eq!(cache.stats().hits, hits + 1);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn quantize_round_trip_error_bounded_by_half_step() {
        crate::util::prop::check("quant-roundtrip", 100, |rng: &mut crate::util::rng::Rng| {
            let d = rng.below(48) + 1;
            let rows = rng.below(30) + 2;
            let data: Vec<f32> =
                (0..rows * d).map(|_| rng.normal_f32() * (rng.below(10) as f32 + 0.5)).collect();
            let mut fit = QuantFitter::new(d);
            for r in 0..rows {
                fit.observe(&data[r * d..(r + 1) * d]);
            }
            let params = fit.finish();
            let (mut codes, mut back) = (Vec::new(), Vec::new());
            for r in 0..rows {
                let row = &data[r * d..(r + 1) * d];
                params.encode_into(row, &mut codes);
                params.decode_into(&codes, &mut back);
                for j in 0..d {
                    let err = (back[j] - row[j]).abs();
                    // half a quantization step, plus f32 slack
                    let bound = params.scale[j] / 2.0 + 1e-4 * row[j].abs().max(1.0);
                    if err > bound {
                        return crate::util::prop::assert_prop(
                            false,
                            format!(
                                "dim {j}: err {err} > bound {bound} (scale {})",
                                params.scale[j]
                            ),
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn constant_dimension_round_trips_exactly() {
        let mut fit = QuantFitter::new(2);
        fit.observe(&[7.5, 1.0]);
        fit.observe(&[7.5, 3.0]);
        let params = fit.finish();
        assert_eq!(params.scale[0], 1.0, "degenerate dim falls back to unit scale");
        let (mut codes, mut back) = (Vec::new(), Vec::new());
        params.encode_into(&[7.5, 2.0], &mut codes);
        assert_eq!(codes[0], 0);
        params.decode_into(&codes, &mut back);
        assert_eq!(back[0], 7.5);
    }

    #[test]
    fn quant_store_owned_dist_and_rerank() {
        let d = 8;
        let data: Vec<f32> = (0..4 * d).map(|x| (x as f32 * 0.37).sin() * 5.0).collect();
        let mut fit = QuantFitter::new(d);
        for r in 0..4 {
            fit.observe(&data[r * d..(r + 1) * d]);
        }
        let params = Arc::new(fit.finish());
        let mut codes = Vec::new();
        let mut all = Vec::with_capacity(4 * d);
        for r in 0..4 {
            params.encode_into(&data[r * d..(r + 1) * d], &mut codes);
            all.extend_from_slice(&codes);
        }
        let qs = QuantStore {
            d,
            params: params.clone(),
            codes: QuantCodes::Owned(all),
            exact: Some(ExactRows::Owned(data.clone())),
        };
        assert_eq!(qs.rows(), 4);
        let q = &data[0..d];
        let mut qcodes = Vec::new();
        params.encode_into(q, &mut qcodes);
        // code-space self distance is zero
        assert_eq!(qs.dist_to(Metric::L2, 0, q, &qcodes), 0.0);
        // rerank uses the exact sidecar: matches the f32 kernel bit-exactly
        let mut buf = Vec::new();
        for i in 0..4 {
            let want = crate::distance::distance(Metric::L2, &data[i * d..(i + 1) * d], q);
            assert_eq!(qs.rerank_dist_to(Metric::L2, i, q, &mut buf), want);
        }
        // without exact rows, rerank falls back to dequantized codes:
        // close to, but not exactly, the f32 value
        let qs2 = QuantStore { exact: None, ..qs.clone() };
        for i in 1..4 {
            let want = crate::distance::distance(Metric::L2, &data[i * d..(i + 1) * d], q);
            let got = qs2.rerank_dist_to(Metric::L2, i, q, &mut buf);
            let tol = 0.05 * want.abs().max(1.0);
            assert!((got - want).abs() <= tol, "i={i} got={got} want={want}");
        }
        // resident accounting: codes are 1 byte/dim + params + exact f32
        assert_eq!(qs.resident_bytes(), 4 * d + 2 * d * 4 + 4 * d * 4);
        assert_eq!(qs2.resident_bytes(), 4 * d + 2 * d * 4);
    }

    #[test]
    fn pq_codes_reference_fitted_centroids_and_lut_matches_reconstruction() {
        crate::util::prop::check("pq-lut-identity", 40, |rng: &mut crate::util::rng::Rng| {
            let m = rng.below(4) + 1;
            let d = m * (rng.below(3) + 1) + rng.below(m); // exercises remainder subspaces
            let rows = rng.below(300) + 20;
            let data: Vec<f32> = (0..rows * d).map(|_| rng.normal_f32() * 3.0).collect();
            let params = PqParams::fit(&data, d, m, 7 + m as u64, 1).unwrap();
            let (mut codes, mut recon, mut lut) = (Vec::new(), Vec::new(), Vec::new());
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            for metric in [Metric::L2, Metric::Ip] {
                params.build_lut(metric, &q, &mut lut);
                for r in 0..rows.min(40) {
                    let row = &data[r * d..(r + 1) * d];
                    params.encode_into(row, &mut codes);
                    let (ksub, _) = params.parts();
                    for (sub, &c) in codes.iter().enumerate() {
                        if (c as u32) >= ksub[sub] {
                            return crate::util::prop::assert_prop(
                                false,
                                format!("code {c} >= ksub {}", ksub[sub]),
                            );
                        }
                    }
                    // the ADC identity: LUT sum == distance(q, reconstruction)
                    params.decode_into(&codes, &mut recon);
                    let want = crate::distance::distance(metric, &q, &recon);
                    let got = crate::distance::pq_lut_sum(&lut, &codes);
                    let tol = 1e-3 * want.abs().max(1.0);
                    if (got - want).abs() > tol {
                        return crate::util::prop::assert_prop(
                            false,
                            format!("m={m} d={d} {metric:?}: lut {got} vs recon {want}"),
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pq_small_training_set_clamps_codebooks() {
        // 10 rows < 256: every subquantizer must clamp to k = 10 and
        // codes must stay valid
        let (rows, d, m) = (10usize, 8usize, 4usize);
        let data: Vec<f32> = (0..rows * d).map(|x| (x as f32 * 0.73).cos()).collect();
        let params = PqParams::fit(&data, d, m, 3, 1).unwrap();
        let (ksub, _) = params.parts();
        assert!(ksub.iter().all(|&k| k <= rows as u32), "ksub {ksub:?}");
        let mut codes = Vec::new();
        params.encode_into(&data[0..d], &mut codes);
        assert_eq!(codes.len(), m);
        // a fitted centroid round-trips exactly through encode/decode
        let mut recon = Vec::new();
        params.decode_into(&codes, &mut recon);
        let mut codes2 = Vec::new();
        params.encode_into(&recon, &mut codes2);
        assert_eq!(codes, codes2);
    }

    #[test]
    fn pq_store_owned_dist_and_rerank() {
        let (rows, d, m) = (300usize, 16usize, 4usize);
        let data: Vec<f32> = (0..rows * d).map(|x| (x as f32 * 0.37).sin() * 5.0).collect();
        let params = Arc::new(PqParams::fit(&data, d, m, 11, 1).unwrap());
        let mut codes = Vec::new();
        let mut all = Vec::with_capacity(rows * m);
        for r in 0..rows {
            params.encode_into(&data[r * d..(r + 1) * d], &mut codes);
            all.extend_from_slice(&codes);
        }
        let ps = PqStore {
            d,
            params: params.clone(),
            codes: QuantCodes::Owned(all),
            exact: Some(ExactRows::Owned(data.clone())),
        };
        assert_eq!(ps.rows(), rows);
        let q = &data[0..d];
        let mut lut = Vec::new();
        params.build_lut(Metric::L2, q, &mut lut);
        // beam distance == distance to the reconstruction
        let (mut recon, mut buf) = (Vec::new(), Vec::new());
        for i in [0usize, 1, rows / 2, rows - 1] {
            ps.decode_row_into(i, &mut recon);
            let want = crate::distance::distance(Metric::L2, q, &recon);
            let got = ps.dist_to_lut(i, &lut);
            assert!((got - want).abs() <= 1e-3 * want.max(1.0), "i={i} got={got} want={want}");
            // rerank uses the exact sidecar: matches the f32 kernel bit-exactly
            let exact = crate::distance::distance(Metric::L2, &data[i * d..(i + 1) * d], q);
            assert_eq!(ps.rerank_dist_to(Metric::L2, i, q, &mut buf), exact);
        }
        // resident accounting: m bytes/row + codebooks + exact f32 rows
        assert_eq!(ps.resident_bytes(), rows * m + params.mem_bytes() + rows * d * 4);
        // codes are 4x smaller than scalar-quantized (d bytes/row)
        assert!(rows * m * 4 == rows * d);
    }

    #[test]
    fn doorkeeper_two_visit_window() {
        let mut d = Doorkeeper::new(8);
        assert!(!d.admit(1));
        assert!(d.admit(1));
        // rotation keeps the previous generation visible...
        for k in 2..10 {
            d.admit(k);
        }
        assert!(d.admit(1), "key aged out within one generation");
        // ...but two rotations forget
        for k in 100..120 {
            d.admit(k);
        }
        assert!(!d.admit(1));
    }
}
