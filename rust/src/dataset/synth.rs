//! Synthetic benchmark datasets shaped like the paper's Table 1 suite.
//!
//! The real SIFT1M/DEEP1M/GIST1M/GloVe corpora are multi-GB downloads not
//! available in this offline environment, so we substitute generators
//! that reproduce the statistics NN-Descent's behaviour depends on —
//! dimensionality, cluster structure and intrinsic dimension (the paper
//! §3.1 notes NN-Descent's hill climbing is governed by intrinsic
//! dimension). Recall is always measured against exact ground truth of
//! the *same* synthetic data, so quality numbers remain meaningful.
//! See DESIGN.md "Substitutions".

use crate::config::Metric;
use crate::util::rng::Rng;

use super::Dataset;

/// Gaussian-mixture generator: `centers` cluster centres drawn uniformly
/// in `[0, span]^d`, points = centre + N(0, sigma^2 I).
fn gmm(n: usize, d: usize, centers: usize, span: f32, sigma: f32, rng: &mut Rng) -> Vec<f32> {
    let mut cs = vec![0f32; centers * d];
    for c in cs.iter_mut() {
        *c = rng.f32() * span;
    }
    let mut data = vec![0f32; n * d];
    for i in 0..n {
        let c = rng.below(centers);
        for j in 0..d {
            data[i * d + j] = cs[c * d + j] + rng.normal_f32() * sigma;
        }
    }
    data
}

/// SIFT-like: d=128 local-feature histograms — clustered, non-negative,
/// integer-quantized values in [0, 255].
pub fn sift_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x51F7);
    let d = 128;
    let mut data = gmm(n, d, 64.max(n / 2000), 160.0, 24.0, &mut rng);
    for x in data.iter_mut() {
        *x = x.round().clamp(0.0, 255.0);
    }
    Dataset::new(format!("sift-like-{n}"), d, Metric::L2, data)
}

/// DEEP-like: d=96 CNN descriptors — l2-normalized dense embeddings.
pub fn deep_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xDEE9);
    let d = 96;
    let mut data = gmm(n, d, 48.max(n / 2500), 2.0, 0.35, &mut rng);
    for i in 0..n {
        crate::distance::normalize(&mut data[i * d..(i + 1) * d]);
    }
    Dataset::new(format!("deep-like-{n}"), d, Metric::L2, data)
}

/// GIST-like: d=960 global scene descriptors with *low intrinsic
/// dimension* — a 24-d latent GMM pushed through a random linear map
/// plus small ambient noise. High d / low intrinsic-d is exactly the
/// regime where NN-Descent still converges well (paper §3.1).
pub fn gist_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x6157);
    let (d, latent) = (960, 24);
    // random projection matrix [latent x d]
    let mut proj = vec![0f32; latent * d];
    let scale = 1.0 / (latent as f32).sqrt();
    for p in proj.iter_mut() {
        *p = rng.normal_f32() * scale;
    }
    let z = gmm(n, latent, 32.max(n / 3000), 4.0, 0.5, &mut rng);
    let mut data = vec![0f32; n * d];
    for i in 0..n {
        for l in 0..latent {
            let zl = z[i * latent + l];
            let row = &proj[l * d..(l + 1) * d];
            let out = &mut data[i * d..(i + 1) * d];
            for j in 0..d {
                out[j] += zl * row[j];
            }
        }
        for j in 0..d {
            data[i * d + j] += rng.normal_f32() * 0.01;
        }
    }
    Dataset::new(format!("gist-like-{n}"), d, Metric::L2, data)
}

/// GloVe-like: d=100 word embeddings — heavy-tailed coordinates, cosine
/// metric (the paper's only non-l2 benchmark; exercises genericness).
pub fn glove_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x610E);
    let d = 100;
    let centers = 96.max(n / 2000);
    let mut cs = vec![0f32; centers * d];
    for c in cs.iter_mut() {
        *c = rng.normal_f32() * 1.2;
    }
    let mut data = vec![0f32; n * d];
    for i in 0..n {
        let c = rng.below(centers);
        // Student-t-ish tail: normal / sqrt(chi2/df) with df=4, via
        // averaging 4 squared normals.
        for j in 0..d {
            let mut chi = 0f32;
            for _ in 0..4 {
                let g = rng.normal_f32();
                chi += g * g;
            }
            let t = rng.normal_f32() / (chi / 4.0).sqrt();
            data[i * d + j] = cs[c * d + j] + 0.6 * t;
        }
    }
    Dataset::new(format!("glove-like-{n}"), d, Metric::Cosine, data)
}

/// Low-dimensional easy dataset for fast unit tests.
pub fn uniform(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x0417);
    let data = (0..n * d).map(|_| rng.f32()).collect();
    Dataset::new(format!("uniform-{n}x{d}"), d, Metric::L2, data)
}

/// Clustered low-d dataset for fast integration tests (recall converges
/// in few iterations).
pub fn clustered(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xC105);
    let data = gmm(n, d, 16.max(n / 500), 10.0, 0.4, &mut rng);
    Dataset::new(format!("clustered-{n}x{d}"), d, Metric::L2, data)
}

/// Look up a generator by name (CLI + experiment harness).
pub fn by_name(name: &str, n: usize, seed: u64) -> crate::Result<Dataset> {
    Ok(match name {
        "sift" | "sift-like" => sift_like(n, seed),
        "deep" | "deep-like" => deep_like(n, seed),
        "gist" | "gist-like" => gist_like(n, seed),
        "glove" | "glove-like" => glove_like(n, seed),
        "uniform" => uniform(n, 16, seed),
        "clustered" => clustered(n, 16, seed),
        _ => anyhow::bail!("unknown dataset {name:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        for (name, d) in [("sift", 128), ("deep", 96), ("glove", 100)] {
            let a = by_name(name, 200, 1).unwrap();
            let b = by_name(name, 200, 1).unwrap();
            assert_eq!(a.d, d);
            assert_eq!(a.len(), 200);
            assert_eq!(a.raw(), b.raw(), "{name} not deterministic");
            let c = by_name(name, 200, 2).unwrap();
            assert_ne!(a.raw(), c.raw(), "{name} ignores seed");
        }
    }

    #[test]
    fn sift_like_is_quantized_in_range() {
        let ds = sift_like(100, 3);
        for &x in ds.raw() {
            assert!((0.0..=255.0).contains(&x));
            assert_eq!(x, x.round());
        }
    }

    #[test]
    fn deep_like_rows_are_normalized() {
        let ds = deep_like(50, 4);
        for i in 0..ds.len() {
            let n = crate::distance::dot(ds.vec(i), ds.vec(i));
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn gist_like_has_low_intrinsic_dim() {
        // Crude check: energy should concentrate — pairwise distances in
        // 960-d should behave like ~24-d data, i.e. distance variance
        // relative to mean should be far from the concentration you get
        // for iid 960-d gaussians.
        let ds = gist_like(120, 5);
        assert_eq!(ds.d, 960);
        let mut rng = crate::util::rng::Rng::new(9);
        let (mut s, mut s2, m) = (0f64, 0f64, 400);
        for _ in 0..m {
            let i = rng.below(ds.len());
            let j = rng.below(ds.len());
            if i == j {
                continue;
            }
            let d = ds.dist(i, j) as f64;
            s += d;
            s2 += d * d;
        }
        let mean = s / m as f64;
        let var = (s2 / m as f64 - mean * mean).max(0.0);
        let rel = var.sqrt() / mean;
        assert!(rel > 0.2, "distances too concentrated (rel={rel})");
    }

    #[test]
    fn glove_like_is_cosine_normalized() {
        let ds = glove_like(60, 6);
        assert_eq!(ds.metric, Metric::Cosine);
        for i in 0..ds.len() {
            let n = crate::distance::dot(ds.vec(i), ds.vec(i));
            assert!((n - 1.0).abs() < 1e-4);
        }
    }
}
