//! Exact k-NN ground truth (the Recall@k denominator, paper Eq. 4).
//!
//! Brute-force over the whole dataset, parallelized over queries. For
//! large n the paper evaluates recall over the full graph; at repro
//! scale we also support evaluating on a deterministic sample of objects
//! (standard ANN-benchmark practice) to keep ground-truth costs sane.

use crate::dataset::Dataset;
use crate::util::{rng::Rng, split_ranges};

/// Exact top-k neighbor ids (self excluded) for the given query ids.
///
/// Returns one row per query id, each row sorted by ascending distance,
/// length `min(k, n-1)`.
pub fn exact_topk_for(ds: &Dataset, query_ids: &[usize], k: usize) -> Vec<Vec<u32>> {
    let n = ds.len();
    let threads = crate::util::num_threads().min(query_ids.len().max(1));
    let ranges = split_ranges(query_ids.len(), threads);
    let mut out: Vec<Vec<Vec<u32>>> = Vec::new();
    crossbeam_utils::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|r| {
                let ids = &query_ids[r.clone()];
                s.spawn(move |_| {
                    let mut rows = Vec::with_capacity(ids.len());
                    for &q in ids {
                        rows.push(topk_one(ds, q, k, n));
                    }
                    rows
                })
            })
            .collect();
        for h in handles {
            out.push(h.join().unwrap());
        }
    })
    .unwrap();
    out.into_iter().flatten().collect()
}

fn topk_one(ds: &Dataset, q: usize, k: usize, n: usize) -> Vec<u32> {
    // bounded max-heap on (dist, id)
    let mut heap: std::collections::BinaryHeap<(ordered::F32, u32)> =
        std::collections::BinaryHeap::with_capacity(k + 1);
    for j in 0..n {
        if j == q {
            continue;
        }
        let d = ds.dist(q, j);
        if heap.len() < k {
            heap.push((ordered::F32(d), j as u32));
        } else if d < heap.peek().unwrap().0 .0 {
            heap.pop();
            heap.push((ordered::F32(d), j as u32));
        }
    }
    let mut v: Vec<(ordered::F32, u32)> = heap.into_vec();
    v.sort_unstable();
    v.into_iter().map(|(_, id)| id).collect()
}

/// Exact top-k for all objects.
pub fn exact_topk(ds: &Dataset, k: usize) -> Vec<Vec<u32>> {
    let ids: Vec<usize> = (0..ds.len()).collect();
    exact_topk_for(ds, &ids, k)
}

/// Ground truth on a deterministic sample of `m` objects.
/// Returns (sampled ids, truth rows).
pub fn sampled_truth(ds: &Dataset, m: usize, k: usize, seed: u64) -> (Vec<usize>, Vec<Vec<u32>>) {
    let m = m.min(ds.len());
    let mut rng = Rng::new(seed ^ 0x6711);
    let ids = rng.distinct(ds.len(), m);
    let rows = exact_topk_for(ds, &ids, k);
    (ids, rows)
}

/// Total-orderable f32 wrapper (distances are never NaN by construction).
pub(crate) mod ordered {
    #[derive(Clone, Copy, PartialEq, PartialOrd)]
    pub struct F32(pub f32);
    impl Eq for F32 {}
    #[allow(clippy::derive_ord_xor_partial_ord)]
    impl Ord for F32 {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn truth_matches_naive_sort() {
        let ds = synth::uniform(80, 4, 1);
        let truth = exact_topk(&ds, 5);
        for q in 0..ds.len() {
            let mut all: Vec<(f32, u32)> = (0..ds.len())
                .filter(|&j| j != q)
                .map(|j| (ds.dist(q, j), j as u32))
                .collect();
            all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let want: Vec<u32> = all[..5].iter().map(|x| x.1).collect();
            // compare distances not ids (ties)
            let got_d: Vec<f32> = truth[q].iter().map(|&id| ds.dist(q, id as usize)).collect();
            let want_d: Vec<f32> = want.iter().map(|&id| ds.dist(q, id as usize)).collect();
            assert_eq!(got_d, want_d, "q={q}");
            assert!(!truth[q].contains(&(q as u32)));
        }
    }

    #[test]
    fn k_larger_than_n() {
        let ds = synth::uniform(4, 3, 2);
        let truth = exact_topk(&ds, 10);
        for row in &truth {
            assert_eq!(row.len(), 3);
        }
    }

    #[test]
    fn sampled_truth_is_deterministic() {
        let ds = synth::uniform(50, 4, 3);
        let (ids1, rows1) = sampled_truth(&ds, 10, 5, 7);
        let (ids2, rows2) = sampled_truth(&ds, 10, 5, 7);
        assert_eq!(ids1, ids2);
        assert_eq!(rows1, rows2);
    }
}
