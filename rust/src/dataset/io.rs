//! Dataset and graph file I/O.
//!
//! * `.fvecs` / `.ivecs` — the TEXMEX interchange formats used by the
//!   paper's benchmarks (SIFT1M etc.), so real corpora drop in when
//!   available.
//! * `.dsb` — our own raw binary dataset format (spec below), used by
//!   the out-of-core shard store because it supports metric metadata,
//!   fast bulk reads, and (v2) random row access for paged serving.
//!
//! # `.dsb` format spec
//!
//! All integers little-endian u32; all vector components little-endian
//! f32.
//!
//! **v2** (written by [`write_dsb`]) — fixed-stride, pageable:
//!
//! ```text
//! offset  field
//!      0  magic        0x4453_4232 ("DSB2")
//!      4  d            vector dimensionality
//!      8  n            number of rows
//!     12  metric       0 = l2, 1 = ip, 2 = cosine (rows pre-normalized)
//!     16  row_stride   bytes per row, = 4*d (recorded so row offsets
//!                      are computable without knowledge of the codec)
//!     20  block_rows   writer's block-size hint (readers may page at
//!                      any row-aligned block size; this records the
//!                      default-`DEFAULT_BLOCK_BYTES` granularity the
//!                      file was written for)
//!     24  data         n rows x row_stride bytes, row i at
//!                      24 + i*row_stride
//! ```
//!
//! Because the stride is fixed and recorded, any row's byte offset is
//! computable without scanning — the property the paged
//! ([`read_dsb_paged`]) serving path relies on.
//!
//! **v1** (legacy; still read, written only by [`write_dsb_v1`]):
//! magic 0x4453_4231 ("DSB1"), d, n, metric, then n*d f32. v1 files
//! always load fully resident (the owned path), including under
//! block-residency serving.
//!
//! **q1** (scalar-quantized; written by [`write_dsb_quantized_with`]
//! / `gnnd quantize`) — the v2 layout with u8 code rows and a
//! [`QuantParams`] sidecar between header and data:
//!
//! ```text
//! offset      field
//!      0      magic        0x4453_5131 ("DSQ1")
//!      4      d            vector dimensionality
//!      8      n            number of rows
//!     12      metric       same codes as v2
//!     16      row_stride   bytes per row, = d (one u8 code per dim)
//!     20      block_rows   writer's block-size hint
//!     24      scale        d f32 (per-dimension quantization step)
//!     24+4d   offset       d f32 (per-dimension minimum)
//!     24+8d   data         n rows x d bytes, row i at 24 + 8*d + i*d
//! ```
//!
//! Dimension `j` of row `x` encodes as
//! `round((x[j] - offset[j]) / scale[j])` clamped to `[0, 255]`.
//! Readers auto-detect the magic: [`read_dsb`] loads codes owned,
//! [`read_dsb_paged`] pages them through the block cache at 1 byte per
//! dimension (4x the rows per byte of budget vs. v2), and
//! [`read_dsb_quantized`] additionally attaches a paged full-precision
//! v2 sidecar for the exact rerank phase of two-phase search.
//!
//! **p1** (product-quantized; written by [`write_dsb_pq_with`] /
//! `gnnd quantize --pq-m M`) — the v2 layout with m-byte PQ code rows
//! and the [`PqParams`] codebooks between header and data:
//!
//! ```text
//! offset         field
//!      0         magic        0x4453_5031 ("DSP1")
//!      4         d            vector dimensionality
//!      8         n            number of rows
//!     12         metric       same codes as v2
//!     16         row_stride   bytes per row, = m (subquantizer count)
//!     20         block_rows   writer's block-size hint
//!     24         ksub         m u32 (fitted centroids per subquantizer)
//!     24+4m      codebooks    256*d f32, subspace-contiguous (see
//!                             [`PqParams`]; slots past ksub are zero)
//!     24+4m+1024d data        n rows x m bytes
//! ```
//!
//! Readers auto-detect the magic exactly like q1: [`read_dsb`] loads
//! codes owned, [`read_dsb_paged`] pages them at m bytes per row
//! (~4d/m× the rows per byte of budget vs. v2), and [`read_dsb_pq`]
//! attaches the paged full-precision v2 sidecar for exact rerank.
//!
//! Both readers validate the header against the actual file length on
//! open, so truncated or corrupt files fail with the path and expected
//! vs. actual sizes instead of a `read_exact` EOF mid-load.
//!
//! The `.knng` graph format mirrors this scheme (KNG1/KNG2); see
//! [`crate::graph::KnnGraph::save`].

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context};

use crate::config::Metric;

use super::store::{
    self, BlockCache, ExactRows, PagedRows, PqParams, PqStore, QuantCodes, QuantFitter,
    QuantParams, QuantStore, VectorStore, DEFAULT_BLOCK_BYTES,
};
use super::Dataset;

const DSB_MAGIC_V1: u32 = 0x4453_4231; // "DSB1"
const DSB_MAGIC_V2: u32 = 0x4453_4232; // "DSB2"
const DSB_MAGIC_Q1: u32 = 0x4453_5131; // "DSQ1"
const DSB_MAGIC_P1: u32 = 0x4453_5031; // "DSP1"

/// Training rows sampled when fitting PQ codebooks on a dataset's own
/// rows (k-means bounds its own seeding sample anyway; past this the
/// fit stops improving and the streaming passes stop being cheap).
pub(crate) const PQ_TRAIN_MAX_ROWS: usize = 16 * 1024;

/// Deterministic base seed of `gnnd quantize --pq-m` codebook fits.
pub const PQ_FIT_SEED: u64 = 0x5051_F17;

/// v2 header length in bytes (q1 shares it; its params sidecar starts
/// right after).
const DSB_V2_HEADER: u64 = 24;
/// v1 header length in bytes.
const DSB_V1_HEADER: u64 = 16;

fn metric_code(m: Metric) -> u32 {
    match m {
        Metric::L2 => 0,
        Metric::Ip => 1,
        Metric::Cosine => 2,
    }
}

fn metric_from_code(c: u32) -> crate::Result<Metric> {
    Ok(match c {
        0 => Metric::L2,
        1 => Metric::Ip,
        2 => Metric::Cosine,
        _ => bail!("bad metric code {c}"),
    })
}

fn read_u32(r: &mut impl Read) -> crate::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> crate::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Validate a parsed header against the real file length — the
/// difference between "truncated `x.dsb`: expected 4824 bytes (n=300
/// d=4), file has 4100" and a bare `read_exact` EOF three layers down.
pub(crate) fn check_file_len(
    path: &Path,
    actual: u64,
    expected: u64,
    detail: &str,
) -> crate::Result<()> {
    anyhow::ensure!(
        actual == expected,
        "truncated or corrupt {path:?}: header implies {expected} bytes ({detail}), \
         file has {actual}"
    );
    Ok(())
}

/// `header + rows * stride` in checked u64 arithmetic: the fields come
/// from an untrusted header, and the validation guarding against
/// corrupt files must not itself wrap (and then accidentally match the
/// file length) on crafted n/stride values.
pub(crate) fn expected_file_len(
    path: &Path,
    header: u64,
    rows: usize,
    stride: usize,
) -> crate::Result<u64> {
    (rows as u64)
        .checked_mul(stride as u64)
        .and_then(|payload| payload.checked_add(header))
        .with_context(|| {
            format!("corrupt {path:?}: header implies an impossibly large file (rows={rows} stride={stride})")
        })
}

/// Read the real file length plus up to `max_len` leading header bytes
/// (shorter files yield what exists; callers zero-pad via
/// [`header_word`]). Shared by the `.dsb` and `.knng` readers so the
/// probe/validation machinery cannot drift between the two mirrored
/// formats.
pub(crate) fn probe_header(
    file: &mut File,
    path: &Path,
    max_len: usize,
) -> crate::Result<(u64, Vec<u8>)> {
    let actual = file.metadata()?.len();
    let take = max_len.min(actual as usize);
    let mut head = vec![0u8; take];
    file.read_exact(&mut head)
        .with_context(|| format!("read header of {path:?}"))?;
    anyhow::ensure!(take >= 4, "file too short for a magic number: {path:?}");
    Ok((actual, head))
}

/// Little-endian u32 word `i` of a probed header (zero when the probe
/// was shorter than the requested word).
pub(crate) fn header_word(head: &[u8], i: usize) -> u32 {
    let mut b = [0u8; 4];
    let off = i * 4;
    if off + 4 <= head.len() {
        b.copy_from_slice(&head[off..off + 4]);
    }
    u32::from_le_bytes(b)
}

/// Serialize rows into reusable byte buffers and write them in bulk —
/// the shard-spill path of `ooc-build` writes every vector this way
/// (the old one-`f32`-at-a-time loop paid a `BufWriter` call per
/// component).
fn write_f32s_bulk(w: &mut impl Write, data: &[f32]) -> crate::Result<()> {
    const CHUNK_F32S: usize = 64 * 1024; // 256 KiB staging buffer
    let mut buf = Vec::with_capacity(CHUNK_F32S.min(data.len()) * 4);
    for chunk in data.chunks(CHUNK_F32S) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Write a dataset in `.dsb` v2 (fixed-stride; see the module spec).
pub fn write_dsb(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    let row_stride = (ds.d * 4) as u32;
    let block_rows = (DEFAULT_BLOCK_BYTES as u32 / row_stride).max(1);
    w.write_all(&DSB_MAGIC_V2.to_le_bytes())?;
    w.write_all(&(ds.d as u32).to_le_bytes())?;
    w.write_all(&(ds.len() as u32).to_le_bytes())?;
    w.write_all(&metric_code(ds.metric).to_le_bytes())?;
    w.write_all(&row_stride.to_le_bytes())?;
    w.write_all(&block_rows.to_le_bytes())?;
    write_f32s_bulk(&mut w, ds.raw())?;
    Ok(())
}

/// Write the legacy `.dsb` v1 layout. Kept for compatibility coverage
/// (old shard directories keep serving); new files should use
/// [`write_dsb`].
pub fn write_dsb_v1(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    w.write_all(&DSB_MAGIC_V1.to_le_bytes())?;
    w.write_all(&(ds.d as u32).to_le_bytes())?;
    w.write_all(&(ds.len() as u32).to_le_bytes())?;
    w.write_all(&metric_code(ds.metric).to_le_bytes())?;
    write_f32s_bulk(&mut w, ds.raw())?;
    Ok(())
}

/// Write a dataset as a scalar-quantized `.dsb` q1 file, encoding every
/// row with the given (already-fitted) `params`. A sharded store passes
/// the same union-fitted params for every shard so code-space distances
/// stay comparable across shards at gather time.
pub fn write_dsb_quantized_with(
    ds: &Dataset,
    params: &QuantParams,
    path: impl AsRef<Path>,
) -> crate::Result<()> {
    anyhow::ensure!(
        params.d() == ds.d,
        "quant params dimension {} != dataset dimension {}",
        params.d(),
        ds.d
    );
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    let block_rows = (DEFAULT_BLOCK_BYTES / ds.d).max(1) as u32;
    w.write_all(&DSB_MAGIC_Q1.to_le_bytes())?;
    w.write_all(&(ds.d as u32).to_le_bytes())?;
    w.write_all(&(ds.len() as u32).to_le_bytes())?;
    w.write_all(&metric_code(ds.metric).to_le_bytes())?;
    w.write_all(&(ds.d as u32).to_le_bytes())?; // row_stride = d: 1 byte/dim
    w.write_all(&block_rows.to_le_bytes())?;
    write_f32s_bulk(&mut w, &params.scale)?;
    write_f32s_bulk(&mut w, &params.offset)?;
    const STAGE_BYTES: usize = 256 * 1024;
    let mut codes = Vec::with_capacity(ds.d);
    let mut buf: Vec<u8> = Vec::with_capacity(STAGE_BYTES + ds.d);
    for i in 0..ds.len() {
        ds.with_vec(i, |row| params.encode_into(row, &mut codes));
        buf.extend_from_slice(&codes);
        if buf.len() >= STAGE_BYTES {
            w.write_all(&buf)?;
            buf.clear();
        }
    }
    w.write_all(&buf)?;
    Ok(())
}

/// Fit [`QuantParams`] on `ds`'s own rows and write it as a quantized
/// `.dsb` q1 — the single-file form of `gnnd quantize`.
pub fn write_dsb_quantized(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let mut fit = QuantFitter::new(ds.d);
    for i in 0..ds.len() {
        ds.with_vec(i, |row| fit.observe(row));
    }
    write_dsb_quantized_with(ds, &fit.finish(), path)
}

/// Fit [`PqParams`] on a stride-sample of `ds`'s rows (at most
/// [`PQ_TRAIN_MAX_ROWS`], deterministic per `seed`).
pub fn fit_pq_params(
    ds: &Dataset,
    m: usize,
    seed: u64,
    threads: usize,
) -> crate::Result<PqParams> {
    let n = ds.len();
    anyhow::ensure!(n > 0, "pq fit needs a non-empty dataset");
    let step = n.div_ceil(PQ_TRAIN_MAX_ROWS).max(1);
    let mut sample = Vec::with_capacity(n.div_ceil(step) * ds.d);
    let mut i = 0;
    while i < n {
        ds.with_vec(i, |row| sample.extend_from_slice(row));
        i += step;
    }
    PqParams::fit(&sample, ds.d, m, seed, threads)
}

/// Write a dataset as a product-quantized `.dsb` p1 file, encoding
/// every row with the given (already-fitted) `params`. A sharded store
/// passes the same corpus-fitted codebooks for every shard so one
/// per-query LUT scores candidates of every probed shard.
pub fn write_dsb_pq_with(ds: &Dataset, params: &PqParams, path: impl AsRef<Path>) -> crate::Result<()> {
    anyhow::ensure!(
        params.d() == ds.d,
        "pq params dimension {} != dataset dimension {}",
        params.d(),
        ds.d
    );
    let m = params.m();
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    let block_rows = (DEFAULT_BLOCK_BYTES / m).max(1) as u32;
    w.write_all(&DSB_MAGIC_P1.to_le_bytes())?;
    w.write_all(&(ds.d as u32).to_le_bytes())?;
    w.write_all(&(ds.len() as u32).to_le_bytes())?;
    w.write_all(&metric_code(ds.metric).to_le_bytes())?;
    w.write_all(&(m as u32).to_le_bytes())?; // row_stride = m: 1 byte/subspace
    w.write_all(&block_rows.to_le_bytes())?;
    let (ksub, centroids) = params.parts();
    for &k in ksub {
        w.write_all(&k.to_le_bytes())?;
    }
    write_f32s_bulk(&mut w, centroids)?;
    const STAGE_BYTES: usize = 256 * 1024;
    let mut codes = Vec::with_capacity(m);
    let mut buf: Vec<u8> = Vec::with_capacity(STAGE_BYTES + m);
    for i in 0..ds.len() {
        ds.with_vec(i, |row| params.encode_into(row, &mut codes));
        buf.extend_from_slice(&codes);
        if buf.len() >= STAGE_BYTES {
            w.write_all(&buf)?;
            buf.clear();
        }
    }
    w.write_all(&buf)?;
    Ok(())
}

/// Fit PQ codebooks on `ds`'s own rows and write it as a `.dsb` p1 —
/// the single-file form of `gnnd quantize --pq-m M`.
pub fn write_dsb_pq(ds: &Dataset, m: usize, path: impl AsRef<Path>) -> crate::Result<()> {
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let params = fit_pq_params(ds, m, PQ_FIT_SEED, threads)?;
    write_dsb_pq_with(ds, &params, path)
}

/// Parsed `.dsb` header (any version; `version` is 1, 2, 3 for q1, or
/// 4 for p1), with the file length already validated against it. For
/// p1, `row_stride` doubles as the subquantizer count m.
struct DsbHeader {
    version: u32,
    d: usize,
    n: usize,
    metric: Metric,
    data_off: u64,
    row_stride: usize,
}

fn read_dsb_header(file: &mut File, path: &Path) -> crate::Result<DsbHeader> {
    let (actual, head) = probe_header(file, path, DSB_V2_HEADER as usize)?;
    let word = |i: usize| header_word(&head, i);
    match word(0) {
        DSB_MAGIC_V1 => {
            anyhow::ensure!(
                head.len() as u64 >= DSB_V1_HEADER,
                "truncated .dsb v1 header: {path:?}"
            );
            let (d, n) = (word(1) as usize, word(2) as usize);
            let metric = metric_from_code(word(3))?;
            anyhow::ensure!(d > 0, "{path:?}: zero dimension");
            let row_stride = d * 4;
            check_file_len(
                path,
                actual,
                expected_file_len(path, DSB_V1_HEADER, n, row_stride)?,
                &format!("v1, n={n} d={d}"),
            )?;
            Ok(DsbHeader { version: 1, d, n, metric, data_off: DSB_V1_HEADER, row_stride })
        }
        DSB_MAGIC_V2 => {
            anyhow::ensure!(
                head.len() as u64 >= DSB_V2_HEADER,
                "truncated .dsb v2 header: {path:?}"
            );
            let (d, n) = (word(1) as usize, word(2) as usize);
            let metric = metric_from_code(word(3))?;
            let row_stride = word(4) as usize;
            anyhow::ensure!(d > 0, "{path:?}: zero dimension");
            anyhow::ensure!(
                row_stride == d * 4,
                "{path:?}: row stride {row_stride} != 4*d ({}) — unsupported layout",
                d * 4
            );
            check_file_len(
                path,
                actual,
                expected_file_len(path, DSB_V2_HEADER, n, row_stride)?,
                &format!("v2, n={n} d={d} stride={row_stride}"),
            )?;
            Ok(DsbHeader { version: 2, d, n, metric, data_off: DSB_V2_HEADER, row_stride })
        }
        DSB_MAGIC_Q1 => {
            anyhow::ensure!(
                head.len() as u64 >= DSB_V2_HEADER,
                "truncated .dsb q1 header: {path:?}"
            );
            let (d, n) = (word(1) as usize, word(2) as usize);
            let metric = metric_from_code(word(3))?;
            let row_stride = word(4) as usize;
            anyhow::ensure!(d > 0, "{path:?}: zero dimension");
            anyhow::ensure!(
                row_stride == d,
                "{path:?}: quantized row stride {row_stride} != d ({d}) — unsupported layout"
            );
            // params sidecar (2*d f32) sits between header and data
            let data_off = DSB_V2_HEADER + 8 * d as u64;
            check_file_len(
                path,
                actual,
                expected_file_len(path, data_off, n, row_stride)?,
                &format!("q1, n={n} d={d}"),
            )?;
            Ok(DsbHeader { version: 3, d, n, metric, data_off, row_stride })
        }
        DSB_MAGIC_P1 => {
            anyhow::ensure!(
                head.len() as u64 >= DSB_V2_HEADER,
                "truncated .dsb p1 header: {path:?}"
            );
            let (d, n) = (word(1) as usize, word(2) as usize);
            let metric = metric_from_code(word(3))?;
            let m = word(4) as usize; // row_stride = m
            anyhow::ensure!(d > 0, "{path:?}: zero dimension");
            anyhow::ensure!(
                m >= 1 && m <= d,
                "{path:?}: pq row stride {m} outside 1..=d ({d}) — unsupported layout"
            );
            // ksub words + codebooks sit between header and data
            let data_off =
                DSB_V2_HEADER + 4 * m as u64 + 4 * (crate::distance::PQ_KSUB * d) as u64;
            check_file_len(
                path,
                actual,
                expected_file_len(path, data_off, n, m)?,
                &format!("p1, n={n} d={d} m={m}"),
            )?;
            Ok(DsbHeader { version: 4, d, n, metric, data_off, row_stride: m })
        }
        _ => bail!("not a .dsb file: {path:?}"),
    }
}

/// Read the q1 params sidecar (leaves the cursor at the start of the
/// code rows).
fn read_quant_params(file: &mut File, path: &Path, d: usize) -> crate::Result<QuantParams> {
    file.seek(SeekFrom::Start(DSB_V2_HEADER))?;
    let scale = read_f32s(file, d).with_context(|| format!("read quant scales of {path:?}"))?;
    let offset = read_f32s(file, d).with_context(|| format!("read quant offsets of {path:?}"))?;
    Ok(QuantParams { scale, offset })
}

/// Read the p1 codebook sidecar (leaves the cursor at the start of the
/// code rows). `m` comes from the header's row stride.
fn read_pq_params(file: &mut File, path: &Path, d: usize, m: usize) -> crate::Result<PqParams> {
    file.seek(SeekFrom::Start(DSB_V2_HEADER))?;
    let mut ksub = Vec::with_capacity(m);
    for _ in 0..m {
        ksub.push(read_u32(file).with_context(|| format!("read pq ksub of {path:?}"))?);
    }
    // no BufReader: its readahead would leave the File cursor past the
    // codebooks, and the owned-codes path reads from the cursor next
    let centroids = read_f32s(file, crate::distance::PQ_KSUB * d)
        .with_context(|| format!("read pq codebooks of {path:?}"))?;
    PqParams::from_parts(d, m, ksub, centroids)
}

fn dsb_name(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dsb".into())
}

/// Read a `.dsb` dataset (any version) fully into memory: f32 rows
/// owned for v1/v2, u8 codes owned (a `Quantized` backing with no
/// exact sidecar) for q1.
pub fn read_dsb(path: impl AsRef<Path>) -> crate::Result<Dataset> {
    let path = path.as_ref();
    let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let h = read_dsb_header(&mut file, path)?;
    if h.version == 3 {
        return finish_q1(file, h, path, None, None);
    }
    if h.version == 4 {
        return finish_pq(file, h, path, None, None);
    }
    // the header probe may have read past a short (v1) header
    file.seek(SeekFrom::Start(h.data_off))?;
    let mut r = BufReader::new(file);
    let data = read_f32s(&mut r, h.n * h.d)?;
    // bypass Dataset::new to avoid re-normalizing cosine data
    Ok(Dataset {
        name: dsb_name(path),
        d: h.d,
        metric: h.metric,
        data: VectorStore::Owned(data),
    })
}

/// Assemble the `Quantized` dataset from an opened q1 file: params
/// sidecar, then codes either paged through `cache` or read owned, and
/// an optional exact-rows attachment.
fn finish_q1(
    mut file: File,
    h: DsbHeader,
    path: &Path,
    cache: Option<&Arc<BlockCache>>,
    exact: Option<ExactRows>,
) -> crate::Result<Dataset> {
    let params = Arc::new(read_quant_params(&mut file, path, h.d)?);
    let codes = match cache {
        Some(cache) => QuantCodes::Paged(PagedRows::new(
            file,
            path.to_path_buf(),
            h.data_off,
            h.n,
            h.row_stride,
            h.d,
            cache,
            store::decode_u8_block,
        )),
        None => {
            // read_quant_params left the cursor at the code rows
            let mut v = vec![0u8; h.n * h.d];
            file.read_exact(&mut v)
                .with_context(|| format!("read quantized rows of {path:?}"))?;
            QuantCodes::Owned(v)
        }
    };
    // every open of a quantized store is (4-1) bytes/dim of row payload
    // the f32 form would have cost
    crate::telemetry::global()
        .counter("quant.bytes_saved")
        .add(3 * (h.n as u64) * (h.d as u64));
    Ok(Dataset {
        name: dsb_name(path),
        d: h.d,
        metric: h.metric,
        data: VectorStore::Quantized(Box::new(QuantStore { d: h.d, params, codes, exact })),
    })
}

/// Assemble the `Pq` dataset from an opened p1 file: codebook sidecar,
/// then m-byte code rows either paged through `cache` or read owned,
/// and an optional exact-rows attachment.
fn finish_pq(
    mut file: File,
    h: DsbHeader,
    path: &Path,
    cache: Option<&Arc<BlockCache>>,
    exact: Option<ExactRows>,
) -> crate::Result<Dataset> {
    let m = h.row_stride;
    let params = Arc::new(read_pq_params(&mut file, path, h.d, m)?);
    let codes = match cache {
        Some(cache) => QuantCodes::Paged(PagedRows::new(
            file,
            path.to_path_buf(),
            h.data_off,
            h.n,
            m,
            m,
            cache,
            store::decode_u8_block,
        )),
        None => {
            // read_pq_params left the cursor at the code rows
            let mut v = vec![0u8; h.n * m];
            file.read_exact(&mut v)
                .with_context(|| format!("read pq rows of {path:?}"))?;
            QuantCodes::Owned(v)
        }
    };
    // every open of a PQ store is (4d - m) bytes/row of payload the f32
    // form would have cost
    crate::telemetry::global()
        .counter("pq.bytes_saved")
        .add((h.n as u64) * (4 * h.d as u64 - m as u64));
    Ok(Dataset {
        name: dsb_name(path),
        d: h.d,
        metric: h.metric,
        data: VectorStore::Pq(Box::new(PqStore { d: h.d, params, codes, exact })),
    })
}

/// Open a product-quantized p1 `.dsb` for serving — the PQ mirror of
/// [`read_dsb_quantized`]: codes paged through `cache` (`paged = true`)
/// or fully owned, with `exact_path` optionally attaching the original
/// full-precision v2 file as a *paged* rerank sidecar.
pub fn read_dsb_pq(
    pq_path: impl AsRef<Path>,
    exact_path: Option<&Path>,
    cache: &Arc<BlockCache>,
    paged: bool,
) -> crate::Result<Dataset> {
    let path = pq_path.as_ref();
    let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let h = read_dsb_header(&mut file, path)?;
    anyhow::ensure!(h.version == 4, "not a product-quantized .dsb (expected p1 magic): {path:?}");
    let exact = match exact_path {
        Some(ep) => attach_exact(ep, &h, cache)?,
        None => None,
    };
    finish_pq(file, h, path, paged.then_some(cache), exact)
}

/// Open a quantized q1 `.dsb` for serving: codes paged through `cache`
/// (`paged = true`, the block-residency path — 4x the rows per byte of
/// budget vs. f32) or fully owned (`paged = false`, shard residency),
/// with `exact_path` optionally attaching the original full-precision
/// v2 file as a *paged* sidecar for the exact rerank phase (rows fault
/// in through the same cache, so rerank reads only the rows it
/// scores). A v1 exact file has no pageable layout — it is skipped
/// with a warning and rerank falls back to dequantized codes.
pub fn read_dsb_quantized(
    quant_path: impl AsRef<Path>,
    exact_path: Option<&Path>,
    cache: &Arc<BlockCache>,
    paged: bool,
) -> crate::Result<Dataset> {
    let path = quant_path.as_ref();
    let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let h = read_dsb_header(&mut file, path)?;
    anyhow::ensure!(h.version == 3, "not a quantized .dsb (expected q1 magic): {path:?}");
    let exact = match exact_path {
        Some(ep) => attach_exact(ep, &h, cache)?,
        None => None,
    };
    finish_q1(file, h, path, paged.then_some(cache), exact)
}

/// Open the full-precision sidecar of a quantized store as paged rows.
fn attach_exact(
    path: &Path,
    qh: &DsbHeader,
    cache: &Arc<BlockCache>,
) -> crate::Result<Option<ExactRows>> {
    let mut file = File::open(path).with_context(|| format!("open exact rows {path:?}"))?;
    let h = read_dsb_header(&mut file, path)?;
    anyhow::ensure!(
        h.d == qh.d && h.n == qh.n,
        "exact rows {path:?} (n={} d={}) do not match the quantized store (n={} d={})",
        h.n,
        h.d,
        qh.n,
        qh.d
    );
    if h.version != 2 {
        crate::telemetry::warn!(
            "quantized store: exact rows {path:?} are not .dsb v2 (pageable); \
             rerank will use dequantized codes"
        );
        return Ok(None);
    }
    Ok(Some(ExactRows::Paged(PagedRows::new(
        file,
        path.to_path_buf(),
        h.data_off,
        h.n,
        h.row_stride,
        h.d,
        cache,
        store::decode_f32_block,
    ))))
}

/// Open a `.dsb` for *paged* row access through `cache`: rows are
/// fetched in row-aligned blocks on demand, nothing is read eagerly
/// beyond the header. v1 files have no pageable guarantee recorded, so
/// they fall back to the fully-resident owned path (documented compat
/// behavior — old shard directories keep serving under
/// `--residency block`, just without partial reads).
pub fn read_dsb_paged(path: impl AsRef<Path>, cache: &Arc<BlockCache>) -> crate::Result<Dataset> {
    let path = path.as_ref();
    let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let h = read_dsb_header(&mut file, path)?;
    if h.version == 1 {
        return read_dsb(path);
    }
    if h.version == 3 {
        return finish_q1(file, h, path, Some(cache), None);
    }
    if h.version == 4 {
        return finish_pq(file, h, path, Some(cache), None);
    }
    let rows = PagedRows::new(
        file,
        path.to_path_buf(),
        h.data_off,
        h.n,
        h.row_stride,
        h.d,
        cache,
        store::decode_f32_block,
    );
    Ok(Dataset {
        name: dsb_name(path),
        d: h.d,
        metric: h.metric,
        data: VectorStore::Paged(rows),
    })
}

/// Read a TEXMEX `.fvecs` file (each row: i32 dim then dim f32).
pub fn read_fvecs(path: impl AsRef<Path>, metric: Metric, limit: Option<usize>) -> crate::Result<Dataset> {
    let mut r = BufReader::new(File::open(path.as_ref())?);
    let mut data = Vec::new();
    let mut d = 0usize;
    let mut n = 0usize;
    loop {
        let dim = match read_u32(&mut r) {
            Ok(v) => v as usize,
            Err(_) => break, // EOF
        };
        if d == 0 {
            d = dim;
        } else if dim != d {
            bail!("inconsistent fvecs dims: {d} vs {dim}");
        }
        data.extend(read_f32s(&mut r, d)?);
        n += 1;
        if let Some(l) = limit {
            if n >= l {
                break;
            }
        }
    }
    if n == 0 {
        bail!("empty fvecs file {:?}", path.as_ref());
    }
    Ok(Dataset::new(
        path.as_ref().file_stem().unwrap().to_string_lossy(),
        d,
        metric,
        data,
    ))
}

/// Write `.ivecs` rows (ground truth neighbor id lists).
pub fn write_ivecs(rows: &[Vec<u32>], path: impl AsRef<Path>) -> crate::Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    for row in rows {
        w.write_all(&(row.len() as u32).to_le_bytes())?;
        for &x in row {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read `.ivecs` rows.
pub fn read_ivecs(path: impl AsRef<Path>) -> crate::Result<Vec<Vec<u32>>> {
    let mut r = BufReader::new(File::open(path.as_ref())?);
    let mut rows = Vec::new();
    loop {
        let len = match read_u32(&mut r) {
            Ok(v) => v as usize,
            Err(_) => break,
        };
        let mut row = Vec::with_capacity(len);
        for _ in 0..len {
            row.push(read_u32(&mut r)?);
        }
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::util::prop;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "gnnd-io-test-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn dsb_roundtrip() {
        let dir = tmpdir();
        let ds = synth::clustered(37, 9, 1);
        let p = dir.join("x.dsb");
        write_dsb(&ds, &p).unwrap();
        let back = read_dsb(&p).unwrap();
        assert_eq!(back.d, ds.d);
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.metric, ds.metric);
        assert_eq!(back.raw(), ds.raw());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_v1_still_reads() {
        let dir = tmpdir();
        let ds = synth::clustered(23, 5, 3);
        let p = dir.join("legacy.dsb");
        write_dsb_v1(&ds, &p).unwrap();
        let back = read_dsb(&p).unwrap();
        assert_eq!(back.raw(), ds.raw());
        assert_eq!((back.d, back.metric), (ds.d, ds.metric));
        // the paged open falls back to the owned path on v1
        let cache = BlockCache::new(0, 256);
        let paged = read_dsb_paged(&p, &cache).unwrap();
        assert!(!paged.is_paged());
        assert_eq!(paged.raw(), ds.raw());
        assert_eq!(cache.stats().fetches, 0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_format_roundtrip_property() {
        // random (n, d, metric, version) grids round-trip bit-exactly
        let dir = tmpdir();
        let p = dir.join("prop.dsb");
        prop::check("dsb-roundtrip", 25, |rng| {
            let n = 1 + rng.below(60);
            let d = 1 + rng.below(17);
            let metric = match rng.below(3) {
                0 => Metric::L2,
                1 => Metric::Ip,
                _ => Metric::Cosine,
            };
            let data: Vec<f32> = (0..n * d).map(|_| rng.f32() * 8.0 - 4.0).collect();
            let ds = Dataset::new("prop", d, metric, data);
            if rng.below(2) == 0 {
                write_dsb(&ds, &p).map_err(|e| e.to_string())?;
            } else {
                write_dsb_v1(&ds, &p).map_err(|e| e.to_string())?;
            }
            let back = read_dsb(&p).map_err(|e| e.to_string())?;
            prop::assert_prop(back.raw() == ds.raw(), "data mismatch")?;
            prop::assert_prop(
                (back.d, back.len(), back.metric) == (ds.d, ds.len(), ds.metric),
                "geometry mismatch",
            )
        });
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_paged_matches_owned_rows() {
        let dir = tmpdir();
        // d=7 (28B stride) with 64B blocks -> 2 rows/block, short tail
        let ds = synth::uniform(11, 7, 9);
        let p = dir.join("paged.dsb");
        write_dsb(&ds, &p).unwrap();
        let cache = BlockCache::new(0, 64);
        let paged = read_dsb_paged(&p, &cache).unwrap();
        assert!(paged.is_paged());
        assert_eq!(paged.len(), ds.len());
        assert_eq!(paged.d, ds.d);
        for i in 0..ds.len() {
            assert_eq!(paged.vector(i), ds.vec(i), "row {i}");
            assert_eq!(paged.dist_to(i, ds.vec(0)), ds.dist_to(i, ds.vec(0)));
        }
        assert!(cache.stats().fetches > 1, "multiple blocks must have paged in");
        // materialize round-trips the full matrix
        assert_eq!(paged.materialize().raw(), ds.raw());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_cosine_paged_no_double_normalize() {
        let dir = tmpdir();
        let ds = synth::glove_like(20, 2);
        let p = dir.join("g.dsb");
        write_dsb(&ds, &p).unwrap();
        let back = read_dsb(&p).unwrap();
        assert_eq!(back.raw(), ds.raw());
        let cache = BlockCache::new(0, 128);
        let paged = read_dsb_paged(&p, &cache).unwrap();
        for i in 0..ds.len() {
            assert_eq!(paged.vector(i), ds.vec(i));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_dsb_reports_sizes() {
        let dir = tmpdir();
        let ds = synth::uniform(30, 4, 5);
        for v2 in [true, false] {
            let name = if v2 { "t2.dsb" } else { "t1.dsb" };
            let p = dir.join(name);
            if v2 {
                write_dsb(&ds, &p).unwrap();
            } else {
                write_dsb_v1(&ds, &p).unwrap();
            }
            let full = std::fs::read(&p).unwrap();
            std::fs::write(&p, &full[..full.len() - 7]).unwrap();
            let err = format!("{:#}", read_dsb(&p).unwrap_err());
            assert!(
                err.contains("truncated") && err.contains(name) && err.contains("bytes"),
                "unhelpful truncation error: {err}"
            );
            let cache = BlockCache::new(0, 128);
            assert!(read_dsb_paged(&p, &cache).is_err(), "paged open must validate too");
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quantized_dsb_roundtrip_owned_and_paged() {
        let dir = tmpdir();
        let ds = synth::clustered(60, 9, 4);
        let p = dir.join("q.dsb");
        write_dsb_quantized(&ds, &p).unwrap();
        // auto-detect: read_dsb yields a quantized backing
        let q = read_dsb(&p).unwrap();
        assert!(q.is_quantized());
        assert_eq!((q.len(), q.d, q.metric), (ds.len(), ds.d, ds.metric));
        // dequantized rows stay within half a quantization step of the
        // originals (step = per-dim range / 255)
        let mut lo = vec![f32::INFINITY; ds.d];
        let mut hi = vec![f32::NEG_INFINITY; ds.d];
        for i in 0..ds.len() {
            for (j, &x) in ds.vec(i).iter().enumerate() {
                lo[j] = lo[j].min(x);
                hi[j] = hi[j].max(x);
            }
        }
        for i in 0..ds.len() {
            let back = q.vector(i);
            for j in 0..ds.d {
                let bound = (hi[j] - lo[j]) / 255.0 / 2.0 + 1e-4 * ds.vec(i)[j].abs().max(1.0);
                assert!(
                    (back[j] - ds.vec(i)[j]).abs() <= bound,
                    "row {i} dim {j}: {} vs {}",
                    back[j],
                    ds.vec(i)[j]
                );
            }
        }
        // paged codes serve the same dequantized rows bit-identically
        let cache = BlockCache::new(0, 64);
        let paged = read_dsb_paged(&p, &cache).unwrap();
        assert!(paged.is_quantized());
        for i in 0..ds.len() {
            assert_eq!(paged.vector(i), q.vector(i), "row {i}");
        }
        assert!(cache.stats().fetches > 1, "u8 blocks must have paged in");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quantized_exact_sidecar_serves_f32_rerank_rows() {
        let dir = tmpdir();
        let ds = synth::uniform(33, 7, 2);
        let f = dir.join("f.dsb");
        let qp = dir.join("q.dsb");
        write_dsb(&ds, &f).unwrap();
        write_dsb_quantized(&ds, &qp).unwrap();
        let cache = BlockCache::new(0, 256);
        let q = read_dsb_quantized(&qp, Some(&f), &cache, true).unwrap();
        let mut buf = Vec::new();
        for i in 0..ds.len() {
            // rerank matches the f32 kernel bit-exactly via the sidecar
            let want = ds.dist_to(i, ds.vec(0));
            assert_eq!(q.rerank_dist_to(i, ds.vec(0), &mut buf), want, "row {i}");
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quantized_exact_sidecar_mismatch_errors_and_v1_falls_back() {
        let dir = tmpdir();
        let ds = synth::uniform(20, 4, 7);
        let qp = dir.join("q.dsb");
        write_dsb_quantized(&ds, &qp).unwrap();
        let cache = BlockCache::new(0, 256);
        // geometry mismatch is an error, not silent wrong answers
        let other = synth::uniform(10, 4, 7);
        let bad = dir.join("bad.dsb");
        write_dsb(&other, &bad).unwrap();
        assert!(read_dsb_quantized(&qp, Some(&bad), &cache, false).is_err());
        // a v1 sidecar is skipped (not pageable): rerank still answers,
        // from dequantized codes
        let v1 = dir.join("v1.dsb");
        write_dsb_v1(&ds, &v1).unwrap();
        let q = read_dsb_quantized(&qp, Some(&v1), &cache, false).unwrap();
        let mut buf = Vec::new();
        assert!(q.rerank_dist_to(1, ds.vec(0), &mut buf).is_finite());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_quantized_dsb_reports_sizes() {
        let dir = tmpdir();
        let ds = synth::uniform(30, 4, 5);
        let p = dir.join("tq.dsb");
        write_dsb_quantized(&ds, &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 7]).unwrap();
        let err = format!("{:#}", read_dsb(&p).unwrap_err());
        assert!(
            err.contains("truncated") && err.contains("tq.dsb") && err.contains("bytes"),
            "unhelpful truncation error: {err}"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn pq_dsb_roundtrip_owned_and_paged() {
        let dir = tmpdir();
        let ds = synth::clustered(300, 12, 4);
        let p = dir.join("pq.dsb");
        write_dsb_pq(&ds, 4, &p).unwrap();
        // auto-detect: read_dsb yields a PQ backing, 4x smaller rows
        let q = read_dsb(&p).unwrap();
        assert!(q.is_pq());
        assert_eq!((q.len(), q.d, q.metric), (ds.len(), ds.d, ds.metric));
        // paged codes serve the same reconstructed rows bit-identically
        let cache = BlockCache::new(0, 64);
        let paged = read_dsb_paged(&p, &cache).unwrap();
        assert!(paged.is_pq());
        for i in 0..ds.len() {
            assert_eq!(paged.vector(i), q.vector(i), "row {i}");
        }
        assert!(cache.stats().fetches > 1, "pq blocks must have paged in");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn pq_exact_sidecar_serves_f32_rerank_rows() {
        let dir = tmpdir();
        let ds = synth::uniform(64, 10, 2);
        let f = dir.join("f.dsb");
        let pp = dir.join("pq.dsb");
        write_dsb(&ds, &f).unwrap();
        write_dsb_pq(&ds, 5, &pp).unwrap();
        let cache = BlockCache::new(0, 256);
        let q = read_dsb_pq(&pp, Some(&f), &cache, true).unwrap();
        let mut buf = Vec::new();
        for i in 0..ds.len() {
            // rerank matches the f32 kernel bit-exactly via the sidecar
            let want = ds.dist_to(i, ds.vec(0));
            assert_eq!(q.rerank_dist_to(i, ds.vec(0), &mut buf), want, "row {i}");
        }
        // geometry mismatch is an error, not silent wrong answers
        let other = synth::uniform(10, 10, 7);
        let bad = dir.join("bad.dsb");
        write_dsb(&other, &bad).unwrap();
        assert!(read_dsb_pq(&pp, Some(&bad), &cache, false).is_err());
        // a v2 open is not a p1 open
        assert!(read_dsb_pq(&f, None, &cache, false).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_pq_dsb_reports_sizes() {
        let dir = tmpdir();
        let ds = synth::uniform(30, 6, 5);
        let p = dir.join("tp.dsb");
        write_dsb_pq(&ds, 3, &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() - 7]).unwrap();
        let err = format!("{:#}", read_dsb(&p).unwrap_err());
        assert!(
            err.contains("truncated") && err.contains("tp.dsb") && err.contains("bytes"),
            "unhelpful truncation error: {err}"
        );
        let cache = BlockCache::new(0, 128);
        assert!(read_dsb_paged(&p, &cache).is_err(), "paged open must validate too");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let dir = tmpdir();
        let rows = vec![vec![1u32, 2, 3], vec![], vec![9]];
        let p = dir.join("gt.ivecs");
        write_ivecs(&rows, &p).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fvecs_roundtrip_via_manual_write() {
        let dir = tmpdir();
        let p = dir.join("v.fvecs");
        {
            let mut w = BufWriter::new(File::create(&p).unwrap());
            for row in [[1.0f32, 2.0], [3.0, 4.0], [5.0, 6.0]] {
                w.write_all(&2u32.to_le_bytes()).unwrap();
                for x in row {
                    w.write_all(&x.to_le_bytes()).unwrap();
                }
            }
        }
        let ds = read_fvecs(&p, Metric::L2, None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.vec(2), &[5.0, 6.0]);
        let ds2 = read_fvecs(&p, Metric::L2, Some(2)).unwrap();
        assert_eq!(ds2.len(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmpdir();
        let p = dir.join("bad.dsb");
        std::fs::write(&p, b"notadsbfile").unwrap();
        assert!(read_dsb(&p).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
