//! Dataset and graph file I/O.
//!
//! * `.fvecs` / `.ivecs` — the TEXMEX interchange formats used by the
//!   paper's benchmarks (SIFT1M etc.), so real corpora drop in when
//!   available.
//! * `.dsb` — our own raw binary dataset format (spec below), used by
//!   the out-of-core shard store because it supports metric metadata,
//!   fast bulk reads, and (v2) random row access for paged serving.
//!
//! # `.dsb` format spec
//!
//! All integers little-endian u32; all vector components little-endian
//! f32.
//!
//! **v2** (written by [`write_dsb`]) — fixed-stride, pageable:
//!
//! ```text
//! offset  field
//!      0  magic        0x4453_4232 ("DSB2")
//!      4  d            vector dimensionality
//!      8  n            number of rows
//!     12  metric       0 = l2, 1 = ip, 2 = cosine (rows pre-normalized)
//!     16  row_stride   bytes per row, = 4*d (recorded so row offsets
//!                      are computable without knowledge of the codec)
//!     20  block_rows   writer's block-size hint (readers may page at
//!                      any row-aligned block size; this records the
//!                      default-`DEFAULT_BLOCK_BYTES` granularity the
//!                      file was written for)
//!     24  data         n rows x row_stride bytes, row i at
//!                      24 + i*row_stride
//! ```
//!
//! Because the stride is fixed and recorded, any row's byte offset is
//! computable without scanning — the property the paged
//! ([`read_dsb_paged`]) serving path relies on.
//!
//! **v1** (legacy; still read, written only by [`write_dsb_v1`]):
//! magic 0x4453_4231 ("DSB1"), d, n, metric, then n*d f32. v1 files
//! always load fully resident (the owned path), including under
//! block-residency serving.
//!
//! Both readers validate the header against the actual file length on
//! open, so truncated or corrupt files fail with the path and expected
//! vs. actual sizes instead of a `read_exact` EOF mid-load.
//!
//! The `.knng` graph format mirrors this scheme (KNG1/KNG2); see
//! [`crate::graph::KnnGraph::save`].

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context};

use crate::config::Metric;

use super::store::{self, BlockCache, PagedRows, VectorStore, DEFAULT_BLOCK_BYTES};
use super::Dataset;

const DSB_MAGIC_V1: u32 = 0x4453_4231; // "DSB1"
const DSB_MAGIC_V2: u32 = 0x4453_4232; // "DSB2"

/// v2 header length in bytes.
const DSB_V2_HEADER: u64 = 24;
/// v1 header length in bytes.
const DSB_V1_HEADER: u64 = 16;

fn metric_code(m: Metric) -> u32 {
    match m {
        Metric::L2 => 0,
        Metric::Ip => 1,
        Metric::Cosine => 2,
    }
}

fn metric_from_code(c: u32) -> crate::Result<Metric> {
    Ok(match c {
        0 => Metric::L2,
        1 => Metric::Ip,
        2 => Metric::Cosine,
        _ => bail!("bad metric code {c}"),
    })
}

fn read_u32(r: &mut impl Read) -> crate::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> crate::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Validate a parsed header against the real file length — the
/// difference between "truncated `x.dsb`: expected 4824 bytes (n=300
/// d=4), file has 4100" and a bare `read_exact` EOF three layers down.
pub(crate) fn check_file_len(
    path: &Path,
    actual: u64,
    expected: u64,
    detail: &str,
) -> crate::Result<()> {
    anyhow::ensure!(
        actual == expected,
        "truncated or corrupt {path:?}: header implies {expected} bytes ({detail}), \
         file has {actual}"
    );
    Ok(())
}

/// `header + rows * stride` in checked u64 arithmetic: the fields come
/// from an untrusted header, and the validation guarding against
/// corrupt files must not itself wrap (and then accidentally match the
/// file length) on crafted n/stride values.
pub(crate) fn expected_file_len(
    path: &Path,
    header: u64,
    rows: usize,
    stride: usize,
) -> crate::Result<u64> {
    (rows as u64)
        .checked_mul(stride as u64)
        .and_then(|payload| payload.checked_add(header))
        .with_context(|| {
            format!("corrupt {path:?}: header implies an impossibly large file (rows={rows} stride={stride})")
        })
}

/// Read the real file length plus up to `max_len` leading header bytes
/// (shorter files yield what exists; callers zero-pad via
/// [`header_word`]). Shared by the `.dsb` and `.knng` readers so the
/// probe/validation machinery cannot drift between the two mirrored
/// formats.
pub(crate) fn probe_header(
    file: &mut File,
    path: &Path,
    max_len: usize,
) -> crate::Result<(u64, Vec<u8>)> {
    let actual = file.metadata()?.len();
    let take = max_len.min(actual as usize);
    let mut head = vec![0u8; take];
    file.read_exact(&mut head)
        .with_context(|| format!("read header of {path:?}"))?;
    anyhow::ensure!(take >= 4, "file too short for a magic number: {path:?}");
    Ok((actual, head))
}

/// Little-endian u32 word `i` of a probed header (zero when the probe
/// was shorter than the requested word).
pub(crate) fn header_word(head: &[u8], i: usize) -> u32 {
    let mut b = [0u8; 4];
    let off = i * 4;
    if off + 4 <= head.len() {
        b.copy_from_slice(&head[off..off + 4]);
    }
    u32::from_le_bytes(b)
}

/// Serialize rows into reusable byte buffers and write them in bulk —
/// the shard-spill path of `ooc-build` writes every vector this way
/// (the old one-`f32`-at-a-time loop paid a `BufWriter` call per
/// component).
fn write_f32s_bulk(w: &mut impl Write, data: &[f32]) -> crate::Result<()> {
    const CHUNK_F32S: usize = 64 * 1024; // 256 KiB staging buffer
    let mut buf = Vec::with_capacity(CHUNK_F32S.min(data.len()) * 4);
    for chunk in data.chunks(CHUNK_F32S) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Write a dataset in `.dsb` v2 (fixed-stride; see the module spec).
pub fn write_dsb(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    let row_stride = (ds.d * 4) as u32;
    let block_rows = (DEFAULT_BLOCK_BYTES as u32 / row_stride).max(1);
    w.write_all(&DSB_MAGIC_V2.to_le_bytes())?;
    w.write_all(&(ds.d as u32).to_le_bytes())?;
    w.write_all(&(ds.len() as u32).to_le_bytes())?;
    w.write_all(&metric_code(ds.metric).to_le_bytes())?;
    w.write_all(&row_stride.to_le_bytes())?;
    w.write_all(&block_rows.to_le_bytes())?;
    write_f32s_bulk(&mut w, ds.raw())?;
    Ok(())
}

/// Write the legacy `.dsb` v1 layout. Kept for compatibility coverage
/// (old shard directories keep serving); new files should use
/// [`write_dsb`].
pub fn write_dsb_v1(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    w.write_all(&DSB_MAGIC_V1.to_le_bytes())?;
    w.write_all(&(ds.d as u32).to_le_bytes())?;
    w.write_all(&(ds.len() as u32).to_le_bytes())?;
    w.write_all(&metric_code(ds.metric).to_le_bytes())?;
    write_f32s_bulk(&mut w, ds.raw())?;
    Ok(())
}

/// Parsed `.dsb` header (either version), with the file length already
/// validated against it.
struct DsbHeader {
    version: u32,
    d: usize,
    n: usize,
    metric: Metric,
    data_off: u64,
    row_stride: usize,
}

fn read_dsb_header(file: &mut File, path: &Path) -> crate::Result<DsbHeader> {
    let (actual, head) = probe_header(file, path, DSB_V2_HEADER as usize)?;
    let word = |i: usize| header_word(&head, i);
    match word(0) {
        DSB_MAGIC_V1 => {
            anyhow::ensure!(
                head.len() as u64 >= DSB_V1_HEADER,
                "truncated .dsb v1 header: {path:?}"
            );
            let (d, n) = (word(1) as usize, word(2) as usize);
            let metric = metric_from_code(word(3))?;
            anyhow::ensure!(d > 0, "{path:?}: zero dimension");
            let row_stride = d * 4;
            check_file_len(
                path,
                actual,
                expected_file_len(path, DSB_V1_HEADER, n, row_stride)?,
                &format!("v1, n={n} d={d}"),
            )?;
            Ok(DsbHeader { version: 1, d, n, metric, data_off: DSB_V1_HEADER, row_stride })
        }
        DSB_MAGIC_V2 => {
            anyhow::ensure!(
                head.len() as u64 >= DSB_V2_HEADER,
                "truncated .dsb v2 header: {path:?}"
            );
            let (d, n) = (word(1) as usize, word(2) as usize);
            let metric = metric_from_code(word(3))?;
            let row_stride = word(4) as usize;
            anyhow::ensure!(d > 0, "{path:?}: zero dimension");
            anyhow::ensure!(
                row_stride == d * 4,
                "{path:?}: row stride {row_stride} != 4*d ({}) — unsupported layout",
                d * 4
            );
            check_file_len(
                path,
                actual,
                expected_file_len(path, DSB_V2_HEADER, n, row_stride)?,
                &format!("v2, n={n} d={d} stride={row_stride}"),
            )?;
            Ok(DsbHeader { version: 2, d, n, metric, data_off: DSB_V2_HEADER, row_stride })
        }
        _ => bail!("not a .dsb file: {path:?}"),
    }
}

fn dsb_name(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dsb".into())
}

/// Read a `.dsb` dataset (v1 or v2) fully into memory.
pub fn read_dsb(path: impl AsRef<Path>) -> crate::Result<Dataset> {
    let path = path.as_ref();
    let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let h = read_dsb_header(&mut file, path)?;
    // the header probe may have read past a short (v1) header
    file.seek(SeekFrom::Start(h.data_off))?;
    let mut r = BufReader::new(file);
    let data = read_f32s(&mut r, h.n * h.d)?;
    // bypass Dataset::new to avoid re-normalizing cosine data
    Ok(Dataset {
        name: dsb_name(path),
        d: h.d,
        metric: h.metric,
        data: VectorStore::Owned(data),
    })
}

/// Open a `.dsb` for *paged* row access through `cache`: rows are
/// fetched in row-aligned blocks on demand, nothing is read eagerly
/// beyond the header. v1 files have no pageable guarantee recorded, so
/// they fall back to the fully-resident owned path (documented compat
/// behavior — old shard directories keep serving under
/// `--residency block`, just without partial reads).
pub fn read_dsb_paged(path: impl AsRef<Path>, cache: &Arc<BlockCache>) -> crate::Result<Dataset> {
    let path = path.as_ref();
    let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
    let h = read_dsb_header(&mut file, path)?;
    if h.version == 1 {
        return read_dsb(path);
    }
    let rows = PagedRows::new(
        file,
        path.to_path_buf(),
        h.data_off,
        h.n,
        h.row_stride,
        h.d,
        cache,
        store::decode_f32_block,
    );
    Ok(Dataset {
        name: dsb_name(path),
        d: h.d,
        metric: h.metric,
        data: VectorStore::Paged(rows),
    })
}

/// Read a TEXMEX `.fvecs` file (each row: i32 dim then dim f32).
pub fn read_fvecs(path: impl AsRef<Path>, metric: Metric, limit: Option<usize>) -> crate::Result<Dataset> {
    let mut r = BufReader::new(File::open(path.as_ref())?);
    let mut data = Vec::new();
    let mut d = 0usize;
    let mut n = 0usize;
    loop {
        let dim = match read_u32(&mut r) {
            Ok(v) => v as usize,
            Err(_) => break, // EOF
        };
        if d == 0 {
            d = dim;
        } else if dim != d {
            bail!("inconsistent fvecs dims: {d} vs {dim}");
        }
        data.extend(read_f32s(&mut r, d)?);
        n += 1;
        if let Some(l) = limit {
            if n >= l {
                break;
            }
        }
    }
    if n == 0 {
        bail!("empty fvecs file {:?}", path.as_ref());
    }
    Ok(Dataset::new(
        path.as_ref().file_stem().unwrap().to_string_lossy(),
        d,
        metric,
        data,
    ))
}

/// Write `.ivecs` rows (ground truth neighbor id lists).
pub fn write_ivecs(rows: &[Vec<u32>], path: impl AsRef<Path>) -> crate::Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    for row in rows {
        w.write_all(&(row.len() as u32).to_le_bytes())?;
        for &x in row {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read `.ivecs` rows.
pub fn read_ivecs(path: impl AsRef<Path>) -> crate::Result<Vec<Vec<u32>>> {
    let mut r = BufReader::new(File::open(path.as_ref())?);
    let mut rows = Vec::new();
    loop {
        let len = match read_u32(&mut r) {
            Ok(v) => v as usize,
            Err(_) => break,
        };
        let mut row = Vec::with_capacity(len);
        for _ in 0..len {
            row.push(read_u32(&mut r)?);
        }
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::util::prop;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "gnnd-io-test-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn dsb_roundtrip() {
        let dir = tmpdir();
        let ds = synth::clustered(37, 9, 1);
        let p = dir.join("x.dsb");
        write_dsb(&ds, &p).unwrap();
        let back = read_dsb(&p).unwrap();
        assert_eq!(back.d, ds.d);
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.metric, ds.metric);
        assert_eq!(back.raw(), ds.raw());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_v1_still_reads() {
        let dir = tmpdir();
        let ds = synth::clustered(23, 5, 3);
        let p = dir.join("legacy.dsb");
        write_dsb_v1(&ds, &p).unwrap();
        let back = read_dsb(&p).unwrap();
        assert_eq!(back.raw(), ds.raw());
        assert_eq!((back.d, back.metric), (ds.d, ds.metric));
        // the paged open falls back to the owned path on v1
        let cache = BlockCache::new(0, 256);
        let paged = read_dsb_paged(&p, &cache).unwrap();
        assert!(!paged.is_paged());
        assert_eq!(paged.raw(), ds.raw());
        assert_eq!(cache.stats().fetches, 0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_format_roundtrip_property() {
        // random (n, d, metric, version) grids round-trip bit-exactly
        let dir = tmpdir();
        let p = dir.join("prop.dsb");
        prop::check("dsb-roundtrip", 25, |rng| {
            let n = 1 + rng.below(60);
            let d = 1 + rng.below(17);
            let metric = match rng.below(3) {
                0 => Metric::L2,
                1 => Metric::Ip,
                _ => Metric::Cosine,
            };
            let data: Vec<f32> = (0..n * d).map(|_| rng.f32() * 8.0 - 4.0).collect();
            let ds = Dataset::new("prop", d, metric, data);
            if rng.below(2) == 0 {
                write_dsb(&ds, &p).map_err(|e| e.to_string())?;
            } else {
                write_dsb_v1(&ds, &p).map_err(|e| e.to_string())?;
            }
            let back = read_dsb(&p).map_err(|e| e.to_string())?;
            prop::assert_prop(back.raw() == ds.raw(), "data mismatch")?;
            prop::assert_prop(
                (back.d, back.len(), back.metric) == (ds.d, ds.len(), ds.metric),
                "geometry mismatch",
            )
        });
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_paged_matches_owned_rows() {
        let dir = tmpdir();
        // d=7 (28B stride) with 64B blocks -> 2 rows/block, short tail
        let ds = synth::uniform(11, 7, 9);
        let p = dir.join("paged.dsb");
        write_dsb(&ds, &p).unwrap();
        let cache = BlockCache::new(0, 64);
        let paged = read_dsb_paged(&p, &cache).unwrap();
        assert!(paged.is_paged());
        assert_eq!(paged.len(), ds.len());
        assert_eq!(paged.d, ds.d);
        for i in 0..ds.len() {
            assert_eq!(paged.vector(i), ds.vec(i), "row {i}");
            assert_eq!(paged.dist_to(i, ds.vec(0)), ds.dist_to(i, ds.vec(0)));
        }
        assert!(cache.stats().fetches > 1, "multiple blocks must have paged in");
        // materialize round-trips the full matrix
        assert_eq!(paged.materialize().raw(), ds.raw());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_cosine_paged_no_double_normalize() {
        let dir = tmpdir();
        let ds = synth::glove_like(20, 2);
        let p = dir.join("g.dsb");
        write_dsb(&ds, &p).unwrap();
        let back = read_dsb(&p).unwrap();
        assert_eq!(back.raw(), ds.raw());
        let cache = BlockCache::new(0, 128);
        let paged = read_dsb_paged(&p, &cache).unwrap();
        for i in 0..ds.len() {
            assert_eq!(paged.vector(i), ds.vec(i));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn truncated_dsb_reports_sizes() {
        let dir = tmpdir();
        let ds = synth::uniform(30, 4, 5);
        for v2 in [true, false] {
            let name = if v2 { "t2.dsb" } else { "t1.dsb" };
            let p = dir.join(name);
            if v2 {
                write_dsb(&ds, &p).unwrap();
            } else {
                write_dsb_v1(&ds, &p).unwrap();
            }
            let full = std::fs::read(&p).unwrap();
            std::fs::write(&p, &full[..full.len() - 7]).unwrap();
            let err = format!("{:#}", read_dsb(&p).unwrap_err());
            assert!(
                err.contains("truncated") && err.contains(name) && err.contains("bytes"),
                "unhelpful truncation error: {err}"
            );
            let cache = BlockCache::new(0, 128);
            assert!(read_dsb_paged(&p, &cache).is_err(), "paged open must validate too");
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let dir = tmpdir();
        let rows = vec![vec![1u32, 2, 3], vec![], vec![9]];
        let p = dir.join("gt.ivecs");
        write_ivecs(&rows, &p).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fvecs_roundtrip_via_manual_write() {
        let dir = tmpdir();
        let p = dir.join("v.fvecs");
        {
            let mut w = BufWriter::new(File::create(&p).unwrap());
            for row in [[1.0f32, 2.0], [3.0, 4.0], [5.0, 6.0]] {
                w.write_all(&2u32.to_le_bytes()).unwrap();
                for x in row {
                    w.write_all(&x.to_le_bytes()).unwrap();
                }
            }
        }
        let ds = read_fvecs(&p, Metric::L2, None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.vec(2), &[5.0, 6.0]);
        let ds2 = read_fvecs(&p, Metric::L2, Some(2)).unwrap();
        assert_eq!(ds2.len(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmpdir();
        let p = dir.join("bad.dsb");
        std::fs::write(&p, b"notadsbfile").unwrap();
        assert!(read_dsb(&p).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
