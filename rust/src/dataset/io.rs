//! Dataset and graph file I/O.
//!
//! * `.fvecs` / `.ivecs` — the TEXMEX interchange formats used by the
//!   paper's benchmarks (SIFT1M etc.), so real corpora drop in when
//!   available.
//! * `.dsb` — our own raw binary dataset format (header + f32 rows),
//!   used by the out-of-core shard store because it supports metric
//!   metadata and fast bulk reads.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context};

use crate::config::Metric;

use super::Dataset;

const DSB_MAGIC: u32 = 0x4453_4231; // "DSB1"

fn metric_code(m: Metric) -> u32 {
    match m {
        Metric::L2 => 0,
        Metric::Ip => 1,
        Metric::Cosine => 2,
    }
}

fn metric_from_code(c: u32) -> crate::Result<Metric> {
    Ok(match c {
        0 => Metric::L2,
        1 => Metric::Ip,
        2 => Metric::Cosine,
        _ => bail!("bad metric code {c}"),
    })
}

fn read_u32(r: &mut impl Read) -> crate::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> crate::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a dataset in `.dsb` (magic, d, n, metric, then n*d f32 LE).
pub fn write_dsb(ds: &Dataset, path: impl AsRef<Path>) -> crate::Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    w.write_all(&DSB_MAGIC.to_le_bytes())?;
    w.write_all(&(ds.d as u32).to_le_bytes())?;
    w.write_all(&(ds.len() as u32).to_le_bytes())?;
    w.write_all(&metric_code(ds.metric).to_le_bytes())?;
    for &x in ds.raw() {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Read a `.dsb` dataset.
pub fn read_dsb(path: impl AsRef<Path>) -> crate::Result<Dataset> {
    let mut r = BufReader::new(
        File::open(path.as_ref()).with_context(|| format!("open {:?}", path.as_ref()))?,
    );
    if read_u32(&mut r)? != DSB_MAGIC {
        bail!("not a .dsb file: {:?}", path.as_ref());
    }
    let d = read_u32(&mut r)? as usize;
    let n = read_u32(&mut r)? as usize;
    let metric = metric_from_code(read_u32(&mut r)?)?;
    let data = read_f32s(&mut r, n * d)?;
    let name = path
        .as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dsb".into());
    // bypass Dataset::new to avoid re-normalizing cosine data
    Ok(Dataset { name, d, metric, data })
}

/// Read a TEXMEX `.fvecs` file (each row: i32 dim then dim f32).
pub fn read_fvecs(path: impl AsRef<Path>, metric: Metric, limit: Option<usize>) -> crate::Result<Dataset> {
    let mut r = BufReader::new(File::open(path.as_ref())?);
    let mut data = Vec::new();
    let mut d = 0usize;
    let mut n = 0usize;
    loop {
        let dim = match read_u32(&mut r) {
            Ok(v) => v as usize,
            Err(_) => break, // EOF
        };
        if d == 0 {
            d = dim;
        } else if dim != d {
            bail!("inconsistent fvecs dims: {d} vs {dim}");
        }
        data.extend(read_f32s(&mut r, d)?);
        n += 1;
        if let Some(l) = limit {
            if n >= l {
                break;
            }
        }
    }
    if n == 0 {
        bail!("empty fvecs file {:?}", path.as_ref());
    }
    Ok(Dataset::new(
        path.as_ref().file_stem().unwrap().to_string_lossy(),
        d,
        metric,
        data,
    ))
}

/// Write `.ivecs` rows (ground truth neighbor id lists).
pub fn write_ivecs(rows: &[Vec<u32>], path: impl AsRef<Path>) -> crate::Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref())?);
    for row in rows {
        w.write_all(&(row.len() as u32).to_le_bytes())?;
        for &x in row {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read `.ivecs` rows.
pub fn read_ivecs(path: impl AsRef<Path>) -> crate::Result<Vec<Vec<u32>>> {
    let mut r = BufReader::new(File::open(path.as_ref())?);
    let mut rows = Vec::new();
    loop {
        let len = match read_u32(&mut r) {
            Ok(v) => v as usize,
            Err(_) => break,
        };
        let mut row = Vec::with_capacity(len);
        for _ in 0..len {
            row.push(read_u32(&mut r)?);
        }
        rows.push(row);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "gnnd-io-test-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn dsb_roundtrip() {
        let dir = tmpdir();
        let ds = synth::clustered(37, 9, 1);
        let p = dir.join("x.dsb");
        write_dsb(&ds, &p).unwrap();
        let back = read_dsb(&p).unwrap();
        assert_eq!(back.d, ds.d);
        assert_eq!(back.len(), ds.len());
        assert_eq!(back.metric, ds.metric);
        assert_eq!(back.raw(), ds.raw());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn dsb_cosine_roundtrip_no_double_normalize() {
        let dir = tmpdir();
        let ds = synth::glove_like(20, 2);
        let p = dir.join("g.dsb");
        write_dsb(&ds, &p).unwrap();
        let back = read_dsb(&p).unwrap();
        assert_eq!(back.raw(), ds.raw());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let dir = tmpdir();
        let rows = vec![vec![1u32, 2, 3], vec![], vec![9]];
        let p = dir.join("gt.ivecs");
        write_ivecs(&rows, &p).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fvecs_roundtrip_via_manual_write() {
        let dir = tmpdir();
        let p = dir.join("v.fvecs");
        {
            let mut w = BufWriter::new(File::create(&p).unwrap());
            for row in [[1.0f32, 2.0], [3.0, 4.0], [5.0, 6.0]] {
                w.write_all(&2u32.to_le_bytes()).unwrap();
                for x in row {
                    w.write_all(&x.to_le_bytes()).unwrap();
                }
            }
        }
        let ds = read_fvecs(&p, Metric::L2, None).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.vec(2), &[5.0, 6.0]);
        let ds2 = read_fvecs(&p, Metric::L2, Some(2)).unwrap();
        assert_eq!(ds2.len(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = tmpdir();
        let p = dir.join("bad.dsb");
        std::fs::write(&p, b"notadsbfile").unwrap();
        assert!(read_dsb(&p).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
