//! Datasets: a flat row-major f32 matrix plus metric metadata.
//!
//! Rows live behind a [`store::VectorStore`]: fully in memory
//! (`Owned`, every construction path) or paged from a `.dsb` v2 file
//! through a shared block cache (`Paged`, the serving path of
//! [`crate::merge::outofcore::ShardStore`] in block-residency mode).
//! Accessors split accordingly: [`Dataset::vec`] / [`Dataset::raw`]
//! borrow and exist only for owned data; [`Dataset::with_vec`],
//! [`Dataset::vector`], [`Dataset::dist`] and [`Dataset::dist_to`]
//! work on either backing (a paged row is borrowed for the duration of
//! a closure — a borrow that outlived the access could dangle past the
//! block's next eviction, the same reasoning behind
//! [`crate::search::AnnIndex::vector`] returning owned data).

pub mod groundtruth;
pub mod io;
pub mod store;
pub mod synth;

use crate::config::Metric;
use crate::distance;

use store::VectorStore;

/// A dataset of `n` vectors of dimension `d` (row-major).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub d: usize,
    pub metric: Metric,
    data: VectorStore,
}

impl Dataset {
    pub fn new(name: impl Into<String>, d: usize, metric: Metric, data: Vec<f32>) -> Self {
        assert!(d > 0, "dimension must be positive");
        assert_eq!(data.len() % d, 0, "data length must be a multiple of d");
        let mut data = data;
        if metric == Metric::Cosine {
            // Cosine is served as normalize-once + negated inner product
            // (monotone in cosine distance); mirrors the L2 model design.
            for row in data.chunks_exact_mut(d) {
                distance::normalize(row);
            }
        }
        Dataset { name: name.into(), d, metric, data: VectorStore::Owned(data) }
    }

    /// Number of vectors.
    pub fn len(&self) -> usize {
        match &self.data {
            VectorStore::Owned(v) => v.len() / self.d,
            VectorStore::Paged(p) => p.rows(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when rows are paged from disk rather than memory-resident.
    pub fn is_paged(&self) -> bool {
        matches!(self.data, VectorStore::Paged(_))
    }

    /// Bytes this dataset holds resident *itself* (paged datasets keep
    /// only a handle; their blocks are accounted by the shared cache).
    pub fn resident_bytes(&self) -> usize {
        match &self.data {
            VectorStore::Owned(v) => v.len() * std::mem::size_of::<f32>(),
            VectorStore::Paged(_) => store::PAGED_HANDLE_BYTES,
        }
    }

    /// Row view. Owned backing only — a paged row cannot be borrowed
    /// past the access (use [`Dataset::with_vec`] / [`Dataset::vector`]).
    #[inline]
    pub fn vec(&self, i: usize) -> &[f32] {
        match &self.data {
            VectorStore::Owned(v) => &v[i * self.d..(i + 1) * self.d],
            VectorStore::Paged(_) => {
                panic!("Dataset::vec on a paged dataset; use with_vec/vector")
            }
        }
    }

    /// Borrow row `i` for the duration of `f` — works on either
    /// backing (the hot-path shape: no copy on owned, one block-cache
    /// access on paged).
    #[inline]
    pub fn with_vec<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        match &self.data {
            VectorStore::Owned(v) => f(&v[i * self.d..(i + 1) * self.d]),
            VectorStore::Paged(p) => p.with_f32_row(i, f),
        }
    }

    /// Row `i`, copied out (backing-agnostic).
    pub fn vector(&self, i: usize) -> Vec<f32> {
        self.with_vec(i, |row| row.to_vec())
    }

    /// Raw flat storage. Owned backing only.
    pub fn raw(&self) -> &[f32] {
        match &self.data {
            VectorStore::Owned(v) => v,
            VectorStore::Paged(_) => {
                panic!("Dataset::raw on a paged dataset; use extend_flat_into/materialize")
            }
        }
    }

    /// Append every row to `out` in order (streams blocks on a paged
    /// backing; a bulk copy on owned).
    pub fn extend_flat_into(&self, out: &mut Vec<f32>) {
        match &self.data {
            VectorStore::Owned(v) => out.extend_from_slice(v),
            VectorStore::Paged(p) => {
                for i in 0..p.rows() {
                    p.with_f32_row(i, |row| out.extend_from_slice(row));
                }
            }
        }
    }

    /// The paged backing's cache namespace id, if paged (lets the shard
    /// store drop a re-saved shard's stale blocks).
    pub(crate) fn block_store_id(&self) -> Option<u64> {
        match &self.data {
            VectorStore::Owned(_) => None,
            VectorStore::Paged(p) => Some(p.store_id()),
        }
    }

    /// A fully memory-resident copy of this dataset (reads every block
    /// of a paged backing once; rows are already normalized, so no
    /// re-normalization happens).
    pub fn materialize(&self) -> Dataset {
        let mut data = Vec::with_capacity(self.len() * self.d);
        self.extend_flat_into(&mut data);
        Dataset { name: self.name.clone(), d: self.d, metric: self.metric, data: VectorStore::Owned(data) }
    }

    /// Distance between rows `i` and `j` under the dataset metric.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f32 {
        match &self.data {
            VectorStore::Owned(v) => distance::distance(
                self.metric,
                &v[i * self.d..(i + 1) * self.d],
                &v[j * self.d..(j + 1) * self.d],
            ),
            VectorStore::Paged(_) => {
                self.with_vec(i, |vi| self.with_vec(j, |vj| distance::distance(self.metric, vi, vj)))
            }
        }
    }

    /// Distance between row `i` and an external query vector.
    #[inline]
    pub fn dist_to(&self, i: usize, q: &[f32]) -> f32 {
        match &self.data {
            VectorStore::Owned(v) => {
                distance::distance(self.metric, &v[i * self.d..(i + 1) * self.d], q)
            }
            VectorStore::Paged(p) => {
                p.with_f32_row(i, |row| distance::distance(self.metric, row, q))
            }
        }
    }

    /// New dataset holding the selected rows (in the given order).
    /// Owned backing only (a construction-side utility).
    pub fn select(&self, ids: &[usize], name: impl Into<String>) -> Dataset {
        let mut data = Vec::with_capacity(ids.len() * self.d);
        for &i in ids {
            data.extend_from_slice(self.vec(i));
        }
        // rows are already normalized if cosine; Dataset::new would
        // re-normalize harmlessly, but skip the cost:
        Dataset { name: name.into(), d: self.d, metric: self.metric, data: VectorStore::Owned(data) }
    }

    /// Concatenate two datasets with identical (d, metric). Owned only.
    pub fn concat(&self, other: &Dataset, name: impl Into<String>) -> Dataset {
        assert_eq!(self.d, other.d);
        assert_eq!(self.metric, other.metric);
        let mut data = self.raw().to_vec();
        data.extend_from_slice(other.raw());
        Dataset { name: name.into(), d: self.d, metric: self.metric, data: VectorStore::Owned(data) }
    }

    /// Split into `parts` near-equal contiguous shards. Owned only.
    pub fn split(&self, parts: usize) -> Vec<Dataset> {
        crate::util::split_ranges(self.len(), parts)
            .into_iter()
            .enumerate()
            .map(|(i, r)| Dataset {
                name: format!("{}[shard{}]", self.name, i),
                d: self.d,
                metric: self.metric,
                data: VectorStore::Owned(self.raw()[r.start * self.d..r.end * self.d].to_vec()),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new("t", 2, Metric::L2, vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0])
    }

    #[test]
    fn basic_accessors() {
        let ds = tiny();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.vec(1), &[3.0, 4.0]);
        assert_eq!(ds.dist(0, 1), 25.0);
        assert_eq!(ds.vector(1), vec![3.0, 4.0]);
        assert_eq!(ds.with_vec(2, |v| v.to_vec()), vec![1.0, 1.0]);
        assert!(!ds.is_paged());
        assert_eq!(ds.resident_bytes(), 6 * 4);
    }

    #[test]
    fn cosine_normalizes_rows() {
        let ds = Dataset::new("c", 2, Metric::Cosine, vec![3.0, 4.0, 0.0, 5.0]);
        let v = ds.vec(0);
        assert!((v[0] - 0.6).abs() < 1e-6 && (v[1] - 0.8).abs() < 1e-6);
        // self-distance is -1 (= perfectly aligned) under negated IP
        assert!((ds.dist(0, 0) + 1.0).abs() < 1e-5);
    }

    #[test]
    fn select_concat_split_roundtrip() {
        let ds = tiny();
        let sel = ds.select(&[2, 0], "sel");
        assert_eq!(sel.vec(0), ds.vec(2));
        let cat = ds.concat(&sel, "cat");
        assert_eq!(cat.len(), 5);
        let shards = cat.split(2);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].len() + shards[1].len(), 5);
        assert_eq!(shards[1].vec(0), cat.vec(3));
    }

    #[test]
    fn materialize_is_identity_on_owned() {
        let ds = tiny();
        let m = ds.materialize();
        assert_eq!(m.raw(), ds.raw());
        assert_eq!(m.metric, ds.metric);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Dataset::new("bad", 4, Metric::L2, vec![1.0; 7]);
    }
}
