//! Datasets: a flat row-major f32 matrix plus metric metadata.

pub mod groundtruth;
pub mod io;
pub mod synth;

use crate::config::Metric;
use crate::distance;

/// An in-memory dataset of `n` vectors of dimension `d` (row-major).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub d: usize,
    pub metric: Metric,
    data: Vec<f32>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, d: usize, metric: Metric, data: Vec<f32>) -> Self {
        assert!(d > 0, "dimension must be positive");
        assert_eq!(data.len() % d, 0, "data length must be a multiple of d");
        let mut ds = Dataset { name: name.into(), d, metric, data };
        if metric == Metric::Cosine {
            // Cosine is served as normalize-once + negated inner product
            // (monotone in cosine distance); mirrors the L2 model design.
            for i in 0..ds.len() {
                let row = &mut ds.data[i * d..(i + 1) * d];
                distance::normalize(row);
            }
        }
        ds
    }

    /// Number of vectors.
    pub fn len(&self) -> usize {
        self.data.len() / self.d
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row view.
    #[inline]
    pub fn vec(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Raw flat storage.
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// Distance between rows `i` and `j` under the dataset metric.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f32 {
        distance::distance(self.metric, self.vec(i), self.vec(j))
    }

    /// Distance between row `i` and an external query vector.
    #[inline]
    pub fn dist_to(&self, i: usize, q: &[f32]) -> f32 {
        distance::distance(self.metric, self.vec(i), q)
    }

    /// New dataset holding the selected rows (in the given order).
    pub fn select(&self, ids: &[usize], name: impl Into<String>) -> Dataset {
        let mut data = Vec::with_capacity(ids.len() * self.d);
        for &i in ids {
            data.extend_from_slice(self.vec(i));
        }
        // rows are already normalized if cosine; Dataset::new would
        // re-normalize harmlessly, but skip the cost:
        Dataset { name: name.into(), d: self.d, metric: self.metric, data }
    }

    /// Concatenate two datasets with identical (d, metric).
    pub fn concat(&self, other: &Dataset, name: impl Into<String>) -> Dataset {
        assert_eq!(self.d, other.d);
        assert_eq!(self.metric, other.metric);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Dataset { name: name.into(), d: self.d, metric: self.metric, data }
    }

    /// Split into `parts` near-equal contiguous shards.
    pub fn split(&self, parts: usize) -> Vec<Dataset> {
        crate::util::split_ranges(self.len(), parts)
            .into_iter()
            .enumerate()
            .map(|(i, r)| Dataset {
                name: format!("{}[shard{}]", self.name, i),
                d: self.d,
                metric: self.metric,
                data: self.data[r.start * self.d..r.end * self.d].to_vec(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new("t", 2, Metric::L2, vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0])
    }

    #[test]
    fn basic_accessors() {
        let ds = tiny();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.vec(1), &[3.0, 4.0]);
        assert_eq!(ds.dist(0, 1), 25.0);
    }

    #[test]
    fn cosine_normalizes_rows() {
        let ds = Dataset::new("c", 2, Metric::Cosine, vec![3.0, 4.0, 0.0, 5.0]);
        let v = ds.vec(0);
        assert!((v[0] - 0.6).abs() < 1e-6 && (v[1] - 0.8).abs() < 1e-6);
        // self-distance is -1 (= perfectly aligned) under negated IP
        assert!((ds.dist(0, 0) + 1.0).abs() < 1e-5);
    }

    #[test]
    fn select_concat_split_roundtrip() {
        let ds = tiny();
        let sel = ds.select(&[2, 0], "sel");
        assert_eq!(sel.vec(0), ds.vec(2));
        let cat = ds.concat(&sel, "cat");
        assert_eq!(cat.len(), 5);
        let shards = cat.split(2);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].len() + shards[1].len(), 5);
        assert_eq!(shards[1].vec(0), cat.vec(3));
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Dataset::new("bad", 4, Metric::L2, vec![1.0; 7]);
    }
}
