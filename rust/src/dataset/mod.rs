//! Datasets: a flat row-major f32 matrix plus metric metadata.
//!
//! Rows live behind a [`store::VectorStore`]: fully in memory
//! (`Owned`, every construction path), paged from a `.dsb` v2 file
//! through a shared block cache (`Paged`, the serving path of
//! [`crate::merge::outofcore::ShardStore`] in block-residency mode),
//! or compressed into code space: scalar-quantized u8 codes with a
//! [`store::QuantParams`] sidecar (`Quantized`) or product-quantized
//! m-byte codes with the [`store::PqParams`] codebooks (`Pq`) — the
//! cheap beam-phase backings of two-phase serving (see
//! [`Dataset::prepare_query`], [`Dataset::dist_to_quant`] and
//! [`Dataset::rerank_dist_to`]).
//! Accessors split accordingly: [`Dataset::vec`] / [`Dataset::raw`]
//! borrow and exist only for owned data; [`Dataset::with_vec`],
//! [`Dataset::vector`], [`Dataset::dist`] and [`Dataset::dist_to`]
//! work on any backing (a paged row is borrowed for the duration of
//! a closure — a borrow that outlived the access could dangle past the
//! block's next eviction, the same reasoning behind
//! [`crate::search::AnnIndex::vector`] returning owned data; a
//! quantized row is dequantized into a transient buffer first).

pub mod groundtruth;
pub mod io;
pub mod store;
pub mod synth;

use crate::config::Metric;
use crate::distance;

use store::{ExactRows, QuantCodes, QuantFitter, QuantStore, VectorStore};

/// A dataset of `n` vectors of dimension `d` (row-major).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub d: usize,
    pub metric: Metric,
    data: VectorStore,
}

impl Dataset {
    pub fn new(name: impl Into<String>, d: usize, metric: Metric, data: Vec<f32>) -> Self {
        assert!(d > 0, "dimension must be positive");
        assert_eq!(data.len() % d, 0, "data length must be a multiple of d");
        let mut data = data;
        if metric == Metric::Cosine {
            // Cosine is served as normalize-once + negated inner product
            // (monotone in cosine distance); mirrors the L2 model design.
            for row in data.chunks_exact_mut(d) {
                distance::normalize(row);
            }
        }
        Dataset { name: name.into(), d, metric, data: VectorStore::Owned(data) }
    }

    /// Number of vectors.
    pub fn len(&self) -> usize {
        match &self.data {
            VectorStore::Owned(v) => v.len() / self.d,
            VectorStore::Paged(p) => p.rows(),
            VectorStore::Quantized(q) => q.rows(),
            VectorStore::Pq(p) => p.rows(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when f32 rows are paged from disk rather than
    /// memory-resident (a quantized backing is *not* "paged" even when
    /// its codes are — check [`Dataset::is_quantized`]).
    pub fn is_paged(&self) -> bool {
        matches!(self.data, VectorStore::Paged(_))
    }

    /// True when rows are scalar-quantized u8 codes (not
    /// product-quantized — check [`Dataset::is_pq`] for that).
    pub fn is_quantized(&self) -> bool {
        matches!(self.data, VectorStore::Quantized(_))
    }

    /// True when rows are product-quantized m-byte codes.
    pub fn is_pq(&self) -> bool {
        matches!(self.data, VectorStore::Pq(_))
    }

    /// True when rows live in a lossy code space (scalar- or
    /// product-quantized) — the backings whose beam phase runs on
    /// [`Dataset::dist_to_quant`] and wants a rerank pass.
    pub fn is_compressed(&self) -> bool {
        matches!(self.data, VectorStore::Quantized(_) | VectorStore::Pq(_))
    }

    /// True when rows are a fully memory-resident f32 matrix — the
    /// backing the construction-side utilities ([`Dataset::select`],
    /// [`Dataset::concat`], [`Dataset::split`], [`Dataset::raw`])
    /// require.
    pub fn is_owned(&self) -> bool {
        matches!(self.data, VectorStore::Owned(_))
    }

    /// Human-readable backing name for error messages and `describe()`.
    pub fn backing_kind(&self) -> &'static str {
        match &self.data {
            VectorStore::Owned(_) => "owned",
            VectorStore::Paged(_) => "paged",
            VectorStore::Quantized(_) => "quantized",
            VectorStore::Pq(_) => "pq",
        }
    }

    /// Bytes this dataset holds resident *itself* (paged datasets keep
    /// only a handle; their blocks are accounted by the shared cache;
    /// quantized datasets hold 1 byte per dimension plus the params
    /// sidecar).
    pub fn resident_bytes(&self) -> usize {
        match &self.data {
            VectorStore::Owned(v) => v.len() * std::mem::size_of::<f32>(),
            VectorStore::Paged(_) => store::PAGED_HANDLE_BYTES,
            VectorStore::Quantized(q) => q.resident_bytes(),
            VectorStore::Pq(p) => p.resident_bytes(),
        }
    }

    /// Bytes of stored row payload touched per candidate in the beam
    /// phase: 4 bytes/dim for f32 backings, 1 byte/dim scalar-quantized,
    /// m bytes/row product-quantized. Used by byte-budget accounting
    /// and `describe()`.
    pub fn stored_row_bytes(&self) -> usize {
        match &self.data {
            VectorStore::Owned(_) | VectorStore::Paged(_) => self.d * std::mem::size_of::<f32>(),
            VectorStore::Quantized(_) => self.d,
            VectorStore::Pq(p) => p.params.m(),
        }
    }

    /// Row view. Owned backing only — a paged row cannot be borrowed
    /// past the access and a quantized row does not exist as f32 (use
    /// [`Dataset::with_vec`] / [`Dataset::vector`]).
    #[inline]
    pub fn vec(&self, i: usize) -> &[f32] {
        match &self.data {
            VectorStore::Owned(v) => &v[i * self.d..(i + 1) * self.d],
            _ => panic!(
                "Dataset::vec on a {} dataset; use with_vec/vector",
                self.backing_kind()
            ),
        }
    }

    /// Borrow row `i` for the duration of `f` — works on any backing
    /// (the hot-path shape: no copy on owned, one block-cache access on
    /// paged, a transient dequantize on quantized).
    #[inline]
    pub fn with_vec<R>(&self, i: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        match &self.data {
            VectorStore::Owned(v) => f(&v[i * self.d..(i + 1) * self.d]),
            VectorStore::Paged(p) => p.with_f32_row(i, f),
            VectorStore::Quantized(q) => {
                let mut buf = Vec::with_capacity(self.d);
                q.decode_row_into(i, &mut buf);
                f(&buf)
            }
            VectorStore::Pq(p) => {
                let mut buf = Vec::with_capacity(self.d);
                p.decode_row_into(i, &mut buf);
                f(&buf)
            }
        }
    }

    /// Row `i`, copied out (backing-agnostic; dequantized on a
    /// quantized backing).
    pub fn vector(&self, i: usize) -> Vec<f32> {
        self.with_vec(i, |row| row.to_vec())
    }

    /// Raw flat storage. Owned backing only.
    pub fn raw(&self) -> &[f32] {
        match &self.data {
            VectorStore::Owned(v) => v,
            _ => panic!(
                "Dataset::raw requires an owned (in-memory f32) backing, got {}; \
                 use extend_flat_into/materialize",
                self.backing_kind()
            ),
        }
    }

    /// Append every row to `out` in order (streams blocks on a paged
    /// backing; dequantizes on quantized; a bulk copy on owned).
    pub fn extend_flat_into(&self, out: &mut Vec<f32>) {
        match &self.data {
            VectorStore::Owned(v) => out.extend_from_slice(v),
            VectorStore::Paged(p) => {
                for i in 0..p.rows() {
                    p.with_f32_row(i, |row| out.extend_from_slice(row));
                }
            }
            VectorStore::Quantized(q) => {
                let mut buf = Vec::with_capacity(self.d);
                for i in 0..q.rows() {
                    q.decode_row_into(i, &mut buf);
                    out.extend_from_slice(&buf);
                }
            }
            VectorStore::Pq(p) => {
                let mut buf = Vec::with_capacity(self.d);
                for i in 0..p.rows() {
                    p.decode_row_into(i, &mut buf);
                    out.extend_from_slice(&buf);
                }
            }
        }
    }

    /// The paged backing's cache namespace id, if any (lets the shard
    /// store drop a re-saved or evicted shard's stale blocks). For a
    /// quantized backing this is the *codes* namespace; the exact-rows
    /// namespace is [`Dataset::exact_block_store_id`].
    pub(crate) fn block_store_id(&self) -> Option<u64> {
        match &self.data {
            VectorStore::Owned(_) => None,
            VectorStore::Paged(p) => Some(p.store_id()),
            VectorStore::Quantized(q) => q.codes_store_id(),
            VectorStore::Pq(p) => p.codes_store_id(),
        }
    }

    /// Cache namespace of a compressed backing's paged exact rows, if
    /// present — eviction must forget this namespace too.
    pub(crate) fn exact_block_store_id(&self) -> Option<u64> {
        match &self.data {
            VectorStore::Quantized(q) => q.exact_store_id(),
            VectorStore::Pq(p) => p.exact_store_id(),
            _ => None,
        }
    }

    /// A fully memory-resident copy of this dataset (reads every block
    /// of a paged backing once; rows are already normalized, so no
    /// re-normalization happens).
    pub fn materialize(&self) -> Dataset {
        let mut data = Vec::with_capacity(self.len() * self.d);
        self.extend_flat_into(&mut data);
        Dataset {
            name: self.name.clone(),
            d: self.d,
            metric: self.metric,
            data: VectorStore::Owned(data),
        }
    }

    /// Distance between rows `i` and `j` under the dataset metric.
    #[inline]
    pub fn dist(&self, i: usize, j: usize) -> f32 {
        match &self.data {
            VectorStore::Owned(v) => distance::distance(
                self.metric,
                &v[i * self.d..(i + 1) * self.d],
                &v[j * self.d..(j + 1) * self.d],
            ),
            _ => {
                self.with_vec(i, |vi| self.with_vec(j, |vj| distance::distance(self.metric, vi, vj)))
            }
        }
    }

    /// Distance between row `i` and an external query vector. On a
    /// compressed backing the row is reconstructed first (metric-unit
    /// result carrying quantization error); the beam hot path uses
    /// [`Dataset::dist_to_quant`] instead, which stays in code space.
    #[inline]
    pub fn dist_to(&self, i: usize, q: &[f32]) -> f32 {
        match &self.data {
            VectorStore::Owned(v) => {
                distance::distance(self.metric, &v[i * self.d..(i + 1) * self.d], q)
            }
            VectorStore::Paged(p) => {
                p.with_f32_row(i, |row| distance::distance(self.metric, row, q))
            }
            VectorStore::Quantized(_) | VectorStore::Pq(_) => {
                self.with_vec(i, |row| distance::distance(self.metric, row, q))
            }
        }
    }

    /// Prepare a query for this backing's beam phase (both outputs are
    /// cleared first). On a scalar-quantized backing, encodes `q` into
    /// code space (`qcodes`); on a product-quantized backing, builds
    /// the per-query ADC lookup table (`lut`, `m * 256` entries, timed
    /// into the `query.lut_build_us` counter) so the beam inner loop
    /// reduces to m table gathers per candidate. Returns `false` —
    /// leaving both outputs empty — on an uncompressed backing.
    pub fn prepare_query(&self, q: &[f32], qcodes: &mut Vec<u8>, lut: &mut Vec<f32>) -> bool {
        match &self.data {
            VectorStore::Quantized(qs) => {
                lut.clear();
                qs.params.encode_into(q, qcodes);
                true
            }
            VectorStore::Pq(ps) => {
                qcodes.clear();
                let t0 = std::time::Instant::now();
                ps.params.build_lut(self.metric, q, lut);
                crate::telemetry::global()
                    .counter("query.lut_build_us")
                    .add(t0.elapsed().as_micros() as u64);
                true
            }
            _ => {
                qcodes.clear();
                lut.clear();
                false
            }
        }
    }

    /// Beam-phase distance of row `i` to the query: the approximate
    /// code-space kernel on a compressed backing (scalar-quantized:
    /// against `qcodes`; product-quantized: m gathers from `lut` —
    /// both from [`Dataset::prepare_query`]), the exact f32 path
    /// otherwise (`qcodes` / `lut` ignored).
    #[inline]
    pub fn dist_to_quant(&self, i: usize, q: &[f32], qcodes: &[u8], lut: &[f32]) -> f32 {
        match &self.data {
            VectorStore::Quantized(qs) => qs.dist_to(self.metric, i, q, qcodes),
            VectorStore::Pq(ps) => ps.dist_to_lut(i, lut),
            _ => self.dist_to(i, q),
        }
    }

    /// Rerank-phase distance of row `i` to the query: full-precision
    /// on a compressed backing (the exact-rows sidecar when attached,
    /// else the reconstructed row via `buf`), identical to
    /// [`Dataset::dist_to`] otherwise.
    #[inline]
    pub fn rerank_dist_to(&self, i: usize, q: &[f32], buf: &mut Vec<f32>) -> f32 {
        match &self.data {
            VectorStore::Quantized(qs) => qs.rerank_dist_to(self.metric, i, q, buf),
            VectorStore::Pq(ps) => ps.rerank_dist_to(self.metric, i, q, buf),
            _ => self.dist_to(i, q),
        }
    }

    /// Scalar-quantize this dataset (params fitted on its own rows) to
    /// a memory-resident `Quantized` backing without exact rows —
    /// rerank falls back to dequantized rows. Works on any backing.
    pub fn quantize(&self) -> Dataset {
        self.quantize_impl(false)
    }

    /// Like [`Dataset::quantize`] but also keeps an owned f32 copy of
    /// the rows for exact rerank — the in-memory serving convenience
    /// (`--quantize` on a monolithic `search`): distances go 1
    /// byte/dim, rerank stays bit-exact.
    pub fn quantize_with_exact(&self) -> Dataset {
        self.quantize_impl(true)
    }

    fn quantize_impl(&self, keep_exact: bool) -> Dataset {
        let mut fit = QuantFitter::new(self.d);
        for i in 0..self.len() {
            self.with_vec(i, |row| fit.observe(row));
        }
        let params = std::sync::Arc::new(fit.finish());
        let mut codes = Vec::with_capacity(self.len() * self.d);
        let mut row_codes = Vec::with_capacity(self.d);
        let mut exact =
            if keep_exact { Vec::with_capacity(self.len() * self.d) } else { Vec::new() };
        for i in 0..self.len() {
            self.with_vec(i, |row| {
                params.encode_into(row, &mut row_codes);
                if keep_exact {
                    exact.extend_from_slice(row);
                }
            });
            codes.extend_from_slice(&row_codes);
        }
        Dataset {
            name: self.name.clone(),
            d: self.d,
            metric: self.metric,
            data: VectorStore::Quantized(Box::new(QuantStore {
                d: self.d,
                params,
                codes: QuantCodes::Owned(codes),
                exact: keep_exact.then_some(ExactRows::Owned(exact)),
            })),
        }
    }

    /// Guard for the construction-side, owned-only utilities: a clear
    /// error at the API boundary instead of a panic deep in `vec()`.
    fn require_owned(&self, op: &str) {
        assert!(
            self.is_owned(),
            "Dataset::{op} requires an owned (in-memory f32) backing, got {}; \
             call materialize() first",
            self.backing_kind()
        );
    }

    /// New dataset holding the selected rows (in the given order).
    /// Owned backing only (a construction-side utility) — panics with
    /// the backing kind otherwise; `materialize()` first.
    pub fn select(&self, ids: &[usize], name: impl Into<String>) -> Dataset {
        self.require_owned("select");
        let mut data = Vec::with_capacity(ids.len() * self.d);
        for &i in ids {
            data.extend_from_slice(self.vec(i));
        }
        // rows are already normalized if cosine; Dataset::new would
        // re-normalize harmlessly, but skip the cost:
        Dataset { name: name.into(), d: self.d, metric: self.metric, data: VectorStore::Owned(data) }
    }

    /// Concatenate two datasets with identical (d, metric). Owned
    /// backings only (both sides) — panics with the backing kind
    /// otherwise; `materialize()` first.
    pub fn concat(&self, other: &Dataset, name: impl Into<String>) -> Dataset {
        assert_eq!(self.d, other.d);
        assert_eq!(self.metric, other.metric);
        self.require_owned("concat");
        other.require_owned("concat");
        let mut data = self.raw().to_vec();
        data.extend_from_slice(other.raw());
        Dataset { name: name.into(), d: self.d, metric: self.metric, data: VectorStore::Owned(data) }
    }

    /// Split into `parts` near-equal contiguous shards. Owned only —
    /// panics with the backing kind otherwise.
    pub fn split(&self, parts: usize) -> Vec<Dataset> {
        self.require_owned("split");
        crate::util::split_ranges(self.len(), parts)
            .into_iter()
            .enumerate()
            .map(|(i, r)| Dataset {
                name: format!("{}[shard{}]", self.name, i),
                d: self.d,
                metric: self.metric,
                data: VectorStore::Owned(self.raw()[r.start * self.d..r.end * self.d].to_vec()),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new("t", 2, Metric::L2, vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0])
    }

    #[test]
    fn basic_accessors() {
        let ds = tiny();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.vec(1), &[3.0, 4.0]);
        assert_eq!(ds.dist(0, 1), 25.0);
        assert_eq!(ds.vector(1), vec![3.0, 4.0]);
        assert_eq!(ds.with_vec(2, |v| v.to_vec()), vec![1.0, 1.0]);
        assert!(!ds.is_paged());
        assert_eq!(ds.resident_bytes(), 6 * 4);
    }

    #[test]
    fn cosine_normalizes_rows() {
        let ds = Dataset::new("c", 2, Metric::Cosine, vec![3.0, 4.0, 0.0, 5.0]);
        let v = ds.vec(0);
        assert!((v[0] - 0.6).abs() < 1e-6 && (v[1] - 0.8).abs() < 1e-6);
        // self-distance is -1 (= perfectly aligned) under negated IP
        assert!((ds.dist(0, 0) + 1.0).abs() < 1e-5);
    }

    #[test]
    fn select_concat_split_roundtrip() {
        let ds = tiny();
        let sel = ds.select(&[2, 0], "sel");
        assert_eq!(sel.vec(0), ds.vec(2));
        let cat = ds.concat(&sel, "cat");
        assert_eq!(cat.len(), 5);
        let shards = cat.split(2);
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].len() + shards[1].len(), 5);
        assert_eq!(shards[1].vec(0), cat.vec(3));
    }

    #[test]
    fn materialize_is_identity_on_owned() {
        let ds = tiny();
        let m = ds.materialize();
        assert_eq!(m.raw(), ds.raw());
        assert_eq!(m.metric, ds.metric);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Dataset::new("bad", 4, Metric::L2, vec![1.0; 7]);
    }
}
