//! Figure 6 — construction quality/time curves on the four benchmark
//! datasets (SIFT / DEEP / GIST / GloVe shaped): GNND (k, p sweeps),
//! classic NN-Descent (single thread), FAISS-BF exact point, GGNN
//! (tau / refinement sweeps).
//!
//! Paper claims checked: GNND reaches ~0.99 recall@10 orders of
//! magnitude faster than 1-thread NN-Descent (paper: 100-250x on GPU),
//! is faster than GGNN at equal quality (paper: 2.5-5x), and the
//! brute-force exact point is unscalable (its time grows ~n^2 while
//! GNND grows ~n).

use crate::baselines::{bruteforce, ggnn, nn_descent};
use crate::metrics::{recall_at, Report, Row};
use crate::util::timer::Timer;

use super::{engine_from_env, sampled_truth10, Scale};

pub fn run(scale: Scale) -> Report {
    let mut combined = Report::new("Fig 6: million-scale-analog construction (all datasets)")
        .meta("scale", format!("{scale:?}"))
        .meta("engine", format!("{}", engine_from_env()));
    for ds in super::benchmark_suite(scale) {
        let report = run_dataset(&ds, scale);
        for row in report.rows {
            combined.push(Row {
                label: format!("{} | {}", ds.name, row.label),
                cols: row.cols,
            });
        }
    }
    super::finish(combined)
}

/// One dataset panel of Fig. 6.
pub fn run_dataset(ds: &crate::dataset::Dataset, scale: Scale) -> Report {
    let (ids, truth) = sampled_truth10(ds);
    let mut report = Report::new(format!("Fig 6 panel: {}", ds.name))
        .meta("n", ds.len())
        .meta("d", ds.d)
        .meta("metric", ds.metric);

    // --- GNND curve: sweep (k, p) as the paper does ---
    for (k, p, iters) in [(12, 6, 6), (20, 10, 8), (32, 16, 10)] {
        let params = super::default_params(engine_from_env())
            .with_k(k)
            .with_p(p)
            .with_iters(iters);
        let t = Timer::start();
        let out = crate::gnnd::build_with_stats(ds, &params).expect("gnnd");
        report.push(
            Row::new(format!("gnnd k={k} p={p}"))
                .col("time_s", t.secs())
                .col("recall@10", recall_at(&out.graph, &truth, Some(&ids), 10)),
        );
    }

    // --- classic NN-Descent (single thread), two quality points ---
    for (k, iters) in [(10, 6), (20, 10)] {
        let t = Timer::start();
        let (g, _) = nn_descent::build(
            ds,
            &nn_descent::NnDescentParams { k, max_iter: iters, threads: 1, ..Default::default() },
        );
        report.push(
            Row::new(format!("nn-descent k={k}"))
                .col("time_s", t.secs())
                .col("recall@10", recall_at(&g, &truth, Some(&ids), 10)),
        );
    }

    // --- FAISS-BF exact point ---
    let t = Timer::start();
    let g = bruteforce::build_native(ds, 10);
    report.push(
        Row::new("faiss-bf (exact)")
            .col("time_s", t.secs())
            .col("recall@10", recall_at(&g, &truth, Some(&ids), 10)),
    );

    // --- GGNN curve: k=24 fixed (as in the paper), sweep tau & t ---
    let taus: &[(f64, usize)] = if scale == Scale::Quick {
        &[(0.5, 1)]
    } else {
        &[(0.3, 0), (0.4, 1), (0.5, 2)]
    };
    for &(tau, refinements) in taus {
        let params = ggnn::GgnnParams { k: 24, tau, refinements, ..Default::default() };
        let t = Timer::start();
        let index = ggnn::build(ds, &params);
        report.push(
            Row::new(format!("ggnn tau={tau} t={refinements}"))
                .col("time_s", t.secs())
                .col("recall@10", recall_at(&index.graph, &truth, Some(&ids), 10)),
        );
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn gnnd_beats_single_thread_nn_descent_on_time_at_equal_quality() {
        let ds = synth::sift_like(Scale::Quick.n_base(), 0xF166);
        let report = run_dataset(&ds, Scale::Quick);
        let best = |frag: &str| -> (f64, f64) {
            report
                .rows
                .iter()
                .filter(|r| r.label.contains(frag))
                .map(|r| {
                    let t = r.cols.iter().find(|(n, _)| n == "time_s").unwrap().1;
                    let rec = r.cols.iter().find(|(n, _)| n == "recall@10").unwrap().1;
                    (t, rec)
                })
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
        };
        let (t_g, r_g) = best("gnnd");
        let (t_n, r_n) = best("nn-descent");
        assert!(r_g > 0.9, "gnnd best recall {r_g}");
        // multithreaded selective GNND must beat the 1-thread classic
        // baseline in wall time at >= comparable quality
        if r_g >= r_n - 0.02 {
            assert!(t_g < t_n, "gnnd {t_g}s !< nn-descent {t_n}s");
        }
        let (_, r_bf) = best("faiss-bf");
        assert!(r_bf > 0.999, "bruteforce must be exact, got {r_bf}");
    }
}
