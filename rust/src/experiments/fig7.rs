//! Figure 7 — merging two k-NN graphs: GGM vs GGNN-style search merge.
//!
//! SIFT-like data split into two halves; GNND builds each sub-graph
//! (that cost is excluded, as in the paper); then the halves are merged
//! by (a) GGM with increasing refinement iterations and (b) GGNN-style
//! cross-searching with increasing slack tau. Paper claim: GGM is
//! consistently better by ~5-10% recall@10 at comparable time, because
//! it exploits *both* sub-graphs' neighborhoods.

use crate::baselines::ggnn;
use crate::dataset::synth;
use crate::gnnd::{self};
use crate::merge;
use crate::metrics::{recall_at, Report, Row};
use crate::util::timer::Timer;

use super::{engine_from_env, sampled_truth10, Scale};

pub fn run(scale: Scale) -> Report {
    let ds = synth::sift_like(scale.n_base(), 0xF167);
    let (ids, truth) = sampled_truth10(&ds);
    let n1 = ds.len() / 2;
    let k = 20;

    // --- build the two sub-graphs (cost excluded, as in the paper) ---
    let ids1: Vec<usize> = (0..n1).collect();
    let ids2: Vec<usize> = (n1..ds.len()).collect();
    let d1 = ds.select(&ids1, "half1");
    let d2 = ds.select(&ids2, "half2");
    let build_params = super::default_params(engine_from_env()).with_k(k).with_p(10);
    let g1 = gnnd::build(&d1, &build_params).expect("g1");
    let g2 = gnnd::build(&d2, &build_params).expect("g2");

    let mut report = Report::new("Fig 7: merging two k-NN graphs (GGM vs GGNN)")
        .meta("dataset", &ds.name)
        .meta("n", ds.len())
        .meta("k", k)
        .meta("engine", format!("{}", engine_from_env()));

    // naive join reference (no cross edges at all)
    {
        let mut g2r = g2.clone();
        g2r.remap_ids(|id| id + n1 as u32);
        let joined = g1.stack(&g2r);
        report.push(
            Row::new("naive join (no merge)")
                .col("time_s", 0.0)
                .col("recall@10", recall_at(&joined, &truth, Some(&ids), 10)),
        );
    }

    // --- GGM with increasing refinement budget ---
    for iters in [1usize, 2, 4, 6, 8, 12] {
        let params = super::default_params(engine_from_env())
            .with_k(k)
            .with_p(10)
            .with_iters(iters);
        let t = Timer::start();
        let (g, _) = merge::merge(&ds, n1, &g1, &g2, &params, &gnnd::NativeEngine).expect("ggm");
        report.push(
            Row::new(format!("ggm iters={iters}"))
                .col("time_s", t.secs())
                .col("recall@10", recall_at(&g, &truth, Some(&ids), 10)),
        );
    }

    // --- GGNN-style merge with increasing slack ---
    for tau in [0.3f64, 0.5, 1.0, 2.0] {
        let t = Timer::start();
        let g = ggnn::merge_by_search(&ds, n1, &g1, &g2, tau, 0);
        report.push(
            Row::new(format!("ggnn-search tau={tau}"))
                .col("time_s", t.secs())
                .col("recall@10", recall_at(&g, &truth, Some(&ids), 10)),
        );
    }
    super::finish(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ggm_beats_search_merge_at_quick_scale() {
        let report = run(Scale::Quick);
        let best = |frag: &str| -> f64 {
            report
                .rows
                .iter()
                .filter(|r| r.label.contains(frag))
                .map(|r| r.cols.iter().find(|(n, _)| n == "recall@10").unwrap().1)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let ggm = best("ggm");
        let ggnn = best("ggnn-search");
        let naive = best("naive");
        assert!(ggm > naive, "ggm {ggm} !> naive {naive}");
        // at quick scale (1k per half) exhaustive-ish search merges are
        // near-perfect; the paper's 5-10% GGM gap is the standard-scale
        // bench claim — here we only require parity within noise.
        assert!(ggm >= ggnn - 0.04, "ggm {ggm} not competitive with ggnn {ggnn}");
    }
}
