//! Figure 4 — variation trend of phi(G) (Eq. 3): GNND vs classic
//! NN-Descent, k = 10, SIFT-like data.
//!
//! Paper claim: the GNND trend "largely overlaps" the NN-Descent trend —
//! selective update does not slow convergence. The report prints one row
//! per iteration with both phi values and their ratio; the claim holds
//! when the ratio stays near 1.

use crate::baselines::nn_descent::{self, NnDescentParams};
use crate::dataset::synth;
use crate::gnnd;
use crate::metrics::{Report, Row};

use super::{engine_from_env, Scale};

pub fn run(scale: Scale) -> Report {
    let ds = synth::sift_like(scale.n_base(), 0xF1604);
    let k = 10;

    let mut params = super::default_params(engine_from_env())
        .with_k(k)
        .with_p(5)
        .with_iters(10);
    params.trace_phi = true;
    params.delta = 0.0; // run all iterations for a full trace
    let g_out = gnnd::build_with_stats(&ds, &params).expect("gnnd build");

    let nd_params = NnDescentParams {
        k,
        max_iter: 10,
        delta: 0.0,
        trace_phi: true,
        threads: 1,
        ..Default::default()
    };
    let (_, nd_stats) = nn_descent::build(&ds, &nd_params);

    let mut report = Report::new("Fig 4: phi(G) per iteration (GNND vs NN-Descent)")
        .meta("dataset", &ds.name)
        .meta("n", ds.len())
        .meta("k", k)
        .meta("engine", g_out.stats.engine);
    let iters = g_out.stats.phi_trace.len().max(nd_stats.phi_trace.len());
    for it in 0..iters {
        let a = g_out.stats.phi_trace.get(it).copied();
        let b = nd_stats.phi_trace.get(it).copied();
        let mut row = Row::new(format!("iter {it}"));
        if let Some(a) = a {
            row = row.col("phi_gnnd", a);
        }
        if let Some(b) = b {
            row = row.col("phi_nnd", b);
        }
        if let (Some(a), Some(b)) = (a, b) {
            row = row.col("ratio", if b > 0.0 { a / b } else { f64::NAN });
        }
        report.push(row);
    }
    super::finish(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trends_overlap_at_quick_scale() {
        let report = run(Scale::Quick);
        // both must decrease and end close together (paper: overlap)
        let col = |row: &crate::metrics::Row, name: &str| {
            row.cols.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
        };
        let first = &report.rows[0];
        let last = report.rows.last().unwrap();
        let (g0, n0) = (col(first, "phi_gnnd").unwrap(), col(first, "phi_nnd").unwrap());
        let (g1, n1) = (col(last, "phi_gnnd").unwrap(), col(last, "phi_nnd").unwrap());
        assert!(g1 < g0 * 0.9, "gnnd phi barely moved");
        assert!(n1 < n0 * 0.9, "nnd phi barely moved");
        let ratio = col(last, "ratio").unwrap();
        assert!((0.9..=1.15).contains(&ratio), "final phi ratio {ratio} not near 1");
    }
}
