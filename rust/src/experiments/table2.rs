//! Table 2 — billion-scale-analog construction: sharded GNND + pairwise
//! GGM (out-of-core) vs IVF-PQ, two quality configurations each.
//!
//! The paper's SIFT100M/DEEP100M/1B corpora exceed this testbed by
//! orders of magnitude; the analog keeps the *structure* — the dataset
//! is partitioned into shards treated as the per-device capacity, all
//! vectors are spilled to disk, and the whole pipeline runs from shard
//! files (DESIGN.md "Substitutions"). Claims checked: GNND's recall is
//! well above IVF-PQ's quantization-capped recall, at comparable or
//! better time; IVF-PQ recall saturates even with a larger time budget.

use crate::baselines::ivfpq::{self, IvfPqParams};
use crate::dataset::synth;
use crate::gnnd::NativeEngine;
use crate::merge::outofcore::{build_out_of_core, OutOfCoreConfig};
use crate::metrics::{recall_at, Report, Row};
use crate::util::timer::Timer;

use super::{sampled_truth10, Scale};

pub fn run(scale: Scale) -> Report {
    let n = scale.n_billion_analog();
    let mut report = Report::new("Table 2: billion-scale-analog (out-of-core GNND vs IVF-PQ)")
        .meta("scale", format!("{scale:?}"))
        .meta("n", n);

    for (tag, seed) in [("sift100m-analog", 0x7AB2u64), ("deep100m-analog", 0x7AB3)] {
        let ds = if tag.starts_with("sift") {
            synth::sift_like(n, seed)
        } else {
            synth::deep_like(n, seed)
        };
        let (ids, truth) = sampled_truth10(&ds);

        // --- GNND out-of-core: fast + quality configs ---
        for (label, k, p, iters) in
            [("gnnd-ooc fast", 16usize, 8usize, 4usize), ("gnnd-ooc quality", 32, 16, 8)]
        {
            let params = super::default_params(super::engine_from_env())
                .with_k(k)
                .with_p(p)
                .with_iters(iters);
            let cfg = OutOfCoreConfig {
                shards: if scale == Scale::Quick { 4 } else { 8 },
                workers: 2,
                params,
            };
            let dir = std::env::temp_dir().join(format!(
                "gnnd-table2-{tag}-{label}-{}",
                std::process::id()
            ));
            let t = Timer::start();
            let (g, stats) =
                build_out_of_core(&ds, &dir, &cfg, &NativeEngine).expect("out-of-core");
            report.push(
                Row::new(format!("{tag} {label}"))
                    .col("time_s", t.secs())
                    .col("recall@10", recall_at(&g, &truth, Some(&ids), 10))
                    .col("merge_s", stats.merge_secs)
                    .col("build_s", stats.build_secs),
            );
            std::fs::remove_dir_all(dir).ok();
        }

        // --- IVF-PQ: fast + quality configs (more probes/centroids) ---
        let nlist = (n / 256).clamp(16, 4096);
        // paper: 32-byte PQ codes (m=32) on d=128 with a 2^16 coarse
        // quantizer; nlist is scaled with n, m kept at 16/32 bytes.
        for (label, m, nprobe) in [("ivfpq fast", 16usize, 4usize), ("ivfpq quality", 32, 16)] {
            let params = IvfPqParams { nlist, m: m.min(ds.d / 2), nprobe, ..Default::default() };
            let t = Timer::start();
            let (g, _) = ivfpq::build_graph(&ds, &params, 10);
            report.push(
                Row::new(format!("{tag} {label}"))
                    .col("time_s", t.secs())
                    .col("recall@10", recall_at(&g, &truth, Some(&ids), 10)),
            );
        }
        if scale == Scale::Quick {
            break; // one dataset is enough for the smoke check
        }
    }
    super::finish(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gnnd_quality_exceeds_ivfpq_at_quick_scale() {
        let report = run(Scale::Quick);
        let best = |frag: &str| -> f64 {
            report
                .rows
                .iter()
                .filter(|r| r.label.contains(frag))
                .map(|r| r.cols.iter().find(|(n, _)| n == "recall@10").unwrap().1)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let gnnd = best("gnnd-ooc");
        let ivfpq = best("ivfpq");
        assert!(gnnd > 0.85, "gnnd-ooc recall {gnnd}");
        assert!(
            gnnd > ivfpq,
            "paper's Table-2 ordering violated: gnnd {gnnd} !> ivfpq {ivfpq}"
        );
    }
}
