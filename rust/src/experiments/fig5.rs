//! Figure 5 — ablation over the two §4.3 schemes on SIFT-like data:
//!
//! * `nn-descent`  — classic CPU baseline (single thread);
//! * `gnnd-r1`     — GNND sampling/kernels, but every produced pair is
//!                   inserted (sort+merge, whole-list lock);
//! * `gnnd-r2`     — + selective update (Algorithm-2 winners only);
//! * `gnnd`        — + multiple spinlocks (segmented lists).
//!
//! Paper claims: r2 is >3x faster than r1; full gains a further 5-8%;
//! r1 is >10x faster than CPU NN-Descent (on the paper's GPU — here the
//! gap reflects the coordinator's parallelism instead; the r1->r2->full
//! ordering is the architecture-level claim this bench checks).
//! All runs are driven to comparable Recall@10.

use crate::baselines::nn_descent::{self, NnDescentParams};
use crate::config::UpdateStrategy;
use crate::dataset::synth;
use crate::gnnd;
use crate::metrics::{recall_at, Report, Row};
use crate::util::timer::Timer;

use super::{engine_from_env, sampled_truth10, Scale};

pub fn run(scale: Scale) -> Report {
    let ds = synth::sift_like(scale.n_base(), 0xF165);
    let (ids, truth) = sampled_truth10(&ds);
    let k = 20;
    let iters = 8;

    let mut report = Report::new("Fig 5: ablation (selective update + multi-spinlocks)")
        .meta("dataset", &ds.name)
        .meta("n", ds.len())
        .meta("k", k)
        .meta("iters", iters)
        .meta("engine", format!("{}", engine_from_env()));

    // classic NN-Descent, single thread
    let t = Timer::start();
    let (g, stats) = nn_descent::build(
        &ds,
        &NnDescentParams { k, max_iter: iters, threads: 1, ..Default::default() },
    );
    report.push(
        Row::new("nn-descent (1 thread)")
            .col("time_s", t.secs())
            .col("recall@10", recall_at(&g, &truth, Some(&ids), 10))
            .col("iters", stats.iters as f64),
    );

    for (label, update) in [
        ("gnnd-r1 (insert all)", UpdateStrategy::InsertAll),
        ("gnnd-r2 (+selective)", UpdateStrategy::SelectiveSingleLock),
        ("gnnd (+multi-spinlock)", UpdateStrategy::SelectiveSegmented),
    ] {
        // r1 needs the full distance matrices, which the selective AOT
        // artifacts deliberately never ship to the host — r1 therefore
        // always runs on the native oracle engine.
        let engine = if update == UpdateStrategy::InsertAll {
            crate::config::EngineKind::Native
        } else {
            engine_from_env()
        };
        let params = super::default_params(engine)
            .with_k(k)
            .with_p(10)
            .with_iters(iters)
            .with_update(update);
        let t = Timer::start();
        let out = gnnd::build_with_stats(&ds, &params).expect("gnnd build");
        let secs = t.secs();
        let mut row = Row::new(label)
            .col("time_s", secs)
            .col("recall@10", recall_at(&out.graph, &truth, Some(&ids), 10))
            .col("iters", out.stats.iters as f64);
        for (name, s) in &out.stats.phases {
            if *name == "3.update" {
                row = row.col("update_s", *s);
            }
        }
        report.push(row);
    }
    super::finish(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_ordering_holds_at_quick_scale() {
        let report = run(Scale::Quick);
        let get = |label_frag: &str, col: &str| -> f64 {
            report
                .rows
                .iter()
                .find(|r| r.label.contains(label_frag))
                .and_then(|r| r.cols.iter().find(|(n, _)| n == col))
                .map(|(_, v)| *v)
                .unwrap()
        };
        // every variant reaches reasonable quality
        for frag in ["r1", "r2", "multi-spinlock"] {
            let r = get(frag, "recall@10");
            assert!(r > 0.8, "{frag} recall {r}");
        }
        // the scheme the paper targets: selective update must shrink the
        // *update phase* vs insert-all (total wall time is too noisy to
        // assert in CI, especially in debug builds).
        let u_r1 = get("r1", "update_s");
        let u_r2 = get("r2", "update_s");
        assert!(
            u_r2 < u_r1,
            "selective update phase ({u_r2}s) not below insert-all ({u_r1}s)"
        );
    }
}
