//! Experiment harness: one function per paper table/figure, shared by
//! the `cargo bench` harnesses (`rust/benches/`) and the CLI
//! (`gnnd experiment <id>`). Each returns a [`Report`] whose rows mirror
//! the series the paper plots, and saves JSON under `results/`.
//!
//! Scale: absolute sizes are testbed-bound (we execute XLA on a CPU
//! PJRT client, the paper on an RTX 3090), so the reports check the
//! paper's *relative* claims — orderings, speedup factors, crossovers.
//! `GNND_SCALE=quick|standard|full` (default standard) controls dataset
//! sizes.

pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table2;

use crate::config::{EngineKind, GnndParams};
use crate::dataset::{groundtruth, synth, Dataset};
use crate::metrics::Report;

/// Experiment scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Seconds-fast smoke scale (CI).
    Quick,
    /// Default: minutes, large enough for stable orderings.
    Standard,
    /// The biggest this testbed sustains.
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("GNND_SCALE").as_deref() {
            Ok("quick") => Scale::Quick,
            Ok("full") => Scale::Full,
            _ => Scale::Standard,
        }
    }

    /// Base dataset size for million-scale analog experiments.
    pub fn n_base(self) -> usize {
        match self {
            Scale::Quick => 2_000,
            Scale::Standard => 20_000,
            Scale::Full => 60_000,
        }
    }

    /// Size for the heavy d=960 gist-like runs.
    pub fn n_gist(self) -> usize {
        match self {
            Scale::Quick => 800,
            Scale::Standard => 6_000,
            Scale::Full => 20_000,
        }
    }

    /// Size for the Table-2 out-of-core analog.
    pub fn n_billion_analog(self) -> usize {
        match self {
            Scale::Quick => 6_000,
            Scale::Standard => 48_000,
            Scale::Full => 160_000,
        }
    }
}

/// Engine for the experiments: `GNND_ENGINE=pjrt|native` (default
/// native — the PJRT path is exercised by `examples/e2e_pipeline` and
/// the micro bench; interpret-mode Pallas on a CPU client is far slower
/// than the native oracle, so the fig benches default to native to keep
/// the paper-shape comparisons practical).
pub fn engine_from_env() -> EngineKind {
    match std::env::var("GNND_ENGINE").as_deref() {
        Ok("pjrt") => EngineKind::Pjrt,
        _ => EngineKind::Native,
    }
}

/// Ground truth on min(n, 1000) sampled objects at k=10 (Recall@10 is
/// the paper's quality protocol).
pub fn sampled_truth10(ds: &Dataset) -> (Vec<usize>, Vec<Vec<u32>>) {
    groundtruth::sampled_truth(ds, 1000, 10, 0xE7A1)
}

/// The benchmark datasets of Table 1 at repro scale.
pub fn benchmark_suite(scale: Scale) -> Vec<Dataset> {
    vec![
        synth::sift_like(scale.n_base(), 1),
        synth::deep_like(scale.n_base(), 2),
        synth::gist_like(scale.n_gist(), 3),
        synth::glove_like(scale.n_base(), 4),
    ]
}

/// Default GNND parameters used across experiments.
pub fn default_params(engine: EngineKind) -> GnndParams {
    GnndParams::default().with_engine(engine)
}

/// Save + print a report.
pub fn finish(report: Report) -> Report {
    match report.save_json("results") {
        Ok(path) => println!("{}\n[saved {}]", report.render(), path.display()),
        Err(e) => println!("{}\n[save failed: {e}]", report.render()),
    }
    report
}

/// Named experiment dispatch (CLI).
pub fn run_by_name(name: &str, scale: Scale) -> crate::Result<Report> {
    Ok(match name {
        "fig4" => fig4::run(scale),
        "fig5" => fig5::run(scale),
        "fig6" => fig6::run(scale),
        "fig7" => fig7::run(scale),
        "table2" => table2::run(scale),
        "all" => {
            fig4::run(scale);
            fig5::run(scale);
            fig6::run(scale);
            fig7::run(scale);
            return Ok(table2::run(scale));
        }
        _ => anyhow::bail!("unknown experiment {name:?} (fig4|fig5|fig6|fig7|table2|all)"),
    })
}
