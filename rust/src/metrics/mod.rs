//! Evaluation metrics and experiment reports.
//!
//! * [`recall_at`] — the paper's Eq. 4 Recall@k against exact truth.
//! * [`Report`] — structured experiment output (rows -> aligned text
//!   table + JSON file), used by every fig/table bench harness.

use std::path::Path;

use crate::graph::KnnGraph;
use crate::util::json::Json;

/// Recall@k over the evaluated objects (paper Eq. 4):
/// `sum_i |top-k(G, i) ∩ truth_k(i)| / (n * k)`.
///
/// `truth` rows must be ascending-by-distance ground truth of length
/// >= k for the objects in `ids` (or for `0..n` when `ids` is None).
pub fn recall_at(graph: &KnnGraph, truth: &[Vec<u32>], ids: Option<&[usize]>, k: usize) -> f64 {
    let eval: Vec<usize> = match ids {
        Some(ids) => ids.to_vec(),
        None => (0..graph.n()).collect(),
    };
    assert_eq!(eval.len(), truth.len(), "truth rows must match evaluated ids");
    let mut hit = 0usize;
    let mut total = 0usize;
    for (row, &u) in truth.iter().zip(&eval) {
        let t = k.min(row.len());
        if t == 0 {
            continue;
        }
        let truth_set: std::collections::HashSet<u32> = row[..t].iter().copied().collect();
        hit += graph.ids(u).take(k).filter(|id| truth_set.contains(id)).count();
        total += t;
    }
    if total == 0 {
        0.0
    } else {
        hit as f64 / total as f64
    }
}

/// One experiment row: label + named numeric columns.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub cols: Vec<(String, f64)>,
}

impl Row {
    pub fn new(label: impl Into<String>) -> Self {
        Row { label: label.into(), cols: Vec::new() }
    }

    pub fn col(mut self, name: &str, value: f64) -> Self {
        self.cols.push((name.to_string(), value));
        self
    }
}

/// An experiment report: header metadata + rows, printable as an aligned
/// table (the "same rows the paper reports") and saved as JSON.
#[derive(Debug, Default)]
pub struct Report {
    pub title: String,
    pub meta: Vec<(String, String)>,
    pub rows: Vec<Row>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Report { title: title.into(), meta: Vec::new(), rows: Vec::new() }
    }

    pub fn meta(mut self, key: &str, value: impl ToString) -> Self {
        self.meta.push((key.to_string(), value.to_string()));
        self
    }

    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Render an aligned text table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        for (k, v) in &self.meta {
            out.push_str(&format!("   {k}: {v}\n"));
        }
        if self.rows.is_empty() {
            return out;
        }
        // column set = union over rows, in first-seen order
        let mut names: Vec<String> = Vec::new();
        for row in &self.rows {
            for (name, _) in &row.cols {
                if !names.contains(name) {
                    names.push(name.clone());
                }
            }
        }
        let label_w = self
            .rows
            .iter()
            .map(|r| r.label.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap();
        let mut header = format!("{:<label_w$}", "run");
        for n in &names {
            header.push_str(&format!("  {:>12}", n));
        }
        out.push_str(&header);
        out.push('\n');
        for row in &self.rows {
            let mut line = format!("{:<label_w$}", row.label);
            for n in &names {
                match row.cols.iter().find(|(cn, _)| cn == n) {
                    Some((_, v)) => line.push_str(&format!("  {:>12}", fmt_num(*v))),
                    None => line.push_str(&format!("  {:>12}", "-")),
                }
            }
            out.push_str(&line);
            out.push('\n');
        }
        out
    }

    /// Save as JSON under `dir/<slug>.json`.
    pub fn save_json(&self, dir: impl AsRef<Path>) -> crate::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir.as_ref())?;
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let mut meta = Json::obj();
        for (k, v) in &self.meta {
            meta = meta.set(k, v.as_str());
        }
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut o = Json::obj().set("label", r.label.as_str());
                for (name, v) in &r.cols {
                    o = o.set(name, *v);
                }
                o
            })
            .collect();
        let j = Json::obj()
            .set("title", self.title.as_str())
            .set("meta", meta)
            .set("rows", Json::Arr(rows));
        let path = dir.as_ref().join(format!("{slug}.json"));
        std::fs::write(&path, j.to_string())?;
        Ok(path)
    }
}

fn fmt_num(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 || (v.abs() < 0.01) {
        format!("{v:.3e}")
    } else if v == v.trunc() {
        format!("{v}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::util::rng::Rng;

    #[test]
    fn recall_of_exact_graph_is_one() {
        let ds = synth::uniform(50, 4, 1);
        let truth = groundtruth::exact_topk(&ds, 5);
        let mut g = KnnGraph::empty(50, 5);
        for (u, row) in truth.iter().enumerate() {
            for &v in row {
                g.insert(u, v, ds.dist(u, v as usize), true);
            }
        }
        let r = recall_at(&g, &truth, None, 5);
        assert!((r - 1.0).abs() < 1e-9, "recall={r}");
    }

    #[test]
    fn recall_of_random_graph_is_low() {
        let ds = synth::uniform(300, 8, 2);
        let truth = groundtruth::exact_topk(&ds, 10);
        let mut rng = Rng::new(3);
        let g = KnnGraph::random_init(&ds, 10, &mut rng);
        let r = recall_at(&g, &truth, None, 10);
        assert!(r < 0.3, "random graph recall suspiciously high: {r}");
    }

    #[test]
    fn recall_with_sampled_ids() {
        let ds = synth::uniform(40, 4, 3);
        let (ids, truth) = groundtruth::sampled_truth(&ds, 10, 5, 9);
        let mut g = KnnGraph::empty(40, 5);
        for (row, &u) in truth.iter().zip(&ids) {
            for &v in row {
                g.insert(u, v, ds.dist(u, v as usize), true);
            }
        }
        assert!((recall_at(&g, &truth, Some(&ids), 5) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn report_renders_and_saves() {
        let mut rep = Report::new("Fig. X test").meta("dataset", "uniform");
        rep.push(Row::new("gnnd").col("time_s", 1.5).col("recall@10", 0.99));
        rep.push(Row::new("nnd").col("time_s", 100.0));
        let txt = rep.render();
        assert!(txt.contains("Fig. X test"));
        assert!(txt.contains("recall@10"));
        assert!(txt.contains("gnnd"));
        let dir = std::env::temp_dir().join(format!("gnnd-rep-{}", std::process::id()));
        let path = rep.save_json(&dir).unwrap();
        let s = std::fs::read_to_string(path).unwrap();
        assert!(s.contains("\"recall@10\":0.99"));
        std::fs::remove_dir_all(dir).ok();
    }
}
