//! # GNND — Large-Scale Approximate k-NN Graph Construction
//!
//! A reproduction of *"Large-Scale Approximate k-NN Graph Construction on
//! GPU"* (Wang, Zhao, Zeng — CS.DC 2021) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1/L2** (build-time Python, `python/compile/`): the paper's
//!   distance-evaluation hot spot — tiled pairwise-distance Pallas
//!   kernels wrapped by the `crossmatch` / `bruteforce` jax programs —
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **L3** (this crate): the coordination contribution — fixed-size
//!   sampling, batch assembly, selective update with segmented
//!   spinlocks, the GGM merge primitive, and the out-of-core sharded
//!   construction pipeline. The hot loop executes the AOT artifacts via
//!   the PJRT CPU client (see [`runtime`]; gated behind the `pjrt`
//!   cargo feature); a bit-exact native engine ([`gnnd::engine`])
//!   serves as fallback and oracle.
//! * **Serving** ([`search`]): every finished graph doubles as an ANN
//!   index behind the [`search::AnnIndex`] abstraction —
//!   [`search::SearchIndex`] answers online queries with best-first
//!   beam search (zero-allocation hot path),
//!   [`search::sharded::ShardedIndex`] scatter-gathers across the
//!   per-shard graphs of an out-of-core build (shard residency is
//!   lazily managed by the `ShardStore` LRU cache, so corpora larger
//!   than RAM stay servable), [`search::batch`] fans multi-query
//!   batches across worker threads, and [`search::serve`] benchmarks
//!   the recall-vs-QPS operating curve of a deployment.
//! * **Telemetry** ([`telemetry`]): a contention-free registry of named
//!   work/latency counters, gauges and log2 histograms plus sampled
//!   per-query scatter-gather traces — the live view of the paper's
//!   scanning-rate argument, exported by `serve-bench` and inspected
//!   with `gnnd trace`.
//!
//! Python is never on the construction path: after `make artifacts` the
//! binary is self-contained.
//!
//! ## Quickstart
//!
//! ```no_run
//! use gnnd::dataset::synth;
//! use gnnd::gnnd::{GnndParams, build};
//! use gnnd::search::{SearchIndex, SearchParams};
//!
//! let data = synth::sift_like(10_000, 0xC0FFEE);
//! let graph = build(&data, &GnndParams::default()).unwrap();
//! println!("phi(G) = {}", graph.phi());
//!
//! // serve queries from the graph (note: a dataset row used as the
//! // query matches itself at rank 1 — `search_into_excluding` skips it)
//! let index = SearchIndex::new(&data, &graph, SearchParams::default()).unwrap();
//! let top10 = index.search(data.vec(0), 10);
//! println!("nearest to object 0 (after itself): {:?}", top10.get(1));
//! ```

pub mod baselines;
pub mod config;
pub mod dataset;
pub mod distance;
pub mod experiments;
pub mod gnnd;
pub mod graph;
pub mod merge;
pub mod metrics;
pub mod runtime;
pub mod search;
pub mod telemetry;
pub mod util;

pub use config::{EngineKind, Metric};
pub use dataset::Dataset;
pub use graph::KnnGraph;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
