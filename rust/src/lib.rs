//! # GNND — Large-Scale Approximate k-NN Graph Construction
//!
//! A reproduction of *"Large-Scale Approximate k-NN Graph Construction on
//! GPU"* (Wang, Zhao, Zeng — CS.DC 2021) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1/L2** (build-time Python, `python/compile/`): the paper's
//!   distance-evaluation hot spot — tiled pairwise-distance Pallas
//!   kernels wrapped by the `crossmatch` / `bruteforce` jax programs —
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **L3** (this crate): the coordination contribution — fixed-size
//!   sampling, batch assembly, selective update with segmented
//!   spinlocks, the GGM merge primitive, and the out-of-core sharded
//!   construction pipeline. The hot loop executes the AOT artifacts via
//!   the PJRT CPU client (see [`runtime`]); a bit-exact native engine
//!   ([`gnnd::engine`]) serves as fallback and oracle.
//!
//! Python is never on the construction path: after `make artifacts` the
//! binary is self-contained.
//!
//! ## Quickstart
//!
//! ```no_run
//! use gnnd::dataset::synth;
//! use gnnd::gnnd::{GnndParams, build};
//!
//! let data = synth::sift_like(10_000, 0xC0FFEE);
//! let graph = build(&data, &GnndParams::default()).unwrap();
//! println!("phi(G) = {}", graph.phi());
//! ```

pub mod baselines;
pub mod config;
pub mod dataset;
pub mod distance;
pub mod experiments;
pub mod gnnd;
pub mod graph;
pub mod merge;
pub mod metrics;
pub mod runtime;
pub mod util;

pub use config::{EngineKind, Metric};
pub use dataset::Dataset;
pub use graph::KnnGraph;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
