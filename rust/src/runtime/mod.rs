//! PJRT runtime: load + execute the AOT-compiled XLA artifacts.
//!
//! `make artifacts` (build-time Python, never on the request path)
//! lowers the L2 jax programs — which embed the L1 Pallas kernels — to
//! HLO *text* plus a line-based `manifest.txt`. This module parses the
//! manifest, compiles the selected artifact on the PJRT CPU client
//! (`xla` crate), and marshals batches in and Algorithm-2 reductions
//! out. HLO text (not a serialized proto) is the interchange format:
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.
//!
//! The `xla` crate is not part of the offline dependency closure, so
//! the execution backend is gated behind the `pjrt` cargo feature.
//! Without it, [`PjrtEngine`] / [`BruteforceExec`] are API-compatible
//! stubs whose constructors return a descriptive error, and
//! [`artifacts_available`] reports `false` so benches, examples and
//! tests skip the PJRT paths gracefully.

pub mod manifest;

pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};

/// `true` if the PJRT backend is compiled in *and* a usable manifest
/// exists under `dir` (benches/tests skip PJRT paths gracefully when
/// artifacts were not built or the backend is unavailable).
pub fn artifacts_available(dir: &str) -> bool {
    cfg!(feature = "pjrt") && Manifest::load(dir).is_ok()
}

#[cfg(feature = "pjrt")]
mod backend {
    use anyhow::{bail, Context};

    use crate::config::Metric;
    use crate::dataset::Dataset;
    use crate::gnnd::engine::{Batch, CrossmatchEngine, CrossmatchResult};
    use crate::graph::EMPTY;

    use super::manifest::{ArtifactMeta, Manifest};

    /// Wrapper asserting thread mobility/shareability of the PJRT handles.
    ///
    /// SAFETY: the PJRT CPU client is thread-safe — XLA documents that
    /// `PjRtLoadedExecutable::Execute` may be called concurrently from
    /// multiple threads (the GPU analogy: many streams feeding one device).
    /// The `xla` crate just never added the auto traits because it wraps
    /// raw pointers. Concurrent dispatch matters: serializing executions
    /// behind a mutex makes the runtime the coordinator bottleneck
    /// (§Perf runtime iteration 2: 3.4x end-to-end).
    struct SendExec(xla::PjRtLoadedExecutable);
    unsafe impl Send for SendExec {}
    unsafe impl Sync for SendExec {}

    struct SendClient(#[allow(dead_code)] xla::PjRtClient);
    unsafe impl Send for SendClient {}
    unsafe impl Sync for SendClient {}

    fn f32_bytes(xs: &[f32]) -> &[u8] {
        // SAFETY: plain-old-data reinterpretation; host is little-endian,
        // matching the PJRT CPU client's expectations.
        unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
    }

    fn i32_bytes(xs: &[i32]) -> &[u8] {
        // SAFETY: as above.
        unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
    }

    fn compile(
        client: &xla::PjRtClient,
        path: &std::path::Path,
    ) -> crate::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))
    }

    /// The PJRT-backed cross-matching engine (the paper's on-device path).
    ///
    /// One engine owns one compiled `crossmatch` executable whose static
    /// shape `[B, S, D]` covers the requested `(s, d)`: batches are padded
    /// up (empty slots carry group `-1`, vacant vector lanes are zero —
    /// exact for both metrics) and results sliced back down.
    pub struct PjrtEngine {
        /// Pool of independently-compiled executables, each on its own CPU
        /// client. One TFRT CPU client serializes its executions, so a
        /// single compiled program caps the coordinator at one in-flight
        /// cross-matching call; a small pool restores worker-thread
        /// concurrency (§Perf runtime iteration 7, the paper's multi-stream
        /// analog). Executables are declared before clients so they drop
        /// first.
        pool: Vec<SendExec>,
        cursor: std::sync::atomic::AtomicUsize,
        meta: ArtifactMeta,
        _clients: Vec<SendClient>,
    }

    impl PjrtEngine {
        /// Select, load and compile the smallest pallas `crossmatch`
        /// artifact with `S >= s`, `D >= d` and a matching kernel metric,
        /// with a single-executable pool (tests / light use).
        pub fn load(dir: &str, s: usize, d: usize, metric: Metric) -> crate::Result<Self> {
            Self::load_pooled(dir, s, d, metric, 1)
        }

        /// Like [`PjrtEngine::load`] with a pool of `pool` executables for
        /// concurrent dispatch from the coordinator's worker threads.
        /// `GNND_PJRT_POOL` overrides the requested size.
        pub fn load_pooled(
            dir: &str,
            s: usize,
            d: usize,
            metric: Metric,
            pool: usize,
        ) -> crate::Result<Self> {
            let manifest = Manifest::load(dir)?;
            let meta = manifest.select_crossmatch(s, d, metric)?;
            Self::load_artifact_pooled(dir, meta, pool)
        }

        /// Load a specific artifact (benches use this to pin `impl=jnp`
        /// twins for the kernel ablation).
        pub fn load_artifact(dir: &str, meta: ArtifactMeta) -> crate::Result<Self> {
            Self::load_artifact_pooled(dir, meta, 1)
        }

        pub fn load_artifact_pooled(
            dir: &str,
            meta: ArtifactMeta,
            pool: usize,
        ) -> crate::Result<Self> {
            let pool = std::env::var("GNND_PJRT_POOL")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(pool)
                .max(1);
            let path = std::path::Path::new(dir).join(&meta.file);
            let mut execs = Vec::with_capacity(pool);
            let mut clients = Vec::with_capacity(pool);
            for _ in 0..pool {
                let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
                execs.push(SendExec(compile(&client, &path)?));
                clients.push(SendClient(client));
            }
            Ok(PjrtEngine {
                pool: execs,
                cursor: std::sync::atomic::AtomicUsize::new(0),
                meta,
                _clients: clients,
            })
        }

        /// Round-robin executable selection for this call.
        fn next_exec(&self) -> &SendExec {
            let i = self
                .cursor
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            &self.pool[i % self.pool.len()]
        }

        pub fn artifact(&self) -> &ArtifactMeta {
            &self.meta
        }

        /// Gather `[rows, S, D]` vectors + `[rows, S]` group ids, padded to
        /// the artifact's static shape.
        fn gather(
            &self,
            ds: &Dataset,
            ids: &[u32],
            groups: &[i32],
            rows: usize,
            s: usize,
        ) -> (Vec<f32>, Vec<i32>) {
            let (ab, as_, ad) = (self.meta.b, self.meta.s, self.meta.d);
            debug_assert!(rows <= ab && s <= as_ && ds.d <= ad);
            let mut vecs = vec![0f32; ab * as_ * ad];
            let mut gids = vec![-1i32; ab * as_];
            for r in 0..rows {
                for i in 0..s {
                    let id = ids[r * s + i];
                    if id == EMPTY {
                        continue;
                    }
                    let src = ds.vec(id as usize);
                    let dst = &mut vecs[(r * as_ + i) * ad..(r * as_ + i) * ad + ds.d];
                    dst.copy_from_slice(src);
                    gids[r * as_ + i] = groups[r * s + i];
                }
            }
            (vecs, gids)
        }
    }

    impl CrossmatchEngine for PjrtEngine {
        fn crossmatch(&self, ds: &Dataset, batch: &Batch) -> crate::Result<CrossmatchResult> {
            batch.validate();
            if ds.metric.kernel_metric().as_str() != self.meta.metric {
                bail!(
                    "artifact metric {} does not serve dataset metric {}",
                    self.meta.metric,
                    ds.metric
                );
            }
            let s = batch.s;
            if s > self.meta.s {
                bail!("batch width {s} exceeds artifact S={}", self.meta.s);
            }
            if ds.d > self.meta.d {
                bail!("dataset d={} exceeds artifact D={}", ds.d, self.meta.d);
            }
            let mut out = CrossmatchResult {
                nn_idx: Vec::with_capacity(batch.rows * s),
                nn_dist: Vec::with_capacity(batch.rows * s),
                no_idx: Vec::with_capacity(batch.rows * s),
                no_dist: Vec::with_capacity(batch.rows * s),
                on_idx: Vec::with_capacity(batch.rows * s),
                on_dist: Vec::with_capacity(batch.rows * s),
            };
            // Chunk by the artifact's batch dimension.
            let mut row = 0;
            while row < batch.rows {
                let rows = (batch.rows - row).min(self.meta.b);
                let rng = row * s..(row + rows) * s;
                let (nv, ng) = self.gather(
                    ds,
                    &batch.new_ids[rng.clone()],
                    &batch.groups_new[rng.clone()],
                    rows,
                    s,
                );
                let (ov, og) =
                    self.gather(ds, &batch.old_ids[rng.clone()], &batch.groups_old[rng], rows, s);
                let (ab, as_, ad) = (self.meta.b, self.meta.s, self.meta.d);
                let lit_nv = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &[ab, as_, ad],
                    f32_bytes(&nv),
                )?;
                let lit_ng = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &[ab, as_],
                    i32_bytes(&ng),
                )?;
                let lit_ov = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &[ab, as_, ad],
                    f32_bytes(&ov),
                )?;
                let lit_og = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &[ab, as_],
                    i32_bytes(&og),
                )?;
                let tuple = {
                    let exec = self.next_exec();
                    let res = exec.0.execute::<xla::Literal>(&[lit_nv, lit_ng, lit_ov, lit_og])?;
                    res[0][0].to_literal_sync()?
                };
                let parts = tuple.to_tuple()?;
                if parts.len() != 6 {
                    bail!("crossmatch artifact returned {} outputs, expected 6", parts.len());
                }
                let nn_idx: Vec<i32> = parts[0].to_vec()?;
                let nn_dist: Vec<f32> = parts[1].to_vec()?;
                let no_idx: Vec<i32> = parts[2].to_vec()?;
                let no_dist: Vec<f32> = parts[3].to_vec()?;
                let on_idx: Vec<i32> = parts[4].to_vec()?;
                let on_dist: Vec<f32> = parts[5].to_vec()?;
                // Slice [rows, S_art] back to [rows, s]. Winners always sit
                // in live columns (< s): padded columns carry group -1 and
                // are masked inside the artifact.
                for r in 0..rows {
                    for i in 0..s {
                        let li = r * as_ + i;
                        out.nn_idx.push(nn_idx[li]);
                        out.nn_dist.push(nn_dist[li]);
                        out.no_idx.push(no_idx[li]);
                        out.no_dist.push(no_dist[li]);
                        out.on_idx.push(on_idx[li]);
                        out.on_dist.push(on_dist[li]);
                    }
                }
                row += rows;
            }
            Ok(out)
        }

        fn preferred_batch(&self) -> Option<usize> {
            Some(self.meta.b)
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }

    /// PJRT-backed exact top-k scans (the FAISS-BF baseline + ground truth
    /// on-device path), using the `bruteforce` artifact.
    pub struct BruteforceExec {
        exec: SendExec,
        meta: ArtifactMeta,
        _client: SendClient,
    }

    impl BruteforceExec {
        pub fn load(dir: &str, d: usize, metric: Metric) -> crate::Result<Self> {
            let manifest = Manifest::load(dir)?;
            let meta = manifest.select_bruteforce(d, metric)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let path = std::path::Path::new(dir).join(&meta.file);
            let exec = compile(&client, &path)?;
            Ok(BruteforceExec {
                exec: SendExec(exec),
                meta,
                _client: SendClient(client),
            })
        }

        pub fn artifact(&self) -> &ArtifactMeta {
            &self.meta
        }

        /// Exact top-k (ids ascending by distance) of each query in `qids`
        /// against the whole dataset, self-matches excluded. `k` must be
        /// < artifact K (one slot is reserved to absorb the self-match).
        pub fn topk(&self, ds: &Dataset, qids: &[usize], k: usize) -> crate::Result<Vec<Vec<u32>>> {
            let (aq, an, ad, ak) = (self.meta.q, self.meta.n, self.meta.d, self.meta.k);
            if k >= ak {
                bail!("k={k} must be < artifact K={ak} (self-match slot)");
            }
            if ds.d > ad {
                bail!("dataset d={} exceeds artifact D={ad}", ds.d);
            }
            let n = ds.len();
            // Per-query running best lists, merged across base blocks.
            let mut best: Vec<Vec<(f32, u32)>> = vec![Vec::new(); qids.len()];
            let mut qstart = 0;
            while qstart < qids.len() {
                let qrows = (qids.len() - qstart).min(aq);
                let mut qbuf = vec![0f32; aq * ad];
                for (r, &q) in qids[qstart..qstart + qrows].iter().enumerate() {
                    qbuf[r * ad..r * ad + ds.d].copy_from_slice(ds.vec(q));
                }
                let lit_q = xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &[aq, ad],
                    f32_bytes(&qbuf),
                )?;
                let mut bstart = 0;
                while bstart < n {
                    let brows = (n - bstart).min(an);
                    let mut bbuf = vec![0f32; an * ad];
                    let mut valid = vec![0f32; an];
                    for r in 0..brows {
                        bbuf[r * ad..r * ad + ds.d].copy_from_slice(ds.vec(bstart + r));
                        valid[r] = 1.0;
                    }
                    let lit_b = xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        &[an, ad],
                        f32_bytes(&bbuf),
                    )?;
                    let lit_v = xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        &[an],
                        f32_bytes(&valid),
                    )?;
                    let tuple = {
                        let res =
                            self.exec.0.execute::<xla::Literal>(&[lit_q.clone(), lit_b, lit_v])?;
                        res[0][0].to_literal_sync()?
                    };
                    let (idx_l, dist_l) = tuple.to_tuple2()?;
                    let idx: Vec<i32> = idx_l.to_vec()?;
                    let dist: Vec<f32> = dist_l.to_vec()?;
                    for r in 0..qrows {
                        let q = qids[qstart + r];
                        let row = &mut best[qstart + r];
                        for j in 0..ak {
                            let id = idx[r * ak + j];
                            if id < 0 {
                                break;
                            }
                            let gid = (bstart + id as usize) as u32;
                            if gid as usize == q {
                                continue; // exclude self
                            }
                            row.push((dist[r * ak + j], gid));
                        }
                        row.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                        row.truncate(k);
                    }
                    bstart += brows;
                }
                qstart += qrows;
            }
            Ok(best
                .into_iter()
                .map(|row| row.into_iter().map(|(_, id)| id).collect())
                .collect())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! API-compatible stubs used when the `pjrt` feature (and thus the
    //! `xla` crate) is not compiled in. Constructors fail with a
    //! descriptive error; since [`super::artifacts_available`] reports
    //! `false` in this configuration, well-behaved callers never reach
    //! them.

    use anyhow::bail;

    use crate::config::Metric;
    use crate::dataset::Dataset;
    use crate::gnnd::engine::{Batch, CrossmatchEngine, CrossmatchResult};

    use super::manifest::ArtifactMeta;

    const UNAVAILABLE: &str =
        "PJRT runtime not compiled in (build with `--features pjrt` and a vendored `xla` crate)";

    /// Stub of the PJRT cross-matching engine (`pjrt` feature off).
    pub struct PjrtEngine {
        meta: ArtifactMeta,
    }

    impl PjrtEngine {
        pub fn load(dir: &str, s: usize, d: usize, metric: Metric) -> crate::Result<Self> {
            Self::load_pooled(dir, s, d, metric, 1)
        }

        pub fn load_pooled(
            _dir: &str,
            _s: usize,
            _d: usize,
            _metric: Metric,
            _pool: usize,
        ) -> crate::Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn load_artifact(dir: &str, meta: ArtifactMeta) -> crate::Result<Self> {
            Self::load_artifact_pooled(dir, meta, 1)
        }

        pub fn load_artifact_pooled(
            _dir: &str,
            _meta: ArtifactMeta,
            _pool: usize,
        ) -> crate::Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn artifact(&self) -> &ArtifactMeta {
            &self.meta
        }
    }

    impl CrossmatchEngine for PjrtEngine {
        fn crossmatch(&self, _ds: &Dataset, _batch: &Batch) -> crate::Result<CrossmatchResult> {
            bail!(UNAVAILABLE)
        }

        fn name(&self) -> &'static str {
            "pjrt-unavailable"
        }
    }

    /// Stub of the PJRT bruteforce executor (`pjrt` feature off).
    pub struct BruteforceExec {
        meta: ArtifactMeta,
    }

    impl BruteforceExec {
        pub fn load(_dir: &str, _d: usize, _metric: Metric) -> crate::Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn artifact(&self) -> &ArtifactMeta {
            &self.meta
        }

        pub fn topk(&self, _ds: &Dataset, _qids: &[usize], _k: usize) -> crate::Result<Vec<Vec<u32>>> {
            bail!(UNAVAILABLE)
        }
    }
}

pub use backend::{BruteforceExec, PjrtEngine};
