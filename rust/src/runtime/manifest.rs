//! `artifacts/manifest.txt` parser + artifact selection.
//!
//! The manifest is a line-based `key=value` format emitted by
//! `python/compile/aot.py`, one artifact per line, e.g.:
//!
//! ```text
//! kind=crossmatch name=crossmatch_s32_d128_l2 metric=l2 impl=pallas b=64 s=32 d=128 file=crossmatch_s32_d128_l2.hlo.txt
//! kind=bruteforce name=bruteforce_d128_l2 metric=l2 impl=pallas q=256 n=2048 d=128 k=64 file=bruteforce_d128_l2.hlo.txt
//! ```

use std::path::Path;

use anyhow::{bail, Context};

use crate::config::Metric;

/// Kind of AOT program.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Crossmatch,
    Bruteforce,
}

/// Metadata of one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub kind: ArtifactKind,
    pub name: String,
    /// Kernel metric string ("l2" | "ip").
    pub metric: String,
    /// "pallas" or "jnp" (reference twin for ablation).
    pub impl_: String,
    pub file: String,
    // crossmatch dims
    pub b: usize,
    pub s: usize,
    pub d: usize,
    // bruteforce dims
    pub q: usize,
    pub n: usize,
    pub k: usize,
}

/// All artifacts listed in a manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &str) -> crate::Result<Self> {
        let path = Path::new(dir).join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> crate::Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kind = None;
            let mut name = String::new();
            let mut metric = String::new();
            let mut impl_ = String::from("pallas");
            let mut file = String::new();
            let mut dims = [0usize; 6]; // b s d q n k
            for tok in line.split_whitespace() {
                let (key, val) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad token {tok:?}", lineno + 1))?;
                match key {
                    "kind" => {
                        kind = Some(match val {
                            "crossmatch" => ArtifactKind::Crossmatch,
                            "bruteforce" => ArtifactKind::Bruteforce,
                            _ => bail!("unknown artifact kind {val:?}"),
                        })
                    }
                    "name" => name = val.to_string(),
                    "metric" => metric = val.to_string(),
                    "impl" => impl_ = val.to_string(),
                    "file" => file = val.to_string(),
                    "b" => dims[0] = val.parse()?,
                    "s" => dims[1] = val.parse()?,
                    "d" => dims[2] = val.parse()?,
                    "q" => dims[3] = val.parse()?,
                    "n" => dims[4] = val.parse()?,
                    "k" => dims[5] = val.parse()?,
                    _ => {} // forward compatible
                }
            }
            let kind = kind.with_context(|| format!("manifest line {}: no kind", lineno + 1))?;
            if name.is_empty() || file.is_empty() || metric.is_empty() {
                bail!("manifest line {}: missing name/file/metric", lineno + 1);
            }
            artifacts.push(ArtifactMeta {
                kind,
                name,
                metric,
                impl_,
                file,
                b: dims[0],
                s: dims[1],
                d: dims[2],
                q: dims[3],
                n: dims[4],
                k: dims[5],
            });
        }
        if artifacts.is_empty() {
            bail!("manifest is empty");
        }
        Ok(Manifest { artifacts })
    }

    /// Smallest pallas crossmatch artifact covering `(s, d, metric)`.
    pub fn select_crossmatch(&self, s: usize, d: usize, metric: Metric) -> crate::Result<ArtifactMeta> {
        let want = metric.kernel_metric().as_str();
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Crossmatch
                    && a.impl_ == "pallas"
                    && a.metric == want
                    && a.s >= s
                    && a.d >= d
            })
            .min_by_key(|a| (a.s, a.d))
            .cloned()
            .with_context(|| {
                format!(
                    "no crossmatch artifact for s>={s} d>={d} metric={want}; \
                     regenerate with `make artifacts` or adjust aot.py specs"
                )
            })
    }

    /// Smallest bruteforce artifact covering `(d, metric)`.
    pub fn select_bruteforce(&self, d: usize, metric: Metric) -> crate::Result<ArtifactMeta> {
        let want = metric.kernel_metric().as_str();
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Bruteforce && a.metric == want && a.d >= d)
            .min_by_key(|a| a.d)
            .cloned()
            .with_context(|| format!("no bruteforce artifact for d>={d} metric={want}"))
    }

    /// Find an artifact by exact name (benches pin specific variants).
    pub fn by_name(&self, name: &str) -> crate::Result<ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .cloned()
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
kind=crossmatch name=cm_s16_d32 metric=l2 impl=pallas b=64 s=16 d=32 file=a.hlo.txt
kind=crossmatch name=cm_s32_d128 metric=l2 impl=pallas b=64 s=32 d=128 file=b.hlo.txt
kind=crossmatch name=cm_s32_d128_jnp metric=l2 impl=jnp b=64 s=32 d=128 file=c.hlo.txt
kind=crossmatch name=cm_s32_d100_ip metric=ip impl=pallas b=64 s=32 d=100 file=d.hlo.txt
kind=bruteforce name=bf_d128 metric=l2 impl=pallas q=256 n=2048 d=128 k=64 file=e.hlo.txt
";

    #[test]
    fn parses_and_selects_smallest_cover() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 5);
        let a = m.select_crossmatch(10, 30, Metric::L2).unwrap();
        assert_eq!(a.name, "cm_s16_d32");
        let a = m.select_crossmatch(20, 30, Metric::L2).unwrap();
        assert_eq!(a.name, "cm_s32_d128");
        // cosine lowers to ip
        let a = m.select_crossmatch(32, 100, Metric::Cosine).unwrap();
        assert_eq!(a.name, "cm_s32_d100_ip");
        // jnp twins are never auto-selected
        assert!(m.select_crossmatch(32, 129, Metric::L2).is_err());
        let b = m.select_bruteforce(96, Metric::L2).unwrap();
        assert_eq!(b.name, "bf_d128");
        assert_eq!(b.k, 64);
    }

    #[test]
    fn by_name_and_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.by_name("cm_s32_d128_jnp").unwrap().impl_, "jnp");
        assert!(m.by_name("nope").is_err());
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse("kind=bogus name=x metric=l2 file=f").is_err());
    }
}
