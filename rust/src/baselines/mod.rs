//! Comparison baselines — every system the paper evaluates against,
//! implemented from scratch (DESIGN.md "Substitutions"):
//!
//! * [`nn_descent`] — classic CPU NN-Descent (Dong et al., WWW'11), the
//!   paper's primary baseline (single- and multi-thread).
//! * [`bruteforce`] — exhaustive construction (FAISS-BF analog), native
//!   or through the PJRT `bruteforce` artifact.
//! * [`ggnn`] — hierarchical GPU-style graph build + best-first search
//!   (GGNN analog); its search doubles as the Fig.-7 merge comparator.
//! * [`ivfpq`] — inverted-file product quantization (FAISS-IVFPQ
//!   analog) for the Table-2 billion-scale comparison.
//! * [`kmeans`] — the shared clustering substrate for IVF-PQ.

pub mod bruteforce;
pub mod ggnn;
pub mod ivfpq;
pub mod kmeans;
pub mod nn_descent;
