//! Exhaustive k-NN graph construction — the FAISS-BF analog.
//!
//! Exact by construction: every object is compared against the whole
//! dataset. Used (a) as the Fig.-6 exact-quality/time reference point,
//! (b) as the ground-truth generator, and (c) inside GGNN's bottom-layer
//! block graphs. Two execution paths: native threads, or the PJRT
//! `bruteforce` artifact (tiled Pallas distance kernel + on-device
//! top-k) via [`crate::runtime::BruteforceExec`].

use crate::dataset::{groundtruth, Dataset};
use crate::graph::KnnGraph;
use crate::runtime::BruteforceExec;

/// Build the exact k-NN graph natively (parallel over objects).
pub fn build_native(ds: &Dataset, k: usize) -> KnnGraph {
    let truth = groundtruth::exact_topk(ds, k.min(ds.len() - 1));
    graph_from_rows(ds, &truth, k)
}

/// Build the exact k-NN graph through the PJRT bruteforce artifact.
pub fn build_pjrt(ds: &Dataset, k: usize, exec: &BruteforceExec) -> crate::Result<KnnGraph> {
    let ids: Vec<usize> = (0..ds.len()).collect();
    let rows = exec.topk(ds, &ids, k.min(ds.len() - 1))?;
    Ok(graph_from_rows(ds, &rows, k))
}

/// Assemble a graph from per-object neighbor id rows.
pub fn graph_from_rows(ds: &Dataset, rows: &[Vec<u32>], k: usize) -> KnnGraph {
    let mut g = KnnGraph::empty(ds.len(), k.min(ds.len() - 1));
    for (u, row) in rows.iter().enumerate() {
        let list = g.list_mut(u);
        for (slot, &v) in row.iter().take(list.len()).enumerate() {
            list[slot] = crate::graph::Neighbor {
                id: v,
                dist: ds.dist(u, v as usize),
                new: false,
            };
        }
        // rows arrive ascending already; normalize defensively
        g.normalize_list(u);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::metrics::recall_at;

    #[test]
    fn native_bruteforce_is_exact() {
        let ds = synth::uniform(120, 6, 51);
        let g = build_native(&ds, 10);
        g.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 10);
        let r = recall_at(&g, &truth, None, 10);
        assert!((r - 1.0).abs() < 1e-9, "bruteforce recall {r} != 1");
    }

    #[test]
    fn handles_k_bigger_than_n() {
        let ds = synth::uniform(6, 3, 52);
        let g = build_native(&ds, 32);
        assert_eq!(g.k(), 5);
        g.check_invariants().unwrap();
    }
}
