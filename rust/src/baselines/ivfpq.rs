//! IVF-PQ — the FAISS-IVFPQ analog (Jégou et al., PAMI'11) used in the
//! paper's Table-2 billion-scale comparison.
//!
//! Recipe (faithful to FAISS): a coarse k-means quantizer partitions the
//! dataset into `nlist` inverted lists; residuals `x - c(x)` are encoded
//! by a product quantizer (`m` subspaces x 256 centroids = `m` bytes per
//! vector). Graph construction queries every vector against the index
//! with `nprobe` probed lists and asymmetric distance computation (ADC,
//! per-probe look-up tables). The paper's conclusion — quantization caps
//! graph quality well below GNND — is a property of this recipe, which
//! the Table-2 bench reproduces.

use crate::config::Metric;
use crate::dataset::Dataset;
use crate::graph::KnnGraph;
use crate::util::split_ranges;

use super::kmeans::{self, Codebook};

/// IVF-PQ configuration (defaults scaled from the paper's 2^16-centroid
/// / 32-byte setup to repro scale).
#[derive(Clone, Debug)]
pub struct IvfPqParams {
    /// Coarse centroids (paper: 2^16 for 1e8-1e9 points).
    pub nlist: usize,
    /// PQ subquantizers = bytes per code (paper: 32).
    pub m: usize,
    /// Probed lists per query.
    pub nprobe: usize,
    /// k-means training iterations.
    pub train_iters: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for IvfPqParams {
    fn default() -> Self {
        IvfPqParams { nlist: 128, m: 16, nprobe: 8, train_iters: 8, seed: 0x1F59, threads: 0 }
    }
}

/// A trained IVF-PQ index over a dataset.
pub struct IvfPqIndex {
    pub coarse: Codebook,
    /// One codebook per subspace (256 x dsub each).
    pub books: Vec<Codebook>,
    /// Inverted lists: member object ids per coarse cell.
    pub lists: Vec<Vec<u32>>,
    /// PQ codes, `m` bytes per object.
    pub codes: Vec<u8>,
    pub m: usize,
    pub dsub: usize,
    pub d: usize,
}

const KSUB: usize = 256;

/// Train the index and encode the dataset.
pub fn build_index(ds: &Dataset, params: &IvfPqParams) -> IvfPqIndex {
    let threads = if params.threads == 0 { crate::util::num_threads() } else { params.threads };
    let n = ds.len();
    let d = ds.d;
    let m = params.m.min(d);
    // subspace width: pad-free split (last subspace absorbs remainder)
    let dsub = d / m;
    assert!(dsub > 0, "m must be <= d");
    let nlist = params.nlist.min(n);

    // ---- coarse quantizer ----
    let coarse = kmeans::train(ds.raw(), d, nlist, params.train_iters, Metric::L2, params.seed, threads);

    // ---- assign + residuals ----
    let mut assign = vec![0u32; n];
    parallel_for(n, threads, |i| coarse.assign(ds.vec(i)) as u32, &mut assign);
    let mut residuals = vec![0f32; n * d];
    for i in 0..n {
        let c = coarse.centroid(assign[i] as usize);
        let v = ds.vec(i);
        for j in 0..d {
            residuals[i * d + j] = v[j] - c[j];
        }
    }

    // ---- per-subspace PQ codebooks on residuals ----
    let mut books = Vec::with_capacity(m);
    for sub in 0..m {
        let lo = sub * dsub;
        let w = if sub + 1 == m { d - lo } else { dsub };
        let mut subdata = vec![0f32; n * w];
        for i in 0..n {
            subdata[i * w..(i + 1) * w].copy_from_slice(&residuals[i * d + lo..i * d + lo + w]);
        }
        books.push(kmeans::train(
            &subdata,
            w,
            KSUB,
            params.train_iters,
            Metric::L2,
            params.seed ^ (sub as u64 + 1),
            threads,
        ));
    }

    // ---- encode ----
    let mut codes = vec![0u8; n * m];
    {
        let ranges = split_ranges(n, threads);
        let chunks = split_chunks(&mut codes, &ranges, m);
        crossbeam_utils::thread::scope(|s| {
            for (r, chunk) in ranges.iter().zip(chunks) {
                let r = r.clone();
                let books = &books;
                let residuals = &residuals;
                s.spawn(move |_| {
                    for (slot, i) in r.enumerate() {
                        for (sub, book) in books.iter().enumerate() {
                            let lo = sub * dsub;
                            let w = book.d;
                            let rv = &residuals[i * d + lo..i * d + lo + w];
                            chunk[slot * m + sub] = book.assign(rv) as u8;
                        }
                    }
                });
            }
        })
        .unwrap();
    }

    // ---- inverted lists ----
    let mut lists = vec![Vec::new(); nlist];
    for i in 0..n {
        lists[assign[i] as usize].push(i as u32);
    }

    IvfPqIndex { coarse, books, lists, codes, m, dsub, d }
}

impl IvfPqIndex {
    /// ADC top-k of `q` (object ids ascending by estimated distance),
    /// excluding `exclude`.
    pub fn search(&self, q: &[f32], k: usize, nprobe: usize, exclude: u32) -> Vec<(f32, u32)> {
        // nearest coarse cells
        let mut cells: Vec<(f32, usize)> = (0..self.coarse.k)
            .map(|c| (crate::distance::l2_sq(q, self.coarse.centroid(c)), c))
            .collect();
        cells.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut best: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
        let mut worst = f32::INFINITY;
        let d = self.d;
        for &(_, cell) in cells.iter().take(nprobe.max(1)) {
            if self.lists[cell].is_empty() {
                continue;
            }
            // per-probe LUT on the query residual
            let cen = self.coarse.centroid(cell);
            let qr: Vec<f32> = (0..d).map(|j| q[j] - cen[j]).collect();
            let mut lut = vec![0f32; self.m * KSUB];
            for (sub, book) in self.books.iter().enumerate() {
                let lo = sub * self.dsub;
                let w = book.d;
                let qsub = &qr[lo..lo + w];
                for c in 0..book.k {
                    lut[sub * KSUB + c] = crate::distance::l2_sq(qsub, book.centroid(c));
                }
            }
            for &id in &self.lists[cell] {
                if id == exclude {
                    continue;
                }
                let code = &self.codes[id as usize * self.m..(id as usize + 1) * self.m];
                let mut dist = 0f32;
                for sub in 0..self.m {
                    dist += lut[sub * KSUB + code[sub] as usize];
                }
                if best.len() < k {
                    best.push((dist, id));
                    if best.len() == k {
                        best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                        worst = best[k - 1].0;
                    }
                } else if dist < worst {
                    let pos = best.partition_point(|e| e.0 < dist);
                    best.insert(pos, (dist, id));
                    best.pop();
                    worst = best[k - 1].0;
                }
            }
        }
        best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        best.truncate(k);
        best
        // NOTE: ADC distances are *estimates*; callers re-rank with true
        // distances when assembling the graph (graph stores true dists).
    }
}

/// Build a k-NN graph by querying every vector against the index —
/// the paper's Table-2 IVF-PQ construction.
pub fn build_graph(ds: &Dataset, params: &IvfPqParams, k: usize) -> (KnnGraph, IvfPqIndex) {
    let threads = if params.threads == 0 { crate::util::num_threads() } else { params.threads };
    let index = build_index(ds, params);
    let n = ds.len();
    let k = k.min(n - 1);
    let mut rows: Vec<Vec<u32>> = vec![Vec::new(); n];
    {
        let ranges = split_ranges(n, threads);
        let chunks = split_rows(&mut rows, &ranges);
        crossbeam_utils::thread::scope(|s| {
            for (r, chunk) in ranges.iter().zip(chunks) {
                let r = r.clone();
                let index = &index;
                s.spawn(move |_| {
                    for (slot, i) in r.enumerate() {
                        chunk[slot] = index
                            .search(ds.vec(i), k, params.nprobe, i as u32)
                            .into_iter()
                            .map(|(_, id)| id)
                            .collect();
                    }
                });
            }
        })
        .unwrap();
    }
    // graph stores TRUE distances of the quantizer-chosen ids (as FAISS
    // users do when re-ranking); quality loss comes from wrong ids.
    (super::bruteforce::graph_from_rows(ds, &rows, k), index)
}

fn parallel_for(n: usize, threads: usize, f: impl Fn(usize) -> u32 + Sync, out: &mut [u32]) {
    let ranges = split_ranges(n, threads.max(1));
    let chunks = {
        let mut rest = out;
        let mut v = Vec::new();
        for r in &ranges {
            let (a, b) = rest.split_at_mut(r.len());
            v.push(a);
            rest = b;
        }
        v
    };
    crossbeam_utils::thread::scope(|s| {
        for (r, chunk) in ranges.iter().zip(chunks) {
            let r = r.clone();
            let f = &f;
            s.spawn(move |_| {
                for (slot, i) in r.enumerate() {
                    chunk[slot] = f(i);
                }
            });
        }
    })
    .unwrap();
}

fn split_chunks<'a>(
    data: &'a mut [u8],
    ranges: &[std::ops::Range<usize>],
    stride: usize,
) -> Vec<&'a mut [u8]> {
    let mut rest = data;
    let mut out = Vec::new();
    for r in ranges {
        let (a, b) = rest.split_at_mut(r.len() * stride);
        out.push(a);
        rest = b;
    }
    out
}

fn split_rows<'a>(
    rows: &'a mut [Vec<u32>],
    ranges: &[std::ops::Range<usize>],
) -> Vec<&'a mut [Vec<u32>]> {
    let mut rest = rows;
    let mut out = Vec::new();
    for r in ranges {
        let (a, b) = rest.split_at_mut(r.len());
        out.push(a);
        rest = b;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::metrics::recall_at;

    #[test]
    fn graph_quality_sits_between_random_and_exact() {
        let ds = synth::clustered(500, 8, 71);
        let params = IvfPqParams { nlist: 32, m: 4, nprobe: 6, train_iters: 5, ..Default::default() };
        let (g, _) = build_graph(&ds, &params, 10);
        g.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 10);
        let r = recall_at(&g, &truth, None, 10);
        assert!(r > 0.3, "ivfpq recall {r} too low");
        assert!(r < 0.9999, "ivfpq recall {r} suspiciously exact");
    }

    #[test]
    fn more_probes_more_recall() {
        let ds = synth::clustered(400, 8, 72);
        let truth = groundtruth::exact_topk(&ds, 10);
        let mut rs = Vec::new();
        for nprobe in [1usize, 8] {
            let params = IvfPqParams { nlist: 32, m: 4, nprobe, train_iters: 5, ..Default::default() };
            let (g, _) = build_graph(&ds, &params, 10);
            rs.push(recall_at(&g, &truth, None, 10));
        }
        assert!(rs[1] > rs[0], "nprobe=8 ({}) !> nprobe=1 ({})", rs[1], rs[0]);
    }

    #[test]
    fn codes_have_expected_shape() {
        let ds = synth::clustered(200, 8, 73);
        let params = IvfPqParams { nlist: 16, m: 4, train_iters: 3, ..Default::default() };
        let index = build_index(&ds, &params);
        assert_eq!(index.codes.len(), 200 * 4);
        assert_eq!(index.books.len(), 4);
        let members: usize = index.lists.iter().map(|l| l.len()).sum();
        assert_eq!(members, 200);
    }
}
