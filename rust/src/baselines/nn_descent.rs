//! Classic NN-Descent (Dong, Charikar, Li — WWW 2011), the paper's CPU
//! baseline. Faithful to the original: per-object local join over
//! sampled NEW/OLD neighbors *and reverse neighbors*, immediate
//! both-direction updates of every produced pair, sample rate `rho`,
//! termination at `c < delta * n * k`.
//!
//! The single-thread run is the reference the paper's "100-250x" speedup
//! headline is measured against; a multi-thread variant (scoped threads
//! + whole-list spinlocks, as in the usual OpenMP ports) is included for
//! the fairness ablation.

use crate::dataset::Dataset;
use crate::graph::{concurrent::ConcurrentGraph, KnnGraph};
use crate::util::{rng::Rng, split_ranges};

/// Parameters of a classic NN-Descent run.
#[derive(Clone, Debug)]
pub struct NnDescentParams {
    pub k: usize,
    /// Sample rate (the original paper's rho, default 1.0; 0.5 is the
    /// common speed/quality trade-off).
    pub rho: f64,
    pub max_iter: usize,
    pub delta: f64,
    pub seed: u64,
    /// Worker threads (1 = the paper's single-thread baseline).
    pub threads: usize,
    /// Record phi(G) after every iteration (Fig. 4).
    pub trace_phi: bool,
}

impl Default for NnDescentParams {
    fn default() -> Self {
        NnDescentParams {
            k: 32,
            rho: 1.0,
            max_iter: 30,
            delta: 0.001,
            seed: 0xC1A5_51C0,
            threads: 1,
            trace_phi: false,
        }
    }
}

/// Run statistics.
#[derive(Clone, Debug, Default)]
pub struct NnDescentStats {
    pub iters: usize,
    pub updates: Vec<usize>,
    pub phi_trace: Vec<f64>,
    pub seconds: f64,
    pub distance_evals: u64,
}

/// Build a k-NN graph with classic NN-Descent.
pub fn build(ds: &Dataset, params: &NnDescentParams) -> (KnnGraph, NnDescentStats) {
    let n = ds.len();
    let k = params.k.min(n - 1);
    let mut rng = Rng::new(params.seed);
    let mut graph = KnnGraph::random_init(ds, k, &mut rng);
    let mut stats = NnDescentStats::default();
    let t = crate::util::timer::Timer::start();
    if params.trace_phi {
        stats.phi_trace.push(graph.phi());
    }
    let max_samples = ((params.rho * k as f64).ceil() as usize).max(1);
    let threads = params.threads.max(1);
    let mut dist_evals = 0u64;

    for _ in 0..params.max_iter {
        // ---- sampling: forward NEW (mark sampled OLD) + all OLD ----
        let mut new_f: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut old_f: Vec<Vec<u32>> = vec![Vec::new(); n];
        for u in 0..n {
            // reservoir-free: take up to rho*k NEW (closest first, like
            // the reference implementation), all OLD
            let mut taken = 0;
            let list = graph.list_mut(u);
            for e in list.iter_mut() {
                if e.is_empty() {
                    break;
                }
                if e.new {
                    if taken < max_samples {
                        new_f[u].push(e.id);
                        e.new = false;
                        taken += 1;
                    }
                } else {
                    old_f[u].push(e.id);
                }
            }
        }
        // ---- reverse lists ----
        let mut new_r: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut old_r: Vec<Vec<u32>> = vec![Vec::new(); n];
        for u in 0..n {
            for &v in &new_f[u] {
                new_r[v as usize].push(u as u32);
            }
            for &v in &old_f[u] {
                old_r[v as usize].push(u as u32);
            }
        }
        // ---- join lists: new = new_f ∪ sample(new_r, rho*k) ----
        let mut join_new: Vec<Vec<u32>> = Vec::with_capacity(n);
        let mut join_old: Vec<Vec<u32>> = Vec::with_capacity(n);
        for u in 0..n {
            let mut jn = new_f[u].clone();
            sample_into(&mut jn, &new_r[u], max_samples, &mut rng);
            jn.sort_unstable();
            jn.dedup();
            let mut jo = old_f[u].clone();
            sample_into(&mut jo, &old_r[u], max_samples, &mut rng);
            jo.sort_unstable();
            jo.dedup();
            join_new.push(jn);
            join_old.push(jo);
        }

        // ---- local join + immediate both-direction updates ----
        let iter_updates;
        let iter_evals;
        {
            let cg = ConcurrentGraph::new(&mut graph, usize::MAX); // 1 lock/list
            let ranges = split_ranges(n, threads);
            let evals = std::sync::atomic::AtomicU64::new(0);
            crossbeam_utils::thread::scope(|scope| {
                for r in &ranges {
                    let r = r.clone();
                    let cg = &cg;
                    let (join_new, join_old) = (&join_new, &join_old);
                    let evals = &evals;
                    scope.spawn(move |_| {
                        let mut local_evals = 0u64;
                        for u in r {
                            let jn = &join_new[u];
                            let jo = &join_old[u];
                            for (a, &u1) in jn.iter().enumerate() {
                                let v1 = ds.vec(u1 as usize);
                                // NEW x NEW (unordered pairs)
                                for &u2 in &jn[a + 1..] {
                                    if u1 == u2 {
                                        continue;
                                    }
                                    let d = crate::distance::distance(
                                        ds.metric,
                                        v1,
                                        ds.vec(u2 as usize),
                                    );
                                    local_evals += 1;
                                    cg.insert(u1 as usize, u2, d);
                                    cg.insert(u2 as usize, u1, d);
                                }
                                // NEW x OLD
                                for &u2 in jo.iter() {
                                    if u1 == u2 {
                                        continue;
                                    }
                                    let d = crate::distance::distance(
                                        ds.metric,
                                        v1,
                                        ds.vec(u2 as usize),
                                    );
                                    local_evals += 1;
                                    cg.insert(u1 as usize, u2, d);
                                    cg.insert(u2 as usize, u1, d);
                                }
                            }
                        }
                        evals.fetch_add(local_evals, std::sync::atomic::Ordering::Relaxed);
                    });
                }
            })
            .unwrap();
            iter_updates = cg.updates();
            iter_evals = evals.into_inner();
        }
        graph.normalize_all(threads);
        dist_evals += iter_evals;
        stats.iters += 1;
        stats.updates.push(iter_updates);
        if params.trace_phi {
            stats.phi_trace.push(graph.phi());
        }
        if (iter_updates as f64) < params.delta * (n * k) as f64 {
            break;
        }
    }
    stats.seconds = t.secs();
    stats.distance_evals = dist_evals;
    (graph, stats)
}

/// Append up to `m` random picks of `src` to `dst`.
fn sample_into(dst: &mut Vec<u32>, src: &[u32], m: usize, rng: &mut Rng) {
    if src.len() <= m {
        dst.extend_from_slice(src);
    } else {
        for i in rng.distinct(src.len(), m) {
            dst.push(src[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::metrics::recall_at;

    #[test]
    fn converges_on_clustered_data() {
        // n must dwarf k^2 for the 2011 paper's "small portion of the
        // comparisons" claim to bite (evals ~ c*n*k^2 vs n^2/2 brute).
        let ds = synth::clustered(4_000, 8, 41);
        let params = NnDescentParams { k: 10, max_iter: 12, ..Default::default() };
        let (g, stats) = build(&ds, &params);
        g.check_invariants().unwrap();
        let (ids, truth) = groundtruth::sampled_truth(&ds, 500, 10, 1);
        let r = recall_at(&g, &truth, Some(&ids), 10);
        assert!(r > 0.95, "classic NN-Descent recall {r} (stats {stats:?})");
        assert!(stats.distance_evals > 0);
        let bf = (4_000u64 * 3_999) / 2;
        assert!(stats.distance_evals < bf, "{} >= {bf}", stats.distance_evals);
    }

    #[test]
    fn multi_thread_matches_single_quality() {
        let ds = synth::clustered(400, 6, 42);
        let p1 = NnDescentParams { k: 10, threads: 1, ..Default::default() };
        let p4 = NnDescentParams { k: 10, threads: 4, ..Default::default() };
        let truth = groundtruth::exact_topk(&ds, 10);
        let (g1, _) = build(&ds, &p1);
        let (g4, _) = build(&ds, &p4);
        let r1 = recall_at(&g1, &truth, None, 10);
        let r4 = recall_at(&g4, &truth, None, 10);
        assert!((r1 - r4).abs() < 0.05, "r1={r1} r4={r4}");
    }

    #[test]
    fn phi_trace_monotone() {
        let ds = synth::clustered(250, 6, 43);
        let params = NnDescentParams { k: 8, trace_phi: true, max_iter: 8, ..Default::default() };
        let (_, stats) = build(&ds, &params);
        for w in stats.phi_trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-6);
        }
    }

    #[test]
    fn rho_reduces_work() {
        let ds = synth::clustered(300, 6, 44);
        let full = NnDescentParams { k: 12, rho: 1.0, ..Default::default() };
        let half = NnDescentParams { k: 12, rho: 0.5, ..Default::default() };
        let (_, s_full) = build(&ds, &full);
        let (_, s_half) = build(&ds, &half);
        assert!(
            s_half.distance_evals < s_full.distance_evals,
            "rho=0.5 did not reduce distance evals"
        );
    }
}
