//! Lloyd's k-means with k-means++ seeding — the clustering substrate of
//! the IVF-PQ baseline (coarse quantizer + per-subspace codebooks).

use crate::config::Metric;
use crate::util::{rng::Rng, split_ranges};

/// A trained codebook: `k` centroids of dimension `d` (row-major).
#[derive(Clone, Debug)]
pub struct Codebook {
    pub k: usize,
    pub d: usize,
    pub centroids: Vec<f32>,
}

impl Codebook {
    #[inline]
    pub fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.d..(c + 1) * self.d]
    }

    /// Index of the nearest centroid to `v` (squared L2).
    pub fn assign(&self, v: &[f32]) -> usize {
        let mut best = (f32::INFINITY, 0usize);
        for c in 0..self.k {
            let d = crate::distance::l2_sq(v, self.centroid(c));
            if d < best.0 {
                best = (d, c);
            }
        }
        best.1
    }
}

/// Train k-means on `data` (`n` rows x `d`), `iters` Lloyd rounds.
///
/// Seeding is k-means++ on a bounded sample for O(k * sample) cost.
/// Assignment is always squared-L2 (quantization error), independent of
/// the search metric (as in FAISS); `_metric` is kept in the signature
/// to document that choice at call sites.
pub fn train(
    data: &[f32],
    d: usize,
    k: usize,
    iters: usize,
    _metric: Metric,
    seed: u64,
    threads: usize,
) -> Codebook {
    let n = data.len() / d;
    assert!(n > 0 && k > 0);
    let k = k.min(n);
    let mut rng = Rng::new(seed ^ 0x6B6D);
    let row = |i: usize| &data[i * d..(i + 1) * d];

    // ---- k-means++ seeding on a sample ----
    let sample_n = n.min(k * 16).max(k);
    let sample_ids = rng.distinct(n, sample_n);
    let mut centroids = Vec::with_capacity(k * d);
    let first = sample_ids[rng.below(sample_n)];
    centroids.extend_from_slice(row(first));
    let mut d2: Vec<f32> = sample_ids
        .iter()
        .map(|&i| crate::distance::l2_sq(row(i), &centroids[..d]))
        .collect();
    for c in 1..k {
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let pick = if total <= 0.0 {
            sample_ids[rng.below(sample_n)]
        } else {
            let mut target = rng.f64() * total;
            let mut chosen = sample_ids[sample_n - 1];
            for (j, &i) in sample_ids.iter().enumerate() {
                target -= d2[j] as f64;
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        };
        centroids.extend_from_slice(row(pick));
        let newc = &centroids[c * d..(c + 1) * d];
        for (j, &i) in sample_ids.iter().enumerate() {
            let nd = crate::distance::l2_sq(row(i), newc);
            if nd < d2[j] {
                d2[j] = nd;
            }
        }
    }
    let mut book = Codebook { k, d, centroids };

    // ---- Lloyd iterations (parallel assignment) ----
    let threads = threads.max(1);
    let mut assign = vec![0u32; n];
    for _ in 0..iters {
        let ranges = split_ranges(n, threads);
        {
            let book = &book;
            let chunks: Vec<&mut [u32]> = {
                let mut rest = assign.as_mut_slice();
                let mut out = Vec::new();
                for r in &ranges {
                    let (a, b) = rest.split_at_mut(r.len());
                    out.push(a);
                    rest = b;
                }
                out
            };
            crossbeam_utils::thread::scope(|s| {
                for (r, chunk) in ranges.iter().zip(chunks) {
                    let r = r.clone();
                    s.spawn(move |_| {
                        for (slot, i) in r.enumerate() {
                            chunk[slot] = book.assign(row(i)) as u32;
                        }
                    });
                }
            })
            .unwrap();
        }
        // recompute centroids
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i] as usize;
            counts[c] += 1;
            let v = row(i);
            for j in 0..d {
                sums[c * d + j] += v[j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster from a random point
                let i = rng.below(n);
                book.centroids[c * d..(c + 1) * d].copy_from_slice(row(i));
            } else {
                for j in 0..d {
                    book.centroids[c * d + j] = (sums[c * d + j] / counts[c] as f64) as f32;
                }
            }
        }
    }
    book
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn recovers_separated_clusters() {
        // 3 well-separated blobs -> 3 centroids land near blob means
        let mut rng = Rng::new(61);
        let d = 4;
        let mut data = Vec::new();
        let means = [[0.0f32; 4], [20.0; 4], [-20.0; 4]];
        for i in 0..300 {
            let m = &means[i % 3];
            for j in 0..d {
                data.push(m[j] + rng.normal_f32() * 0.3);
            }
        }
        let book = train(&data, d, 3, 10, Metric::L2, 1, 2);
        for m in &means {
            let best = (0..3)
                .map(|c| crate::distance::l2_sq(m, book.centroid(c)))
                .fold(f32::INFINITY, f32::min);
            assert!(best < 1.0, "no centroid near {m:?} (best {best})");
        }
    }

    #[test]
    fn quantization_error_decreases_with_k() {
        let ds = synth::clustered(400, 8, 62);
        let err = |k: usize| -> f64 {
            let book = train(ds.raw(), ds.d, k, 6, Metric::L2, 2, 2);
            (0..ds.len())
                .map(|i| {
                    let c = book.assign(ds.vec(i));
                    crate::distance::l2_sq(ds.vec(i), book.centroid(c)) as f64
                })
                .sum()
        };
        let e4 = err(4);
        let e32 = err(32);
        assert!(e32 < e4, "e32={e32} !< e4={e4}");
    }

    #[test]
    fn assignment_is_nearest() {
        let book = Codebook { k: 3, d: 2, centroids: vec![0.0, 0.0, 10.0, 0.0, 0.0, 10.0] };
        assert_eq!(book.assign(&[1.0, 1.0]), 0);
        assert_eq!(book.assign(&[9.0, 1.0]), 1);
        assert_eq!(book.assign(&[1.0, 9.0]), 2);
    }
}
