//! GGNN-style hierarchical graph construction + best-first search
//! (Groh et al., arXiv 1912.01059) — the paper's strongest GPU
//! comparator (Fig. 6) and the search-based merge alternative (Fig. 7).
//!
//! Faithful structure at repro scale:
//! 1. a layer hierarchy `L0 ⊃ L1 ⊃ ... ⊃ Lt` by factor-`c` sampling
//!    until the top layer fits one block;
//! 2. bottom-up: each layer is split into blocks whose sub-graphs are
//!    built exhaustively (the "construct k-NN graph for each subset
//!    exhaustively on GPU" step);
//! 3. top-down: every point queries the layer above with greedy
//!    best-first search (with backtracking, slack factor `tau`) to pull
//!    neighborhood relations down, then `t` refinement rounds let each
//!    point re-search its own layer.
//!
//! The searches perform many random accesses per query — exactly the
//! behaviour the paper blames for GGNN's gap to GNND; the Fig.-6 bench
//! measures that gap on this implementation.

use crate::dataset::Dataset;
use crate::dataset::groundtruth::ordered::F32;
use crate::graph::{KnnGraph, Neighbor};
use crate::util::{rng::Rng, split_ranges};

/// GGNN build parameters.
#[derive(Clone, Debug)]
pub struct GgnnParams {
    /// Graph degree (the GGNN paper fixes 24 in the evaluated configs).
    pub k: usize,
    /// Block size for exhaustive sub-graphs.
    pub block: usize,
    /// Layer down-sampling factor.
    pub factor: usize,
    /// Slack factor tau: the search frontier keeps `ceil(tau * k)` extra
    /// exploration slots beyond the best-k (GGNN's slack variable).
    pub tau: f64,
    /// Refinement iterations t.
    pub refinements: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for GgnnParams {
    fn default() -> Self {
        GgnnParams { k: 24, block: 256, factor: 4, tau: 0.5, refinements: 2, seed: 0x66_4E4E, threads: 0 }
    }
}

/// A built GGNN index: the bottom-layer graph is the k-NN graph.
pub struct GgnnIndex {
    pub graph: KnnGraph,
    /// Entry points for searches (top-layer ids).
    pub entries: Vec<u32>,
}

/// Best-first search over `graph` (ids of `subset`, which indexes `ds`)
/// for query vector `q`: returns up to `k` (dist, id) ascending.
/// `ef = k + ceil(tau * k)` is the exploration width.
pub fn search_graph(
    ds: &Dataset,
    graph: &KnnGraph,
    subset: Option<&[u32]>,
    q: &[f32],
    k: usize,
    tau: f64,
    entries: &[u32],
    exclude: u32,
) -> Vec<(f32, u32)> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    // tau is GGNN's slack knob: it widens the exploration beam beyond
    // the best-k frontier (ef-style). tau=0.3..0.5 are the paper's
    // operating points; larger tau trades time for recall.
    let ef = k + ((4.0 * tau * k as f64).ceil() as usize).max(1);
    let to_global = |local: u32| -> u32 {
        match subset {
            Some(map) => map[local as usize],
            None => local,
        }
    };
    let mut visited = std::collections::HashSet::new();
    // frontier: min-heap by distance; results: max-heap of best ef
    let mut frontier: BinaryHeap<Reverse<(F32, u32)>> = BinaryHeap::new();
    let mut results: BinaryHeap<(F32, u32)> = BinaryHeap::new();
    for &e in entries {
        if visited.insert(e) {
            let d = ds.dist_to(to_global(e) as usize, q);
            frontier.push(Reverse((F32(d), e)));
            if to_global(e) != exclude {
                results.push((F32(d), e));
            }
        }
    }
    while let Some(Reverse((F32(d), u))) = frontier.pop() {
        // backtracking bound: stop when the closest open candidate is
        // worse than the worst retained result and results are full
        if results.len() >= ef {
            if let Some(&(F32(w), _)) = results.peek() {
                if d > w {
                    break;
                }
            }
        }
        for e in graph.list(u as usize) {
            if e.is_empty() {
                break;
            }
            if !visited.insert(e.id) {
                continue;
            }
            let dv = ds.dist_to(to_global(e.id) as usize, q);
            frontier.push(Reverse((F32(dv), e.id)));
            if to_global(e.id) != exclude {
                results.push((F32(dv), e.id));
                if results.len() > ef {
                    results.pop();
                }
            }
        }
    }
    let mut out: Vec<(f32, u32)> = results.into_iter().map(|(F32(d), id)| (d, to_global(id))).collect();
    out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    out.truncate(k);
    out
}

/// Exhaustive sub-graph over one block (local indices into `subset`).
fn block_graph(ds: &Dataset, subset: &[u32], block: &[u32], k: usize, g: &mut KnnGraph) {
    for &ul in block {
        let u = subset[ul as usize] as usize;
        let mut cands: Vec<(f32, u32)> = block
            .iter()
            .filter(|&&vl| vl != ul)
            .map(|&vl| (ds.dist(u, subset[vl as usize] as usize), vl))
            .collect();
        cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let list = g.list_mut(ul as usize);
        for (slot, &(d, vl)) in cands.iter().take(k).enumerate() {
            list[slot] = Neighbor { id: vl, dist: d, new: false };
        }
    }
}

/// Build the GGNN index (bottom graph = the k-NN graph of `ds`).
pub fn build(ds: &Dataset, params: &GgnnParams) -> GgnnIndex {
    let n = ds.len();
    let k = params.k.min(n - 1);
    let threads = if params.threads == 0 { crate::util::num_threads() } else { params.threads };
    let mut rng = Rng::new(params.seed);

    // ---- hierarchy of layers (ids into ds) ----
    let mut layers: Vec<Vec<u32>> = vec![(0..n as u32).collect()];
    while layers.last().unwrap().len() > params.block {
        let prev = layers.last().unwrap();
        let m = (prev.len() / params.factor).max(1);
        let picks = rng.distinct(prev.len(), m);
        layers.push(picks.into_iter().map(|i| prev[i]).collect());
    }

    // ---- top-down construction ----
    let mut upper: Option<(KnnGraph, Vec<u32>)> = None; // (graph, subset)
    for layer in layers.iter().rev() {
        let subset = layer.clone();
        let ln = subset.len();
        let lk = k.min(ln.saturating_sub(1)).max(1);
        let mut g = KnnGraph::empty(ln, lk);
        // blocks: random partition, exhaustive sub-graphs
        let mut order: Vec<u32> = (0..ln as u32).collect();
        rng.shuffle(&mut order);
        for chunk in order.chunks(params.block) {
            block_graph(ds, &subset, chunk, lk, &mut g);
        }
        // pull candidates from the layer above via best-first search
        if let Some((ref ug, ref usubset)) = upper {
            // spread entry points across the upper layer (random entries
            // in one region strand the search in that region)
            let m = usubset.len();
            let entries: Vec<u32> = (0..m.min(8))
                .map(|i| ((i * m) / m.min(8)) as u32)
                .collect();
            let ranges = split_ranges(ln, threads);
            let results: Vec<Vec<(f32, u32)>> = parallel_map(&ranges, |ul| {
                let u = subset[ul] as usize;
                search_graph(ds, ug, Some(usubset), ds.vec(u), lk, params.tau, &entries, u as u32)
            });
            // usubset ids are global; map back into this layer's local
            // index space where present (sampled layers are subsets).
            let local_of: std::collections::HashMap<u32, u32> = subset
                .iter()
                .enumerate()
                .map(|(i, &g)| (g, i as u32))
                .collect();
            for (ul, found) in results.into_iter().enumerate() {
                for (d, gid) in found {
                    if let Some(&vl) = local_of.get(&gid) {
                        if vl as usize != ul {
                            g.insert(ul, vl, d, false);
                        }
                    }
                }
            }
        }
        upper = Some((g, subset));
    }
    let (mut graph, _) = upper.unwrap();

    // ---- refinement rounds over the bottom layer ----
    // Each point re-searches the graph for itself, entering from its own
    // current neighborhood (GGNN's refinement walks outward from the
    // point) plus a few spread global entries to escape local islands.
    let globals: Vec<u32> = (0..8.min(n)).map(|i| ((i * n) / 8.min(n)) as u32).collect();
    for _ in 0..params.refinements {
        let ranges = split_ranges(n, threads);
        let graph_ref = &graph;
        let found: Vec<Vec<(f32, u32)>> = parallel_map(&ranges, |u| {
            let mut entries: Vec<u32> = graph_ref.ids(u).take(8).collect();
            entries.extend_from_slice(&globals);
            search_graph(ds, graph_ref, None, ds.vec(u), k, params.tau, &entries, u as u32)
        });
        for (u, cands) in found.into_iter().enumerate() {
            for (d, v) in cands {
                // symmetrize: a discovered neighbor is evidence in both
                // directions (GGNN links are made symmetric on insert)
                graph.insert(u, v, d, false);
                graph.insert(v as usize, u as u32, d, false);
            }
        }
    }
    GgnnIndex { graph, entries: globals }
}

/// Merge two sub-graphs by cross-searching (the Fig.-7 "GGNN" merge):
/// each object of one subset queries the other sub-graph for `k/2`
/// candidates. Only one sub-graph's neighborhood relations are used per
/// search — the structural disadvantage vs GGM the paper calls out.
pub fn merge_by_search(
    ds: &Dataset,
    n1: usize,
    g1: &KnnGraph,
    g2: &KnnGraph,
    tau: f64,
    threads: usize,
) -> KnnGraph {
    let n = ds.len();
    let n2 = n - n1;
    let k = g1.k();
    let threads = if threads == 0 { crate::util::num_threads() } else { threads };
    let mut g2r = g2.clone();
    g2r.remap_ids(|id| id + n1 as u32);
    let mut joined = g1.stack(&g2r);
    let sub1: Vec<u32> = (0..n1 as u32).collect();
    let sub2: Vec<u32> = (n1 as u32..n as u32).collect();
    // spread entry points across each sub-graph
    let spread = |m: usize| -> Vec<u32> {
        let e = 16.min(m);
        (0..e).map(|i| ((i * m) / e) as u32).collect()
    };
    let e1 = spread(n1);
    let e2 = spread(n2);
    let half = (k / 2).max(1);
    let ranges = split_ranges(n, threads);
    let found: Vec<Vec<(f32, u32)>> = parallel_map(&ranges, |u| {
        if u < n1 {
            search_graph(ds, g2, Some(&sub2), ds.vec(u), half, tau, &e2, u as u32)
        } else {
            search_graph(ds, g1, Some(&sub1), ds.vec(u), half, tau, &e1, u as u32)
        }
    });
    for (u, cands) in found.into_iter().enumerate() {
        for (d, v) in cands {
            joined.insert(u, v, d, false);
        }
    }
    joined
}

/// Map `f` over `0..n` in parallel ranges, preserving order.
fn parallel_map<T: Send>(
    ranges: &[std::ops::Range<usize>],
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let mut out: Vec<Vec<T>> = Vec::new();
    crossbeam_utils::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|r| {
                let r = r.clone();
                let f = &f;
                s.spawn(move |_| r.map(f).collect::<Vec<T>>())
            })
            .collect();
        for h in handles {
            out.push(h.join().unwrap());
        }
    })
    .unwrap();
    out.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::metrics::recall_at;

    #[test]
    fn builds_reasonable_graph() {
        let ds = synth::clustered(600, 8, 81);
        let params = GgnnParams { k: 10, block: 128, refinements: 2, ..Default::default() };
        let index = build(&ds, &params);
        index.graph.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 10);
        let r = recall_at(&index.graph, &truth, None, 10);
        assert!(r > 0.7, "ggnn recall {r}");
    }

    #[test]
    fn more_refinement_is_better() {
        let ds = synth::clustered(400, 8, 82);
        let truth = groundtruth::exact_topk(&ds, 10);
        let r_of = |t: usize| {
            let params = GgnnParams { k: 10, block: 64, refinements: t, ..Default::default() };
            recall_at(&build(&ds, &params).graph, &truth, None, 10)
        };
        let r0 = r_of(0);
        let r3 = r_of(3);
        assert!(r3 >= r0, "refinements hurt: {r3} < {r0}");
        assert!(r3 > 0.75, "r3={r3}");
    }

    #[test]
    fn search_finds_near_neighbors_on_exact_graph() {
        // uniform data: the directed exact k-NN graph is navigable (no
        // disconnected cluster islands), so best-first search must work.
        let ds = synth::uniform(300, 6, 83);
        let g = crate::baselines::bruteforce::build_native(&ds, 10);
        let truth = groundtruth::exact_topk(&ds, 5);
        let entries: Vec<u32> = (0..16).map(|i| i * 18).collect();
        let mut hits = 0;
        let mut total = 0;
        for q in (0..300).step_by(10) {
            let found = search_graph(&ds, &g, None, ds.vec(q), 5, 2.0, &entries, q as u32);
            let set: std::collections::HashSet<u32> = found.iter().map(|&(_, id)| id).collect();
            hits += truth[q].iter().filter(|id| set.contains(id)).count();
            total += 5;
        }
        let r = hits as f64 / total as f64;
        assert!(r > 0.8, "graph search recall {r}");
    }

    #[test]
    fn merge_by_search_improves_over_naive_join() {
        let ds = synth::clustered(300, 6, 84);
        let n1 = 150;
        let ids1: Vec<usize> = (0..n1).collect();
        let ids2: Vec<usize> = (n1..300).collect();
        let d1 = ds.select(&ids1, "h1");
        let d2 = ds.select(&ids2, "h2");
        let g1 = crate::baselines::bruteforce::build_native(&d1, 8);
        let g2 = crate::baselines::bruteforce::build_native(&d2, 8);
        let merged = merge_by_search(&ds, n1, &g1, &g2, 1.0, 2);
        merged.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 8);
        let r = recall_at(&merged, &truth, None, 8);
        let mut g2r = g2.clone();
        g2r.remap_ids(|id| id + n1 as u32);
        let naive = g1.stack(&g2r);
        let rn = recall_at(&naive, &truth, None, 8);
        assert!(r > rn, "merge-by-search {r} !> naive {rn}");
    }
}
