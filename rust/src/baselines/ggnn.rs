//! GGNN-style hierarchical graph construction + best-first search
//! (Groh et al., arXiv 1912.01059) — the paper's strongest GPU
//! comparator (Fig. 6) and the search-based merge alternative (Fig. 7).
//!
//! Faithful structure at repro scale:
//! 1. a layer hierarchy `L0 ⊃ L1 ⊃ ... ⊃ Lt` by factor-`c` sampling
//!    until the top layer fits one block;
//! 2. bottom-up: each layer is split into blocks whose sub-graphs are
//!    built exhaustively (the "construct k-NN graph for each subset
//!    exhaustively on GPU" step);
//! 3. top-down: every point queries the layer above with greedy
//!    best-first search (with backtracking, slack factor `tau`) to pull
//!    neighborhood relations down, then `t` refinement rounds let each
//!    point re-search its own layer.
//!
//! The searches perform many random accesses per query — exactly the
//! behaviour the paper blames for GGNN's gap to GNND; the Fig.-6 bench
//! measures that gap on this implementation.

use crate::dataset::Dataset;
use crate::graph::{KnnGraph, Neighbor};
use crate::search::{beam_search, QuerySpec, SearchScratch};
use crate::util::{rng::Rng, split_ranges};

/// GGNN build parameters.
#[derive(Clone, Debug)]
pub struct GgnnParams {
    /// Graph degree (the GGNN paper fixes 24 in the evaluated configs).
    pub k: usize,
    /// Block size for exhaustive sub-graphs.
    pub block: usize,
    /// Layer down-sampling factor.
    pub factor: usize,
    /// Slack factor tau: the search frontier keeps `ceil(tau * k)` extra
    /// exploration slots beyond the best-k (GGNN's slack variable).
    pub tau: f64,
    /// Refinement iterations t.
    pub refinements: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for GgnnParams {
    fn default() -> Self {
        GgnnParams { k: 24, block: 256, factor: 4, tau: 0.5, refinements: 2, seed: 0x66_4E4E, threads: 0 }
    }
}

/// A built GGNN index: the bottom-layer graph is the k-NN graph.
pub struct GgnnIndex {
    pub graph: KnnGraph,
    /// Entry points for searches (top-layer ids).
    pub entries: Vec<u32>,
}

/// Best-first search over `graph` (ids of `subset`, which indexes `ds`)
/// for query vector `q`: returns up to `k` (dist, id) ascending.
/// `ef = k + ceil(tau * k)` is the exploration width.
///
/// Thin adapter over [`crate::search::beam_search`] — the codebase's
/// single greedy-search implementation — translating GGNN's slack
/// factor `tau` into the `ef` exploration width. tau=0.3..0.5 are the
/// GGNN paper's operating points; larger tau trades time for recall.
#[allow(clippy::too_many_arguments)]
pub fn search_graph(
    ds: &Dataset,
    graph: &KnnGraph,
    subset: Option<&[u32]>,
    q: &[f32],
    k: usize,
    tau: f64,
    entries: &[u32],
    exclude: u32,
) -> Vec<(f32, u32)> {
    let mut scratch = SearchScratch::new();
    search_graph_with(ds, graph, subset, q, k, tau, entries, exclude, &mut scratch)
}

/// [`search_graph`] with a caller-kept scratch — the build/merge loops
/// below reuse one scratch per worker thread so the per-query visited
/// set is not reallocated and re-zeroed O(n) times.
#[allow(clippy::too_many_arguments)]
fn search_graph_with(
    ds: &Dataset,
    graph: &KnnGraph,
    subset: Option<&[u32]>,
    q: &[f32],
    k: usize,
    tau: f64,
    entries: &[u32],
    exclude: u32,
    scratch: &mut SearchScratch,
) -> Vec<(f32, u32)> {
    let ef = k + ((4.0 * tau * k as f64).ceil() as usize).max(1);
    let spec = QuerySpec { q, k, ef, beam_width: 0, max_hops: 0, entries, exclude, rerank: 1 };
    let mut out = Vec::with_capacity(k);
    beam_search(ds, graph, subset, &spec, scratch, &mut out);
    out
}

/// Exhaustive sub-graph over one block (local indices into `subset`).
fn block_graph(ds: &Dataset, subset: &[u32], block: &[u32], k: usize, g: &mut KnnGraph) {
    for &ul in block {
        let u = subset[ul as usize] as usize;
        let mut cands: Vec<(f32, u32)> = block
            .iter()
            .filter(|&&vl| vl != ul)
            .map(|&vl| (ds.dist(u, subset[vl as usize] as usize), vl))
            .collect();
        cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let list = g.list_mut(ul as usize);
        for (slot, &(d, vl)) in cands.iter().take(k).enumerate() {
            list[slot] = Neighbor { id: vl, dist: d, new: false };
        }
    }
}

/// Build the GGNN index (bottom graph = the k-NN graph of `ds`).
pub fn build(ds: &Dataset, params: &GgnnParams) -> GgnnIndex {
    let n = ds.len();
    let k = params.k.min(n - 1);
    let threads = if params.threads == 0 { crate::util::num_threads() } else { params.threads };
    let mut rng = Rng::new(params.seed);

    // ---- hierarchy of layers (ids into ds) ----
    let mut layers: Vec<Vec<u32>> = vec![(0..n as u32).collect()];
    while layers.last().unwrap().len() > params.block {
        let prev = layers.last().unwrap();
        let m = (prev.len() / params.factor).max(1);
        let picks = rng.distinct(prev.len(), m);
        layers.push(picks.into_iter().map(|i| prev[i]).collect());
    }

    // ---- top-down construction ----
    let mut upper: Option<(KnnGraph, Vec<u32>)> = None; // (graph, subset)
    for layer in layers.iter().rev() {
        let subset = layer.clone();
        let ln = subset.len();
        let lk = k.min(ln.saturating_sub(1)).max(1);
        let mut g = KnnGraph::empty(ln, lk);
        // blocks: random partition, exhaustive sub-graphs
        let mut order: Vec<u32> = (0..ln as u32).collect();
        rng.shuffle(&mut order);
        for chunk in order.chunks(params.block) {
            block_graph(ds, &subset, chunk, lk, &mut g);
        }
        // pull candidates from the layer above via best-first search
        if let Some((ref ug, ref usubset)) = upper {
            // spread entry points across the upper layer (random entries
            // in one region strand the search in that region)
            let m = usubset.len();
            let entries: Vec<u32> = (0..m.min(8))
                .map(|i| ((i * m) / m.min(8)) as u32)
                .collect();
            let ranges = split_ranges(ln, threads);
            let results: Vec<Vec<(f32, u32)>> = parallel_map(&ranges, |r| {
                let mut scratch = SearchScratch::new();
                r.map(|ul| {
                    let u = subset[ul] as usize;
                    search_graph_with(
                        ds,
                        ug,
                        Some(usubset),
                        ds.vec(u),
                        lk,
                        params.tau,
                        &entries,
                        u as u32,
                        &mut scratch,
                    )
                })
                .collect()
            });
            // usubset ids are global; map back into this layer's local
            // index space where present (sampled layers are subsets).
            let local_of: std::collections::HashMap<u32, u32> = subset
                .iter()
                .enumerate()
                .map(|(i, &g)| (g, i as u32))
                .collect();
            for (ul, found) in results.into_iter().enumerate() {
                for (d, gid) in found {
                    if let Some(&vl) = local_of.get(&gid) {
                        if vl as usize != ul {
                            g.insert(ul, vl, d, false);
                        }
                    }
                }
            }
        }
        upper = Some((g, subset));
    }
    let (mut graph, _) = upper.unwrap();

    // ---- refinement rounds over the bottom layer ----
    // Each point re-searches the graph for itself, entering from its own
    // current neighborhood (GGNN's refinement walks outward from the
    // point) plus a few spread global entries to escape local islands.
    let globals: Vec<u32> = (0..8.min(n)).map(|i| ((i * n) / 8.min(n)) as u32).collect();
    for _ in 0..params.refinements {
        let ranges = split_ranges(n, threads);
        let graph_ref = &graph;
        let found: Vec<Vec<(f32, u32)>> = parallel_map(&ranges, |r| {
            let mut scratch = SearchScratch::new();
            r.map(|u| {
                let mut entries: Vec<u32> = graph_ref.ids(u).take(8).collect();
                entries.extend_from_slice(&globals);
                search_graph_with(
                    ds,
                    graph_ref,
                    None,
                    ds.vec(u),
                    k,
                    params.tau,
                    &entries,
                    u as u32,
                    &mut scratch,
                )
            })
            .collect()
        });
        for (u, cands) in found.into_iter().enumerate() {
            for (d, v) in cands {
                // symmetrize: a discovered neighbor is evidence in both
                // directions (GGNN links are made symmetric on insert)
                graph.insert(u, v, d, false);
                graph.insert(v as usize, u as u32, d, false);
            }
        }
    }
    GgnnIndex { graph, entries: globals }
}

/// Merge two sub-graphs by cross-searching (the Fig.-7 "GGNN" merge):
/// each object of one subset queries the other sub-graph for `k/2`
/// candidates. Only one sub-graph's neighborhood relations are used per
/// search — the structural disadvantage vs GGM the paper calls out.
pub fn merge_by_search(
    ds: &Dataset,
    n1: usize,
    g1: &KnnGraph,
    g2: &KnnGraph,
    tau: f64,
    threads: usize,
) -> KnnGraph {
    let n = ds.len();
    let n2 = n - n1;
    let k = g1.k();
    let threads = if threads == 0 { crate::util::num_threads() } else { threads };
    let mut g2r = g2.clone();
    g2r.remap_ids(|id| id + n1 as u32);
    let mut joined = g1.stack(&g2r);
    let sub1: Vec<u32> = (0..n1 as u32).collect();
    let sub2: Vec<u32> = (n1 as u32..n as u32).collect();
    // spread entry points across each sub-graph
    let spread = |m: usize| -> Vec<u32> {
        let e = 16.min(m);
        (0..e).map(|i| ((i * m) / e) as u32).collect()
    };
    let e1 = spread(n1);
    let e2 = spread(n2);
    let half = (k / 2).max(1);
    let ranges = split_ranges(n, threads);
    let found: Vec<Vec<(f32, u32)>> = parallel_map(&ranges, |r| {
        let mut scratch = SearchScratch::new();
        r.map(|u| {
            if u < n1 {
                search_graph_with(ds, g2, Some(&sub2), ds.vec(u), half, tau, &e2, u as u32, &mut scratch)
            } else {
                search_graph_with(ds, g1, Some(&sub1), ds.vec(u), half, tau, &e1, u as u32, &mut scratch)
            }
        })
        .collect()
    });
    for (u, cands) in found.into_iter().enumerate() {
        for (d, v) in cands {
            joined.insert(u, v, d, false);
        }
    }
    joined
}

/// Map `f` over each range on its own thread, preserving order. `f`
/// receives the whole range so it can keep per-thread state (e.g. one
/// search scratch) across its items.
fn parallel_map<T: Send>(
    ranges: &[std::ops::Range<usize>],
    f: impl Fn(std::ops::Range<usize>) -> Vec<T> + Sync,
) -> Vec<T> {
    let mut out: Vec<Vec<T>> = Vec::new();
    crossbeam_utils::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|r| {
                let r = r.clone();
                let f = &f;
                s.spawn(move |_| f(r))
            })
            .collect();
        for h in handles {
            out.push(h.join().unwrap());
        }
    })
    .unwrap();
    out.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::metrics::recall_at;

    #[test]
    fn builds_reasonable_graph() {
        let ds = synth::clustered(600, 8, 81);
        let params = GgnnParams { k: 10, block: 128, refinements: 2, ..Default::default() };
        let index = build(&ds, &params);
        index.graph.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 10);
        let r = recall_at(&index.graph, &truth, None, 10);
        assert!(r > 0.7, "ggnn recall {r}");
    }

    #[test]
    fn more_refinement_is_better() {
        let ds = synth::clustered(400, 8, 82);
        let truth = groundtruth::exact_topk(&ds, 10);
        let r_of = |t: usize| {
            let params = GgnnParams { k: 10, block: 64, refinements: t, ..Default::default() };
            recall_at(&build(&ds, &params).graph, &truth, None, 10)
        };
        let r0 = r_of(0);
        let r3 = r_of(3);
        assert!(r3 >= r0, "refinements hurt: {r3} < {r0}");
        assert!(r3 > 0.75, "r3={r3}");
    }

    #[test]
    fn search_finds_near_neighbors_on_exact_graph() {
        // uniform data: the directed exact k-NN graph is navigable (no
        // disconnected cluster islands), so best-first search must work.
        let ds = synth::uniform(300, 6, 83);
        let g = crate::baselines::bruteforce::build_native(&ds, 10);
        let truth = groundtruth::exact_topk(&ds, 5);
        let entries: Vec<u32> = (0..16).map(|i| i * 18).collect();
        let mut hits = 0;
        let mut total = 0;
        for q in (0..300).step_by(10) {
            let found = search_graph(&ds, &g, None, ds.vec(q), 5, 2.0, &entries, q as u32);
            let set: std::collections::HashSet<u32> = found.iter().map(|&(_, id)| id).collect();
            hits += truth[q].iter().filter(|id| set.contains(id)).count();
            total += 5;
        }
        let r = hits as f64 / total as f64;
        assert!(r > 0.8, "graph search recall {r}");
    }

    #[test]
    fn merge_by_search_improves_over_naive_join() {
        let ds = synth::clustered(300, 6, 84);
        let n1 = 150;
        let ids1: Vec<usize> = (0..n1).collect();
        let ids2: Vec<usize> = (n1..300).collect();
        let d1 = ds.select(&ids1, "h1");
        let d2 = ds.select(&ids2, "h2");
        let g1 = crate::baselines::bruteforce::build_native(&d1, 8);
        let g2 = crate::baselines::bruteforce::build_native(&d2, 8);
        let merged = merge_by_search(&ds, n1, &g1, &g2, 1.0, 2);
        merged.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 8);
        let r = recall_at(&merged, &truth, None, 8);
        let mut g2r = g2.clone();
        g2r.remap_ids(|id| id + n1 as u32);
        let naive = g1.stack(&g2r);
        let rn = recall_at(&naive, &truth, None, 8);
        assert!(r > rn, "merge-by-search {r} !> naive {rn}");
    }
}
