//! Configuration system: typed parameter structs, a `key=value` config
//! file format, and CLI-style overrides.
//!
//! Experiments are fully described by a [`RunConfig`]; the `gnnd` binary
//! builds one from `--config file` plus `--set key=value` overrides, so
//! every paper experiment is reproducible from a single flat config.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::str::FromStr;

use anyhow::{bail, Context};

/// Distance metric. The paper stresses NN-Descent's *genericness*; we
/// keep that by supporting the metrics its benchmarks use: squared L2
/// (SIFT/DEEP/GIST) and cosine (GloVe). Cosine is implemented as
/// "l2-normalize once, then negated inner product", which is a monotone
/// transform of cosine distance and MXU-friendly (see DESIGN.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Squared euclidean distance.
    L2,
    /// Negated inner product (smaller = closer).
    Ip,
    /// Cosine distance via normalization + `Ip`.
    Cosine,
}

impl Metric {
    /// The metric the compute kernels see (Cosine lowers to Ip).
    pub fn kernel_metric(self) -> Metric {
        match self {
            Metric::Cosine => Metric::Ip,
            m => m,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::Ip => "ip",
            Metric::Cosine => "cosine",
        }
    }
}

impl FromStr for Metric {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "l2" => Ok(Metric::L2),
            "ip" => Ok(Metric::Ip),
            "cosine" | "cos" => Ok(Metric::Cosine),
            _ => bail!("unknown metric {s:?} (expected l2|ip|cosine)"),
        }
    }
}

impl fmt::Display for Metric {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which engine evaluates the cross-matching step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT-compiled XLA executable on the PJRT CPU client (the paper's
    /// "on-device" path; requires `make artifacts`).
    Pjrt,
    /// Bit-compatible native Rust implementation (oracle + fallback).
    Native,
}

impl FromStr for EngineKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "pjrt" => Ok(EngineKind::Pjrt),
            "native" => Ok(EngineKind::Native),
            _ => bail!("unknown engine {s:?} (expected pjrt|native)"),
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EngineKind::Pjrt => "pjrt",
            EngineKind::Native => "native",
        })
    }
}

/// The update strategy ablated in the paper's Fig. 5.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateStrategy {
    /// GNND-r1: every produced neighbor pair updates the graph
    /// (classic NN-Descent semantics, sort-merge insertion).
    InsertAll,
    /// GNND-r2: selective update (Algorithm 2 winners only), one lock
    /// per k-NN list.
    SelectiveSingleLock,
    /// Full GNND: selective update + multiple spinlocks on list
    /// segments (parallel insertion within one list).
    SelectiveSegmented,
}

impl FromStr for UpdateStrategy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "r1" | "insert-all" => Ok(UpdateStrategy::InsertAll),
            "r2" | "selective" => Ok(UpdateStrategy::SelectiveSingleLock),
            "full" | "segmented" => Ok(UpdateStrategy::SelectiveSegmented),
            _ => bail!("unknown update strategy {s:?} (expected r1|r2|full)"),
        }
    }
}

impl fmt::Display for UpdateStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            UpdateStrategy::InsertAll => "r1",
            UpdateStrategy::SelectiveSingleLock => "r2",
            UpdateStrategy::SelectiveSegmented => "full",
        })
    }
}

/// A flat `key=value` config file / override map.
#[derive(Clone, Debug, Default)]
pub struct ConfigMap(pub BTreeMap<String, String>);

impl ConfigMap {
    /// Parse from file: one `key = value` per line, `#` comments.
    pub fn from_file(path: impl AsRef<Path>) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_str_contents(&text)
    }

    pub fn from_str_contents(text: &str) -> crate::Result<Self> {
        let mut map = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key=value", lineno + 1))?;
            map.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(ConfigMap(map))
    }

    /// Apply `key=value` override strings (CLI `--set`).
    pub fn apply_overrides<'a>(
        &mut self,
        overrides: impl IntoIterator<Item = &'a str>,
    ) -> crate::Result<()> {
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .with_context(|| format!("override {ov:?}: expected key=value"))?;
            self.0.insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(())
    }

    pub fn get_parse<T: FromStr>(&self, key: &str, default: T) -> crate::Result<T>
    where
        T::Err: fmt::Display,
    {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("config key {key}={v:?}: {e}")),
        }
    }
}

/// Parameters of one GNND build (paper Algorithm 1 + §4.3 knobs).
#[derive(Clone, Debug)]
pub struct GnndParams {
    /// Graph degree k (paper: tuned per dataset; 10–64 typical).
    pub k: usize,
    /// Sample count p (< k): NEW/OLD samples taken per list; sampled
    /// adjacency lists are capped at 2p after reverse append (§4.1).
    pub p: usize,
    /// Maximum NN-Descent iterations.
    pub max_iter: usize,
    /// Early-termination threshold: stop when the fraction of accepted
    /// updates per (n*k) drops below this (classic NN-Descent `delta`).
    pub delta: f64,
    /// Update strategy (Fig. 5 ablation).
    pub update: UpdateStrategy,
    /// Segment width for the multiple-spinlock scheme. The paper guards
    /// warp-sized (32) segments because one warp performs one insertion;
    /// on CPU threads there is no warp, so the default is narrower (8)
    /// to give `k/8` lock segments at the default k=32 — the same
    /// contention-reduction idea at CPU granularity (DESIGN.md
    /// §Hardware-Adaptation).
    pub segment_width: usize,
    /// Cross-matching engine.
    pub engine: EngineKind,
    /// Directory holding the AOT artifacts (PJRT engine only).
    pub artifacts_dir: String,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Batch of object locals per engine call (matched to the artifact's
    /// leading dimension for the PJRT engine).
    pub batch: usize,
    /// RNG seed (graph init + sampling tie-breaks).
    pub seed: u64,
    /// Record phi(G) after every iteration (Fig. 4 traces).
    pub trace_phi: bool,
}

impl Default for GnndParams {
    fn default() -> Self {
        GnndParams {
            k: 32,
            p: 16,
            max_iter: 12,
            delta: 0.001,
            update: UpdateStrategy::SelectiveSegmented,
            segment_width: 8,
            engine: EngineKind::Native,
            artifacts_dir: "artifacts".to_string(),
            threads: 0,
            batch: 64,
            seed: 0x6E6E64, // "nnd"
            trace_phi: false,
        }
    }
}

impl GnndParams {
    pub fn from_config(cfg: &ConfigMap) -> crate::Result<Self> {
        let d = GnndParams::default();
        let p = GnndParams {
            k: cfg.get_parse("k", d.k)?,
            p: cfg.get_parse("p", d.p)?,
            max_iter: cfg.get_parse("max_iter", d.max_iter)?,
            delta: cfg.get_parse("delta", d.delta)?,
            update: cfg.get_parse("update", d.update)?,
            segment_width: cfg.get_parse("segment_width", d.segment_width)?,
            engine: cfg.get_parse("engine", d.engine)?,
            artifacts_dir: cfg.get_parse("artifacts_dir", d.artifacts_dir.clone())?,
            threads: cfg.get_parse("threads", d.threads)?,
            batch: cfg.get_parse("batch", d.batch)?,
            seed: cfg.get_parse("seed", d.seed)?,
            trace_phi: cfg.get_parse("trace_phi", d.trace_phi)?,
        };
        p.validate()?;
        Ok(p)
    }

    pub fn validate(&self) -> crate::Result<()> {
        if self.k == 0 {
            bail!("k must be > 0");
        }
        if self.p == 0 || self.p > self.k {
            bail!("p must be in 1..=k (got p={}, k={})", self.p, self.k);
        }
        if self.batch == 0 {
            bail!("batch must be > 0");
        }
        if self.segment_width == 0 {
            bail!("segment_width must be > 0");
        }
        Ok(())
    }

    /// Builder-style helpers for tests/examples.
    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }
    pub fn with_p(mut self, p: usize) -> Self {
        self.p = p;
        self
    }
    pub fn with_engine(mut self, e: EngineKind) -> Self {
        self.engine = e;
        self
    }
    pub fn with_update(mut self, u: UpdateStrategy) -> Self {
        self.update = u;
        self
    }
    pub fn with_iters(mut self, it: usize) -> Self {
        self.max_iter = it;
        self
    }
    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_config_text() {
        let cfg = ConfigMap::from_str_contents(
            "# comment\nk = 24\np=8\nupdate = r2\nengine=native\n",
        )
        .unwrap();
        let p = GnndParams::from_config(&cfg).unwrap();
        assert_eq!(p.k, 24);
        assert_eq!(p.p, 8);
        assert_eq!(p.update, UpdateStrategy::SelectiveSingleLock);
        assert_eq!(p.engine, EngineKind::Native);
    }

    #[test]
    fn overrides_win() {
        let mut cfg = ConfigMap::from_str_contents("k=24\n").unwrap();
        cfg.apply_overrides(["k=48", "p=12"]).unwrap();
        let p = GnndParams::from_config(&cfg).unwrap();
        assert_eq!(p.k, 48);
        assert_eq!(p.p, 12);
    }

    #[test]
    fn rejects_bad_params() {
        let cfg = ConfigMap::from_str_contents("k=4\np=9\n").unwrap();
        assert!(GnndParams::from_config(&cfg).is_err());
        let cfg = ConfigMap::from_str_contents("metricxx=1\nk=0\n").unwrap();
        assert!(GnndParams::from_config(&cfg).is_err());
    }

    #[test]
    fn metric_roundtrip() {
        for m in [Metric::L2, Metric::Ip, Metric::Cosine] {
            assert_eq!(m.as_str().parse::<Metric>().unwrap(), m);
        }
        assert_eq!(Metric::Cosine.kernel_metric(), Metric::Ip);
        assert!("foo".parse::<Metric>().is_err());
    }
}
