//! Online ANN query serving over a constructed k-NN graph.
//!
//! The construction pipeline (GNND, GGM merge, out-of-core sharding)
//! produces a [`KnnGraph`]; this subsystem turns any such graph into a
//! *queryable index* — the workload the ROADMAP's "serving heavy
//! traffic" north star is about, and the same structure GGNN exploits
//! as a search index (Groh et al., arXiv 1912.01059).
//!
//! Layers:
//!
//! * this module — the [`AnnIndex`] abstraction every consumer (batch
//!   executor, serve harness, CLI) is written against, plus its
//!   monolithic implementation [`SearchIndex`]: entry-point selection
//!   (random medoids or k-means seeds reusing
//!   [`crate::baselines::kmeans`]) and best-first beam search with a
//!   reusable [`SearchScratch`] (epoch-stamped visited set + persistent
//!   heaps), so the hot path performs **zero allocations** per query
//!   once warm;
//! * [`sharded`] — [`sharded::ShardedIndex`]: scatter-gather serving
//!   over the per-shard graphs of the out-of-core pipeline
//!   ([`crate::merge::outofcore`]), resolving shards per query through
//!   the `ShardStore` residency cache (lazy load + LRU eviction under
//!   a byte budget) and optionally fanning the probed shards across a
//!   persistent worker pool;
//! * [`pool`] — [`pool::ScatterPool`]: the long-lived scatter workers
//!   behind `search_threads > 1` (spawned once at index open, parked
//!   on a job queue between queries, per-worker warm scratch,
//!   panic-safe shutdown on drop);
//! * [`batch`] — multi-query execution fanned across worker threads
//!   (crossbeam scoped threads, per-thread scratch);
//! * [`serve`] — a serving harness reporting QPS, latency percentiles
//!   and recall@k over an `ef` sweep, in closed-loop (workers issue as
//!   fast as they can) or open-loop mode (a seeded Poisson or
//!   fixed-interval arrival schedule, recording queue delay and
//!   service time separately — the regime where tail latency under
//!   load actually lives);
//! * [`proto`] / [`server`] — the network front end: a length-prefixed
//!   binary wire protocol and a pure-std TCP server with a request
//!   coalescing window and queue-depth admission control, plus
//!   [`server::RemoteIndex`] — an [`AnnIndex`] over the wire, so the
//!   serve harness doubles as the network load generator
//!   (`serve-bench --target`).
//!
//! The free function [`beam_search`] is the greedy-search loop of the
//! monolithic path: [`crate::baselines::ggnn`] delegates its hierarchy
//! construction and search-based merge to it, and the per-shard walk in
//! [`sharded`] mirrors it (scoring, but not expanding, cross-shard
//! edges).
//!
//! ```no_run
//! use gnnd::dataset::synth;
//! use gnnd::gnnd::{build, GnndParams};
//! use gnnd::search::{SearchIndex, SearchParams};
//!
//! let ds = synth::sift_like(20_000, 7);
//! let graph = build(&ds, &GnndParams::default()).unwrap();
//! let index = SearchIndex::new(&ds, &graph, SearchParams::default()).unwrap();
//! // a dataset row queried as-is matches itself at rank 1; use
//! // `search_into_excluding` to skip the query object
//! let hits = index.search(ds.vec(0), 10);
//! println!("top-1 of q0 is q0 itself: id={} dist={}", hits[0].1, hits[0].0);
//! ```

pub mod batch;
pub mod hierarchy;
pub mod pool;
pub mod proto;
pub mod serve;
pub mod server;
pub mod sharded;

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::str::FromStr;
use std::sync::Arc;

use crate::baselines::kmeans;
use crate::dataset::groundtruth::ordered::F32;
use crate::dataset::Dataset;
use crate::graph::{KnnGraph, EMPTY};
use crate::merge::outofcore::ResidentShard;
use crate::util::rng::Rng;

/// How the fixed entry points of a [`SearchIndex`] are chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryStrategy {
    /// `n_entry` random medoids (distinct object ids from a seeded RNG).
    Random,
    /// k-means seeds: train `n_entry` centroids (bounded-sample
    /// k-means++ from [`crate::baselines::kmeans`]) and enter from the
    /// dataset object nearest each centroid — entries spread across the
    /// cluster structure instead of landing in one region.
    KMeans,
    /// GGNN-style coarse-to-fine descent ([`hierarchy`]): a small
    /// pyramid of nested sampled levels is searched per query and its
    /// best finest-level points seed the base-graph beam — entries land
    /// *near the query* instead of at fixed medoids, cutting the
    /// walk-in hops. The hierarchy persists as a `hier.bin` sidecar
    /// next to a stored graph/shard.
    Hierarchy,
}

impl std::fmt::Display for EntryStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EntryStrategy::Random => "random",
            EntryStrategy::KMeans => "kmeans",
            EntryStrategy::Hierarchy => "hierarchy",
        })
    }
}

impl FromStr for EntryStrategy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "random" => Ok(EntryStrategy::Random),
            "kmeans" => Ok(EntryStrategy::KMeans),
            "hierarchy" => Ok(EntryStrategy::Hierarchy),
            _ => anyhow::bail!("unknown entry strategy {s:?} (expected random|kmeans|hierarchy)"),
        }
    }
}

/// Query-time knobs of a [`SearchIndex`].
#[derive(Clone, Debug)]
pub struct SearchParams {
    /// Width of the result pool kept during the walk (HNSW-style `ef`).
    /// Clamped up to the requested `k` at query time; larger trades
    /// time for recall — the knob the serve harness sweeps.
    pub ef: usize,
    /// Frontier cap: when > 0, the open-candidate heap is pruned back
    /// to the best `beam_width` entries whenever it overflows 4x that
    /// size. 0 = unbounded (classic best-first).
    pub beam_width: usize,
    /// Hard cap on node expansions per query (tail-latency bound for
    /// serving). 0 = unbounded.
    pub max_hops: usize,
    /// Number of fixed entry points.
    pub n_entry: usize,
    /// Entry-point selection strategy.
    pub entry: EntryStrategy,
    /// Seed for entry selection (fixed seed => identical index).
    pub seed: u64,
    /// Exact-rerank factor for quantized serving: the beam phase runs
    /// over cheap quantized distances, then the best `rerank * k`
    /// candidates are re-scored at full f32 precision and the top `k`
    /// of *those* returned. `1` disables the rerank pass (and on a
    /// non-quantized backing the knob is inert — distances are already
    /// exact). Raising it trades a few exact evaluations for recall;
    /// `4` recovers f32-level recall on the benchmark corpora.
    pub rerank: usize,
    /// Adaptive shard-routing slack ([`sharded::ShardedIndex`] only):
    /// when `> 0`, the route phase probes only the shards whose best
    /// route-centroid distance is within `route_slack × d_best` of the
    /// nearest shard's (at least one, at most the `probe` cap — the
    /// fixed `--probe-shards` count becomes an upper bound). `0`
    /// disables the cutoff: exactly the fixed-probe behavior. Must be
    /// `>= 1.0` when set (a slack below 1 could not even keep the best
    /// shard).
    pub route_slack: f64,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams {
            ef: 64,
            beam_width: 0,
            max_hops: 0,
            n_entry: 8,
            entry: EntryStrategy::Random,
            seed: 0x5EA_6C4, // "sea-rch"
            rerank: 1,
            route_slack: 0.0,
        }
    }
}

impl SearchParams {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.ef > 0, "ef must be > 0");
        anyhow::ensure!(self.n_entry > 0, "n_entry must be > 0");
        anyhow::ensure!(self.rerank >= 1, "rerank must be >= 1 (1 = no rerank pass)");
        anyhow::ensure!(
            self.route_slack == 0.0 || self.route_slack >= 1.0,
            "route_slack must be 0 (disabled) or >= 1.0, got {}",
            self.route_slack
        );
        Ok(())
    }

    /// Builder-style helpers for tests/examples.
    pub fn with_ef(mut self, ef: usize) -> Self {
        self.ef = ef;
        self
    }
    pub fn with_entries(mut self, strategy: EntryStrategy, n_entry: usize) -> Self {
        self.entry = strategy;
        self.n_entry = n_entry;
        self
    }
    pub fn with_max_hops(mut self, hops: usize) -> Self {
        self.max_hops = hops;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn with_rerank(mut self, rerank: usize) -> Self {
        self.rerank = rerank;
        self
    }
    pub fn with_route_slack(mut self, slack: f64) -> Self {
        self.route_slack = slack;
        self
    }
}

/// Epoch-stamped visited set: O(1) insert/test, O(1) reset between
/// queries (no clearing of the backing array until the epoch wraps).
struct VisitedSet {
    stamp: Vec<u32>,
    epoch: u32,
}

impl VisitedSet {
    fn new() -> Self {
        VisitedSet { stamp: Vec::new(), epoch: 0 }
    }

    /// Start a new query over ids `< n`.
    fn begin(&mut self, n: usize) {
        if self.stamp.len() < n {
            self.stamp.resize(n, 0);
        }
        if self.epoch == u32::MAX {
            for s in self.stamp.iter_mut() {
                *s = 0;
            }
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    /// Returns true if `id` was not yet visited this query.
    #[inline]
    fn insert(&mut self, id: u32) -> bool {
        let s = &mut self.stamp[id as usize];
        if *s == self.epoch {
            false
        } else {
            *s = self.epoch;
            true
        }
    }
}

/// Reusable per-query workspace. All containers keep their capacity
/// between queries, so a warm scratch makes the search hot path
/// allocation-free. One scratch per worker thread; see
/// [`batch::BatchExecutor`].
pub struct SearchScratch {
    visited: VisitedSet,
    /// Open candidates, min-heap by (dist, id).
    frontier: BinaryHeap<Reverse<(F32, u32)>>,
    /// Best `ef` results so far, max-heap by (dist, id).
    results: BinaryHeap<(F32, u32)>,
    /// Staging buffer for frontier pruning / result emission.
    buf: Vec<(F32, u32)>,
    /// Scatter-gather accumulator: per-shard top-k candidates awaiting
    /// the final k-way merge ([`sharded::ShardedIndex`] only).
    pub(crate) shard_topk: Vec<(F32, u32)>,
    /// Neighbor-row staging buffer for the sharded walk: rows are
    /// copied out of the graph backing ([`sharded::ShardedIndex`]
    /// only) — a paged row cannot be borrowed across the expansion
    /// loop, and copying keeps the owned and paged walks on one code
    /// path.
    pub(crate) nbuf: Vec<crate::graph::Neighbor>,
    /// Shard routing order: (query-to-centroid distance, shard).
    pub(crate) shard_rank: Vec<(F32, usize)>,
    /// Per-query shard pin table: resolved residency handles, released
    /// (set back to `None`) at the end of every query so a kept
    /// scratch never pins shards ([`sharded::ShardedIndex`] only).
    pub(crate) shard_pins: Vec<Option<Arc<ResidentShard>>>,
    /// Probed set of the current query — the deterministic scoring
    /// universe of the sharded scatter phase.
    pub(crate) shard_probed: Vec<bool>,
    /// Encoded-query staging buffer for quantized serving: the query
    /// vector quantized once per query into the dataset's code space,
    /// then compared against u8 code rows by the integer kernels.
    pub(crate) qcodes: Vec<u8>,
    /// Per-query ADC lookup table for product-quantized serving
    /// (`m * 256` entries from [`crate::dataset::Dataset::prepare_query`]):
    /// the beam inner loop sums m table gathers per candidate.
    pub(crate) lut: Vec<f32>,
    /// f32 staging buffer for the rerank phase (dequantize fallback
    /// when a quantized store has no exact-rows sidecar).
    pub(crate) fbuf: Vec<f32>,
    /// Nested scratch for the entry-hierarchy descent
    /// ([`hierarchy::EntryHierarchy::descend`]): the descent runs its
    /// own beam searches over the tiny level graphs, and those must
    /// not clobber this scratch's per-query counters. Lazily boxed —
    /// flat-entry queries never allocate it.
    pub(crate) hier: Option<Box<SearchScratch>>,
    /// Per-query entry-seed staging buffer: descent output (or a copy
    /// of the fixed entries) handed to [`beam_search`] as
    /// `QuerySpec::entries`.
    pub(crate) entry_buf: Vec<u32>,
    /// `(dist, finest-local id)` staging buffer of the hierarchy
    /// descent (lives on the *nested* scratch).
    pub(crate) hier_out: Vec<(f32, u32)>,
    /// Shards probed by the last query ([`sharded::ShardedIndex`]
    /// only; 0 on a monolithic index). With adaptive routing
    /// (`route_slack > 0`) this varies per query below the fixed cap.
    pub shards_probed: usize,
    /// Distance evaluations performed by the last query. On a
    /// quantized backing these are *approximate* (code-space)
    /// evaluations; the full-precision ones are `rerank_evals`.
    pub dist_evals: usize,
    /// Node expansions performed by the last query.
    pub hops: usize,
    /// Full-precision rerank evaluations performed by the last query
    /// (0 unless the index is quantized and `rerank > 1`).
    pub rerank_evals: usize,
    /// Per-query trace collection point (disabled by default). Armed
    /// by the serve harness for sampled queries; index implementations
    /// fill it with route/shard/gather spans. Observation-only — never
    /// influences results.
    pub trace: crate::telemetry::trace::TraceSink,
}

impl SearchScratch {
    pub fn new() -> Self {
        SearchScratch {
            visited: VisitedSet::new(),
            frontier: BinaryHeap::new(),
            results: BinaryHeap::new(),
            buf: Vec::new(),
            shard_topk: Vec::new(),
            nbuf: Vec::new(),
            shard_rank: Vec::new(),
            shard_pins: Vec::new(),
            shard_probed: Vec::new(),
            qcodes: Vec::new(),
            lut: Vec::new(),
            fbuf: Vec::new(),
            hier: None,
            entry_buf: Vec::new(),
            hier_out: Vec::new(),
            shards_probed: 0,
            dist_evals: 0,
            hops: 0,
            rerank_evals: 0,
            trace: crate::telemetry::trace::TraceSink::default(),
        }
    }
}

impl Default for SearchScratch {
    fn default() -> Self {
        SearchScratch::new()
    }
}

/// One query against a graph: inputs to [`beam_search`].
pub struct QuerySpec<'q> {
    /// Query vector (dimension = dataset dimension).
    pub q: &'q [f32],
    /// Results requested.
    pub k: usize,
    /// Result-pool width (clamped up to `k` internally).
    pub ef: usize,
    /// Frontier cap (0 = unbounded).
    pub beam_width: usize,
    /// Expansion cap (0 = unbounded).
    pub max_hops: usize,
    /// Entry points (graph-local ids).
    pub entries: &'q [u32],
    /// Global object id excluded from results ([`EMPTY`] = none) —
    /// used when a dataset object queries for its own neighbors.
    pub exclude: u32,
    /// Exact-rerank factor (see [`SearchParams::rerank`]): on a
    /// quantized dataset with `rerank > 1`, the beam keeps a pool of
    /// at least `rerank * k` and the best `rerank * k` candidates are
    /// re-scored at full precision before the final top-`k` cut.
    pub rerank: usize,
}

/// Best-first beam search over `graph` for `spec.q`, writing up to
/// `spec.k` `(dist, id)` pairs into `out`, ascending by distance.
///
/// `subset` maps graph-local ids to dataset ids (GGNN's layered
/// sub-graphs search a sampled subset); `None` means the graph covers
/// the dataset directly. Returned ids (and `spec.exclude`) are in the
/// *dataset* id space.
///
/// This is the greedy-search loop of the monolithic path — the
/// [`SearchIndex`] hot path and [`crate::baselines::ggnn`] both call
/// it. ([`sharded`] mirrors this loop with one twist: cross-shard
/// edges are scored but never expanded; keep the two in sync.) Ties on
/// distance break by ascending id (tuple ordering), so results are
/// deterministic for a fixed graph and entry set.
///
/// On a compressed dataset the walk is **two-phase**: candidates are
/// scored with the cheap code-space kernels (the query encoded once
/// into `scratch.qcodes` on a scalar-quantized backing, or expanded
/// once into the `scratch.lut` ADC table on a product-quantized
/// backing), and when `spec.rerank > 1` the best
/// `rerank * k` survivors are re-scored at full f32 precision (the
/// exact-rows sidecar when the store has one) before the final top-`k`
/// cut. Neighbor rows are staged through `scratch.nbuf` via
/// [`KnnGraph::neighbors_into`], so the walk serves owned *and* paged
/// graphs — the same accessor discipline as the sharded path.
pub fn beam_search(
    ds: &Dataset,
    graph: &KnnGraph,
    subset: Option<&[u32]>,
    spec: &QuerySpec,
    scratch: &mut SearchScratch,
    out: &mut Vec<(f32, u32)>,
) {
    let rerank = if ds.is_compressed() { spec.rerank.max(1) } else { 1 };
    // the beam pool must hold every rerank candidate
    let ef = spec.ef.max(spec.k * rerank).max(1);
    let to_global = |local: u32| -> u32 {
        match subset {
            Some(map) => map[local as usize],
            None => local,
        }
    };
    scratch.visited.begin(graph.n());
    scratch.frontier.clear();
    scratch.results.clear();
    scratch.dist_evals = 0;
    scratch.hops = 0;
    scratch.rerank_evals = 0;
    // prepare the query's code-space form once per query (encoded codes
    // or ADC table; no-op clear on an uncompressed backing); taken out
    // of the scratch so the borrows do not conflict with the
    // heap/visited accesses below
    let mut qcodes = std::mem::take(&mut scratch.qcodes);
    let mut lut = std::mem::take(&mut scratch.lut);
    ds.prepare_query(spec.q, &mut qcodes, &mut lut);

    for &e in spec.entries {
        if (e as usize) < graph.n() && scratch.visited.insert(e) {
            let d = ds.dist_to_quant(to_global(e) as usize, spec.q, &qcodes, &lut);
            scratch.dist_evals += 1;
            scratch.frontier.push(Reverse((F32(d), e)));
            if to_global(e) != spec.exclude {
                scratch.results.push((F32(d), e));
                if scratch.results.len() > ef {
                    scratch.results.pop();
                }
            }
        }
    }

    while let Some(Reverse((F32(d), u))) = scratch.frontier.pop() {
        // backtracking bound: stop when the closest open candidate is
        // worse than the worst retained result and the pool is full
        if scratch.results.len() >= ef {
            if let Some(&(F32(w), _)) = scratch.results.peek() {
                if d > w {
                    break;
                }
            }
        }
        if spec.max_hops > 0 && scratch.hops >= spec.max_hops {
            break;
        }
        scratch.hops += 1;
        // stage the neighbor row (live prefix only) so the expansion
        // works on paged graph backings too
        let mut nbuf = std::mem::take(&mut scratch.nbuf);
        graph.neighbors_into(u as usize, &mut nbuf);
        for &e in &nbuf {
            if !scratch.visited.insert(e.id) {
                continue;
            }
            let dv = ds.dist_to_quant(to_global(e.id) as usize, spec.q, &qcodes, &lut);
            scratch.dist_evals += 1;
            scratch.frontier.push(Reverse((F32(dv), e.id)));
            if to_global(e.id) != spec.exclude {
                scratch.results.push((F32(dv), e.id));
                if scratch.results.len() > ef {
                    scratch.results.pop();
                }
            }
        }
        scratch.nbuf = nbuf;
        // frontier pruning: drop hopeless far candidates once the open
        // set overflows 4x the beam width
        if spec.beam_width > 0 && scratch.frontier.len() > 4 * spec.beam_width {
            scratch.buf.clear();
            for _ in 0..spec.beam_width {
                match scratch.frontier.pop() {
                    Some(Reverse(x)) => scratch.buf.push(x),
                    None => break,
                }
            }
            scratch.frontier.clear();
            for &x in &scratch.buf {
                scratch.frontier.push(Reverse(x));
            }
        }
    }
    scratch.qcodes = qcodes;
    scratch.lut = lut;

    // Emit ascending by distance: the results max-heap pops worst-first.
    scratch.buf.clear();
    while let Some(x) = scratch.results.pop() {
        scratch.buf.push(x);
    }
    out.clear();
    if rerank > 1 {
        // exact rerank: re-score the best rerank*k candidates at full
        // precision, then keep the top k of those
        let keep = (spec.k * rerank).min(scratch.buf.len());
        let mut fbuf = std::mem::take(&mut scratch.fbuf);
        for &(_, id) in scratch.buf.iter().rev().take(keep) {
            let g = to_global(id);
            let d = ds.rerank_dist_to(g as usize, spec.q, &mut fbuf);
            scratch.rerank_evals += 1;
            out.push((d, g));
        }
        scratch.fbuf = fbuf;
        out.sort_by(|a, b| (F32(a.0), a.1).cmp(&(F32(b.0), b.1)));
        out.truncate(spec.k);
    } else {
        for &(F32(d), id) in scratch.buf.iter().rev() {
            if out.len() >= spec.k {
                break;
            }
            out.push((d, to_global(id)));
        }
    }
}

/// An object-safe ANN index: the seam between query *execution*
/// ([`batch::BatchExecutor`], [`serve`], the CLI) and index *layout*
/// (monolithic [`SearchIndex`] vs scatter-gather
/// [`sharded::ShardedIndex`]). Executors hold `&dyn AnnIndex` and never
/// learn whether the data behind it is one in-memory graph or a
/// directory of out-of-core shards.
///
/// Ids are always in the index's **global** object id space (for a
/// sharded index: the id space of the original, un-split dataset).
pub trait AnnIndex: Sync {
    /// Number of indexed objects.
    fn len(&self) -> usize;

    /// Vector dimensionality.
    fn dim(&self) -> usize;

    /// Distance metric of the indexed data.
    fn metric(&self) -> crate::config::Metric;

    /// The indexed vector with (global) object id `id`, copied out.
    /// Owned rather than borrowed: a residency-managed index
    /// ([`sharded::ShardedIndex`] under a memory budget) may have to
    /// fault the owning shard in, and a borrow could not outlive that
    /// shard's next eviction.
    fn vector(&self, id: u32) -> Vec<f32>;

    /// The index's configured `ef` (used when a query passes `ef = 0`).
    fn default_ef(&self) -> usize;

    /// One-line description for reports (`monolithic(...)`,
    /// `sharded(...)`).
    fn describe(&self) -> String;

    /// A scratch pre-sized for this index.
    fn make_scratch(&self) -> SearchScratch;

    /// Core query entry point: top-`k` neighbors of `q` written into
    /// `out` (cleared first), ascending by distance. `ef = 0` uses the
    /// index default; `exclude` drops one object id from the results
    /// ([`EMPTY`] = none). Implementations must leave
    /// `scratch.dist_evals` / `scratch.hops` describing the query.
    fn search_ef_into_excluding(
        &self,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    );

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Zero-allocation query at the index's default `ef`.
    fn search_into(
        &self,
        q: &[f32],
        k: usize,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        self.search_ef_into_excluding(q, k, 0, EMPTY, scratch, out)
    }

    /// Convenience single query (allocates a fresh scratch).
    fn search(&self, q: &[f32], k: usize) -> Vec<(f32, u32)> {
        let mut scratch = self.make_scratch();
        let mut out = Vec::with_capacity(k);
        self.search_ef_into_excluding(q, k, 0, EMPTY, &mut scratch, &mut out);
        out
    }
}

/// A queryable ANN index: a finished k-NN graph + its dataset + fixed
/// entry points. Cheap to construct (entry selection only); borrows
/// the graph and dataset rather than owning them, so any build path
/// (in-core, merged, out-of-core assembly) serves without copies.
pub struct SearchIndex<'a> {
    ds: &'a Dataset,
    graph: &'a KnnGraph,
    params: SearchParams,
    entries: Vec<u32>,
    /// Coarse-to-fine entry hierarchy ([`EntryStrategy::Hierarchy`]):
    /// when set, `entries` is empty and every query descends the
    /// hierarchy for its seeds. Shared (`Arc`) so `with_ef` clones and
    /// sidecar-loaded hierarchies are free to hand around.
    hier: Option<Arc<hierarchy::EntryHierarchy>>,
}

impl<'a> SearchIndex<'a> {
    pub fn new(ds: &'a Dataset, graph: &'a KnnGraph, params: SearchParams) -> crate::Result<Self> {
        Self::check(ds, graph, &params)?;
        let (entries, hier) = match params.entry {
            EntryStrategy::Hierarchy => {
                let cfg = hierarchy::HierConfig { seed: params.seed, ..Default::default() };
                (Vec::new(), Some(Arc::new(hierarchy::EntryHierarchy::build(ds, &cfg))))
            }
            _ => (select_entries(ds, graph, &params), None),
        };
        Ok(SearchIndex { ds, graph, params, entries, hier })
    }

    /// Like [`SearchIndex::new`] with [`EntryStrategy::Hierarchy`],
    /// but reusing an already-built (typically sidecar-loaded, see
    /// [`hierarchy::load_or_build`]) hierarchy instead of building one.
    pub fn with_hierarchy(
        ds: &'a Dataset,
        graph: &'a KnnGraph,
        params: SearchParams,
        hier: Arc<hierarchy::EntryHierarchy>,
    ) -> crate::Result<Self> {
        Self::check(ds, graph, &params)?;
        anyhow::ensure!(
            params.entry == EntryStrategy::Hierarchy,
            "with_hierarchy requires EntryStrategy::Hierarchy, got {}",
            params.entry
        );
        Ok(SearchIndex { ds, graph, params, entries: Vec::new(), hier: Some(hier) })
    }

    fn check(ds: &Dataset, graph: &KnnGraph, params: &SearchParams) -> crate::Result<()> {
        anyhow::ensure!(
            graph.n() == ds.len(),
            "graph covers {} objects but dataset has {}",
            graph.n(),
            ds.len()
        );
        anyhow::ensure!(graph.n() > 0, "empty graph");
        params.validate()
    }

    pub fn dataset(&self) -> &Dataset {
        self.ds
    }

    pub fn graph(&self) -> &KnnGraph {
        self.graph
    }

    pub fn params(&self) -> &SearchParams {
        &self.params
    }

    /// The fixed entry points (dataset object ids). Empty under
    /// [`EntryStrategy::Hierarchy`] — seeds are selected per query.
    pub fn entries(&self) -> &[u32] {
        &self.entries
    }

    /// The entry hierarchy, when this index uses one.
    pub fn hierarchy(&self) -> Option<&Arc<hierarchy::EntryHierarchy>> {
        self.hier.as_ref()
    }

    /// The same index at a different `ef` operating point. Entry
    /// selection is independent of `ef`, so this only clones the entry
    /// list — the serve harness sweeps `ef` without re-selecting
    /// (k-means) entries per point.
    pub fn with_ef(&self, ef: usize) -> SearchIndex<'a> {
        SearchIndex {
            ds: self.ds,
            graph: self.graph,
            params: self.params.clone().with_ef(ef),
            entries: self.entries.clone(),
            hier: self.hier.clone(),
        }
    }

    /// A scratch sized for this index.
    pub fn make_scratch(&self) -> SearchScratch {
        let mut s = SearchScratch::new();
        s.visited.begin(self.graph.n());
        s
    }

    /// Convenience single query (allocates a fresh scratch; use
    /// [`SearchIndex::search_into`] with a kept scratch on hot paths).
    pub fn search(&self, q: &[f32], k: usize) -> Vec<(f32, u32)> {
        let mut scratch = self.make_scratch();
        let mut out = Vec::with_capacity(k);
        self.search_into(q, k, &mut scratch, &mut out);
        out
    }

    /// Zero-allocation query: results are written into `out` (cleared
    /// first), ascending by distance.
    pub fn search_into(
        &self,
        q: &[f32],
        k: usize,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        self.search_into_excluding(q, k, EMPTY, scratch, out)
    }

    /// Like [`SearchIndex::search_into`] but excludes object `exclude`
    /// from the results — used when replaying dataset objects as
    /// queries (an object trivially matches itself).
    pub fn search_into_excluding(
        &self,
        q: &[f32],
        k: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        self.run_query(q, k, 0, exclude, scratch, out);
    }

    /// The one query path: seed the beam (fixed entries, or a
    /// hierarchy descent under [`EntryStrategy::Hierarchy`]) and walk
    /// the base graph. `ef = 0` uses the configured default. Descent
    /// distance evaluations are folded into `scratch.dist_evals` (the
    /// beam resets the counters at entry); descent expansions walk the
    /// tiny level graphs only and are *not* counted as base-graph
    /// `hops`.
    fn run_query(
        &self,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        let p = &self.params;
        let mut descent_evals = 0usize;
        let mut entry_buf = std::mem::take(&mut scratch.entry_buf);
        let entries: &[u32] = match &self.hier {
            Some(h) => {
                descent_evals = h.descend(q, p.n_entry, scratch, &mut entry_buf);
                &entry_buf
            }
            None => &self.entries,
        };
        let spec = QuerySpec {
            q,
            k,
            ef: if ef == 0 { p.ef } else { ef },
            beam_width: p.beam_width,
            max_hops: p.max_hops,
            entries,
            exclude,
            rerank: p.rerank,
        };
        beam_search(self.ds, self.graph, None, &spec, scratch, out);
        scratch.dist_evals += descent_evals;
        scratch.entry_buf = entry_buf;
    }
}

impl<'a> AnnIndex for SearchIndex<'a> {
    fn len(&self) -> usize {
        self.graph.n()
    }

    fn dim(&self) -> usize {
        self.ds.d
    }

    fn metric(&self) -> crate::config::Metric {
        self.ds.metric
    }

    fn vector(&self, id: u32) -> Vec<f32> {
        // backing-agnostic copy (dequantizes on a quantized backing)
        self.ds.vector(id as usize)
    }

    fn default_ef(&self) -> usize {
        self.params.ef
    }

    fn describe(&self) -> String {
        format!("monolithic(n={}, graph_k={})", self.graph.n(), self.graph.k())
    }

    fn make_scratch(&self) -> SearchScratch {
        SearchIndex::make_scratch(self)
    }

    fn search_ef_into_excluding(
        &self,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        self.run_query(q, k, ef, exclude, scratch, out);
        crate::telemetry::record_query(scratch.dist_evals, scratch.hops, scratch.rerank_evals);
    }
}

/// Pick the fixed entry points for an index.
fn select_entries(ds: &Dataset, graph: &KnnGraph, params: &SearchParams) -> Vec<u32> {
    let n = graph.n();
    let m = params.n_entry.clamp(1, n);
    match params.entry {
        EntryStrategy::Random => {
            let mut rng = Rng::new(params.seed ^ 0xE27_4A7);
            rng.distinct(n, m).into_iter().map(|i| i as u32).collect()
        }
        EntryStrategy::KMeans => {
            let threads = crate::util::num_threads();
            // Bounded sample: training and the medoid scan below must
            // not materialize a paged or quantized store (the old
            // transient full `materialize()` copy defeated the whole
            // point of block residency at index open). At most
            // `KMEANS_SAMPLE` rows are copied out through the
            // backing-agnostic accessor; when the dataset fits the cap
            // the sample *is* the dataset, so small owned indices
            // select exactly the entries they always did.
            const KMEANS_SAMPLE: usize = 4096;
            let sn = n.min(KMEANS_SAMPLE);
            let sample_ids: Vec<u32> = if sn == n {
                (0..n as u32).collect()
            } else {
                let mut rng = Rng::new(params.seed ^ 0x5A3_917);
                let mut picks = rng.distinct(n, sn);
                picks.sort_unstable();
                picks.into_iter().map(|i| i as u32).collect()
            };
            let mut sample = Vec::with_capacity(sn * ds.d);
            for &i in &sample_ids {
                ds.with_vec(i as usize, |row| sample.extend_from_slice(row));
            }
            let book = kmeans::train(&sample, ds.d, m, 6, ds.metric, params.seed, threads);
            // One parallel pass over the sample finding the nearest
            // object (medoid) of every centroid; per-range minima are
            // reduced with a (dist, id) tie-break so the result is
            // identical for any thread count.
            let ranges = crate::util::split_ranges(sn, threads);
            let mut partials: Vec<Vec<(f32, u32)>> = Vec::new();
            crossbeam_utils::thread::scope(|s| {
                let handles: Vec<_> = ranges
                    .iter()
                    .map(|r| {
                        let r = r.clone();
                        let book = &book;
                        let sample = &sample;
                        let sample_ids = &sample_ids;
                        s.spawn(move |_| {
                            let mut best = vec![(f32::INFINITY, 0u32); book.k];
                            for i in r {
                                let v = &sample[i * book.d..(i + 1) * book.d];
                                for c in 0..book.k {
                                    let d = crate::distance::l2_sq(v, book.centroid(c));
                                    if d < best[c].0 {
                                        best[c] = (d, sample_ids[i]);
                                    }
                                }
                            }
                            best
                        })
                    })
                    .collect();
                for h in handles {
                    partials.push(h.join().unwrap());
                }
            })
            .unwrap();
            let mut out: Vec<u32> = Vec::with_capacity(m);
            for c in 0..book.k {
                let mut best = (f32::INFINITY, 0u32);
                for p in &partials {
                    if p[c].0 < best.0 || (p[c].0 == best.0 && p[c].1 < best.1) {
                        best = p[c];
                    }
                }
                if best.0.is_finite() && !out.contains(&best.1) {
                    out.push(best.1);
                }
            }
            // centroids can collapse onto the same medoid; top up with
            // deterministic ids so the entry count stays at m
            let mut next = 0u32;
            while out.len() < m && (next as usize) < n {
                if !out.contains(&next) {
                    out.push(next);
                }
                next += 1;
            }
            out
        }
        // hierarchy indices have no fixed entries — seeds come from a
        // per-query descent ([`hierarchy::EntryHierarchy::descend`])
        EntryStrategy::Hierarchy => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::bruteforce;
    use crate::dataset::{groundtruth, synth};

    #[test]
    fn finds_exact_neighbors_on_exact_graph() {
        // On the exact k-NN graph of easy uniform data, beam search with
        // a generous ef must recover nearly all true neighbors.
        let ds = synth::uniform(300, 6, 91);
        let g = bruteforce::build_native(&ds, 10);
        let truth = groundtruth::exact_topk(&ds, 5);
        let index = SearchIndex::new(&ds, &g, SearchParams::default().with_ef(64)).unwrap();
        let mut scratch = index.make_scratch();
        let mut out = Vec::new();
        let mut hits = 0;
        let mut total = 0;
        for q in (0..300).step_by(5) {
            index.search_into_excluding(ds.vec(q), 5, q as u32, &mut scratch, &mut out);
            let set: std::collections::HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
            hits += truth[q].iter().filter(|id| set.contains(id)).count();
            total += truth[q].len();
        }
        let r = hits as f64 / total as f64;
        assert!(r > 0.85, "search recall on exact graph {r}");
    }

    #[test]
    fn results_sorted_dedup_and_exclude_respected() {
        let ds = synth::clustered(200, 6, 92);
        let g = bruteforce::build_native(&ds, 8);
        let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
        let mut scratch = index.make_scratch();
        let mut out = Vec::new();
        for q in 0..50 {
            index.search_into_excluding(ds.vec(q), 10, q as u32, &mut scratch, &mut out);
            assert!(!out.is_empty());
            assert!(out.len() <= 10);
            assert!(out.iter().all(|&(_, id)| id != q as u32), "self in results of {q}");
            for w in out.windows(2) {
                assert!(w[0].0 <= w[1].0, "unsorted results for {q}");
            }
            let ids: std::collections::HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
            assert_eq!(ids.len(), out.len(), "duplicate ids for {q}");
        }
    }

    #[test]
    fn ef_improves_recall() {
        let ds = synth::clustered(400, 8, 93);
        let g = bruteforce::build_native(&ds, 8);
        let truth = groundtruth::exact_topk(&ds, 10);
        let recall_for = |ef: usize| -> f64 {
            let index = SearchIndex::new(&ds, &g, SearchParams::default().with_ef(ef)).unwrap();
            let mut scratch = index.make_scratch();
            let mut out = Vec::new();
            let mut hits = 0;
            let mut total = 0;
            for q in 0..ds.len() {
                index.search_into_excluding(ds.vec(q), 10, q as u32, &mut scratch, &mut out);
                let set: std::collections::HashSet<u32> =
                    out.iter().map(|&(_, id)| id).collect();
                hits += truth[q].iter().filter(|id| set.contains(id)).count();
                total += truth[q].len().min(10);
            }
            hits as f64 / total as f64
        };
        let lo = recall_for(10);
        let hi = recall_for(128);
        assert!(hi >= lo, "ef=128 recall {hi} < ef=10 recall {lo}");
        assert!(hi > 0.9, "ef=128 recall {hi}");
    }

    #[test]
    fn max_hops_bounds_expansions() {
        let ds = synth::clustered(300, 6, 94);
        let g = bruteforce::build_native(&ds, 8);
        let params = SearchParams::default().with_ef(64).with_max_hops(3);
        let index = SearchIndex::new(&ds, &g, params).unwrap();
        let mut scratch = index.make_scratch();
        let mut out = Vec::new();
        index.search_into(ds.vec(0), 10, &mut scratch, &mut out);
        assert!(scratch.hops <= 3, "hops {} > max_hops 3", scratch.hops);
        assert!(!out.is_empty());
    }

    #[test]
    fn entry_strategies_are_deterministic_and_sized() {
        let ds = synth::clustered(250, 6, 95);
        let g = bruteforce::build_native(&ds, 8);
        for strategy in [EntryStrategy::Random, EntryStrategy::KMeans] {
            let params = SearchParams::default().with_entries(strategy, 6).with_seed(5);
            let a = SearchIndex::new(&ds, &g, params.clone()).unwrap();
            let b = SearchIndex::new(&ds, &g, params).unwrap();
            assert_eq!(a.entries(), b.entries(), "{strategy} not deterministic");
            assert_eq!(a.entries().len(), 6, "{strategy} entry count");
            let set: std::collections::HashSet<u32> = a.entries().iter().copied().collect();
            assert_eq!(set.len(), 6, "{strategy} duplicate entries");
            assert!(a.entries().iter().all(|&e| (e as usize) < ds.len()));
        }
    }

    #[test]
    fn hierarchy_entry_holds_recall_and_is_deterministic() {
        // the hierarchy only changes which entries seed the beam, so
        // recall must track the flat-entry index (ISSUE 8 invariant:
        // within 2 points) and identical params must serve identical
        // results
        let ds = synth::clustered(600, 8, 99);
        let g = bruteforce::build_native(&ds, 8);
        let truth = groundtruth::exact_topk(&ds, 10);
        let recall_of = |index: &SearchIndex| -> f64 {
            let mut scratch = index.make_scratch();
            let mut out = Vec::new();
            let (mut hits, mut total) = (0, 0);
            for q in 0..ds.len() {
                index.search_into_excluding(ds.vec(q), 10, q as u32, &mut scratch, &mut out);
                let set: std::collections::HashSet<u32> =
                    out.iter().map(|&(_, id)| id).collect();
                hits += truth[q].iter().filter(|id| set.contains(id)).count();
                total += truth[q].len().min(10);
            }
            hits as f64 / total as f64
        };
        let flat_params =
            SearchParams::default().with_ef(64).with_entries(EntryStrategy::KMeans, 8);
        let flat = SearchIndex::new(&ds, &g, flat_params).unwrap();
        let params = SearchParams::default().with_ef(64).with_entries(EntryStrategy::Hierarchy, 8);
        let a = SearchIndex::new(&ds, &g, params.clone()).unwrap();
        assert!(a.entries().is_empty(), "hierarchy index has no fixed entries");
        assert!(a.hierarchy().is_some());
        let (rf, rh) = (recall_of(&flat), recall_of(&a));
        assert!(rh >= rf - 0.02, "hierarchy recall {rh} fell >2 points below flat {rf}");
        // determinism across instances, and descent work is accounted
        let b = SearchIndex::new(&ds, &g, params).unwrap();
        let (mut sa, mut sb) = (a.make_scratch(), b.make_scratch());
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        for q in (0..ds.len()).step_by(17) {
            a.search_into_excluding(ds.vec(q), 10, q as u32, &mut sa, &mut oa);
            b.search_into_excluding(ds.vec(q), 10, q as u32, &mut sb, &mut ob);
            assert_eq!(oa, ob, "hierarchy index not deterministic on query {q}");
            assert_eq!(sa.dist_evals, sb.dist_evals, "work diverged on query {q}");
            assert!(sa.dist_evals > 0);
        }
    }

    #[test]
    fn monolithic_search_serves_paged_graphs_identically() {
        // the nbuf-staged expansion loop must give bit-identical walks
        // on owned and paged graph backings
        let ds = synth::clustered(300, 6, 98);
        let g = bruteforce::build_native(&ds, 8);
        let dir = std::env::temp_dir().join(format!(
            "gnnd-search-paged-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.knng");
        g.save(&p).unwrap();
        let cache = crate::dataset::store::BlockCache::new(0, 512);
        let gp = crate::graph::KnnGraph::load_paged(&p, &cache).unwrap();
        let params = SearchParams::default().with_ef(32);
        let a = SearchIndex::new(&ds, &g, params.clone()).unwrap();
        let b = SearchIndex::new(&ds, &gp, params).unwrap();
        let (mut sa, mut sb) = (a.make_scratch(), b.make_scratch());
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        for q in (0..300).step_by(7) {
            a.search_into_excluding(ds.vec(q), 10, q as u32, &mut sa, &mut oa);
            b.search_into_excluding(ds.vec(q), 10, q as u32, &mut sb, &mut ob);
            assert_eq!(oa, ob, "owned vs paged graph diverged on query {q}");
            assert_eq!(sa.dist_evals, sb.dist_evals, "work diverged on query {q}");
        }
        assert!(cache.stats().fetches > 0, "paged graph never faulted a block");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quantized_rerank_recovers_f32_recall() {
        let ds = synth::clustered(400, 8, 97);
        let g = bruteforce::build_native(&ds, 8);
        let truth = groundtruth::exact_topk(&ds, 10);
        let recall_of = |dsx: &crate::dataset::Dataset, rerank: usize, evals: &mut (usize, usize)| {
            let params = SearchParams::default().with_ef(64).with_rerank(rerank);
            let index = SearchIndex::new(dsx, &g, params).unwrap();
            let mut scratch = index.make_scratch();
            let mut out = Vec::new();
            let (mut hits, mut total) = (0, 0);
            for q in 0..ds.len() {
                // queries replay the original f32 vectors
                index.search_into_excluding(ds.vec(q), 10, q as u32, &mut scratch, &mut out);
                let set: std::collections::HashSet<u32> =
                    out.iter().map(|&(_, id)| id).collect();
                hits += truth[q].iter().filter(|id| set.contains(id)).count();
                total += truth[q].len().min(10);
                evals.0 += scratch.dist_evals;
                evals.1 += scratch.rerank_evals;
            }
            hits as f64 / total as f64
        };
        let mut we = (0, 0);
        let exact = recall_of(&ds, 1, &mut we);
        assert_eq!(we.1, 0, "f32 search must not rerank");
        let qds = ds.quantize_with_exact();
        let mut qe = (0, 0);
        let reranked = recall_of(&qds, 4, &mut qe);
        assert!(
            reranked >= exact - 0.02,
            "rerank=4 recall {reranked} fell more than 2 points below f32 {exact}"
        );
        // the rerank pass touches only rerank*k rows per query — far
        // fewer full-precision evals than the beam performs
        assert!(qe.1 > 0, "quantized rerank search did no rerank evals");
        assert!(
            qe.1 * 4 <= qe.0,
            "rerank evals {} not >= 4x cheaper than beam evals {}",
            qe.1,
            qe.0
        );
        // rerank distances are full-precision (match f32 kernel scale)
        let params = SearchParams::default().with_ef(64).with_rerank(4);
        let qindex = SearchIndex::new(&qds, &g, params).unwrap();
        let hits = qindex.search(ds.vec(0), 5);
        for &(d, id) in &hits {
            let want = ds.dist_to(id as usize, ds.vec(0));
            assert_eq!(d, want, "rerank distance for {id} not the exact f32 value");
        }
    }

    #[test]
    fn rejects_mismatched_graph() {
        let ds = synth::uniform(50, 4, 96);
        let g = crate::graph::KnnGraph::empty(40, 4);
        assert!(SearchIndex::new(&ds, &g, SearchParams::default()).is_err());
        let g2 = crate::graph::KnnGraph::empty(50, 4);
        let bad = SearchParams { ef: 0, ..Default::default() };
        assert!(SearchIndex::new(&ds, &g2, bad).is_err());
    }
}
