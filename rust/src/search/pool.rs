//! Persistent scatter worker pool for [`super::sharded::ShardedIndex`].
//!
//! The parallel scatter phase used to spawn `search_threads - 1` scoped
//! threads *per query* — a fixed cost every query paid regardless of
//! how much per-shard work there was to overlap. GGNN (Groh et al.)
//! keeps long-lived per-GPU worker state across queries for exactly
//! this reason, and the source paper's merge design treats shard walks
//! as independent units of schedulable work — the natural host for
//! them is a long-lived pool, not per-query threads.
//!
//! [`ScatterPool`] is that pool: `N` workers spawned once when the
//! index opens, each parked on a shared job queue with its own warm
//! [`SearchScratch`] (so a worker's visited set / heaps / pin table
//! keep their capacity across every query it ever serves). A query
//! submits one [`ScatterJob`] — the query vector, the probed shard
//! order, a shared work cursor and a result collector — wakes up to
//! `min(workers, shards - 1)` workers, and *participates inline* on
//! the calling thread, so a query never waits on a fully busy pool to
//! make progress. Workers pull shards off the job's cursor until none
//! remain, push their accumulated per-shard top-k lists, and go back
//! to sleep; the dispatcher blocks until every *shard* of the work
//! list has been searched — never on busy workers that have yet to
//! pop an already-drained job copy (under concurrent queries a
//! dispatcher that scattered its whole probe set inline returns
//! immediately).
//!
//! The gather merge in `sharded.rs` sorts the union of per-shard
//! lists, so collection order is irrelevant — pool-based scatter is
//! **bit-identical** to the sequential path (enforced by the parity
//! suite in `tests/sharded.rs`).
//!
//! Shutdown and panics are handled explicitly:
//!
//! * dropping the pool closes the queue, wakes every worker and joins
//!   them — an index drop never leaks threads;
//! * a worker panic inside a job (e.g. the store vanished mid-query,
//!   which [`super::sharded`] deliberately panics on) is caught, the
//!   job is marked poisoned so the dispatcher re-panics on its own
//!   thread (matching the old scoped-thread behavior), and the worker
//!   survives to serve later queries with a cleaned scratch.
//!
//! The job queue is the shared closeable MPMC channel from
//! [`crate::util::mpmc`] (hand-rolled `Mutex<VecDeque>` + `Condvar`:
//! the vendored dependency closure has no channel crate), wrapped here
//! only to keep the live `scatter.queue_depth` gauge at the push/pop
//! transitions — far off the hot path (one send per woken worker per
//! query). The network front end ([`super::server`]) parks its
//! coalescing batcher on the same queue type.

use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::telemetry;
use crate::util::mpmc;
use crate::util::timer::Timer;

use super::sharded::{ScatterOut, ShardCore};
use super::SearchScratch;

/// One query's scatter fan-out: everything a worker needs to pull
/// probed shards off the shared cursor and report its slice. Owns the
/// query vector (copied — `d` floats), so a job outlives any unwinding
/// dispatcher without borrowing from the caller's stack.
///
/// Completion is counted in **finished shards**, not popped job
/// copies: a busy pool can leave a job's queue copies unclaimed long
/// after the dispatcher has drained the cursor inline, and the
/// dispatcher must not wait on workers that have nothing left to
/// contribute (a participant only counts shards it actually searched,
/// and pushes its contribution *before* reporting them finished, so
/// when the count reaches the work-list length every contribution is
/// already visible).
pub(crate) struct ScatterJob {
    pub(crate) q: Vec<f32>,
    pub(crate) k: usize,
    pub(crate) ef: usize,
    pub(crate) exclude: u32,
    /// Probed shards in routing order — the work list.
    pub(crate) order: Vec<usize>,
    /// Collect per-shard trace spans for this query (sampled by the
    /// serve harness; observation-only).
    pub(crate) traced: bool,
    /// Next index into `order` to be claimed.
    cursor: AtomicUsize,
    /// Per-participant (dist_evals, hops, shard top-k) contributions.
    pub(crate) collected: Mutex<Vec<ScatterOut>>,
    /// Shards searched to completion so far + the first participant
    /// panic, if any.
    state: Mutex<JobState>,
    done: Condvar,
}

struct JobState {
    finished_shards: usize,
    /// Payload of the first participant panic — carried to the
    /// dispatcher and re-raised there with `resume_unwind`, preserving
    /// the original message the way the old scoped-scope `.unwrap()`
    /// did.
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
}

/// Job-state lock that shrugs off poisoning: the state is two plain
/// fields mutated atomically under the lock (no invariant can be torn
/// mid-update), and a poisoned-lock unwrap here would cascade one
/// query's panic into every pool worker that later touches the job.
fn lock_state(job: &ScatterJob) -> std::sync::MutexGuard<'_, JobState> {
    job.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

impl ScatterJob {
    fn new(
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        order: Vec<usize>,
        fan: usize,
        traced: bool,
    ) -> Arc<Self> {
        Arc::new(ScatterJob {
            q: q.to_vec(),
            k,
            ef,
            exclude,
            traced,
            cursor: AtomicUsize::new(0),
            collected: Mutex::new(Vec::with_capacity(fan + 1)),
            state: Mutex::new(JobState { finished_shards: 0, panic_payload: None }),
            done: Condvar::new(),
            order,
        })
    }

    /// Claim the next unprocessed shard of the job (None = exhausted).
    pub(crate) fn next_shard(&self) -> Option<usize> {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed);
        self.order.get(i).copied()
    }

    /// Cheap pre-check for a popped job copy whose work list has
    /// already been drained by the other participants — a busy worker
    /// skips it without touching its scratch.
    fn exhausted(&self) -> bool {
        self.cursor.load(Ordering::Relaxed) >= self.order.len()
    }

    /// A participant finished its slice: `shards_done` shards searched
    /// (its contribution is already in `collected`), `panic` = the
    /// payload it unwound with mid-walk, if any. Signals the
    /// dispatcher when the job is complete (every shard searched) or
    /// poisoned.
    fn finish(&self, shards_done: usize, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut st = lock_state(self);
        st.finished_shards += shards_done;
        if st.panic_payload.is_none() {
            st.panic_payload = panic;
        }
        let wake = st.panic_payload.is_some() || st.finished_shards >= self.order.len();
        drop(st);
        if wake {
            self.done.notify_all();
        }
    }

    /// Dispatcher side: block until every shard of the work list has
    /// been searched (regardless of which participants the queue
    /// happened to hand the job to), then re-raise any worker panic on
    /// the calling thread with its original payload (the contract the
    /// per-query scoped scope's `.unwrap()` used to provide). The
    /// guard is released before unwinding so the job's state mutex is
    /// never poisoned by the propagation itself.
    fn wait(&self) {
        let mut st = lock_state(self);
        while st.panic_payload.is_none() && st.finished_shards < self.order.len() {
            st = self
                .done
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        if let Some(payload) = st.panic_payload.take() {
            drop(st);
            panic::resume_unwind(payload);
        }
    }
}

/// The shared MPMC channel plus the live `scatter.queue_depth` gauge:
/// job copies pushed but not yet popped (adjusted at queue
/// transitions, off the search path). Senders push + wake one sleeper;
/// closing wakes everyone so workers drain the queue and exit.
struct JobQueue {
    inner: mpmc::Queue<Arc<ScatterJob>>,
    depth: Arc<telemetry::Gauge>,
}

impl JobQueue {
    fn new() -> Self {
        JobQueue {
            inner: mpmc::Queue::new(),
            depth: telemetry::global().gauge("scatter.queue_depth"),
        }
    }

    fn push(&self, job: Arc<ScatterJob>) {
        if self.inner.push(job) {
            self.depth.add(1);
        }
    }

    /// Next job, blocking while the queue is open and empty; `None`
    /// once the queue is closed and drained.
    fn pop(&self) -> Option<Arc<ScatterJob>> {
        let job = self.inner.pop();
        if job.is_some() {
            self.depth.add(-1);
        }
        job
    }

    fn close(&self) {
        self.inner.close();
    }
}

/// The long-lived scatter worker pool owned by a
/// [`super::sharded::ShardedIndex`]: spawned once at open, parked
/// between queries, joined on drop.
pub struct ScatterPool {
    queue: Arc<JobQueue>,
    workers: Vec<JoinHandle<()>>,
    /// `scatter.jobs` counter: one bump per dispatched query fan-out.
    jobs: Arc<telemetry::Counter>,
}

impl ScatterPool {
    /// Spawn `workers` pool threads over the shared index core. The
    /// dispatching thread always participates inline, so a pool of
    /// `N - 1` workers gives `N`-way scatter parallelism.
    pub(crate) fn new(core: Arc<ShardCore>, workers: usize) -> Self {
        let queue = Arc::new(JobQueue::new());
        let handles = (0..workers)
            .map(|w| {
                let queue = Arc::clone(&queue);
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("gnnd-scatter-{w}"))
                    .spawn(move || worker_loop(&core, &queue, w))
                    .expect("spawn scatter pool worker")
            })
            .collect();
        ScatterPool { queue, workers: handles, jobs: telemetry::global().counter("scatter.jobs") }
    }

    /// Number of parked pool workers (excluding the inline dispatcher).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Fan one query's probed shards across the pool and the calling
    /// thread; blocks until the whole probe set is searched. Returns
    /// every participant's (dist_evals, hops, shard top-k) slice — the
    /// caller's gather sort makes collection order irrelevant.
    pub(crate) fn scatter(
        &self,
        core: &ShardCore,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        order: Vec<usize>,
        traced: bool,
    ) -> Vec<ScatterOut> {
        self.jobs.inc();
        // never wake more workers than there are shards beyond the one
        // the dispatcher itself will take
        let fan = self.workers.len().min(order.len().saturating_sub(1));
        let job = ScatterJob::new(q, k, ef, exclude, order, fan, traced);
        for _ in 0..fan {
            self.queue.push(Arc::clone(&job));
        }
        // inline participation with a pooled warm scratch; an inline
        // panic propagates directly on this thread (the job Arc keeps
        // the in-flight workers' view alive regardless)
        let mut scratch = core.take_scratch();
        let done = core.run_scatter_job(&job, &mut scratch);
        core.put_scratch(scratch);
        job.finish(done, None);
        job.wait();
        std::mem::take(&mut *job.collected.lock().unwrap())
    }
}

impl Drop for ScatterPool {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            // worker panics inside jobs are already caught and reported
            // through the job; a join error here means the thread died
            // outside one — nothing to do mid-drop but not block
            let _ = h.join();
        }
    }
}

/// Body of one pool worker: park on the queue, run each job's slice
/// with a warm thread-local scratch, survive job panics. Worker `w`
/// attributes its wall time to `scatter.worker{w}.busy_us` (running a
/// job) vs `.idle_us` (parked on the queue) — the live view of how
/// well scatter work saturates the pool.
fn worker_loop(core: &ShardCore, queue: &JobQueue, w: usize) {
    let g = telemetry::global();
    let busy_us = g.counter(&format!("scatter.worker{w}.busy_us"));
    let idle_us = g.counter(&format!("scatter.worker{w}.idle_us"));
    let mut scratch = SearchScratch::new();
    loop {
        let t_idle = Timer::start();
        let Some(job) = queue.pop() else { break };
        idle_us.add(telemetry::us(t_idle.secs()));
        let t_busy = Timer::start();
        if job.exhausted() {
            // the dispatcher (or another worker) already drained this
            // job's cursor — nothing to contribute
            job.finish(0, None);
        } else {
            let res = panic::catch_unwind(AssertUnwindSafe(|| {
                core.run_scatter_job(&job, &mut scratch)
            }));
            match res {
                Ok(done) => job.finish(done, None),
                Err(payload) => {
                    // an unwound walk may have left pins (or partial
                    // results) in the scratch: drop them so a poisoned
                    // query can never block eviction or leak candidates
                    // into the next one
                    ShardCore::clear_scratch_after_panic(&mut scratch);
                    job.finish(0, Some(payload));
                }
            }
        }
        busy_us.add(telemetry::us(t_busy.secs()));
    }
}
