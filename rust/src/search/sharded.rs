//! Sharded serving: scatter-gather ANN search over the per-shard graphs
//! of the out-of-core pipeline ([`crate::merge::outofcore`]).
//!
//! `ooc-build` leaves behind a [`ShardStore`] directory: one
//! `shard_<i>.dsb` / `graph_<i>.knng` pair per shard (neighbor ids in
//! the **global** id space, GGM-merged across all shard pairs) plus a
//! [`ShardManifest`]. [`ShardedIndex`] opens that directory and serves
//! it:
//!
//! 1. **route** — rank shards by query-to-centroid distance and keep the
//!    best `probe_shards` (0 = probe everything), so hot paths skip
//!    irrelevant shards;
//! 2. **scatter** — run an independent best-first search *inside* each
//!    probed shard. Only nodes owned by the shard are expanded;
//!    cross-shard edges (the merge's contribution) are scored as
//!    candidate results but never walked, which keeps the per-shard
//!    walks independent — the property that later lets shards live on
//!    different workers or devices;
//! 3. **gather** — k-way merge the per-shard top-k lists (dedup by id:
//!    a cross-shard edge and its home shard can propose the same
//!    object) into the final ascending top-k.
//!
//! The whole pipeline reuses one [`SearchScratch`] per worker thread —
//! the sharded hot path stays allocation-free once warm, exactly like
//! the monolithic one.

use std::cmp::Reverse;
use std::path::Path;

use anyhow::Context;

use crate::config::Metric;
use crate::dataset::groundtruth::ordered::F32;
use crate::dataset::Dataset;
use crate::graph::KnnGraph;
use crate::merge::outofcore::{shard_centroid, ShardStore};

use super::{select_entries, AnnIndex, SearchParams, SearchScratch};

/// One resident shard: its vectors, its merged sub-graph (neighbor ids
/// in the global id space), its global-id offset, fixed entry points
/// (global ids) and routing centroid.
struct Shard {
    ds: Dataset,
    graph: KnnGraph,
    offset: usize,
    entries: Vec<u32>,
    centroid: Vec<f32>,
}

/// An [`AnnIndex`] over the shard files of an out-of-core build.
pub struct ShardedIndex {
    shards: Vec<Shard>,
    /// Start id of each shard, ascending (offsets\[s\] = shard s start).
    offsets: Vec<usize>,
    total: usize,
    d: usize,
    metric: Metric,
    params: SearchParams,
    /// Shards probed per query (0 = all).
    probe_shards: usize,
}

impl ShardedIndex {
    /// Open an `ooc-build` output directory (manifest + shard files).
    pub fn open(
        dir: impl AsRef<Path>,
        params: SearchParams,
        probe_shards: usize,
    ) -> crate::Result<Self> {
        let store = ShardStore::new(dir)?;
        Self::from_store(&store, params, probe_shards)
    }

    pub fn from_store(
        store: &ShardStore,
        params: SearchParams,
        probe_shards: usize,
    ) -> crate::Result<Self> {
        params.validate()?;
        let manifest = store.load_manifest()?;
        anyhow::ensure!(manifest.shards >= 1, "manifest has no shards");
        let mut shards = Vec::with_capacity(manifest.shards);
        let mut offsets = Vec::with_capacity(manifest.shards);
        let mut expect = 0usize;
        for s in 0..manifest.shards {
            let ds = store.load_shard(s)?;
            let graph = store.load_graph(s)?;
            anyhow::ensure!(
                graph.n() == ds.len(),
                "shard {s}: graph covers {} objects but shard has {}",
                graph.n(),
                ds.len()
            );
            anyhow::ensure!(
                ds.d == manifest.d,
                "shard {s}: dim {} != manifest dim {}",
                ds.d,
                manifest.d
            );
            let offset = manifest.offsets[s];
            anyhow::ensure!(
                offset == expect,
                "shard {s}: manifest offset {offset} not contiguous (expected {expect})"
            );
            expect += ds.len();
            // the shards' global id space must be closed over the
            // manifest total — corrupt graphs fail here, not mid-query
            check_global_ids(&graph, offset, manifest.total)
                .with_context(|| format!("shard {s} graph"))?;
            // per-shard entry selection (shard-local ids -> global);
            // decorrelate the per-shard RNG streams with the shard id
            let salt = (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let sp = params.clone().with_seed(params.seed ^ salt);
            let mut entries = select_entries(&ds, &graph, &sp);
            for e in entries.iter_mut() {
                *e += offset as u32;
            }
            let centroid = match manifest.centroids.get(s) {
                Some(c) if !c.is_empty() => c.clone(),
                _ => shard_centroid(&ds),
            };
            offsets.push(offset);
            shards.push(Shard { ds, graph, offset, entries, centroid });
        }
        anyhow::ensure!(
            expect == manifest.total,
            "manifest total {} != sum of shard sizes {expect}",
            manifest.total
        );
        Ok(ShardedIndex {
            shards,
            offsets,
            total: manifest.total,
            d: manifest.d,
            metric: manifest.metric,
            params,
            probe_shards,
        })
    }

    /// Number of shards resident.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Effective shards probed per query.
    pub fn probe(&self) -> usize {
        if self.probe_shards == 0 {
            self.shards.len()
        } else {
            self.probe_shards.min(self.shards.len())
        }
    }

    pub fn params(&self) -> &SearchParams {
        &self.params
    }

    /// The full corpus re-assembled as one in-memory dataset (bench /
    /// ground-truth convenience; true deployments keep shards apart).
    pub fn concat_dataset(&self) -> Dataset {
        let mut it = self.shards.iter();
        let first = it.next().expect("at least one shard").ds.clone();
        it.fold(first, |acc, s| acc.concat(&s.ds, "sharded"))
    }

    /// Owning shard of a global id.
    #[inline]
    fn owner(&self, gid: u32) -> usize {
        self.offsets.partition_point(|&off| off <= gid as usize) - 1
    }

    /// Distance from `q` to global object `gid` (any resident shard).
    #[inline]
    fn dist_to_global(&self, gid: u32, q: &[f32]) -> f32 {
        let s = self.owner(gid);
        self.shards[s].ds.dist_to(gid as usize - self.shards[s].offset, q)
    }

    /// The scatter side: best-first search restricted to shard `s`,
    /// appending the shard's top-`k` (global ids, ascending) to
    /// `scratch.shard_topk`. Mirrors [`super::beam_search`] except that
    /// cross-shard edges are scored but never expanded.
    fn search_shard(
        &self,
        s: usize,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
    ) {
        let shard = &self.shards[s];
        let lo = shard.offset as u32;
        let hi = (shard.offset + shard.ds.len()) as u32;
        scratch.visited.begin(self.total);
        scratch.frontier.clear();
        scratch.results.clear();

        for &e in &shard.entries {
            if scratch.visited.insert(e) {
                let d = shard.ds.dist_to((e - lo) as usize, q);
                scratch.dist_evals += 1;
                scratch.frontier.push(Reverse((F32(d), e)));
                if e != exclude {
                    scratch.results.push((F32(d), e));
                    if scratch.results.len() > ef {
                        scratch.results.pop();
                    }
                }
            }
        }

        let beam_width = self.params.beam_width;
        let max_hops = self.params.max_hops;
        let mut hops = 0usize;
        while let Some(Reverse((F32(d), u))) = scratch.frontier.pop() {
            if scratch.results.len() >= ef {
                if let Some(&(F32(w), _)) = scratch.results.peek() {
                    if d > w {
                        break;
                    }
                }
            }
            if max_hops > 0 && hops >= max_hops {
                break;
            }
            hops += 1;
            for e in shard.graph.list((u - lo) as usize) {
                if e.is_empty() {
                    break;
                }
                if !scratch.visited.insert(e.id) {
                    continue;
                }
                let dv = self.dist_to_global(e.id, q);
                scratch.dist_evals += 1;
                if (lo..hi).contains(&e.id) {
                    scratch.frontier.push(Reverse((F32(dv), e.id)));
                }
                if e.id != exclude {
                    scratch.results.push((F32(dv), e.id));
                    if scratch.results.len() > ef {
                        scratch.results.pop();
                    }
                }
            }
            if beam_width > 0 && scratch.frontier.len() > 4 * beam_width {
                scratch.buf.clear();
                for _ in 0..beam_width {
                    match scratch.frontier.pop() {
                        Some(Reverse(x)) => scratch.buf.push(x),
                        None => break,
                    }
                }
                scratch.frontier.clear();
                for &x in &scratch.buf {
                    scratch.frontier.push(Reverse(x));
                }
            }
        }
        scratch.hops += hops;

        // drain this shard's result pool (max-heap pops worst-first) and
        // keep its best k for the gather phase
        scratch.buf.clear();
        while let Some(x) = scratch.results.pop() {
            scratch.buf.push(x);
        }
        let take = k.min(scratch.buf.len());
        for &x in scratch.buf.iter().rev().take(take) {
            scratch.shard_topk.push(x);
        }
    }
}

/// Every neighbor id of a merged shard graph must stay inside the
/// global id space and never point back at its own node — the
/// invariants [`crate::merge::outofcore::merge_pair_global`] maintains.
fn check_global_ids(graph: &KnnGraph, offset: usize, total: usize) -> crate::Result<()> {
    for u in 0..graph.n() {
        let gid = (offset + u) as u32;
        for e in graph.list(u) {
            if e.is_empty() {
                break;
            }
            anyhow::ensure!(
                (e.id as usize) < total,
                "node {gid}: neighbor id {} outside global space (total {total})",
                e.id
            );
            anyhow::ensure!(e.id != gid, "node {gid}: self loop");
        }
    }
    Ok(())
}

impl AnnIndex for ShardedIndex {
    fn len(&self) -> usize {
        self.total
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn vector(&self, id: u32) -> &[f32] {
        let s = self.owner(id);
        self.shards[s].ds.vec(id as usize - self.shards[s].offset)
    }

    fn default_ef(&self) -> usize {
        self.params.ef
    }

    fn describe(&self) -> String {
        format!("sharded(n={}, shards={}, probe={})", self.total, self.shards.len(), self.probe())
    }

    fn make_scratch(&self) -> SearchScratch {
        let mut s = SearchScratch::new();
        s.visited.begin(self.total);
        s
    }

    fn search_ef_into_excluding(
        &self,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        let ef = (if ef == 0 { self.params.ef } else { ef }).max(k).max(1);
        scratch.dist_evals = 0;
        scratch.hops = 0;

        // ---- route ----
        let probe = self.probe();
        scratch.shard_rank.clear();
        if probe < self.shards.len() {
            for (s, sh) in self.shards.iter().enumerate() {
                let d = crate::distance::distance(self.metric, q, &sh.centroid);
                scratch.shard_rank.push((F32(d), s));
            }
            scratch.shard_rank.sort_unstable();
        } else {
            for s in 0..self.shards.len() {
                scratch.shard_rank.push((F32(0.0), s));
            }
        }

        // ---- scatter ----
        scratch.shard_topk.clear();
        for i in 0..probe {
            let (_, s) = scratch.shard_rank[i];
            self.search_shard(s, q, k, ef, exclude, scratch);
        }

        // ---- gather: k-way merge with cross-shard dedup ----
        scratch.shard_topk.sort_unstable();
        out.clear();
        for &(F32(d), id) in scratch.shard_topk.iter() {
            if out.len() >= k {
                break;
            }
            if out.iter().any(|&(_, have)| have == id) {
                continue;
            }
            out.push((d, id));
        }
    }
}
