//! Sharded serving: scatter-gather ANN search over the per-shard graphs
//! of the out-of-core pipeline ([`crate::merge::outofcore`]).
//!
//! `ooc-build` leaves behind a [`ShardStore`] directory: one
//! `shard_<i>.dsb` / `graph_<i>.knng` pair per shard (neighbor ids in
//! the **global** id space, GGM-merged across all shard pairs) plus a
//! [`ShardManifest`](crate::merge::outofcore::ShardManifest).
//! [`ShardedIndex`] opens that directory and serves it:
//!
//! 1. **route** — rank shards by query-to-centroid distance and keep the
//!    best `probe_shards` (0 = probe everything), so hot paths skip
//!    irrelevant shards;
//! 2. **scatter** — run an independent best-first search *inside* each
//!    probed shard. Only nodes owned by the shard are expanded;
//!    cross-shard edges (the merge's contribution) into *probed* shards
//!    are scored as candidate results but never walked, which keeps the
//!    per-shard walks independent — the property that lets shards fan
//!    across worker threads here and across processes/devices later;
//! 3. **gather** — k-way merge the per-shard top-k lists (dedup by id:
//!    a cross-shard edge and its home shard can propose the same
//!    object) into the final ascending top-k. On a quantized store
//!    ([`ShardStore::quantized`]) the scatter beams score cheap u8
//!    code-space distances, the merge keeps `rerank * k` distinct
//!    survivors, and a final exact-rerank pass re-scores them against
//!    the full-precision rows before the top-k cut.
//!
//! Shard *residency* is managed, not assumed: the index owns no shard
//! data. Every query resolves pinned handles from the
//! [`ShardStore`] LRU cache ([`ShardStore::get_shard`]), so a store
//! opened with a byte budget serves corpora larger than RAM — shards
//! fault in on miss and the cache sheds least-recently-used shards as
//! pins release. The scoring universe of a query is its *probed set*
//! (cross-shard edges into unprobed shards are skipped
//! deterministically), so results depend only on the probe set, never
//! on what happened to be resident — a budget-constrained index
//! returns bit-identical results to an unbounded one. Under
//! whole-shard residency ([`ResidencyMode::Shard`]) a query pins the
//! full data of every probed shard, so *peak* residency is bounded by
//! the probe set, not the budget (the CLI warns when probe and budget
//! disagree). Under block residency ([`ResidencyMode::Block`]) pins
//! hold only cheap paged handles and rows page in block-by-block
//! through a shared budget-capped cache, so even a budget smaller
//! than one shard serves — cold-start cost is proportional to rows
//! actually visited, not shard size.
//!
//! With `search_threads > 1` the scatter phase fans the probed shards
//! across a **persistent** [`ScatterPool`]: `search_threads - 1`
//! workers spawned once at open (each with its own warm
//! [`SearchScratch`]), parked on a job queue between queries, with the
//! querying thread always participating inline — a query pays channel
//! wakeups, never thread spawns. A worker faulting a cold shard in
//! from disk overlaps with the other workers' warm-shard compute. The
//! gather sort is order-independent, so pooled scatter is bit-identical
//! to sequential (enforced by the parity suite in `tests/sharded.rs`).

use std::cmp::Reverse;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::config::Metric;
use crate::dataset::groundtruth::ordered::F32;
use crate::dataset::Dataset;
use crate::graph::KnnGraph;
use crate::merge::outofcore::{
    shard_centroid, ResidencyMode, ResidencyStats, ResidentShard, ShardCompression, ShardStore,
};

use crate::telemetry::trace::ShardSpan;
use crate::util::timer::Timer;

use super::pool::{ScatterJob, ScatterPool};
use super::{hierarchy, select_entries, AnnIndex, EntryStrategy, SearchParams, SearchScratch};

/// One scatter participant's contribution to a query: its work
/// counters, the per-shard top-k entries it accumulated, and — when
/// the query is traced — one [`ShardSpan`] per shard it searched
/// (empty otherwise).
pub(crate) struct ScatterOut {
    pub(crate) dist_evals: usize,
    pub(crate) hops: usize,
    pub(crate) topk: Vec<(F32, u32)>,
    pub(crate) spans: Vec<ShardSpan>,
}

/// Serving metadata of one shard — everything a query needs *before*
/// touching the shard's data: geometry, fixed entry points (global
/// ids) and the routing centroid(s). Vectors and graph are resolved
/// through the [`ShardStore`] cache per query.
struct ShardMeta {
    offset: usize,
    len: usize,
    /// Fixed entry points (empty under [`EntryStrategy::Hierarchy`] —
    /// seeds come from `hier` per query).
    entries: Vec<u32>,
    /// Mean-vector routing centroid (every manifest has one).
    centroid: Vec<f32>,
    /// Multi-centroid routing: per-shard k-means centroids from the
    /// manifest (`route_centroids`). Empty for pre-PR8 manifests —
    /// routing then falls back to `centroid`, bit-identical to the
    /// old single-centroid ranking.
    route_centroids: Vec<Vec<f32>>,
    /// Per-shard entry hierarchy ([`EntryStrategy::Hierarchy`]):
    /// loaded from (or persisted to) a `hier_<s>.bin` sidecar in the
    /// store directory at open.
    hier: Option<Arc<hierarchy::EntryHierarchy>>,
}

/// Resolve (and pin) shard `s` into a query's pin table
/// (`scratch.shard_pins`). Shard files vanishing mid-query means the
/// store was deleted or corrupted underneath a live index —
/// unrecoverable, so this panics rather than returning partial
/// results.
fn pin_handle(
    store: &ShardStore,
    pins: &mut [Option<Arc<ResidentShard>>],
    s: usize,
) -> Arc<ResidentShard> {
    if let Some(h) = &pins[s] {
        return Arc::clone(h);
    }
    let h = store
        .get_shard(s)
        .unwrap_or_else(|e| panic!("shard {s} unreadable mid-query (store corrupt?): {e:#}"));
    pins[s] = Some(Arc::clone(&h));
    h
}

/// The per-shard [`hierarchy::HierConfig`] serving expects: the
/// store-wide base seed decorrelated by the shard id (the same salt
/// expression [`ShardedIndex::from_store`] applies to entry
/// selection). Shared with the out-of-core builder so pre-built and
/// refreshed `hier_<s>.bin` sidecars pass the
/// [`hierarchy::EntryHierarchy::matches`] gate at open instead of
/// being rebuilt.
pub(crate) fn shard_hier_config(base_seed: u64, s: usize) -> hierarchy::HierConfig {
    let salt = (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    hierarchy::HierConfig { seed: base_seed ^ salt, ..Default::default() }
}

/// `--probe-shards` beyond the manifest shard count would silently
/// "probe" phantom shards; the CLI clamps it with a warning (same
/// pattern as [`crate::search::serve::clamp_ef`]). Returns the
/// effective probe count and whether clamping happened.
pub fn clamp_probe(probe: usize, shards: usize) -> (usize, bool) {
    if probe > shards {
        (shards, true)
    } else {
        (probe, false)
    }
}

/// `--search-threads 0` would mean "no scatter workers at all" — it was
/// only masked by [`ShardedIndex::scatter_threads`]'s `max(1)` at query
/// time, so an operator asking for zero silently got one. The CLI
/// clamps it to 1 (sequential scatter) with a warning at parse time,
/// mirroring [`clamp_probe`]; the query-time `max(1)` stays as a
/// backstop for library callers. Returns the effective thread count and
/// whether clamping happened.
pub fn clamp_search_threads(threads: usize) -> (usize, bool) {
    if threads == 0 {
        (1, true)
    } else {
        (threads, false)
    }
}

/// Everything a scatter participant — the querying thread or a
/// [`ScatterPool`] worker — needs to walk shards: the residency-managed
/// store, per-shard serving metadata, and the scratch reuse pool.
/// Shared as an `Arc` between the [`ShardedIndex`] front end and the
/// pool's long-lived worker threads.
pub(crate) struct ShardCore {
    store: ShardStore,
    meta: Vec<ShardMeta>,
    /// Unbounded-budget fast path: with no byte budget nothing can
    /// ever be evicted, so the core keeps one permanent pin per shard
    /// and queries resolve handles with an `Arc` clone instead of
    /// taking the cache mutex. Empty when a budget is set. Consequence:
    /// an unbounded index serves a *snapshot taken at open* — saving
    /// over shard files via [`ShardedIndex::store`] mid-serving is only
    /// picked up by budget-constrained indexes (the pre-residency
    /// `ShardedIndex` had the same snapshot-at-open semantics).
    pinned_all: Vec<Arc<ResidentShard>>,
    /// Start id of each shard, ascending (offsets\[s\] = shard s start).
    offsets: Vec<usize>,
    total: usize,
    d: usize,
    metric: Metric,
    params: SearchParams,
    /// Warm scratches for inline scatter dispatch, reused across
    /// queries (pool workers own their scratch thread-locally instead).
    scratch_pool: Mutex<Vec<SearchScratch>>,
}

impl ShardCore {
    /// Owning shard of a global id.
    #[inline]
    fn owner(&self, gid: u32) -> usize {
        self.offsets.partition_point(|&off| off <= gid as usize) - 1
    }

    /// Route distance of a query to one shard: the minimum over the
    /// shard's `route_centroids` (a query near *any* cluster of the
    /// shard routes there — the single mean of a multi-modal shard
    /// sits between its clusters and misroutes). Falls back to the
    /// mean centroid when the manifest predates `route_centroids`,
    /// which keeps the fallback ranking bit-identical to the old
    /// single-centroid route.
    fn route_score(&self, q: &[f32], m: &ShardMeta) -> f32 {
        if m.route_centroids.is_empty() {
            return crate::distance::distance(self.metric, q, &m.centroid);
        }
        let mut best = f32::INFINITY;
        for c in &m.route_centroids {
            let d = crate::distance::distance(self.metric, q, c);
            if d < best {
                best = d;
            }
        }
        best
    }

    /// Resolve shard `s` for the current query: the permanent pin when
    /// the budget is unbounded (an `Arc` clone, no lock), else through
    /// the query's pin table and the residency cache.
    #[inline]
    fn resolve(&self, pins: &mut [Option<Arc<ResidentShard>>], s: usize) -> Arc<ResidentShard> {
        if let Some(h) = self.pinned_all.get(s) {
            return Arc::clone(h);
        }
        pin_handle(&self.store, pins, s)
    }

    /// Reset the scratch's pin table for a new query: no pins held,
    /// probed set empty. `clear` + `resize` keep capacity, so a warm
    /// scratch allocates nothing here.
    fn begin_pins(&self, scratch: &mut SearchScratch) {
        let n = self.meta.len();
        scratch.shard_pins.clear();
        scratch.shard_pins.resize(n, None);
        scratch.shard_probed.clear();
        scratch.shard_probed.resize(n, false);
    }

    /// Release every pin the query holds (a kept scratch must never
    /// block eviction between queries).
    fn release_pins(scratch: &mut SearchScratch) {
        for p in scratch.shard_pins.iter_mut() {
            *p = None;
        }
    }

    /// Restore a pool worker's scratch after a job panicked out of a
    /// walk: drop any pins the unwound query still holds and discard
    /// its partial candidates, so a poisoned query can never block
    /// eviction or leak results into the next one.
    pub(crate) fn clear_scratch_after_panic(scratch: &mut SearchScratch) {
        Self::release_pins(scratch);
        scratch.shard_topk.clear();
        scratch.trace.clear();
        scratch.trace.enabled = false;
    }

    /// The scatter side: best-first search restricted to shard `s`,
    /// appending the shard's top-`k` (global ids, ascending) to
    /// `scratch.shard_topk`. Mirrors [`super::beam_search`] except that
    /// cross-shard edges are scored (via the scratch's pin table,
    /// against probed shards only) but never expanded.
    fn search_shard(
        &self,
        s: usize,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
    ) {
        // tracing is observation-only: everything below the timers runs
        // identically whether or not the sink is armed
        let tracing = scratch.trace.enabled;
        let t_shard = tracing.then(Timer::start);
        let (blk_hits0, blk_fetches0) = if tracing {
            crate::dataset::store::thread_block_counters()
        } else {
            (0, 0)
        };
        let evals0 = scratch.dist_evals;
        let t_pin = tracing.then(Timer::start);
        let home = self.resolve(&mut scratch.shard_pins, s);
        let wait_ms = t_pin.map_or(0.0, |t| t.ms());
        // code-space scoring on a compressed store: prepare the query
        // once per scratch — every shard shares the one code space
        // `quantize_store` / `pq_quantize_store` fitted (scalar params
        // or PQ codebooks), so the first shard's encode / LUT build
        // serves the whole scatter (and cross-shard scores stay
        // comparable). On an f32 store this leaves both buffers empty
        // and every `dist_to_quant` below falls through to the exact
        // f32 path.
        let mut qcodes = std::mem::take(&mut scratch.qcodes);
        let mut lut = std::mem::take(&mut scratch.lut);
        if qcodes.is_empty() && lut.is_empty() {
            home.ds.prepare_query(q, &mut qcodes, &mut lut);
        }
        let m = &self.meta[s];
        let lo = m.offset as u32;
        let hi = (m.offset + m.len) as u32;
        scratch.visited.begin(self.total);
        scratch.frontier.clear();
        scratch.results.clear();

        // seed the beam: fixed per-shard entries, or a per-query
        // coarse-to-fine descent (shard-local seeds mapped to global
        // ids; descent distance work counts toward this shard's evals,
        // but its walks over the tiny level graphs are not base-graph
        // hops)
        let mut entry_buf = std::mem::take(&mut scratch.entry_buf);
        if let Some(h) = &m.hier {
            let devals = h.descend(q, self.params.n_entry, scratch, &mut entry_buf);
            scratch.dist_evals += devals;
            for e in entry_buf.iter_mut() {
                *e += lo;
            }
        } else {
            entry_buf.clear();
            entry_buf.extend_from_slice(&m.entries);
        }
        for &e in &entry_buf {
            if scratch.visited.insert(e) {
                let d = home.ds.dist_to_quant((e - lo) as usize, q, &qcodes, &lut);
                scratch.dist_evals += 1;
                scratch.frontier.push(Reverse((F32(d), e)));
                if e != exclude {
                    scratch.results.push((F32(d), e));
                    if scratch.results.len() > ef {
                        scratch.results.pop();
                    }
                }
            }
        }
        scratch.entry_buf = entry_buf;

        let beam_width = self.params.beam_width;
        let max_hops = self.params.max_hops;
        let mut hops = 0usize;
        while let Some(Reverse((F32(d), u))) = scratch.frontier.pop() {
            if scratch.results.len() >= ef {
                if let Some(&(F32(w), _)) = scratch.results.peek() {
                    if d > w {
                        break;
                    }
                }
            }
            if max_hops > 0 && hops >= max_hops {
                break;
            }
            hops += 1;
            // copy the row out of the graph backing (owned: a short
            // memcpy; paged: one block-cache access) — a borrow could
            // not be held across the expansion's own shard resolves
            let mut nbuf = std::mem::take(&mut scratch.nbuf);
            home.graph.neighbors_into((u - lo) as usize, &mut nbuf);
            for e in &nbuf {
                if !scratch.visited.insert(e.id) {
                    continue;
                }
                let dv = if (lo..hi).contains(&e.id) {
                    home.ds.dist_to_quant((e.id - lo) as usize, q, &qcodes, &lut)
                } else {
                    // cross-shard edge: scored against its owning shard
                    // iff that shard is probed — the scoring universe is
                    // the probed set, independent of cache residency
                    let o = self.owner(e.id);
                    if !scratch.shard_probed[o] {
                        continue;
                    }
                    let sh = self.resolve(&mut scratch.shard_pins, o);
                    sh.ds.dist_to_quant(e.id as usize - self.meta[o].offset, q, &qcodes, &lut)
                };
                scratch.dist_evals += 1;
                if (lo..hi).contains(&e.id) {
                    scratch.frontier.push(Reverse((F32(dv), e.id)));
                }
                if e.id != exclude {
                    scratch.results.push((F32(dv), e.id));
                    if scratch.results.len() > ef {
                        scratch.results.pop();
                    }
                }
            }
            scratch.nbuf = nbuf;
            if beam_width > 0 && scratch.frontier.len() > 4 * beam_width {
                scratch.buf.clear();
                for _ in 0..beam_width {
                    match scratch.frontier.pop() {
                        Some(Reverse(x)) => scratch.buf.push(x),
                        None => break,
                    }
                }
                scratch.frontier.clear();
                for &x in &scratch.buf {
                    scratch.frontier.push(Reverse(x));
                }
            }
        }
        scratch.hops += hops;
        scratch.qcodes = qcodes;
        scratch.lut = lut;

        // drain this shard's result pool (max-heap pops worst-first) and
        // keep its best k for the gather phase
        scratch.buf.clear();
        while let Some(x) = scratch.results.pop() {
            scratch.buf.push(x);
        }
        let take = k.min(scratch.buf.len());
        for &x in scratch.buf.iter().rev().take(take) {
            scratch.shard_topk.push(x);
        }

        if let Some(t) = t_shard {
            let (blk_hits1, blk_fetches1) = crate::dataset::store::thread_block_counters();
            scratch.trace.shards.push(ShardSpan {
                shard: s,
                wait_ms,
                search_ms: t.ms(),
                dist_evals: scratch.dist_evals - evals0,
                hops,
                block_fetches: blk_fetches1 - blk_fetches0,
                block_hits: blk_hits1 - blk_hits0,
            });
        }
    }

    /// A warm scratch from the reuse pool (or a fresh one), reset for a
    /// new scatter task.
    pub(crate) fn take_scratch(&self) -> SearchScratch {
        let mut s = self.scratch_pool.lock().unwrap().pop().unwrap_or_default();
        s.shard_topk.clear();
        s.dist_evals = 0;
        s.hops = 0;
        s.rerank_evals = 0;
        s.qcodes.clear();
        s.lut.clear();
        s
    }

    pub(crate) fn put_scratch(&self, s: SearchScratch) {
        self.scratch_pool.lock().unwrap().push(s);
    }

    /// One scatter participant's slice of a job: pull probed shards off
    /// the job's shared cursor until none remain, then hand the
    /// accumulated per-shard top-k (plus eval/hop counts) to the job's
    /// collector. Runs on parked [`ScatterPool`] workers *and* inline
    /// on the dispatching thread. Returns the number of shards this
    /// participant searched — the unit the job's completion is counted
    /// in; the contribution is pushed *before* the caller reports the
    /// count, and a participant that claimed nothing (its job copy was
    /// popped after the cursor ran dry) contributes nothing at all.
    pub(crate) fn run_scatter_job(&self, job: &ScatterJob, scratch: &mut SearchScratch) -> usize {
        scratch.shard_topk.clear();
        scratch.dist_evals = 0;
        scratch.hops = 0;
        scratch.rerank_evals = 0;
        scratch.qcodes.clear();
        scratch.lut.clear();
        scratch.trace.enabled = job.traced;
        scratch.trace.clear();
        self.begin_pins(scratch);
        for &s in &job.order {
            scratch.shard_probed[s] = true;
        }
        let mut done = 0usize;
        while let Some(s) = job.next_shard() {
            self.search_shard(s, &job.q, job.k, job.ef, job.exclude, scratch);
            done += 1;
        }
        Self::release_pins(scratch);
        if done > 0 {
            let topk = std::mem::take(&mut scratch.shard_topk);
            let spans = std::mem::take(&mut scratch.trace.shards);
            job.collected.lock().unwrap().push(ScatterOut {
                dist_evals: scratch.dist_evals,
                hops: scratch.hops,
                topk,
                spans,
            });
        }
        scratch.trace.enabled = false;
        done
    }
}

/// An [`AnnIndex`] over the shard files of an out-of-core build, with
/// managed shard residency and an optional persistent scatter pool.
pub struct ShardedIndex {
    core: Arc<ShardCore>,
    /// Long-lived scatter workers (`search_threads - 1` of them),
    /// spawned once at open; `None` when scatter is sequential.
    pool: Option<ScatterPool>,
    /// Shards probed per query (0 = all).
    probe_shards: usize,
    /// Scatter participants per query (<= 1 = sequential scatter).
    search_threads: usize,
}

impl ShardedIndex {
    /// Open an `ooc-build` output directory (manifest + shard files)
    /// with an unbounded residency budget and sequential scatter — the
    /// pre-residency behavior.
    pub fn open(
        dir: impl AsRef<Path>,
        params: SearchParams,
        probe_shards: usize,
    ) -> crate::Result<Self> {
        Self::open_with(dir, params, probe_shards, 0, 1)
    }

    /// Open with the serving knobs: `memory_budget_bytes` caps resident
    /// shard bytes (0 = unbounded) and `search_threads` sizes the
    /// persistent scatter pool (<= 1 = sequential). Residency is
    /// whole-shard; see [`ShardedIndex::open_with_residency`] for
    /// block-granular serving.
    pub fn open_with(
        dir: impl AsRef<Path>,
        params: SearchParams,
        probe_shards: usize,
        memory_budget_bytes: usize,
        search_threads: usize,
    ) -> crate::Result<Self> {
        Self::open_with_residency(
            dir,
            params,
            probe_shards,
            memory_budget_bytes,
            search_threads,
            ResidencyMode::Shard,
        )
    }

    /// Open with an explicit [`ResidencyMode`]: `ResidencyMode::Block`
    /// serves shards straight from disk in fixed-size blocks (the byte
    /// budget then caps *blocks across all shards*, so budgets smaller
    /// than one shard — unservable under whole-shard residency — work,
    /// with bit-identical results to any other configuration).
    pub fn open_with_residency(
        dir: impl AsRef<Path>,
        params: SearchParams,
        probe_shards: usize,
        memory_budget_bytes: usize,
        search_threads: usize,
        mode: ResidencyMode,
    ) -> crate::Result<Self> {
        let store = ShardStore::with_residency(dir, memory_budget_bytes, mode)?;
        Self::from_store(store, params, probe_shards, search_threads)
    }

    /// Build over an existing store (takes ownership — the index and
    /// the residency cache live and die together). Opening streams
    /// every shard through the cache exactly once for validation and
    /// entry selection, then sheds back down to the budget; with
    /// `search_threads > 1` the scatter pool is spawned here, once,
    /// and lives until the index drops.
    pub fn from_store(
        store: ShardStore,
        params: SearchParams,
        probe_shards: usize,
        search_threads: usize,
    ) -> crate::Result<Self> {
        params.validate()?;
        let manifest = store.load_manifest()?;
        anyhow::ensure!(manifest.shards >= 1, "manifest has no shards");
        let mut meta = Vec::with_capacity(manifest.shards);
        let mut offsets = Vec::with_capacity(manifest.shards);
        let mut pinned_all = Vec::new();
        let mut expect = 0usize;
        for s in 0..manifest.shards {
            let handle = store.get_shard(s)?;
            let (ds, graph) = (&handle.ds, &handle.graph);
            anyhow::ensure!(
                graph.n() == ds.len(),
                "shard {s}: graph covers {} objects but shard has {}",
                graph.n(),
                ds.len()
            );
            anyhow::ensure!(
                ds.d == manifest.d,
                "shard {s}: dim {} != manifest dim {}",
                ds.d,
                manifest.d
            );
            let offset = manifest.offsets[s];
            anyhow::ensure!(
                offset == expect,
                "shard {s}: manifest offset {offset} not contiguous (expected {expect})"
            );
            expect += ds.len();
            // the shards' global id space must be closed over the
            // manifest total — corrupt graphs fail here, not mid-query.
            // A *paged* graph is exempt: walking every row would read
            // the whole file and defeat the point of block residency
            // (cold start proportional to rows visited); corrupt paged
            // graphs instead fail at query time with a panic, like any
            // store mutated underneath a live index
            if !graph.is_paged() {
                check_global_ids(graph, offset, manifest.total)
                    .map_err(|e| e.context(format!("shard {s} graph")))?;
            }
            // per-shard entry selection (shard-local ids -> global);
            // decorrelate the per-shard RNG streams with the shard id.
            // select_entries is backing-agnostic (bounded-sample
            // k-means reads rows through the accessor), so paged and
            // owned shards pick identical entries with no transient
            // materialized copy.
            let salt = (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let sp = params.clone().with_seed(params.seed ^ salt);
            let mut entries = select_entries(ds, graph, &sp);
            for e in entries.iter_mut() {
                *e += offset as u32;
            }
            // per-shard entry hierarchy: load the hier_<s>.bin sidecar
            // (or build + persist it on first open) — later opens pay
            // one file read, not the O(sample^2) build
            let hier = if sp.entry == EntryStrategy::Hierarchy {
                let cfg = shard_hier_config(params.seed, s);
                let path = store.dir().join(format!("hier_{s}.bin"));
                Some(Arc::new(hierarchy::load_or_build(&path, ds, &cfg)))
            } else {
                None
            };
            let centroid = match manifest.centroids.get(s) {
                Some(c) if !c.is_empty() => c.clone(),
                _ => shard_centroid(ds),
            };
            let route_centroids = manifest.route_centroids.get(s).cloned().unwrap_or_default();
            offsets.push(offset);
            meta.push(ShardMeta {
                offset,
                len: ds.len(),
                entries,
                centroid,
                route_centroids,
                hier,
            });
            if store.budget_bytes() == 0 {
                // unbounded: nothing will ever be evicted, so pin every
                // shard permanently and skip the cache mutex per query
                pinned_all.push(handle);
            }
        }
        anyhow::ensure!(
            expect == manifest.total,
            "manifest total {} != sum of shard sizes {expect}",
            manifest.total
        );
        if params.route_slack > 0.0 && meta.iter().all(|m| m.route_centroids.is_empty()) {
            crate::telemetry::warn!(
                "route_slack {} requested but the manifest carries no route_centroids \
                 (pre-PR8 store?): adaptive routing falls back to one mean centroid per \
                 shard — run `quantize` on the store (or rebuild it) to backfill",
                params.route_slack
            );
        }
        // the validation sweep pinned shards one at a time; shed the
        // cache back down to the budget before serving starts
        store.evict_to_budget();
        let core = Arc::new(ShardCore {
            store,
            meta,
            pinned_all,
            offsets,
            total: manifest.total,
            d: manifest.d,
            metric: manifest.metric,
            params,
            scratch_pool: Mutex::new(Vec::new()),
        });
        // a participant beyond the shard count can never claim work
        // (fan is capped at shards - 1 per query), so don't spawn
        // threads that would park forever — a 2-shard store opened
        // with --search-threads 8 gets 1 pool worker, not 7
        let pool_size = (search_threads.saturating_sub(1)).min(core.meta.len().saturating_sub(1));
        let pool = if pool_size > 0 {
            Some(ScatterPool::new(Arc::clone(&core), pool_size))
        } else {
            None
        };
        Ok(ShardedIndex { core, pool, probe_shards, search_threads })
    }

    /// Number of shards in the store.
    pub fn shards(&self) -> usize {
        self.core.meta.len()
    }

    /// Effective shards probed per query.
    pub fn probe(&self) -> usize {
        if self.probe_shards == 0 {
            self.core.meta.len()
        } else {
            self.probe_shards.min(self.core.meta.len())
        }
    }

    /// Effective scatter participants per query (inline + pool).
    pub fn scatter_threads(&self) -> usize {
        self.search_threads.max(1).min(self.probe())
    }

    /// Parked pool workers (0 = sequential scatter, no pool spawned).
    pub fn pool_workers(&self) -> usize {
        self.pool.as_ref().map_or(0, ScatterPool::workers)
    }

    pub fn params(&self) -> &SearchParams {
        &self.core.params
    }

    /// The underlying residency-managed store.
    pub fn store(&self) -> &ShardStore {
        &self.core.store
    }

    /// Snapshot of the residency cache counters.
    pub fn residency(&self) -> ResidencyStats {
        self.core.store.residency()
    }

    /// The full corpus re-assembled as one in-memory dataset (bench /
    /// ground-truth convenience; true deployments keep shards apart).
    /// Streams shard by shard through the cache (rows are copied out
    /// through the backing accessor, so paged shards stream block by
    /// block): peak extra memory is one shard, not a second copy of
    /// the whole corpus.
    pub fn concat_dataset(&self) -> crate::Result<Dataset> {
        let mut data = Vec::with_capacity(self.core.total * self.core.d);
        for s in 0..self.core.meta.len() {
            let h = self.core.store.get_shard(s)?;
            h.ds.extend_flat_into(&mut data);
        }
        self.core.store.evict_to_budget();
        Ok(Dataset::new("sharded", self.core.d, self.core.metric, data))
    }
}

/// Human-readable byte count for [`AnnIndex::describe`] strings.
fn fmt_bytes(b: usize) -> String {
    if b >= 1024 * 1024 {
        format!("{:.1}MB", b as f64 / (1024.0 * 1024.0))
    } else if b >= 1024 {
        format!("{:.1}KB", b as f64 / 1024.0)
    } else {
        format!("{b}B")
    }
}

/// Every neighbor id of a merged shard graph must stay inside the
/// global id space and never point back at its own node — the
/// invariants [`crate::merge::outofcore::merge_pair_global`] maintains.
fn check_global_ids(graph: &KnnGraph, offset: usize, total: usize) -> crate::Result<()> {
    for u in 0..graph.n() {
        let gid = (offset + u) as u32;
        for e in graph.list(u) {
            if e.is_empty() {
                break;
            }
            anyhow::ensure!(
                (e.id as usize) < total,
                "node {gid}: neighbor id {} outside global space (total {total})",
                e.id
            );
            anyhow::ensure!(e.id != gid, "node {gid}: self loop");
        }
    }
    Ok(())
}

impl AnnIndex for ShardedIndex {
    fn len(&self) -> usize {
        self.core.total
    }

    fn dim(&self) -> usize {
        self.core.d
    }

    fn metric(&self) -> Metric {
        self.core.metric
    }

    fn vector(&self, id: u32) -> Vec<f32> {
        let s = self.core.owner(id);
        let h = match self.core.pinned_all.get(s) {
            Some(h) => Arc::clone(h),
            None => self
                .core
                .store
                .get_shard(s)
                .unwrap_or_else(|e| panic!("shard {s} unreadable (store corrupt?): {e:#}")),
        };
        h.ds.vector(id as usize - self.core.meta[s].offset)
    }

    fn default_ef(&self) -> usize {
        self.core.params.ef
    }

    fn describe(&self) -> String {
        let budget = match self.core.store.budget_bytes() {
            0 => "unbounded".to_string(),
            b => fmt_bytes(b),
        };
        // block residency's operative knobs are the block size and the
        // block-cache budget — surface them where operators look first
        let residency = match self.core.store.mode() {
            ResidencyMode::Block { block_bytes } => {
                let cache = match self.core.store.block_cache().budget_bytes() {
                    0 => "unbounded".to_string(),
                    b => fmt_bytes(b),
                };
                format!("block[block={}, cache={}]", fmt_bytes(block_bytes), cache)
            }
            ResidencyMode::Shard => "shard".to_string(),
        };
        let backing = match self.core.store.compression() {
            ShardCompression::F32 => "f32".to_string(),
            ShardCompression::Scalar => {
                format!("u8-quantized(rerank={})", self.core.params.rerank.max(1))
            }
            ShardCompression::Pq => format!("pq(rerank={})", self.core.params.rerank.max(1)),
        };
        format!(
            "sharded(n={}, shards={}, probe={}, budget={}, residency={}, backing={}, \
             scatter_threads={}, pool_workers={})",
            self.core.total,
            self.core.meta.len(),
            self.probe(),
            budget,
            residency,
            backing,
            self.scatter_threads(),
            self.pool_workers()
        )
    }

    fn make_scratch(&self) -> SearchScratch {
        let mut s = SearchScratch::new();
        s.visited.begin(self.core.total);
        s
    }

    fn search_ef_into_excluding(
        &self,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        // two-phase serving on a quantized store: the scatter beams run
        // on cheap code-space distances and each shard returns its best
        // `keep = rerank * k`, so the gather phase has enough distinct
        // survivors to re-score at full precision before the top-k cut.
        // On an f32 store rerank collapses to 1 and this is the exact
        // pre-quantization pipeline (bit-identical results).
        let rerank = if self.core.store.quantized() { self.core.params.rerank.max(1) } else { 1 };
        let keep = k * rerank;
        let ef = (if ef == 0 { self.core.params.ef } else { ef }).max(keep).max(1);
        scratch.dist_evals = 0;
        scratch.hops = 0;
        scratch.rerank_evals = 0;
        scratch.qcodes.clear();
        scratch.lut.clear();
        let traced = scratch.trace.enabled;
        if traced {
            scratch.trace.clear();
        }

        // ---- route ----
        let t_route = traced.then(Timer::start);
        let probe_cap = self.probe();
        let slack = self.core.params.route_slack;
        scratch.shard_rank.clear();
        let probe = if probe_cap < self.core.meta.len() || slack > 0.0 {
            for (s, m) in self.core.meta.iter().enumerate() {
                scratch.shard_rank.push((F32(self.core.route_score(q, m)), s));
            }
            scratch.shard_rank.sort_unstable();
            if slack > 0.0 {
                // adaptive cutoff: probe every shard whose best
                // centroid is within `route_slack x d_best` (Ip scores
                // can be negative — divide there so the bound still
                // widens), capped by the fixed probe count and never
                // below one shard
                let F32(d_best) = scratch.shard_rank[0].0;
                let thr = if d_best >= 0.0 {
                    d_best as f64 * slack
                } else {
                    d_best as f64 / slack
                };
                scratch.shard_rank[..probe_cap]
                    .iter()
                    .take_while(|&&(F32(d), _)| d as f64 <= thr)
                    .count()
                    .max(1)
            } else {
                probe_cap
            }
        } else {
            for s in 0..self.core.meta.len() {
                scratch.shard_rank.push((F32(0.0), s));
            }
            probe_cap
        };
        scratch.shards_probed = probe;
        if let Some(t) = &t_route {
            scratch.trace.route_ms = t.ms();
        }

        // ---- scatter ----
        scratch.shard_topk.clear();
        match &self.pool {
            // pool scatter only pays off with work to overlap: two or
            // more probed shards. A single-shard probe runs the
            // sequential path below even when a pool exists.
            Some(pool) if probe > 1 => {
                // fan the probed shards across the persistent pool: a
                // worker faulting a cold shard in from disk overlaps
                // with the others' warm-shard compute. Workers pull
                // shard tasks from the job's shared cursor; the gather
                // sort below is order-independent, so the result is
                // bit-identical to the sequential path. The dispatching
                // thread participates inline — a query never waits on a
                // fully busy pool to start making progress.
                let order: Vec<usize> =
                    scratch.shard_rank[..probe].iter().map(|&(_, s)| s).collect();
                let collected = pool.scatter(&self.core, q, keep, ef, exclude, order, traced);
                for mut part in collected {
                    scratch.dist_evals += part.dist_evals;
                    scratch.hops += part.hops;
                    scratch.shard_topk.append(&mut part.topk);
                    scratch.trace.shards.append(&mut part.spans);
                }
            }
            _ => {
                self.core.begin_pins(scratch);
                for i in 0..probe {
                    let s = scratch.shard_rank[i].1;
                    scratch.shard_probed[s] = true;
                }
                for i in 0..probe {
                    let (_, s) = scratch.shard_rank[i];
                    self.core.search_shard(s, q, keep, ef, exclude, scratch);
                }
                ShardCore::release_pins(scratch);
            }
        }

        // ---- gather: k-way merge with cross-shard dedup ----
        let t_gather = traced.then(Timer::start);
        scratch.shard_topk.sort_unstable();
        out.clear();
        for &(F32(d), id) in scratch.shard_topk.iter() {
            if out.len() >= keep {
                break;
            }
            if out.iter().any(|&(_, have)| have == id) {
                continue;
            }
            out.push((d, id));
        }
        if rerank > 1 {
            // exact rerank of the surviving candidates: the scatter
            // pins were released, so re-acquire each survivor's owning
            // shard (warm in the cache — the scatter just touched it)
            // and re-score against the exact f32 rows. Code-space
            // distances got the *set* right; this gets the order and
            // the reported distances right.
            self.core.begin_pins(scratch);
            let mut fbuf = std::mem::take(&mut scratch.fbuf);
            for (d, id) in out.iter_mut() {
                let s = self.core.owner(*id);
                let h = self.core.resolve(&mut scratch.shard_pins, s);
                let local = *id as usize - self.core.meta[s].offset;
                *d = h.ds.rerank_dist_to(local, q, &mut fbuf);
                scratch.rerank_evals += 1;
            }
            scratch.fbuf = fbuf;
            ShardCore::release_pins(scratch);
            out.sort_by(|a, b| (F32(a.0), a.1).cmp(&(F32(b.0), b.1)));
            out.truncate(k);
        }
        if let Some(t) = &t_gather {
            scratch.trace.gather_ms = t.ms();
            // participants report in completion order under pooled
            // scatter; sort so a trace is deterministic either way
            scratch.trace.shards.sort_by_key(|sp| sp.shard);
        }
        crate::telemetry::record_query(scratch.dist_evals, scratch.hops, scratch.rerank_evals);
        crate::telemetry::record_probe(scratch.shards_probed);
    }
}
