//! Length-prefixed binary wire protocol for `gnnd serve`.
//!
//! Every frame on the wire is a little-endian `u32` payload length followed
//! by the payload. The payload starts with an 8-byte header — magic (`u32`),
//! protocol version (`u16`), message kind (`u16`) — then a kind-specific
//! body. Requests use magic `"GNNQ"`, responses `"GNNR"`.
//!
//! Kinds:
//!
//! | kind | request body                          | response body |
//! |------|---------------------------------------|---------------|
//! | 1    | `Info` (empty)                        | n `u64`, d `u32`, default_ef `u32`, metric str, describe str |
//! | 2    | `Search`: k/ef/rerank/d/nq `u32`, nq·d `f32` rows, nq `u32` exclude ids | k `u32`, nq `u32`, per query cnt `u32` + cnt × (`f32` dist, `u32` id) |
//! | 3    | —                                     | `Error`: status `u16`, message str |
//!
//! Strings are a `u16` length + UTF-8 bytes. An exclude id of `u32::MAX`
//! means "exclude nothing" (the bench client uses real ids so self-hits are
//! excluded exactly as the in-process replay does). `ef == 0` asks the
//! server to use its default. `f32` values travel via `to_le_bytes`, so
//! results round-trip bit-exactly.
//!
//! Decoding mirrors the untrusted-header discipline of
//! [`crate::dataset::io`]: every length is bounds-checked against the
//! payload before use and errors say what was expected versus present, so a
//! truncated, oversized, or corrupt frame produces a typed error instead of
//! a panic or over-allocation.

use anyhow::{bail, ensure, Result};
use std::io::{Read, Write};

/// Request magic: `"GNNQ"` little-endian.
pub const MAGIC_REQ: u32 = u32::from_le_bytes(*b"GNNQ");
/// Response magic: `"GNNR"` little-endian.
pub const MAGIC_RESP: u32 = u32::from_le_bytes(*b"GNNR");
/// Protocol version spoken by this build.
pub const VERSION: u16 = 1;
/// Hard cap on payload size; larger length prefixes are rejected before any
/// allocation happens.
pub const MAX_FRAME_BYTES: usize = 16 << 20;
/// Payload header: magic `u32` + version `u16` + kind `u16`.
pub const HEADER_BYTES: usize = 8;

pub const KIND_INFO: u16 = 1;
pub const KIND_SEARCH: u16 = 2;
pub const KIND_ERROR: u16 = 3;

/// Error status codes carried by kind-3 responses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Admission control shed the request; retry later at a lower rate.
    Overloaded,
    /// The request was malformed or inconsistent with the served index.
    BadRequest,
    /// The server failed internally while executing the request.
    Internal,
}

impl Status {
    pub fn code(self) -> u16 {
        match self {
            Status::Overloaded => 1,
            Status::BadRequest => 2,
            Status::Internal => 3,
        }
    }

    pub fn from_code(code: u16) -> Result<Status> {
        Ok(match code {
            1 => Status::Overloaded,
            2 => Status::BadRequest,
            3 => Status::Internal,
            other => bail!("bad frame: unknown error status code {other}"),
        })
    }
}

impl std::fmt::Display for Status {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Status::Overloaded => "overloaded",
            Status::BadRequest => "bad-request",
            Status::Internal => "internal",
        };
        f.write_str(s)
    }
}

/// A client → server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Info,
    Search(SearchRequest),
}

/// Body of a kind-2 request: a batch of `nq` queries sharing k/ef/rerank.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchRequest {
    pub k: u32,
    /// Candidate-list width; `0` means "use the server default".
    pub ef: u32,
    /// Advisory rerank depth (quantized stores rerank server-side already).
    pub rerank: u32,
    pub d: u32,
    /// `nq * d` row-major query components.
    pub queries: Vec<f32>,
    /// One id per query; `u32::MAX` excludes nothing.
    pub exclude: Vec<u32>,
}

/// A server → client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Info(InfoResponse),
    Search(SearchResponse),
    Error(ErrorResponse),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InfoResponse {
    pub n: u64,
    pub d: u32,
    pub default_ef: u32,
    pub metric: String,
    pub describe: String,
}

#[derive(Debug, Clone, PartialEq)]
pub struct SearchResponse {
    pub k: u32,
    /// One `(distance, id)` list per query, at most `k` entries each.
    pub results: Vec<Vec<(f32, u32)>>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorResponse {
    pub status: Status,
    pub msg: String,
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_str(buf: &mut Vec<u8>, s: &str) {
    let len = s.len().min(u16::MAX as usize);
    buf.extend_from_slice(&(len as u16).to_le_bytes());
    buf.extend_from_slice(&s.as_bytes()[..len]);
}

fn frame(magic: u32, kind: u16, body: &[u8]) -> Vec<u8> {
    let payload_len = HEADER_BYTES + body.len();
    let mut out = Vec::with_capacity(4 + payload_len);
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.extend_from_slice(&magic.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(body);
    out
}

/// Encode a request as a complete frame (length prefix included).
pub fn encode_request(req: &Request) -> Vec<u8> {
    match req {
        Request::Info => frame(MAGIC_REQ, KIND_INFO, &[]),
        Request::Search(s) => {
            assert_eq!(
                s.queries.len(),
                s.d as usize * s.exclude.len(),
                "queries must hold nq * d components"
            );
            let nq = s.exclude.len() as u32;
            let mut body = Vec::with_capacity(20 + s.queries.len() * 4 + s.exclude.len() * 4);
            body.extend_from_slice(&s.k.to_le_bytes());
            body.extend_from_slice(&s.ef.to_le_bytes());
            body.extend_from_slice(&s.rerank.to_le_bytes());
            body.extend_from_slice(&s.d.to_le_bytes());
            body.extend_from_slice(&nq.to_le_bytes());
            for v in &s.queries {
                body.extend_from_slice(&v.to_le_bytes());
            }
            for id in &s.exclude {
                body.extend_from_slice(&id.to_le_bytes());
            }
            frame(MAGIC_REQ, KIND_SEARCH, &body)
        }
    }
}

/// Encode a response as a complete frame (length prefix included).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    match resp {
        Response::Info(i) => {
            let mut body = Vec::with_capacity(16 + i.metric.len() + i.describe.len() + 4);
            body.extend_from_slice(&i.n.to_le_bytes());
            body.extend_from_slice(&i.d.to_le_bytes());
            body.extend_from_slice(&i.default_ef.to_le_bytes());
            put_str(&mut body, &i.metric);
            put_str(&mut body, &i.describe);
            frame(MAGIC_RESP, KIND_INFO, &body)
        }
        Response::Search(s) => {
            let per: usize = s.results.iter().map(|r| 4 + r.len() * 8).sum();
            let mut body = Vec::with_capacity(8 + per);
            body.extend_from_slice(&s.k.to_le_bytes());
            body.extend_from_slice(&(s.results.len() as u32).to_le_bytes());
            for row in &s.results {
                body.extend_from_slice(&(row.len() as u32).to_le_bytes());
                for &(dist, id) in row {
                    body.extend_from_slice(&dist.to_le_bytes());
                    body.extend_from_slice(&id.to_le_bytes());
                }
            }
            frame(MAGIC_RESP, KIND_SEARCH, &body)
        }
        Response::Error(e) => {
            let mut body = Vec::with_capacity(4 + e.msg.len());
            body.extend_from_slice(&e.status.code().to_le_bytes());
            put_str(&mut body, &e.msg);
            frame(MAGIC_RESP, KIND_ERROR, &body)
        }
    }
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian reader over an untrusted payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let remain = self.buf.len() - self.pos;
        ensure!(
            remain >= n,
            "truncated frame: {what} needs {n} bytes, payload has {remain} left"
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        let b = self.take(4, what)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn string(&mut self, what: &str) -> Result<String> {
        let len = self.u16(what)? as usize;
        let b = self.take(len, what)?;
        Ok(std::str::from_utf8(b)
            .map_err(|e| anyhow::anyhow!("bad frame: {what} is not UTF-8: {e}"))?
            .to_string())
    }

    fn finish(&self, what: &str) -> Result<()> {
        let extra = self.buf.len() - self.pos;
        ensure!(
            extra == 0,
            "bad frame: {what} has {extra} trailing bytes past the message body"
        );
        Ok(())
    }
}

fn check_header(cur: &mut Cursor<'_>, magic: u32, side: &str) -> Result<u16> {
    let got_magic = cur.u32("magic")?;
    ensure!(
        got_magic == magic,
        "bad frame: {side} magic {got_magic:#010x}, expected {magic:#010x}"
    );
    let ver = cur.u16("version")?;
    ensure!(
        ver == VERSION,
        "bad frame: protocol version {ver}, this build speaks {VERSION}"
    );
    cur.u16("kind")
}

/// Decode a request payload (the bytes after the length prefix).
pub fn decode_request(payload: &[u8]) -> Result<Request> {
    let mut cur = Cursor::new(payload);
    let kind = check_header(&mut cur, MAGIC_REQ, "request")?;
    match kind {
        KIND_INFO => {
            cur.finish("info request")?;
            Ok(Request::Info)
        }
        KIND_SEARCH => {
            let k = cur.u32("k")?;
            let ef = cur.u32("ef")?;
            let rerank = cur.u32("rerank")?;
            let d = cur.u32("d")?;
            let nq = cur.u32("nq")?;
            ensure!(k >= 1, "bad frame: search request k must be >= 1");
            ensure!(d >= 1, "bad frame: search request d must be >= 1");
            ensure!(nq >= 1, "bad frame: search request nq must be >= 1");
            let comps = (nq as u64) * (d as u64);
            let need = comps * 4 + (nq as u64) * 4;
            let remain = (payload.len() - cur.pos) as u64;
            ensure!(
                remain == need,
                "truncated frame: nq={nq} d={d} implies {need} body bytes, payload has {remain}"
            );
            let mut queries = Vec::with_capacity(comps as usize);
            for _ in 0..comps {
                queries.push(cur.f32("query component")?);
            }
            let mut exclude = Vec::with_capacity(nq as usize);
            for _ in 0..nq {
                exclude.push(cur.u32("exclude id")?);
            }
            cur.finish("search request")?;
            Ok(Request::Search(SearchRequest {
                k,
                ef,
                rerank,
                d,
                queries,
                exclude,
            }))
        }
        other => bail!("bad frame: unknown request kind {other}"),
    }
}

/// Decode a response payload (the bytes after the length prefix).
pub fn decode_response(payload: &[u8]) -> Result<Response> {
    let mut cur = Cursor::new(payload);
    let kind = check_header(&mut cur, MAGIC_RESP, "response")?;
    match kind {
        KIND_INFO => {
            let n = cur.u64("n")?;
            let d = cur.u32("d")?;
            let default_ef = cur.u32("default_ef")?;
            let metric = cur.string("metric")?;
            let describe = cur.string("describe")?;
            cur.finish("info response")?;
            Ok(Response::Info(InfoResponse {
                n,
                d,
                default_ef,
                metric,
                describe,
            }))
        }
        KIND_SEARCH => {
            let k = cur.u32("k")?;
            let nq = cur.u32("nq")? as usize;
            // Each query contributes at least a 4-byte count; bound nq by
            // the remaining bytes before allocating.
            let remain = payload.len() - cur.pos;
            ensure!(
                nq <= remain / 4,
                "truncated frame: nq={nq} result lists cannot fit in {remain} bytes"
            );
            let mut results = Vec::with_capacity(nq);
            for qi in 0..nq {
                let cnt = cur.u32("result count")? as usize;
                let left = payload.len() - cur.pos;
                ensure!(
                    cnt <= left / 8,
                    "truncated frame: query {qi} claims {cnt} results, {left} bytes left"
                );
                let mut row = Vec::with_capacity(cnt);
                for _ in 0..cnt {
                    let dist = cur.f32("result distance")?;
                    let id = cur.u32("result id")?;
                    row.push((dist, id));
                }
                results.push(row);
            }
            cur.finish("search response")?;
            Ok(Response::Search(SearchResponse { k, results }))
        }
        KIND_ERROR => {
            let status = Status::from_code(cur.u16("status")?)?;
            let msg = cur.string("error message")?;
            cur.finish("error response")?;
            Ok(Response::Error(ErrorResponse { status, msg }))
        }
        other => bail!("bad frame: unknown response kind {other}"),
    }
}

// ---------------------------------------------------------------------------
// Framed IO
// ---------------------------------------------------------------------------

/// Write one already-encoded frame.
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> std::io::Result<()> {
    w.write_all(frame)?;
    w.flush()
}

/// Read one frame payload. Returns `Ok(None)` on clean EOF at a frame
/// boundary; mid-frame EOF and oversized length prefixes are errors.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    read_frame_with(r, || true)
}

/// Like [`read_frame`], but tolerant of read timeouts: on
/// `WouldBlock`/`TimedOut` the `keep_going` predicate decides whether to
/// retry (partial bytes already read are preserved) or give up with
/// `Ok(None)`. This lets a server poll a stop flag while blocked on a read.
pub fn read_frame_with(r: &mut impl Read, keep_going: impl Fn() -> bool) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    match read_full(r, &mut len_buf, &keep_going)? {
        Filled::Eof => return Ok(None),
        Filled::Stopped => return Ok(None),
        Filled::PartialEof(got) => {
            bail!("truncated frame: EOF after {got} of 4 length-prefix bytes")
        }
        Filled::Done => {}
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    ensure!(
        len >= HEADER_BYTES,
        "bad frame: payload length {len} below minimum header size {HEADER_BYTES}"
    );
    ensure!(
        len <= MAX_FRAME_BYTES,
        "oversized frame: payload length {len} exceeds cap {MAX_FRAME_BYTES}"
    );
    let mut payload = vec![0u8; len];
    match read_full(r, &mut payload, &keep_going)? {
        Filled::Done => Ok(Some(payload)),
        Filled::Stopped => Ok(None),
        Filled::Eof | Filled::PartialEof(_) => {
            bail!("truncated frame: EOF before {len} payload bytes arrived")
        }
    }
}

enum Filled {
    Done,
    Eof,
    PartialEof(usize),
    Stopped,
}

fn read_full(r: &mut impl Read, buf: &mut [u8], keep_going: &impl Fn() -> bool) -> Result<Filled> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                return Ok(if got == 0 {
                    Filled::Eof
                } else {
                    Filled::PartialEof(got)
                });
            }
            Ok(n) => got += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if !keep_going() {
                    return Ok(Filled::Stopped);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Filled::Done)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn gen_search_request(rng: &mut Rng) -> SearchRequest {
        let d = 1 + (rng.next_u64() % 16) as u32;
        let nq = 1 + (rng.next_u64() % 8) as u32;
        let k = 1 + (rng.next_u64() % 32) as u32;
        let queries: Vec<f32> = (0..(d * nq)).map(|_| rng.f32() * 8.0 - 4.0).collect();
        let exclude: Vec<u32> = (0..nq)
            .map(|_| {
                if rng.next_u64() % 4 == 0 {
                    u32::MAX
                } else {
                    (rng.next_u64() % 10_000) as u32
                }
            })
            .collect();
        SearchRequest {
            k,
            ef: (rng.next_u64() % 256) as u32,
            rerank: (rng.next_u64() % 64) as u32,
            d,
            queries,
            exclude,
        }
    }

    fn gen_response(rng: &mut Rng) -> Response {
        match rng.next_u64() % 3 {
            0 => Response::Info(InfoResponse {
                n: rng.next_u64(),
                d: (rng.next_u64() % 512) as u32,
                default_ef: (rng.next_u64() % 256) as u32,
                metric: ["l2", "ip", "cosine"][(rng.next_u64() % 3) as usize].to_string(),
                describe: format!("sharded(shards={})", rng.next_u64() % 32),
            }),
            1 => {
                let nq = (rng.next_u64() % 6) as usize;
                let results = (0..nq)
                    .map(|_| {
                        let cnt = (rng.next_u64() % 12) as usize;
                        (0..cnt)
                            .map(|_| (rng.f32() * 100.0, (rng.next_u64() % 50_000) as u32))
                            .collect()
                    })
                    .collect();
                Response::Search(SearchResponse {
                    k: 1 + (rng.next_u64() % 32) as u32,
                    results,
                })
            }
            _ => Response::Error(ErrorResponse {
                status: [Status::Overloaded, Status::BadRequest, Status::Internal]
                    [(rng.next_u64() % 3) as usize],
                msg: format!("case {}", rng.next_u64() % 1000),
            }),
        }
    }

    /// Round-trip a frame through the streaming reader and the decoder.
    fn round_trip_req(req: &Request) -> Request {
        let bytes = encode_request(req);
        let mut r = &bytes[..];
        let payload = read_frame(&mut r).unwrap().expect("one frame present");
        assert!(r.is_empty(), "reader must consume the exact frame");
        decode_request(&payload).unwrap()
    }

    #[test]
    fn prop_request_round_trip() {
        prop::check("proto_request_round_trip", 64, |rng| {
            let req = Request::Search(gen_search_request(rng));
            let back = round_trip_req(&req);
            prop::assert_prop(back == req, "decoded request differs from original")
        });
    }

    #[test]
    fn prop_response_round_trip() {
        prop::check("proto_response_round_trip", 64, |rng| {
            let resp = gen_response(rng);
            let bytes = encode_response(&resp);
            let mut r = &bytes[..];
            let payload = read_frame(&mut r).unwrap().expect("one frame present");
            let back = decode_response(&payload).unwrap();
            prop::assert_prop(back == resp, "decoded response differs from original")
        });
    }

    #[test]
    fn info_round_trip_and_eof() {
        assert_eq!(round_trip_req(&Request::Info), Request::Info);
        let mut empty: &[u8] = &[];
        assert!(read_frame(&mut empty).unwrap().is_none(), "clean EOF is None");
    }

    #[test]
    fn prop_truncation_never_panics_and_errors() {
        prop::check("proto_truncation_rejected", 64, |rng| {
            let req = Request::Search(gen_search_request(rng));
            let bytes = encode_request(&req);
            // Cut anywhere strictly inside the frame (after byte 0 so the
            // reader sees a partial frame, not clean EOF).
            let cut = 1 + (rng.next_u64() as usize) % (bytes.len() - 1);
            let mut r = &bytes[..cut];
            let res = read_frame(&mut r);
            let ok = match res {
                Err(e) => e.to_string().contains("truncated frame"),
                // A cut exactly at the 4-byte prefix boundary with len==0
                // can't happen (header is mandatory), so any Ok(Some) here
                // would be a bug; Ok(None) only for cut < 1 which we avoid.
                Ok(_) => false,
            };
            prop::assert_prop(ok, "truncated frame must yield a 'truncated frame' error")
        });
    }

    #[test]
    fn oversized_and_garbage_frames_rejected() {
        // Oversized length prefix: rejected before allocation.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&(((MAX_FRAME_BYTES + 1) as u32).to_le_bytes()));
        let mut r = &bytes[..];
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("oversized frame"), "got: {err}");

        // Length below the mandatory header.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&4u32.to_le_bytes());
        bytes.extend_from_slice(&[0; 4]);
        let mut r = &bytes[..];
        let err = read_frame(&mut r).unwrap_err().to_string();
        assert!(err.contains("below minimum header"), "got: {err}");

        // Bad magic.
        let mut frame = encode_request(&Request::Info);
        frame[4] ^= 0xFF;
        let mut r = &frame[..];
        let payload = read_frame(&mut r).unwrap().unwrap();
        let err = decode_request(&payload).unwrap_err().to_string();
        assert!(err.contains("magic"), "got: {err}");

        // Bad version.
        let mut frame = encode_request(&Request::Info);
        frame[8] = 0x7F;
        let mut r = &frame[..];
        let payload = read_frame(&mut r).unwrap().unwrap();
        let err = decode_request(&payload).unwrap_err().to_string();
        assert!(err.contains("protocol version"), "got: {err}");

        // Unknown kind.
        let mut frame = encode_request(&Request::Info);
        frame[10] = 0x77;
        let mut r = &frame[..];
        let payload = read_frame(&mut r).unwrap().unwrap();
        let err = decode_request(&payload).unwrap_err().to_string();
        assert!(err.contains("unknown request kind"), "got: {err}");

        // Body length inconsistent with nq/d: claims 2 queries, carries 1.
        let req = SearchRequest {
            k: 5,
            ef: 0,
            rerank: 0,
            d: 4,
            queries: vec![0.0; 4],
            exclude: vec![u32::MAX],
        };
        let mut frame = encode_request(&Request::Search(req));
        let nq_off = 4 + HEADER_BYTES + 12; // prefix + header + k/ef/rerank
        frame[nq_off + 4] = 2; // bump nq from 1 to 2 (d at nq_off, nq next)
        let mut r = &frame[..];
        let payload = read_frame(&mut r).unwrap().unwrap();
        let err = decode_request(&payload).unwrap_err().to_string();
        assert!(err.contains("implies"), "got: {err}");
    }

    #[test]
    fn status_codes_round_trip() {
        for s in [Status::Overloaded, Status::BadRequest, Status::Internal] {
            assert_eq!(Status::from_code(s.code()).unwrap(), s);
        }
        assert!(Status::from_code(42).is_err());
    }
}
