//! `gnnd serve`: a TCP front end over any [`AnnIndex`].
//!
//! The serving stack so far ends at an in-process bench harness
//! ([`super::serve`]); this module is the missing network layer — a
//! pure-std [`TcpListener`] speaking the length-prefixed binary
//! protocol of [`super::proto`], with two serving policies layered on
//! the connection handling:
//!
//! * **Request coalescing.** GGNN (Groh et al.) gets its GPU
//!   throughput by batching queries into one pass; the same idea one
//!   level up: queries arriving within `--coalesce-window <µs>` of the
//!   first are drained into a single [`BatchExecutor::run_jobs`] /
//!   scatter pass instead of fanning out per query. Queries are
//!   independent, so coalescing is **bit-identical** to serving them
//!   one at a time (enforced by the parity grid in `tests/server.rs`).
//! * **Admission control.** The pending-query queue is depth-bounded
//!   (`--queue-limit`); a request that would overflow it is shed with
//!   an explicit [`Status::Overloaded`] response instead of letting
//!   queue delay run away. The bound is enforced all-or-nothing under
//!   one lock ([`mpmc::Queue::push_all_within`]), so depth never
//!   overshoots and `server.shed_total` reconciles exactly with
//!   client-observed sheds.
//!
//! Threading: `run` parks a single batcher thread on the pending
//! queue, spawns one thread per accepted connection (each request
//! blocks its connection until its queries complete — pipelining
//! happens across connections), and keeps the accept loop on the
//! calling thread. Shutdown ([`ServerHandle::shutdown`]) sets a stop
//! flag and self-connects to wake the blocking accept; connection
//! reads poll the flag on a short timeout, and the queue close
//! releases the batcher once drained.
//!
//! [`RemoteIndex`] is the client half: it implements [`AnnIndex`] over
//! a connection pool, so the whole serve harness (arrival schedules,
//! queue/service percentiles, recall) repoints at a live server with
//! `serve-bench --target <addr>` — the bench numbers become numbers
//! about a thing users can run.
//!
//! Registered metrics (doc table in [`crate::telemetry`]):
//! `server.accepted` / `server.shed_total` / `server.connections`
//! (counters, per request), `server.coalesced_batch_size` /
//! `server.queue_wait_us` (histograms, per batch / per query), and on
//! the client side `client.shed_total`.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::graph::EMPTY;
use crate::telemetry;
use crate::util::mpmc;

use super::batch::{BatchExecutor, QueryJob};
use super::proto::{
    self, ErrorResponse, InfoResponse, Request, Response, SearchRequest, SearchResponse, Status,
};
use super::{AnnIndex, SearchScratch};

/// Cap on queries drained into one coalesced batch: bounds both the
/// response latency of the first query in a batch and the transient
/// memory of a batch under a hot queue.
pub const MAX_BATCH: usize = 256;

/// Cap on the coalescing window: a window above one second is a
/// misconfiguration (every query would pay it in added latency), not a
/// throughput choice.
pub const MAX_COALESCE_WINDOW_US: u64 = 1_000_000;

/// Clamp a requested coalescing window to [`MAX_COALESCE_WINDOW_US`];
/// the bool reports whether clamping occurred (mirrors
/// `serve::clamp_ef` / `sharded` probe clamping).
pub fn clamp_coalesce_window(us: u64) -> (u64, bool) {
    if us > MAX_COALESCE_WINDOW_US {
        (MAX_COALESCE_WINDOW_US, true)
    } else {
        (us, false)
    }
}

/// [`clamp_coalesce_window`] + the operator warning the CLI emits.
pub fn clamp_coalesce_window_warn(us: u64) -> u64 {
    let (v, clamped) = clamp_coalesce_window(us);
    if clamped {
        telemetry::warn!(
            "serve: --coalesce-window {us}µs exceeds the {MAX_COALESCE_WINDOW_US}µs cap; \
             clamped to {v}µs"
        );
    }
    v
}

/// Serving-policy knobs of a [`Server`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Batching window in µs: queries arriving within this window of
    /// the first pending query ride one executor pass. 0 = no waiting
    /// (still drains whatever is already queued).
    pub coalesce_window_us: u64,
    /// Admission bound on pending queries; a request whose queries
    /// would overflow it is shed with `Overloaded`. 0 = unbounded.
    pub queue_limit: usize,
    /// Executor threads per batch (0 = auto).
    pub exec_threads: usize,
    /// Test-only fault injection: sleep this long before executing
    /// every batch, so admission-control tests fill the queue
    /// deterministically. 0 = disabled.
    pub debug_slow_shard_ms: u64,
    /// When set, a background thread rewrites this path (atomic
    /// tmp+rename) with the global telemetry snapshot twice a second —
    /// the server's metrics survive even a hard kill.
    pub stats_out: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            coalesce_window_us: 100,
            queue_limit: 1024,
            exec_threads: 0,
            debug_slow_shard_ms: 0,
            stats_out: None,
        }
    }
}

/// One admitted query waiting for the batcher: owns its row, knows its
/// response slot.
struct PendingQuery {
    q: Vec<f32>,
    k: usize,
    /// 0 = server default (the executor resolves it).
    ef: usize,
    exclude: u32,
    enqueued: Instant,
    slot: Arc<ResultSlot>,
    idx: usize,
}

struct SlotState {
    remaining: usize,
    failed: bool,
    results: Vec<Vec<(f32, u32)>>,
}

/// Rendezvous between a connection thread and the batcher: the
/// connection blocks in [`ResultSlot::wait`] until every query of its
/// request has been filled (or the batch poisoned).
struct ResultSlot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl ResultSlot {
    fn new(nq: usize) -> Arc<Self> {
        Arc::new(ResultSlot {
            state: Mutex::new(SlotState {
                remaining: nq,
                failed: false,
                results: vec![Vec::new(); nq],
            }),
            cv: Condvar::new(),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SlotState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn fill(&self, idx: usize, res: Vec<(f32, u32)>) {
        let mut st = self.lock();
        st.results[idx] = res;
        st.remaining -= 1;
        let done = st.remaining == 0;
        drop(st);
        if done {
            self.cv.notify_all();
        }
    }

    /// Poison the slot (batch execution panicked): the waiting
    /// connection answers `Internal` instead of hanging forever.
    fn fail(&self) {
        let mut st = self.lock();
        st.failed = true;
        drop(st);
        self.cv.notify_all();
    }

    fn wait(&self) -> std::result::Result<Vec<Vec<(f32, u32)>>, ()> {
        let mut st = self.lock();
        while st.remaining > 0 && !st.failed {
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        if st.failed {
            Err(())
        } else {
            Ok(std::mem::take(&mut st.results))
        }
    }
}

/// Cached handles to the server's registered metrics.
struct ServerMetrics {
    accepted: Arc<telemetry::Counter>,
    shed_total: Arc<telemetry::Counter>,
    connections: Arc<telemetry::Counter>,
    coalesced_batch_size: Arc<telemetry::Histogram>,
    queue_wait_us: Arc<telemetry::Histogram>,
}

impl ServerMetrics {
    fn new() -> Self {
        let g = telemetry::global();
        ServerMetrics {
            accepted: g.counter("server.accepted"),
            shed_total: g.counter("server.shed_total"),
            connections: g.counter("server.connections"),
            coalesced_batch_size: g.histogram("server.coalesced_batch_size"),
            queue_wait_us: g.histogram("server.queue_wait_us"),
        }
    }
}

/// Handle for stopping a running [`Server`] from another thread.
#[derive(Clone)]
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Request shutdown: sets the stop flag and self-connects to wake
    /// the blocking accept loop. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

/// The TCP front end: bind once, then [`Server::run`] until shutdown.
pub struct Server {
    listener: TcpListener,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port). The
    /// coalescing window is clamp-validated here too, so programmatic
    /// users get the same bound the CLI enforces.
    pub fn bind(addr: &str, mut cfg: ServerConfig) -> Result<Server> {
        cfg.coalesce_window_us = clamp_coalesce_window(cfg.coalesce_window_us).0;
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind server listener on {addr}"))?;
        Ok(Server { listener, cfg, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A shutdown handle usable from any thread.
    pub fn handle(&self) -> Result<ServerHandle> {
        Ok(ServerHandle { addr: self.local_addr()?, stop: Arc::clone(&self.stop) })
    }

    /// Serve `index` until [`ServerHandle::shutdown`]: accept loop on
    /// the calling thread, one batcher thread, one thread per
    /// connection. Returns after every connection and the batcher have
    /// drained.
    pub fn run(&self, index: &dyn AnnIndex) -> Result<()> {
        let queue: mpmc::Queue<PendingQuery> = mpmc::Queue::new();
        let metrics = ServerMetrics::new();
        let stop: &AtomicBool = &self.stop;
        let cfg = &self.cfg;
        crossbeam_utils::thread::scope(|s| {
            let queue = &queue;
            let metrics = &metrics;
            s.builder()
                .name("gnnd-batcher".to_string())
                .spawn(move |_| batcher_loop(index, queue, cfg, metrics))
                .expect("spawn batcher thread");
            if let Some(path) = cfg.stats_out.as_deref() {
                s.builder()
                    .name("gnnd-stats".to_string())
                    .spawn(move |_| stats_loop(path, stop))
                    .expect("spawn stats thread");
            }
            for conn in self.listener.incoming() {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                s.spawn(move |_| handle_conn(stream, index, queue, cfg, stop, metrics));
            }
            // release the batcher (it drains admitted queries first, so
            // no connection is left waiting on an unfilled slot)
            queue.close();
        })
        .unwrap();
        if let Some(path) = self.cfg.stats_out.as_deref() {
            write_stats_file(path);
        }
        Ok(())
    }
}

/// The coalescing batcher: pop the first pending query, drain
/// followers within the window (or whatever is already queued when the
/// window is 0), execute the batch in one pass, fill every slot.
fn batcher_loop(
    index: &dyn AnnIndex,
    queue: &mpmc::Queue<PendingQuery>,
    cfg: &ServerConfig,
    m: &ServerMetrics,
) {
    let exec = BatchExecutor::new(index, cfg.exec_threads);
    let window = Duration::from_micros(cfg.coalesce_window_us);
    while let Some(first) = queue.pop() {
        let mut batch = vec![first];
        if window.is_zero() {
            while batch.len() < MAX_BATCH {
                match queue.try_pop() {
                    Some(p) => batch.push(p),
                    None => break,
                }
            }
        } else {
            let deadline = Instant::now() + window;
            while batch.len() < MAX_BATCH {
                match queue.pop_deadline(deadline) {
                    mpmc::Pop::Item(p) => batch.push(p),
                    mpmc::Pop::TimedOut | mpmc::Pop::Closed => break,
                }
            }
        }
        let drained = Instant::now();
        for p in &batch {
            let waited = drained.saturating_duration_since(p.enqueued);
            m.queue_wait_us.record(telemetry::us(waited.as_secs_f64()));
        }
        m.coalesced_batch_size.record(batch.len() as u64);
        if cfg.debug_slow_shard_ms > 0 {
            std::thread::sleep(Duration::from_millis(cfg.debug_slow_shard_ms));
        }
        let jobs: Vec<QueryJob<'_>> = batch
            .iter()
            .map(|p| QueryJob { q: &p.q, k: p.k, ef: p.ef, exclude: p.exclude })
            .collect();
        // a poisoned batch (e.g. the store vanished mid-query) must
        // answer Internal on every affected connection, not kill the
        // batcher and hang the server
        match panic::catch_unwind(AssertUnwindSafe(|| exec.run_jobs(&jobs))) {
            Ok(results) => {
                for (p, r) in batch.iter().zip(results) {
                    p.slot.fill(p.idx, r);
                }
            }
            Err(_) => {
                for p in &batch {
                    p.slot.fail();
                }
            }
        }
    }
}

/// One connection: framed request/response loop until EOF, a protocol
/// violation, or shutdown. Malformed frames answer a typed
/// `BadRequest` and close; the server never panics on client bytes.
fn handle_conn(
    mut stream: TcpStream,
    index: &dyn AnnIndex,
    queue: &mpmc::Queue<PendingQuery>,
    cfg: &ServerConfig,
    stop: &AtomicBool,
    m: &ServerMetrics,
) {
    m.connections.inc();
    let _ = stream.set_nodelay(true);
    // short read timeout so a parked connection notices shutdown
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    loop {
        let payload = match proto::read_frame_with(&mut stream, || !stop.load(Ordering::Relaxed)) {
            Ok(Some(p)) => p,
            Ok(None) => break, // clean EOF or shutdown
            Err(e) => {
                respond_error(&mut stream, Status::BadRequest, &format!("{e:#}"));
                break;
            }
        };
        let req = match proto::decode_request(&payload) {
            Ok(r) => r,
            Err(e) => {
                respond_error(&mut stream, Status::BadRequest, &format!("{e:#}"));
                break;
            }
        };
        match req {
            Request::Info => {
                let resp = Response::Info(InfoResponse {
                    n: index.len() as u64,
                    d: index.dim() as u32,
                    default_ef: index.default_ef() as u32,
                    metric: index.metric().to_string(),
                    describe: index.describe(),
                });
                if proto::write_frame(&mut stream, &proto::encode_response(&resp)).is_err() {
                    break;
                }
            }
            Request::Search(s) => {
                if s.d as usize != index.dim() {
                    // well-formed but inconsistent: answer and keep the
                    // connection
                    respond_error(
                        &mut stream,
                        Status::BadRequest,
                        &format!("query dimension {} but index dimension {}", s.d, index.dim()),
                    );
                    continue;
                }
                let d = s.d as usize;
                let nq = s.exclude.len();
                let slot = ResultSlot::new(nq);
                let enqueued = Instant::now();
                let pending: Vec<PendingQuery> = (0..nq)
                    .map(|i| PendingQuery {
                        q: s.queries[i * d..(i + 1) * d].to_vec(),
                        k: s.k as usize,
                        ef: s.ef as usize,
                        exclude: if s.exclude[i] == u32::MAX { EMPTY } else { s.exclude[i] },
                        enqueued,
                        slot: Arc::clone(&slot),
                        idx: i,
                    })
                    .collect();
                match queue.push_all_within(pending, cfg.queue_limit) {
                    mpmc::PushOutcome::Pushed => {
                        m.accepted.inc();
                        match slot.wait() {
                            Ok(results) => {
                                let resp =
                                    Response::Search(SearchResponse { k: s.k, results });
                                if proto::write_frame(
                                    &mut stream,
                                    &proto::encode_response(&resp),
                                )
                                .is_err()
                                {
                                    break;
                                }
                            }
                            Err(()) => {
                                respond_error(
                                    &mut stream,
                                    Status::Internal,
                                    "batch execution failed",
                                );
                                break;
                            }
                        }
                    }
                    mpmc::PushOutcome::OverLimit => {
                        m.shed_total.inc();
                        respond_error(
                            &mut stream,
                            Status::Overloaded,
                            &format!("pending-query queue at limit {}", cfg.queue_limit),
                        );
                    }
                    mpmc::PushOutcome::Closed => {
                        respond_error(&mut stream, Status::Internal, "server shutting down");
                        break;
                    }
                }
            }
        }
    }
}

fn respond_error(stream: &mut TcpStream, status: Status, msg: &str) {
    let resp = Response::Error(ErrorResponse { status, msg: msg.to_string() });
    let _ = proto::write_frame(stream, &proto::encode_response(&resp));
}

/// Atomically (tmp + rename) rewrite `path` with the global telemetry
/// snapshot.
fn write_stats_file(path: &str) {
    let json = telemetry::global().snapshot().to_json().to_string();
    let tmp = format!("{path}.tmp");
    if std::fs::write(&tmp, &json).is_ok() {
        let _ = std::fs::rename(&tmp, path);
    }
}

fn stats_loop(path: &str, stop: &AtomicBool) {
    write_stats_file(path);
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(500));
        write_stats_file(path);
    }
    write_stats_file(path);
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// An [`AnnIndex`] served over the wire: each search sends one
/// single-query frame on a pooled connection and blocks for the
/// response, so the in-process serve harness (arrival schedules,
/// percentiles, recall) drives a live server unchanged via
/// `serve-bench --target`.
///
/// Work counters (`dist_evals`, `hops`, `rerank_evals`,
/// `shards_probed`) read 0 through a remote index — they happen on the
/// server, which exports them through its own telemetry. A shed query
/// (`Overloaded`) returns an *empty* result list and bumps the global
/// `client.shed_total` counter; transport and protocol errors panic
/// (the bench treats a broken target as fatal, and [`AnnIndex`]
/// returns no `Result`).
pub struct RemoteIndex {
    addr: String,
    info: InfoResponse,
    metric: crate::config::Metric,
    pool: Mutex<Vec<TcpStream>>,
    shed: Arc<telemetry::Counter>,
}

impl RemoteIndex {
    /// Connect and exchange `Info` with the server at `addr`.
    pub fn connect(addr: &str) -> Result<RemoteIndex> {
        let mut stream = dial(addr)?;
        proto::write_frame(&mut stream, &proto::encode_request(&Request::Info))
            .with_context(|| format!("send info request to {addr}"))?;
        let payload = proto::read_frame(&mut stream)?
            .ok_or_else(|| anyhow!("server {addr} closed before answering info"))?;
        let info = match proto::decode_response(&payload)? {
            Response::Info(i) => i,
            Response::Error(e) => {
                return Err(anyhow!("server {addr} answered info with {}: {}", e.status, e.msg))
            }
            Response::Search(_) => {
                return Err(anyhow!("server {addr} answered info with a search response"))
            }
        };
        let metric = info
            .metric
            .parse::<crate::config::Metric>()
            .with_context(|| format!("server {addr} reported metric {:?}", info.metric))?;
        Ok(RemoteIndex {
            addr: addr.to_string(),
            info,
            metric,
            pool: Mutex::new(vec![stream]),
            shed: telemetry::global().counter("client.shed_total"),
        })
    }

    /// [`RemoteIndex::connect`], retrying refused connections until
    /// `timeout` — for racing a just-spawned server process.
    pub fn connect_with_retries(addr: &str, timeout: Duration) -> Result<RemoteIndex> {
        let deadline = Instant::now() + timeout;
        loop {
            match Self::connect(addr) {
                Ok(r) => return Ok(r),
                Err(e) if Instant::now() < deadline => {
                    let _ = e; // refused or reset while the server starts
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// The server's `Info` answer.
    pub fn info(&self) -> &InfoResponse {
        &self.info
    }

    fn take_conn(&self) -> Result<TcpStream> {
        if let Some(s) = self.pool.lock().unwrap_or_else(PoisonError::into_inner).pop() {
            return Ok(s);
        }
        dial(&self.addr)
    }

    fn put_conn(&self, s: TcpStream) {
        self.pool.lock().unwrap_or_else(PoisonError::into_inner).push(s);
    }
}

fn dial(addr: &str) -> Result<TcpStream> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect to {addr}"))?;
    let _ = stream.set_nodelay(true);
    Ok(stream)
}

impl AnnIndex for RemoteIndex {
    fn len(&self) -> usize {
        self.info.n as usize
    }

    fn dim(&self) -> usize {
        self.info.d as usize
    }

    fn metric(&self) -> crate::config::Metric {
        self.metric
    }

    fn vector(&self, id: u32) -> Vec<f32> {
        panic!("RemoteIndex cannot fetch vectors (id {id}); keep the corpus local (--data)")
    }

    fn default_ef(&self) -> usize {
        self.info.default_ef as usize
    }

    fn describe(&self) -> String {
        format!("remote({}, {})", self.addr, self.info.describe)
    }

    fn make_scratch(&self) -> SearchScratch {
        SearchScratch::new()
    }

    fn search_ef_into_excluding(
        &self,
        q: &[f32],
        k: usize,
        ef: usize,
        exclude: u32,
        scratch: &mut SearchScratch,
        out: &mut Vec<(f32, u32)>,
    ) {
        // the work happens server-side; a remote query reports none
        scratch.dist_evals = 0;
        scratch.hops = 0;
        scratch.rerank_evals = 0;
        scratch.shards_probed = 0;
        out.clear();
        let req = Request::Search(SearchRequest {
            k: k as u32,
            ef: ef as u32,
            rerank: 0,
            d: self.info.d,
            queries: q.to_vec(),
            exclude: vec![if exclude == EMPTY { u32::MAX } else { exclude }],
        });
        let mut stream = self.take_conn().expect("dial remote index");
        let exchanged = (|| -> Result<Response> {
            proto::write_frame(&mut stream, &proto::encode_request(&req))?;
            let payload = proto::read_frame(&mut stream)?
                .ok_or_else(|| anyhow!("server closed the connection mid-search"))?;
            proto::decode_response(&payload)
        })();
        match exchanged {
            Ok(Response::Search(mut s)) => {
                assert_eq!(s.results.len(), 1, "one result list per single-query request");
                self.put_conn(stream);
                out.append(&mut s.results[0]);
            }
            Ok(Response::Error(e)) if e.status == Status::Overloaded => {
                // shed: empty results, counted for shed-reconciliation
                self.put_conn(stream);
                self.shed.inc();
            }
            Ok(Response::Error(e)) => panic!("server error ({}): {}", e.status, e.msg),
            Ok(Response::Info(_)) => panic!("unexpected info response to a search"),
            Err(e) => panic!("remote search against {} failed: {e:#}", self.addr),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesce_window_clamps_with_flag() {
        assert_eq!(clamp_coalesce_window(0), (0, false));
        assert_eq!(clamp_coalesce_window(100), (100, false));
        assert_eq!(
            clamp_coalesce_window(MAX_COALESCE_WINDOW_US),
            (MAX_COALESCE_WINDOW_US, false)
        );
        assert_eq!(
            clamp_coalesce_window(MAX_COALESCE_WINDOW_US + 1),
            (MAX_COALESCE_WINDOW_US, true)
        );
        let before = telemetry::warnings_total();
        assert_eq!(clamp_coalesce_window_warn(u64::MAX), MAX_COALESCE_WINDOW_US);
        assert!(telemetry::warnings_total() > before, "clamp must warn");
    }

    #[test]
    fn result_slot_fills_out_of_order_and_poisons() {
        let slot = ResultSlot::new(2);
        slot.fill(1, vec![(2.0, 7)]);
        slot.fill(0, vec![(1.0, 3)]);
        let got = slot.wait().expect("filled slot");
        assert_eq!(got, vec![vec![(1.0, 3)], vec![(2.0, 7)]]);

        let slot = ResultSlot::new(2);
        slot.fill(0, vec![]);
        slot.fail();
        assert!(slot.wait().is_err(), "poisoned slot must report failure");
    }

    #[test]
    fn server_config_defaults_are_sane() {
        let cfg = ServerConfig::default();
        assert!(cfg.coalesce_window_us <= MAX_COALESCE_WINDOW_US);
        assert!(cfg.queue_limit > 0);
        assert_eq!(cfg.debug_slow_shard_ms, 0);
    }
}
