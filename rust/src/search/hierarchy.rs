//! GGNN-style coarse-to-fine entry hierarchy over a finished graph
//! (Groh et al., arXiv 1912.01059 — the multi-layer search structure
//! PAPERS.md credits for cheap hop reduction at scale).
//!
//! A [`EntryHierarchy`] is a small pyramid of nested sampled points
//! over the indexed objects: the finest level is a bounded sample of
//! the dataset (`max_base` points, so construction cost and memory are
//! O(sample), never O(n)), each coarser level a factor-`branch`
//! subsample of the one below, until the top fits `top_cap` points.
//! Every level carries an exact (brute-forced) k-NN graph over its
//! points. At query time [`EntryHierarchy::descend`] brute-forces the
//! top level, then greedily searches each finer level (via
//! [`crate::search::beam_search`], the codebase's single greedy-search
//! loop) seeded by the level above, and returns the best finest-level
//! points as **entry seeds** for the base-graph beam. The hierarchy
//! only changes *which* entries seed the beam — results still come
//! from the base graph walk, so recall tracks the flat-entry index
//! while the walk skips the "walk in from a random region" hops.
//!
//! Construction is deterministic from `(data, HierConfig)`: sampling
//! uses a seeded [`Rng`], levels are stored sorted, and distances are
//! evaluated in a fixed order — the same inputs produce a
//! byte-identical `hier.bin` sidecar ([`EntryHierarchy::save`], HIR1
//! format below), which is how [`load_or_build`] can trust a sidecar
//! found on disk after validating its header.
//!
//! # `hier.bin` (HIR1) format
//!
//! All integers little-endian u32, all floats little-endian f32 — the
//! same conventions as the `.dsb`/`.knng` formats in
//! [`crate::dataset::io`].
//!
//! ```text
//! offset  field
//!      0  magic       0x4849_5231 ("HIR1")
//!      4  d           vector dimensionality
//!      8  n           dataset size the hierarchy was built over
//!     12  metric      0 = l2, 1 = ip, 2 = cosine
//!     16  m           finest-level sample size
//!     20  levels      level count L (top/coarsest first)
//!     24  degree      configured per-level graph degree
//!     28  seed_lo     low 32 bits of the build seed
//!     32  seed_hi     high 32 bits of the build seed
//!     36  global_ids  m u32 (finest-local -> dataset id, ascending)
//!          vectors    m * d f32 (finest-level rows, build order)
//!          L levels:  len u32, lk u32 (effective degree),
//!                     len u32 ids (finest-local, ascending),
//!                     len * lk neighbor slots (u32 id, f32 dist;
//!                     id = EMPTY pads short rows)
//! ```

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::Context;

use crate::config::Metric;
use crate::dataset::groundtruth::ordered::F32;
use crate::dataset::Dataset;
use crate::graph::{KnnGraph, Neighbor, EMPTY};
use crate::util::rng::Rng;

use super::{beam_search, QuerySpec, SearchScratch};

const HIER_MAGIC: u32 = 0x4849_5231; // "HIR1"
/// Fixed header length in bytes (9 u32 words).
const HIER_HEADER: usize = 36;
/// Sanity bounds for untrusted headers (a corrupt file must fail the
/// parse, not drive a huge allocation).
const MAX_SAMPLE: usize = 1 << 22;
const MAX_LEVELS: usize = 64;

/// Construction knobs of an [`EntryHierarchy`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HierConfig {
    /// Finest-level sample cap: the hierarchy covers
    /// `min(n, max_base)` points, bounding build cost (O(max_base^2)
    /// distances) and memory independently of the dataset size.
    pub max_base: usize,
    /// Down-sampling factor between levels.
    pub branch: usize,
    /// Stop coarsening once a level fits this many points (the top
    /// level is brute-forced per query, so it must stay small).
    pub top_cap: usize,
    /// Per-level exact k-NN graph degree.
    pub degree: usize,
    /// Sampling seed (fixed seed + data => byte-identical sidecar).
    pub seed: u64,
}

impl Default for HierConfig {
    fn default() -> Self {
        HierConfig { max_base: 1024, branch: 8, top_cap: 32, degree: 8, seed: 0x5EA_6C4 }
    }
}

/// One level of the pyramid: its member points (finest-local ids,
/// ascending — a subset of every finer level, so a coarser point maps
/// into the next level by binary search) and an exact k-NN graph over
/// them (graph-local ids index into `ids`).
struct HierLevel {
    ids: Vec<u32>,
    graph: KnnGraph,
}

/// A coarse-to-fine entry hierarchy (see the module docs). Owns an
/// f32 copy of its finest-level sample rows, so descent never touches
/// the (possibly paged or quantized) base dataset.
pub struct EntryHierarchy {
    /// Finest-level sample vectors (owned f32, `m` rows).
    ds: Dataset,
    /// Finest-local id -> dataset id (ascending).
    global_ids: Vec<u32>,
    /// Levels, coarsest (top) first; the last level covers the whole
    /// sample (`ids = 0..m`).
    levels: Vec<HierLevel>,
    /// Dataset size the hierarchy was built over (validation).
    n: usize,
    /// Configured degree (validation; levels may clamp below it).
    degree: usize,
    seed: u64,
}

/// Exact k-NN graph over one level by brute force — levels are small
/// (≤ `max_base`), so O(len^2) distances at build time buy exact
/// navigability with zero tuning. Ties break by ascending id, so the
/// result is deterministic.
fn exact_level_graph(hds: &Dataset, ids: &[u32], degree: usize) -> KnnGraph {
    let ln = ids.len();
    let lk = degree.min(ln.saturating_sub(1)).max(1);
    let mut g = KnnGraph::empty(ln, lk);
    let mut cands: Vec<(F32, u32)> = Vec::with_capacity(ln);
    for ul in 0..ln {
        cands.clear();
        for vl in 0..ln {
            if vl != ul {
                let d = hds.dist(ids[ul] as usize, ids[vl] as usize);
                cands.push((F32(d), vl as u32));
            }
        }
        cands.sort_unstable();
        let list = g.list_mut(ul);
        for (slot, &(F32(d), vl)) in cands.iter().take(lk).enumerate() {
            list[slot] = Neighbor { id: vl, dist: d, new: false };
        }
    }
    g
}

impl EntryHierarchy {
    /// Build a hierarchy over `ds` (any backing — rows are copied out
    /// through the accessor, so paged and quantized datasets build the
    /// same structure as owned ones for identical row values).
    pub fn build(ds: &Dataset, cfg: &HierConfig) -> EntryHierarchy {
        assert!(ds.len() > 0, "cannot build a hierarchy over an empty dataset");
        let n = ds.len();
        let m = n.min(cfg.max_base.max(1));
        let mut rng = Rng::new(cfg.seed ^ 0x41E7_A9C1);
        // finest-level sample, ascending (stable file bytes + the
        // binary-search id mapping below)
        let global_ids: Vec<u32> = if m == n {
            (0..n as u32).collect()
        } else {
            let mut picks = rng.distinct(n, m);
            picks.sort_unstable();
            picks.into_iter().map(|i| i as u32).collect()
        };
        let mut data = Vec::with_capacity(m * ds.d);
        for &g in &global_ids {
            ds.with_vec(g as usize, |row| data.extend_from_slice(row));
        }
        let hds = Dataset::new("hier", ds.d, ds.metric, data);
        // nested levels, finest -> coarsest, then reversed to top-first
        let branch = cfg.branch.max(2);
        let mut level_ids: Vec<Vec<u32>> = vec![(0..m as u32).collect()];
        while level_ids.last().unwrap().len() > cfg.top_cap.max(1) {
            let prev = level_ids.last().unwrap();
            let mc = (prev.len() / branch).max(1);
            let picks = rng.distinct(prev.len(), mc);
            let mut ids: Vec<u32> = picks.into_iter().map(|i| prev[i]).collect();
            ids.sort_unstable();
            level_ids.push(ids);
        }
        level_ids.reverse();
        let levels = level_ids
            .into_iter()
            .map(|ids| {
                let graph = exact_level_graph(&hds, &ids, cfg.degree);
                HierLevel { ids, graph }
            })
            .collect();
        EntryHierarchy { ds: hds, global_ids, levels, n, degree: cfg.degree, seed: cfg.seed }
    }

    /// True when a loaded sidecar describes this `(dataset, config)`
    /// pair — the load-or-rebuild gate of [`load_or_build`].
    pub fn matches(&self, ds: &Dataset, cfg: &HierConfig) -> bool {
        self.ds.d == ds.d
            && self.n == ds.len()
            && self.ds.metric == ds.metric
            && self.seed == cfg.seed
            && self.degree == cfg.degree
            && self.ds.len() == ds.len().min(cfg.max_base.max(1))
    }

    /// Finest-level sample size.
    pub fn sample_len(&self) -> usize {
        self.ds.len()
    }

    /// Level count (top/coarsest first).
    pub fn level_count(&self) -> usize {
        self.levels.len()
    }

    /// Level sizes, coarsest first.
    pub fn level_sizes(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.ids.len()).collect()
    }

    /// Coarse-to-fine descent: brute-force the top level, greedily
    /// search each finer level seeded by the one above, and write the
    /// best `n_out` finest-level points into `out` as **dataset ids**
    /// of the dataset the hierarchy was built over (shard-local for a
    /// per-shard hierarchy). Returns the distance evaluations spent —
    /// the caller folds them into its own `dist_evals` accounting
    /// (beam hops on the *base* graph are reported separately; descent
    /// expansions walk the tiny level graphs and are deliberately not
    /// counted as base-graph hops).
    ///
    /// Uses the nested `scratch.hier` child scratch, so it can run
    /// mid-query without clobbering the caller's accumulated counters.
    pub fn descend(
        &self,
        q: &[f32],
        n_out: usize,
        scratch: &mut SearchScratch,
        out: &mut Vec<u32>,
    ) -> usize {
        out.clear();
        if n_out == 0 || self.levels.is_empty() {
            return 0;
        }
        let w = n_out;
        let mut evals = 0usize;
        let mut child = scratch.hier.take().unwrap_or_else(|| Box::new(SearchScratch::new()));
        let mut best = std::mem::take(&mut child.hier_out);
        let mut entries = std::mem::take(&mut child.entry_buf);
        // ---- top level: score every point (it fits top_cap) ----
        best.clear();
        let top = &self.levels[0];
        for &fl in &top.ids {
            best.push((self.ds.dist_to(fl as usize, q), fl));
        }
        evals += top.ids.len();
        best.sort_unstable_by(|a, b| (F32(a.0), a.1).cmp(&(F32(b.0), b.1)));
        best.truncate(w);
        // ---- finer levels: greedy beam seeded from the level above ----
        for level in &self.levels[1..] {
            entries.clear();
            for &(_, fl) in best.iter() {
                // levels are nested, so every coarser point exists in
                // each finer level and the lookup cannot fail
                let ll = level.ids.binary_search(&fl).expect("hierarchy levels not nested");
                entries.push(ll as u32);
            }
            let spec = QuerySpec {
                q,
                k: w,
                ef: w,
                beam_width: 0,
                max_hops: 0,
                entries: &entries,
                exclude: EMPTY,
                rerank: 1,
            };
            beam_search(&self.ds, &level.graph, Some(&level.ids), &spec, &mut child, &mut best);
            evals += child.dist_evals;
        }
        for &(_, fl) in best.iter().take(n_out) {
            out.push(self.global_ids[fl as usize]);
        }
        child.hier_out = best;
        child.entry_buf = entries;
        scratch.hier = Some(child);
        evals
    }

    /// Persist as a `hier.bin` sidecar (HIR1; see the module docs).
    /// Deterministic: the same hierarchy writes the same bytes.
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let path = path.as_ref();
        let mut w = BufWriter::new(
            File::create(path).with_context(|| format!("create {path:?}"))?,
        );
        let metric = match self.ds.metric {
            Metric::L2 => 0u32,
            Metric::Ip => 1,
            Metric::Cosine => 2,
        };
        for word in [
            HIER_MAGIC,
            self.ds.d as u32,
            self.n as u32,
            metric,
            self.ds.len() as u32,
            self.levels.len() as u32,
            self.degree as u32,
            self.seed as u32,
            (self.seed >> 32) as u32,
        ] {
            w.write_all(&word.to_le_bytes())?;
        }
        for &g in &self.global_ids {
            w.write_all(&g.to_le_bytes())?;
        }
        for &x in self.ds.raw() {
            w.write_all(&x.to_le_bytes())?;
        }
        for level in &self.levels {
            let lk = level.graph.k();
            w.write_all(&(level.ids.len() as u32).to_le_bytes())?;
            w.write_all(&(lk as u32).to_le_bytes())?;
            for &id in &level.ids {
                w.write_all(&id.to_le_bytes())?;
            }
            for u in 0..level.graph.n() {
                let row = level.graph.list(u);
                for slot in 0..lk {
                    let e = row[slot];
                    w.write_all(&e.id.to_le_bytes())?;
                    w.write_all(&e.dist.to_le_bytes())?;
                }
            }
        }
        w.flush()?;
        Ok(())
    }

    /// Read a `hier.bin` sidecar back. Fails (with the path and what
    /// was wrong) on a bad magic, corrupt header geometry, or trailing
    /// / missing bytes — callers treat any error as "rebuild".
    pub fn load(path: impl AsRef<Path>) -> crate::Result<EntryHierarchy> {
        let path = path.as_ref();
        let mut bytes = Vec::new();
        File::open(path)
            .and_then(|mut f| f.read_to_end(&mut bytes))
            .with_context(|| format!("read {path:?}"))?;
        let mut off = 0usize;
        let mut take_u32 = |bytes: &[u8]| -> crate::Result<u32> {
            anyhow::ensure!(off + 4 <= bytes.len(), "truncated {path:?} at byte {off}");
            let v = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            off += 4;
            Ok(v)
        };
        anyhow::ensure!(bytes.len() >= HIER_HEADER, "{path:?} too short for a HIR1 header");
        let magic = take_u32(&bytes)?;
        anyhow::ensure!(magic == HIER_MAGIC, "{path:?}: bad magic {magic:#x} (want HIR1)");
        let d = take_u32(&bytes)? as usize;
        let n = take_u32(&bytes)? as usize;
        let metric = match take_u32(&bytes)? {
            0 => Metric::L2,
            1 => Metric::Ip,
            2 => Metric::Cosine,
            c => anyhow::bail!("{path:?}: bad metric code {c}"),
        };
        let m = take_u32(&bytes)? as usize;
        let nlevels = take_u32(&bytes)? as usize;
        let degree = take_u32(&bytes)? as usize;
        let seed_lo = take_u32(&bytes)? as u64;
        let seed_hi = take_u32(&bytes)? as u64;
        let seed = seed_lo | (seed_hi << 32);
        anyhow::ensure!(
            d > 0 && m > 0 && m <= MAX_SAMPLE && nlevels >= 1 && nlevels <= MAX_LEVELS,
            "{path:?}: implausible header (d={d}, m={m}, levels={nlevels})"
        );
        let mut global_ids = Vec::with_capacity(m);
        for _ in 0..m {
            global_ids.push(take_u32(&bytes)?);
        }
        let mut data = Vec::with_capacity(m * d);
        for _ in 0..m * d {
            data.push(f32::from_bits(take_u32(&bytes)?));
        }
        // The rows were written from a Dataset built at the same
        // metric, so Dataset::new's cosine re-normalization is a no-op
        // on them (rows are already unit-norm).
        let hds = Dataset::new("hier", d, metric, data);
        let mut levels = Vec::with_capacity(nlevels);
        for _ in 0..nlevels {
            let len = take_u32(&bytes)? as usize;
            let lk = take_u32(&bytes)? as usize;
            anyhow::ensure!(
                len >= 1 && len <= m && lk >= 1 && lk <= m,
                "{path:?}: implausible level (len={len}, lk={lk})"
            );
            let mut ids = Vec::with_capacity(len);
            for _ in 0..len {
                let id = take_u32(&bytes)?;
                anyhow::ensure!((id as usize) < m, "{path:?}: level id {id} out of range");
                ids.push(id);
            }
            let mut graph = KnnGraph::empty(len, lk);
            for u in 0..len {
                let row = graph.list_mut(u);
                for slot in row.iter_mut().take(lk) {
                    let id = take_u32(&bytes)?;
                    let dist = f32::from_bits(take_u32(&bytes)?);
                    if id != EMPTY {
                        anyhow::ensure!(
                            (id as usize) < len,
                            "{path:?}: neighbor id {id} outside level (len={len})"
                        );
                        *slot = Neighbor { id, dist, new: false };
                    }
                }
            }
            levels.push(HierLevel { ids, graph });
        }
        anyhow::ensure!(
            off == bytes.len(),
            "{path:?}: {} trailing bytes after the last level",
            bytes.len() - off
        );
        Ok(EntryHierarchy { ds: hds, global_ids, levels, n, degree, seed })
    }
}

/// Load a validated sidecar from `path`, or (re)build from `ds` and
/// persist it. A sidecar that fails to parse, or parses but describes
/// a different `(dataset, config)` pair, is rebuilt with a warning; a
/// failed save is also only a warning (the in-memory hierarchy serves
/// either way — a read-only store directory must not break serving).
pub fn load_or_build(
    path: impl AsRef<Path>,
    ds: &Dataset,
    cfg: &HierConfig,
) -> EntryHierarchy {
    let path = path.as_ref();
    if path.exists() {
        match EntryHierarchy::load(path) {
            Ok(h) if h.matches(ds, cfg) => return h,
            Ok(_) => crate::telemetry::warn!(
                "hierarchy: {path:?} is stale (different data/config); rebuilding"
            ),
            Err(e) => crate::telemetry::warn!(
                "hierarchy: {path:?} unreadable ({e:#}); rebuilding"
            ),
        }
    }
    let h = EntryHierarchy::build(ds, cfg);
    if let Err(e) = h.save(path) {
        crate::telemetry::warn!("hierarchy: could not persist {path:?} ({e:#}); serving in-memory");
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "gnnd-hier-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn levels_are_nested_and_sized() {
        let ds = synth::clustered(2_000, 8, 41);
        let cfg = HierConfig { max_base: 512, branch: 4, top_cap: 16, degree: 6, seed: 7 };
        let h = EntryHierarchy::build(&ds, &cfg);
        assert_eq!(h.sample_len(), 512);
        let sizes = h.level_sizes();
        assert!(sizes.len() >= 2, "{sizes:?}");
        assert_eq!(*sizes.last().unwrap(), 512, "finest level covers the sample");
        assert!(sizes[0] <= 16, "top level over cap: {sizes:?}");
        for w in sizes.windows(2) {
            assert!(w[0] < w[1], "levels not strictly coarsening: {sizes:?}");
        }
        // nestedness: every coarser level ⊆ the next finer one
        for lw in h.levels.windows(2) {
            for id in &lw[0].ids {
                assert!(lw[1].ids.binary_search(id).is_ok(), "level not nested");
            }
        }
    }

    #[test]
    fn same_seed_writes_identical_sidecars() {
        let ds = synth::clustered(1_500, 8, 42);
        let cfg = HierConfig { max_base: 256, seed: 99, ..Default::default() };
        let dir = tmpdir("det");
        let (pa, pb) = (dir.join("a.bin"), dir.join("b.bin"));
        EntryHierarchy::build(&ds, &cfg).save(&pa).unwrap();
        EntryHierarchy::build(&ds, &cfg).save(&pb).unwrap();
        let (a, b) = (std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
        assert!(!a.is_empty());
        assert_eq!(a, b, "same (data, seed) must write byte-identical hier.bin");
        // a different seed samples differently
        let cfg2 = HierConfig { seed: 100, ..cfg };
        let pc = dir.join("c.bin");
        EntryHierarchy::build(&ds, &cfg2).save(&pc).unwrap();
        assert_ne!(a, std::fs::read(&pc).unwrap(), "seed ignored");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_round_trips_and_descends_identically() {
        let ds = synth::clustered(1_200, 8, 43);
        let cfg = HierConfig { max_base: 300, seed: 5, ..Default::default() };
        let built = EntryHierarchy::build(&ds, &cfg);
        let dir = tmpdir("rt");
        let p = dir.join("h.bin");
        built.save(&p).unwrap();
        let loaded = EntryHierarchy::load(&p).unwrap();
        assert!(loaded.matches(&ds, &cfg));
        assert_eq!(loaded.sample_len(), built.sample_len());
        assert_eq!(loaded.level_sizes(), built.level_sizes());
        let mut sa = SearchScratch::new();
        let mut sb = SearchScratch::new();
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        for q in (0..ds.len()).step_by(97) {
            let ea = built.descend(ds.vec(q), 8, &mut sa, &mut oa);
            let eb = loaded.descend(ds.vec(q), 8, &mut sb, &mut ob);
            assert_eq!(oa, ob, "loaded hierarchy diverged on query {q}");
            assert_eq!(ea, eb, "descent work diverged on query {q}");
            assert!(!oa.is_empty() && oa.len() <= 8);
            assert!(oa.iter().all(|&g| (g as usize) < ds.len()));
        }
        // truncation must fail the parse, not panic or mis-load
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        assert!(EntryHierarchy::load(&p).is_err(), "truncated sidecar must not load");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn descent_entries_are_near_the_query() {
        // the whole point: descent seeds must be much closer than
        // random ids, on average
        let ds = synth::clustered(3_000, 8, 44);
        let cfg = HierConfig { max_base: 1024, seed: 3, ..Default::default() };
        let h = EntryHierarchy::build(&ds, &cfg);
        let mut scratch = SearchScratch::new();
        let mut out = Vec::new();
        let mut rng = Rng::new(11);
        let (mut d_hier, mut d_rand) = (0.0f64, 0.0f64);
        for q in (0..ds.len()).step_by(53) {
            let evals = h.descend(ds.vec(q), 8, &mut scratch, &mut out);
            assert!(evals > 0, "descent did no work");
            for &g in &out {
                d_hier += ds.dist_to(g as usize, ds.vec(q)) as f64;
            }
            for _ in 0..out.len() {
                d_rand += ds.dist_to(rng.below(ds.len()), ds.vec(q)) as f64;
            }
        }
        assert!(
            d_hier < 0.5 * d_rand,
            "descent seeds not meaningfully closer: hier {d_hier} vs random {d_rand}"
        );
    }

    #[test]
    fn load_or_build_persists_then_reuses() {
        let ds = synth::clustered(800, 6, 45);
        let cfg = HierConfig { max_base: 200, seed: 21, ..Default::default() };
        let dir = tmpdir("lob");
        let p = dir.join("hier_0.bin");
        let _ = load_or_build(&p, &ds, &cfg);
        assert!(p.is_file(), "sidecar not written");
        let bytes = std::fs::read(&p).unwrap();
        let _ = load_or_build(&p, &ds, &cfg);
        assert_eq!(bytes, std::fs::read(&p).unwrap(), "reload must not rewrite");
        // a different seed invalidates the sidecar and rebuilds it
        let cfg2 = HierConfig { seed: 22, ..cfg };
        let _ = load_or_build(&p, &ds, &cfg2);
        assert_ne!(bytes, std::fs::read(&p).unwrap(), "stale sidecar not rebuilt");
        std::fs::remove_dir_all(dir).ok();
    }
}
