//! Batched multi-query execution across worker threads.
//!
//! Queries are split into contiguous ranges (same idiom as the
//! coordinator in [`crate::graph::concurrent`] / the baselines): one
//! crossbeam scoped thread per range, one warm
//! [`crate::search::SearchScratch`] per thread reused across all of
//! that thread's queries, results written
//! into disjoint output chunks. Queries are independent, so batched
//! results are bit-identical to single-query execution regardless of
//! the thread count.

use crate::graph::EMPTY;
use crate::util::split_ranges;

use super::SearchIndex;

/// Multi-query executor over a [`SearchIndex`].
pub struct BatchExecutor<'i, 'a> {
    index: &'i SearchIndex<'a>,
    threads: usize,
}

impl<'i, 'a> BatchExecutor<'i, 'a> {
    /// `threads = 0` = auto ([`crate::util::num_threads`]).
    pub fn new(index: &'i SearchIndex<'a>, threads: usize) -> Self {
        let threads = if threads == 0 { crate::util::num_threads() } else { threads };
        BatchExecutor { index, threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Search every row of `queries` (row-major `[nq][d]`): returns one
    /// ascending `(dist, id)` top-`k` list per query.
    pub fn run(&self, queries: &[f32], d: usize, k: usize) -> Vec<Vec<(f32, u32)>> {
        self.run_excluding(queries, d, k, &[])
    }

    /// Like [`BatchExecutor::run`], excluding object `exclude[i]` from
    /// query `i`'s results ([`EMPTY`] = none; shorter slices are
    /// EMPTY-padded) — used when dataset objects replay as queries.
    pub fn run_excluding(
        &self,
        queries: &[f32],
        d: usize,
        k: usize,
        exclude: &[u32],
    ) -> Vec<Vec<(f32, u32)>> {
        assert!(d > 0 && queries.len() % d == 0, "queries must be [nq][{d}] row-major");
        let nq = queries.len() / d;
        let mut out: Vec<Vec<(f32, u32)>> = vec![Vec::new(); nq];
        if nq == 0 {
            return out;
        }
        let ranges = split_ranges(nq, self.threads);
        let chunks = {
            let mut rest = out.as_mut_slice();
            let mut v = Vec::new();
            for r in &ranges {
                let (a, b) = rest.split_at_mut(r.len());
                v.push(a);
                rest = b;
            }
            v
        };
        let index = self.index;
        crossbeam_utils::thread::scope(|s| {
            for (r, chunk) in ranges.iter().zip(chunks) {
                let r = r.clone();
                s.spawn(move |_| {
                    // per-thread scratch, warm across this range
                    let mut scratch = index.make_scratch();
                    for (slot, qi) in r.enumerate() {
                        let q = &queries[qi * d..(qi + 1) * d];
                        let ex = exclude.get(qi).copied().unwrap_or(EMPTY);
                        index.search_into_excluding(q, k, ex, &mut scratch, &mut chunk[slot]);
                    }
                });
            }
        })
        .unwrap();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::bruteforce;
    use crate::dataset::synth;
    use crate::search::SearchParams;

    #[test]
    fn batched_is_bit_identical_to_single() {
        let ds = synth::clustered(300, 8, 101);
        let g = bruteforce::build_native(&ds, 8);
        let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
        let nq = 40;
        let mut qbuf = Vec::with_capacity(nq * ds.d);
        let mut exclude = Vec::with_capacity(nq);
        for q in 0..nq {
            qbuf.extend_from_slice(ds.vec(q));
            exclude.push(q as u32);
        }
        let batched = BatchExecutor::new(&index, 4).run_excluding(&qbuf, ds.d, 10, &exclude);
        let mut scratch = index.make_scratch();
        let mut single = Vec::new();
        for q in 0..nq {
            index.search_into_excluding(ds.vec(q), 10, q as u32, &mut scratch, &mut single);
            assert_eq!(batched[q], single, "query {q} differs");
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let ds = synth::clustered(250, 6, 102);
        let g = bruteforce::build_native(&ds, 8);
        let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
        let nq = 30;
        let mut qbuf = Vec::new();
        for q in 0..nq {
            qbuf.extend_from_slice(ds.vec(q));
        }
        let a = BatchExecutor::new(&index, 1).run(&qbuf, ds.d, 5);
        let b = BatchExecutor::new(&index, 3).run(&qbuf, ds.d, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_batch_is_fine() {
        let ds = synth::uniform(60, 4, 103);
        let g = bruteforce::build_native(&ds, 6);
        let index = SearchIndex::new(&ds, &g, SearchParams::default()).unwrap();
        let out = BatchExecutor::new(&index, 2).run(&[], ds.d, 5);
        assert!(out.is_empty());
    }
}
