//! Batched multi-query execution across worker threads.
//!
//! Queries are split into contiguous ranges (same idiom as the
//! coordinator in [`crate::graph::concurrent`] / the baselines): one
//! crossbeam scoped thread per range, one warm
//! [`crate::search::SearchScratch`] per thread reused across all of
//! that thread's queries, results written into disjoint output chunks.
//! Queries are independent, so batched results are bit-identical to
//! single-query execution regardless of the thread count.
//!
//! The executor is written against [`AnnIndex`] only — it fans the
//! same way over any index layout, monolithic or sharded.

use crate::graph::EMPTY;
use crate::util::split_ranges;

use super::AnnIndex;

/// One query of a heterogeneous batch: its own `k`/`ef`/exclusion,
/// borrowing the query row. The network server's coalescing window
/// produces these — queries landing in the same window may come from
/// different clients with different parameters, yet still ride one
/// scatter pass ([`BatchExecutor::run_jobs`]).
pub struct QueryJob<'q> {
    pub q: &'q [f32],
    pub k: usize,
    /// 0 = use the executor's `ef` (which itself falls back to the
    /// index default when 0).
    pub ef: usize,
    /// Object id excluded from this query's results ([`EMPTY`] = none).
    pub exclude: u32,
}

/// Multi-query executor over any [`AnnIndex`].
pub struct BatchExecutor<'i> {
    index: &'i dyn AnnIndex,
    threads: usize,
    /// `ef` override applied to every query (0 = index default) — the
    /// knob the serve harness sweeps without rebuilding indexes.
    ef: usize,
}

impl<'i> BatchExecutor<'i> {
    /// `threads = 0` = auto ([`crate::util::num_threads`]).
    pub fn new(index: &'i dyn AnnIndex, threads: usize) -> Self {
        let threads = if threads == 0 { crate::util::num_threads() } else { threads };
        BatchExecutor { index, threads, ef: 0 }
    }

    /// Run every query at this `ef` operating point (0 = index default).
    pub fn with_ef(mut self, ef: usize) -> Self {
        self.ef = ef;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Search every row of `queries` (row-major `[nq][d]`): returns one
    /// ascending `(dist, id)` top-`k` list per query.
    pub fn run(&self, queries: &[f32], d: usize, k: usize) -> Vec<Vec<(f32, u32)>> {
        self.run_excluding(queries, d, k, &[])
    }

    /// Like [`BatchExecutor::run`], excluding object `exclude[i]` from
    /// query `i`'s results ([`EMPTY`] = none; shorter slices are
    /// EMPTY-padded) — used when dataset objects replay as queries.
    pub fn run_excluding(
        &self,
        queries: &[f32],
        d: usize,
        k: usize,
        exclude: &[u32],
    ) -> Vec<Vec<(f32, u32)>> {
        assert!(d > 0 && queries.len() % d == 0, "queries must be [nq][{d}] row-major");
        let nq = queries.len() / d;
        let jobs: Vec<QueryJob<'_>> = (0..nq)
            .map(|qi| QueryJob {
                q: &queries[qi * d..(qi + 1) * d],
                k,
                ef: 0,
                exclude: exclude.get(qi).copied().unwrap_or(EMPTY),
            })
            .collect();
        self.run_jobs(&jobs)
    }

    /// Search a heterogeneous batch (per-query `k`/`ef`/exclusion), in
    /// job order. Queries are independent, so results are bit-identical
    /// to running each job alone — the property the server's coalescing
    /// parity grid enforces across window sizes.
    pub fn run_jobs(&self, jobs: &[QueryJob<'_>]) -> Vec<Vec<(f32, u32)>> {
        let nq = jobs.len();
        let mut out: Vec<Vec<(f32, u32)>> = vec![Vec::new(); nq];
        if nq == 0 {
            return out;
        }
        let base_ef = self.ef;
        if self.threads <= 1 || nq == 1 {
            // inline fast path: no scope setup for the common
            // single-query / single-thread case
            let mut scratch = self.index.make_scratch();
            for (slot, job) in out.iter_mut().zip(jobs) {
                let ef = if job.ef != 0 { job.ef } else { base_ef };
                self.index
                    .search_ef_into_excluding(job.q, job.k, ef, job.exclude, &mut scratch, slot);
            }
            return out;
        }
        let ranges = split_ranges(nq, self.threads);
        let chunks = {
            let mut rest = out.as_mut_slice();
            let mut v = Vec::new();
            for r in &ranges {
                let (a, b) = rest.split_at_mut(r.len());
                v.push(a);
                rest = b;
            }
            v
        };
        let index = self.index;
        crossbeam_utils::thread::scope(|s| {
            for (r, chunk) in ranges.iter().zip(chunks) {
                let r = r.clone();
                s.spawn(move |_| {
                    // per-thread scratch, warm across this range
                    let mut scratch = index.make_scratch();
                    for (slot, qi) in r.enumerate() {
                        let job = &jobs[qi];
                        let ef = if job.ef != 0 { job.ef } else { base_ef };
                        let out = &mut chunk[slot];
                        index.search_ef_into_excluding(
                            job.q,
                            job.k,
                            ef,
                            job.exclude,
                            &mut scratch,
                            out,
                        );
                    }
                });
            }
        })
        .unwrap();
        out
    }
}
