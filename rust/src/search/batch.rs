//! Batched multi-query execution across worker threads.
//!
//! Queries are split into contiguous ranges (same idiom as the
//! coordinator in [`crate::graph::concurrent`] / the baselines): one
//! crossbeam scoped thread per range, one warm
//! [`crate::search::SearchScratch`] per thread reused across all of
//! that thread's queries, results written into disjoint output chunks.
//! Queries are independent, so batched results are bit-identical to
//! single-query execution regardless of the thread count.
//!
//! The executor is written against [`AnnIndex`] only — it fans the
//! same way over any index layout, monolithic or sharded.

use crate::graph::EMPTY;
use crate::util::split_ranges;

use super::AnnIndex;

/// Multi-query executor over any [`AnnIndex`].
pub struct BatchExecutor<'i> {
    index: &'i dyn AnnIndex,
    threads: usize,
    /// `ef` override applied to every query (0 = index default) — the
    /// knob the serve harness sweeps without rebuilding indexes.
    ef: usize,
}

impl<'i> BatchExecutor<'i> {
    /// `threads = 0` = auto ([`crate::util::num_threads`]).
    pub fn new(index: &'i dyn AnnIndex, threads: usize) -> Self {
        let threads = if threads == 0 { crate::util::num_threads() } else { threads };
        BatchExecutor { index, threads, ef: 0 }
    }

    /// Run every query at this `ef` operating point (0 = index default).
    pub fn with_ef(mut self, ef: usize) -> Self {
        self.ef = ef;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Search every row of `queries` (row-major `[nq][d]`): returns one
    /// ascending `(dist, id)` top-`k` list per query.
    pub fn run(&self, queries: &[f32], d: usize, k: usize) -> Vec<Vec<(f32, u32)>> {
        self.run_excluding(queries, d, k, &[])
    }

    /// Like [`BatchExecutor::run`], excluding object `exclude[i]` from
    /// query `i`'s results ([`EMPTY`] = none; shorter slices are
    /// EMPTY-padded) — used when dataset objects replay as queries.
    pub fn run_excluding(
        &self,
        queries: &[f32],
        d: usize,
        k: usize,
        exclude: &[u32],
    ) -> Vec<Vec<(f32, u32)>> {
        assert!(d > 0 && queries.len() % d == 0, "queries must be [nq][{d}] row-major");
        let nq = queries.len() / d;
        let mut out: Vec<Vec<(f32, u32)>> = vec![Vec::new(); nq];
        if nq == 0 {
            return out;
        }
        let ranges = split_ranges(nq, self.threads);
        let chunks = {
            let mut rest = out.as_mut_slice();
            let mut v = Vec::new();
            for r in &ranges {
                let (a, b) = rest.split_at_mut(r.len());
                v.push(a);
                rest = b;
            }
            v
        };
        let index = self.index;
        let ef = self.ef;
        crossbeam_utils::thread::scope(|s| {
            for (r, chunk) in ranges.iter().zip(chunks) {
                let r = r.clone();
                s.spawn(move |_| {
                    // per-thread scratch, warm across this range
                    let mut scratch = index.make_scratch();
                    for (slot, qi) in r.enumerate() {
                        let q = &queries[qi * d..(qi + 1) * d];
                        let ex = exclude.get(qi).copied().unwrap_or(EMPTY);
                        let out = &mut chunk[slot];
                        index.search_ef_into_excluding(q, k, ef, ex, &mut scratch, out);
                    }
                });
            }
        })
        .unwrap();
        out
    }
}
