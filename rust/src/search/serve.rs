//! Serving harness: replay a query stream against any [`AnnIndex`] and
//! measure what a serving deployment cares about — throughput (QPS),
//! tail latency (p50/p95/p99) and quality (recall@k against exact
//! ground truth) — across an `ef` sweep, emitting a [`Report`] of the
//! recall-vs-QPS operating curve. The harness never sees the index
//! layout, so the same sweep produces the monolithic-vs-sharded
//! operating curves — including budget-constrained sharded indexes,
//! whose residency knobs (`--memory-budget`, `--search-threads`)
//! surface in the report's `index` metadata via [`AnnIndex::describe`].
//!
//! Two load models for the timing pass, selected by
//! [`ServeConfig::arrival_rate`]:
//!
//! * **closed loop** (`arrival_rate = 0`): `threads` workers pull query
//!   indices from a shared cursor and issue back to back — measures the
//!   system's *capacity* (max sustainable QPS), but can never show
//!   queueing delay because the next query only arrives when a worker
//!   is free;
//! * **open loop** (`arrival_rate > 0` qps): queries *arrive* on a
//!   seeded deterministic schedule — Poisson (exponential gaps, the
//!   memoryless arrivals of real user traffic) or fixed-interval
//!   ([`Arrival`]) — independent of completions. Each query's **queue
//!   delay** (arrival → a worker picks it up) and **service time** (the
//!   search itself) are recorded separately; when the offered rate
//!   exceeds capacity the queue grows without bound and the row's
//!   `overload` flag trips. This is the regime a "millions of users"
//!   deployment lives in: tail latency is dominated by queueing, which
//!   the closed-loop numbers structurally cannot see.
//!
//! Two passes per operating point:
//! 1. a *quality* pass through [`BatchExecutor`] computing recall@k
//!    (identical in both load models — recall depends on the queries,
//!    not their arrival times);
//! 2. a *timing* pass under the selected load model recording
//!    per-query wall latencies (and, open loop, queue delays).
//!
//! Operating points with `ef < k` are clamped up to `k` (with a printed
//! warning): beam search caps the result pool at `max(ef, k)` anyway,
//! so a sub-`k` point would silently run — and be reported — at a
//! different `ef` than its label claims.
//!
//! The timing pass is instrumented ([`crate::telemetry`]): per-query
//! service time and open-loop queue delay feed global histograms,
//! every `ServeConfig::trace_sample`-th query records a full
//! [`QueryTrace`], and [`run_sweep_with`] snapshots the registry per
//! operating point ([`ServeSinks`]) — all observation-only.

use std::str::FromStr;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::dataset::{groundtruth, Dataset};
use crate::metrics::{Report, Row};
use crate::telemetry::{self, trace::QueryTrace, trace::TraceWriter};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

use super::batch::BatchExecutor;
use super::{AnnIndex, SearchParams};

/// Achieved-vs-offered slack before an open-loop point is flagged
/// overloaded: finite runs end a hair above or below the offered rate
/// (the wall clock includes the last queries' drain), so a strict
/// `achieved < offered` would flap on healthy points.
const OVERLOAD_MARGIN: f64 = 0.95;

/// Arrival process of the open-loop load generator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arrival {
    /// Exponential inter-arrival gaps (memoryless, the standard model
    /// of independent user traffic) from a seeded [`Rng`].
    Poisson,
    /// Fixed-interval arrivals (`1/rate` apart) — the zero-variance
    /// baseline that isolates service-time jitter from arrival burst.
    Uniform,
}

impl std::fmt::Display for Arrival {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Arrival::Poisson => "poisson",
            Arrival::Uniform => "uniform",
        })
    }
}

impl FromStr for Arrival {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "poisson" => Ok(Arrival::Poisson),
            "uniform" => Ok(Arrival::Uniform),
            _ => anyhow::bail!("unknown arrival process {s:?} (expected poisson|uniform)"),
        }
    }
}

/// Configuration of a serving benchmark.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Neighbors per query (recall is measured at this k).
    pub k: usize,
    /// `ef` operating points, one report row each (points below `k`
    /// clamp to `k`, see [`clamp_ef`]).
    pub ef_sweep: Vec<usize>,
    /// Total queries replayed per operating point.
    pub n_queries: usize,
    /// Distinct query vectors sampled from the dataset (ground truth is
    /// computed for exactly these, so keep it moderate).
    pub distinct_queries: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Base search parameters; `ef` is overridden by the sweep.
    pub params: SearchParams,
    /// Query-sampling (and arrival-schedule) seed.
    pub seed: u64,
    /// Offered arrival rate in queries/sec; 0 = closed loop (workers
    /// issue as fast as they can).
    pub arrival_rate: f64,
    /// Arrival process of the open-loop schedule (ignored closed loop).
    pub arrival: Arrival,
    /// Trace every Nth query of the timing pass into a
    /// [`QueryTrace`] (0 = tracing off). Observation-only: traced
    /// queries return bit-identical results to untraced ones.
    pub trace_sample: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            k: 10,
            ef_sweep: vec![8, 16, 32, 64, 128],
            n_queries: 2_000,
            distinct_queries: 1_000,
            threads: 0,
            params: SearchParams::default(),
            seed: 0x5E27E,
            arrival_rate: 0.0,
            arrival: Arrival::Poisson,
            trace_sample: 0,
        }
    }
}

/// Measured behaviour of one operating point. `ef` is the *effective*
/// width the point ran at (requested, clamped up to `k`). Latency
/// percentiles (`p50_ms`..) are **service time** (the search itself);
/// open-loop points additionally report **queue delay** percentiles
/// (arrival → service start) and whether the point was overloaded.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub ef: usize,
    /// Achieved rate (queries / wall seconds of the timing pass).
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub recall: f64,
    /// Offered arrival rate of the point (0 = closed loop).
    pub offered_rate: f64,
    /// Queue-delay percentiles (0 closed loop — a closed loop has no
    /// queue by construction).
    pub queue_p50_ms: f64,
    pub queue_p99_ms: f64,
    /// Achieved rate fell short of the offered rate: the index cannot
    /// keep up and the queue grows without bound.
    pub overload: bool,
    /// Mean distance evaluations per query of the timing pass — the
    /// paper's scanning-rate metric as an operating-curve column.
    pub dist_evals: f64,
    /// Mean beam-search hops per query of the timing pass.
    pub hops: f64,
    /// Mean exact f32 re-scores per query of the timing pass (0 unless
    /// the index serves quantized rows with `rerank > 1`). Against
    /// `dist_evals` this is the two-phase bargain in one row: how few
    /// full-precision evaluations bought the reported recall.
    pub rerank_evals: f64,
    /// Mean shards probed per query of the timing pass (0 for
    /// monolithic indexes, which have no route phase). With adaptive
    /// routing (`--route-slack`) this falls below the `--probe-shards`
    /// cap whenever the router prunes; at slack 0 it equals the cap.
    pub probe_mean: f64,
    /// Queries of the timing pass shed by a remote server's admission
    /// control (`client.shed_total` delta; always 0 against an
    /// in-process index — only [`super::server::RemoteIndex`] sheds).
    pub shed: u64,
}

/// The sampled query stream: flat query matrix + the object ids the
/// rows came from (each query excludes itself from its results) + the
/// exact ground truth rows for recall.
pub struct QueryStream {
    pub d: usize,
    pub qbuf: Vec<f32>,
    pub qids: Vec<usize>,
    pub truth: Vec<Vec<u32>>,
}

/// Sample `m` distinct dataset objects as queries and compute their
/// exact top-`k` ground truth.
pub fn sample_queries(ds: &Dataset, m: usize, k: usize, seed: u64) -> QueryStream {
    let m = m.clamp(1, ds.len());
    let mut rng = Rng::new(seed ^ 0x9E27);
    let qids = rng.distinct(ds.len(), m);
    let mut qbuf = Vec::with_capacity(m * ds.d);
    for &q in &qids {
        qbuf.extend_from_slice(ds.vec(q));
    }
    let truth = groundtruth::exact_topk_for(ds, &qids, k);
    QueryStream { d: ds.d, qbuf, qids, truth }
}

/// Recall@k of per-query results against exact truth rows.
pub fn recall_of(results: &[Vec<(f32, u32)>], truth: &[Vec<u32>], k: usize) -> f64 {
    assert_eq!(results.len(), truth.len());
    let mut hit = 0usize;
    let mut total = 0usize;
    for (got, want) in results.iter().zip(truth) {
        let t = k.min(want.len());
        if t == 0 {
            continue;
        }
        let want_set: std::collections::HashSet<u32> = want[..t].iter().copied().collect();
        hit += got.iter().take(k).filter(|&&(_, id)| want_set.contains(&id)).count();
        total += t;
    }
    if total == 0 {
        0.0
    } else {
        hit as f64 / total as f64
    }
}

/// `ef < k` silently caps the result pool at `k` inside beam search, so
/// a sub-`k` operating point would be mislabeled. Returns the effective
/// `ef` and whether clamping happened.
pub fn clamp_ef(ef: usize, k: usize) -> (usize, bool) {
    if ef < k {
        (k, true)
    } else {
        (ef, false)
    }
}

/// [`clamp_ef`] plus the operator-facing warning — the single place the
/// clamp message lives (used by both [`run_point`] and the sweep).
fn clamp_ef_warn(ef: usize, k: usize) -> usize {
    let (eff, clamped) = clamp_ef(ef, k);
    if clamped {
        telemetry::warn!(
            "serve: ef={ef} < k={k}; clamped to ef={eff} \
             (ef below k silently caps the result pool and recall)"
        );
    }
    eff
}

/// Linear-interpolated percentile of ascending seconds, in ms. The
/// previous nearest-rank rounding collapsed high percentiles onto the
/// max for small samples (p99 of 50 latencies *was* the max, silently),
/// which made tiny sweeps look tail-heavy; interpolation gives the
/// standard exclusive-of-nothing estimate for every n >= 1 and is
/// monotone in `p`, so `p99 >= p50` always holds.
fn percentile_ms(sorted_secs: &[f64], p: f64) -> f64 {
    if sorted_secs.is_empty() {
        return 0.0;
    }
    let pos = (p / 100.0).clamp(0.0, 1.0) * (sorted_secs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    (sorted_secs[lo] + (sorted_secs[hi] - sorted_secs[lo]) * frac) * 1e3
}

/// Deterministic open-loop arrival schedule: seconds-from-start of each
/// of `n` arrivals at offered rate `rate` qps. The first arrival is at
/// t = 0; Poisson gaps are exponential draws from a seeded [`Rng`], so
/// the same (n, rate, seed) triple replays the exact same schedule —
/// open-loop runs are as reproducible as everything else in the crate.
pub fn arrival_schedule(n: usize, rate: f64, arrival: Arrival, seed: u64) -> Vec<f64> {
    assert!(rate > 0.0 && rate.is_finite(), "arrival rate must be positive, got {rate}");
    match arrival {
        Arrival::Uniform => (0..n).map(|i| i as f64 / rate).collect(),
        Arrival::Poisson => {
            let mut rng = Rng::new(seed ^ 0xA221_7A1E);
            let mut t = 0.0f64;
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(t);
                // inverse-CDF exponential: u in [0,1) keeps 1-u in
                // (0,1], so the gap is finite and non-negative
                t += -(1.0 - rng.f64()).ln() / rate;
            }
            out
        }
    }
}

/// Measure one operating point (`ef`) of the sweep against any index
/// (traces, if sampling is configured, are discarded — see
/// [`run_point_traced`]).
pub fn run_point(
    index: &dyn AnnIndex,
    stream: &QueryStream,
    cfg: &ServeConfig,
    ef: usize,
) -> ServeStats {
    run_point_traced(index, stream, cfg, ef, &mut Vec::new())
}

/// [`run_point`], appending the timing pass's sampled [`QueryTrace`]s
/// (every `cfg.trace_sample`-th query; none when 0) to `traces` in
/// query order. The timing pass also feeds the global telemetry
/// registry: `query.service_us` per query and, open loop,
/// `query.queue_wait_us` per arrival.
pub fn run_point_traced(
    index: &dyn AnnIndex,
    stream: &QueryStream,
    cfg: &ServeConfig,
    ef: usize,
    traces: &mut Vec<QueryTrace>,
) -> ServeStats {
    let ef = clamp_ef_warn(ef, cfg.k);
    let threads = if cfg.threads == 0 { crate::util::num_threads() } else { cfg.threads };
    let exclude: Vec<u32> = stream.qids.iter().map(|&q| q as u32).collect();

    // ---- quality pass ----
    let results = BatchExecutor::new(index, threads).with_ef(ef).run_excluding(
        &stream.qbuf,
        stream.d,
        cfg.k,
        &exclude,
    );
    let recall = recall_of(&results, &stream.truth, cfg.k);

    // ---- timing pass (closed or open loop) ----
    let nq = stream.qids.len();
    let total = cfg.n_queries.max(nq);
    // open loop: arrival offsets (secs from pass start) per query index
    let sched: Option<Vec<f64>> = if cfg.arrival_rate > 0.0 {
        Some(arrival_schedule(total, cfg.arrival_rate, cfg.arrival, cfg.seed))
    } else {
        None
    };
    let cursor = AtomicUsize::new(0);
    let lat = Mutex::new(Vec::with_capacity(total));
    let qdelay = Mutex::new(Vec::with_capacity(if sched.is_some() { total } else { 0 }));
    let collected_traces = Mutex::new(Vec::new());
    let tot_evals = AtomicU64::new(0);
    let tot_hops = AtomicU64::new(0);
    let tot_rerank = AtomicU64::new(0);
    let tot_probe = AtomicU64::new(0);
    let h_service = telemetry::global().histogram("query.service_us");
    let h_queue = telemetry::global().histogram("query.queue_wait_us");
    // sheds observed by the timing pass only (the quality pass above
    // may also shed against a remote target; that shows up as recall
    // loss there, not in this column)
    let c_shed = telemetry::global().counter("client.shed_total");
    let shed_before = c_shed.get();
    let d = stream.d;
    let k = cfg.k;
    let trace_sample = cfg.trace_sample;
    let qbuf = stream.qbuf.as_slice();
    let exclude_ref = exclude.as_slice();
    let sched_ref = sched.as_deref();
    let wall = Timer::start();
    crossbeam_utils::thread::scope(|s| {
        for _ in 0..threads {
            let cursor = &cursor;
            let lat = &lat;
            let qdelay = &qdelay;
            let collected_traces = &collected_traces;
            let tot_evals = &tot_evals;
            let tot_hops = &tot_hops;
            let tot_rerank = &tot_rerank;
            let tot_probe = &tot_probe;
            let h_service = &h_service;
            let h_queue = &h_queue;
            let wall = &wall;
            s.spawn(move |_| {
                let mut scratch = index.make_scratch();
                let mut out = Vec::with_capacity(k);
                let mut local = Vec::new();
                let mut local_q = Vec::new();
                let mut local_traces = Vec::new();
                let mut local_evals = 0u64;
                let mut local_hops = 0u64;
                let mut local_rerank = 0u64;
                let mut local_probe = 0u64;
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let mut queue_secs = 0.0f64;
                    if let Some(sched) = sched_ref {
                        // open loop: the query *arrives* at sched[i]
                        // whether or not anyone is free to serve it. If
                        // this worker got here late, the lateness IS the
                        // queue delay — the number the closed loop can
                        // never show. If it got here early it parks
                        // until the arrival and the delay is zero by
                        // definition: the delay is sampled at *claim*
                        // time, so OS sleep overshoot (a load-generator
                        // artifact) never masquerades as queueing.
                        let due = sched[i];
                        let claimed = wall.secs();
                        if claimed < due {
                            loop {
                                let now = wall.secs();
                                if now >= due {
                                    break;
                                }
                                std::thread::sleep(std::time::Duration::from_secs_f64(
                                    due - now,
                                ));
                            }
                        } else {
                            queue_secs = claimed - due;
                        }
                        local_q.push(queue_secs);
                        h_queue.record(telemetry::us(queue_secs));
                    }
                    let traced = trace_sample > 0 && i % trace_sample == 0;
                    if traced {
                        scratch.trace.begin();
                    }
                    let qi = i % nq;
                    let t = Timer::start();
                    index.search_ef_into_excluding(
                        &qbuf[qi * d..(qi + 1) * d],
                        k,
                        ef,
                        exclude_ref[qi],
                        &mut scratch,
                        &mut out,
                    );
                    let service_secs = t.secs();
                    local.push(service_secs);
                    h_service.record(telemetry::us(service_secs));
                    local_evals += scratch.dist_evals as u64;
                    local_hops += scratch.hops as u64;
                    local_rerank += scratch.rerank_evals as u64;
                    local_probe += scratch.shards_probed as u64;
                    if traced {
                        scratch.trace.end();
                        local_traces.push(QueryTrace {
                            query: i,
                            ef,
                            queue_ms: queue_secs * 1e3,
                            service_ms: service_secs * 1e3,
                            route_ms: scratch.trace.route_ms,
                            gather_ms: scratch.trace.gather_ms,
                            dist_evals: scratch.dist_evals,
                            hops: scratch.hops,
                            shards: std::mem::take(&mut scratch.trace.shards),
                        });
                    }
                    std::hint::black_box(&out);
                }
                lat.lock().unwrap().extend_from_slice(&local);
                if !local_q.is_empty() {
                    qdelay.lock().unwrap().extend_from_slice(&local_q);
                }
                if !local_traces.is_empty() {
                    collected_traces.lock().unwrap().append(&mut local_traces);
                }
                tot_evals.fetch_add(local_evals, Ordering::Relaxed);
                tot_hops.fetch_add(local_hops, Ordering::Relaxed);
                tot_rerank.fetch_add(local_rerank, Ordering::Relaxed);
                tot_probe.fetch_add(local_probe, Ordering::Relaxed);
            });
        }
    })
    .unwrap();
    let wall_secs = wall.secs();
    let mut new_traces = collected_traces.into_inner().unwrap();
    new_traces.sort_by_key(|t| t.query);
    traces.append(&mut new_traces);
    let mut lats = lat.into_inner().unwrap();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut qdelays = qdelay.into_inner().unwrap();
    qdelays.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let qps = total as f64 / wall_secs.max(1e-9);
    let offered = cfg.arrival_rate;
    ServeStats {
        ef,
        qps,
        p50_ms: percentile_ms(&lats, 50.0),
        p95_ms: percentile_ms(&lats, 95.0),
        p99_ms: percentile_ms(&lats, 99.0),
        recall,
        offered_rate: offered,
        queue_p50_ms: percentile_ms(&qdelays, 50.0),
        queue_p99_ms: percentile_ms(&qdelays, 99.0),
        overload: offered > 0.0 && qps < OVERLOAD_MARGIN * offered,
        dist_evals: tot_evals.load(Ordering::Relaxed) as f64 / total as f64,
        hops: tot_hops.load(Ordering::Relaxed) as f64 / total as f64,
        rerank_evals: tot_rerank.load(Ordering::Relaxed) as f64 / total as f64,
        probe_mean: tot_probe.load(Ordering::Relaxed) as f64 / total as f64,
        shed: c_shed.get().saturating_sub(shed_before),
    }
}

/// Telemetry destinations of a sweep ([`run_sweep_with`]): sampled
/// query traces stream to a JSONL writer as each point finishes;
/// per-point registry snapshots accumulate in `metrics_points`.
#[derive(Default)]
pub struct ServeSinks {
    /// Destination for sampled [`QueryTrace`]s (`None` = discard).
    pub trace: Option<TraceWriter>,
    /// One entry per operating point, in sweep order: the row label,
    /// the cumulative registry [`telemetry::Snapshot`] taken after the
    /// point, and the delta against the previous point (the first
    /// point's delta is against the sweep's starting snapshot, so it
    /// isolates that point's own work).
    pub metrics_points: Vec<(String, telemetry::Snapshot, telemetry::Snapshot)>,
}

/// Run the whole `ef` sweep against an already-constructed index,
/// returning the recall-vs-QPS table. `ds` supplies the query stream
/// (sampled objects + exact ground truth) and must be the corpus the
/// index serves — for a sharded index, the un-split original dataset.
/// With `cfg.arrival_rate > 0` every point runs open loop and the rows
/// gain `rate` (offered), `queue_p50_ms`/`queue_p99_ms` and an
/// `overload` flag (1.0 = the point could not keep up).
pub fn run_sweep_on(
    index: &dyn AnnIndex,
    ds: &Dataset,
    cfg: &ServeConfig,
) -> crate::Result<Report> {
    run_sweep_with(index, ds, cfg, &mut ServeSinks::default())
}

/// [`run_sweep_on`] with explicit telemetry sinks: sampled traces are
/// appended (and flushed) to `sinks.trace` after every operating
/// point, and a cumulative + delta registry snapshot per point lands
/// in `sinks.metrics_points` — the `--metrics-out` payload.
pub fn run_sweep_with(
    index: &dyn AnnIndex,
    ds: &Dataset,
    cfg: &ServeConfig,
    sinks: &mut ServeSinks,
) -> crate::Result<Report> {
    anyhow::ensure!(!cfg.ef_sweep.is_empty(), "ef_sweep is empty");
    anyhow::ensure!(cfg.k > 0, "k must be > 0");
    anyhow::ensure!(
        cfg.arrival_rate >= 0.0 && cfg.arrival_rate.is_finite(),
        "arrival rate must be finite and >= 0"
    );
    anyhow::ensure!(
        index.len() == ds.len(),
        "index covers {} objects but query corpus has {}",
        index.len(),
        ds.len()
    );
    anyhow::ensure!(
        index.dim() == ds.d,
        "index dim {} != query corpus dim {}",
        index.dim(),
        ds.d
    );
    anyhow::ensure!(
        index.metric() == ds.metric,
        "index metric {} != query corpus metric {}",
        index.metric(),
        ds.metric
    );
    let stream = sample_queries(ds, cfg.distinct_queries, cfg.k, cfg.seed);
    let threads = if cfg.threads == 0 { crate::util::num_threads() } else { cfg.threads };
    let mut report = Report::new(format!("Serve bench: {}", ds.name))
        .meta("index", index.describe())
        .meta("n", ds.len())
        .meta("d", ds.d)
        .meta("k", cfg.k)
        .meta("threads", threads)
        .meta("entry", format!("{}x{}", cfg.params.n_entry, cfg.params.entry))
        .meta("queries", format!("{} distinct, {} replayed", stream.qids.len(), cfg.n_queries));
    if cfg.arrival_rate > 0.0 {
        report = report.meta(
            "arrival",
            format!("{} open loop @ {:.1} qps offered", cfg.arrival, cfg.arrival_rate),
        );
    } else {
        report = report.meta("arrival", "closed loop");
    }
    let recall_col = format!("recall@{}", cfg.k);
    // clamp sub-k points up front and dedupe: ef=2,4,8 at k=10 are all
    // the same operating point — measure (and report) it once
    let mut sweep: Vec<usize> = Vec::with_capacity(cfg.ef_sweep.len());
    for &ef in &cfg.ef_sweep {
        let eff = clamp_ef_warn(ef, cfg.k);
        if !sweep.contains(&eff) {
            sweep.push(eff);
        }
    }
    let mut prev = telemetry::global().snapshot();
    for &ef in &sweep {
        let mut traces = Vec::new();
        let s = run_point_traced(index, &stream, cfg, ef, &mut traces);
        if let Some(w) = sinks.trace.as_mut() {
            for t in &traces {
                w.append(t)?;
            }
            w.flush()?;
        }
        let snap = telemetry::global().snapshot();
        let delta = snap.delta(&prev);
        prev = snap.clone();
        sinks.metrics_points.push((format!("ef={}", s.ef), snap, delta));
        let mut row = Row::new(format!("ef={}", s.ef))
            .col("ef", s.ef as f64)
            .col("qps", s.qps)
            .col("p50_ms", s.p50_ms)
            .col("p95_ms", s.p95_ms)
            .col("p99_ms", s.p99_ms)
            .col("dist_evals", s.dist_evals)
            .col("hops", s.hops)
            .col("rerank_evals", s.rerank_evals)
            .col("probe_mean", s.probe_mean)
            .col("shed", s.shed as f64)
            .col(&recall_col, s.recall);
        if cfg.arrival_rate > 0.0 {
            row = row
                .col("rate", s.offered_rate)
                .col("queue_p50_ms", s.queue_p50_ms)
                .col("queue_p99_ms", s.queue_p99_ms)
                .col("overload", if s.overload { 1.0 } else { 0.0 });
        }
        report.push(row);
    }
    Ok(report)
}

/// Outcome of a [`capacity_search`].
#[derive(Clone, Debug)]
pub struct CapacityResult {
    /// Highest probed offered rate (qps) that met the SLO: not
    /// overloaded, accepted-query `queue_p99` within `slo_ms`, zero
    /// sheds. 0 when even the lowest probe failed.
    pub max_rate: f64,
    /// Closed-loop throughput that seeded the bisection bracket.
    pub closed_loop_qps: f64,
    /// One row per probed operating point, in probe order.
    pub report: Report,
}

/// `gnnd capacity`: binary-search the highest offered arrival rate
/// whose open-loop `queue_p99` stays under `slo_ms` (and which neither
/// overloads nor sheds — sheds only occur against a remote server's
/// admission control). A closed-loop point measures raw throughput
/// `C`, then `iters` open-loop probes bisect `[0, 1.25 C]` — the +25%
/// headroom lets the search prove an SLO-feasible rate *above* the
/// closed-loop estimate when queueing is cheap. Runs at the first `ef`
/// of `cfg.ef_sweep`; `cfg.arrival_rate` is ignored (each probe sets
/// its own).
pub fn capacity_search(
    index: &dyn AnnIndex,
    ds: &Dataset,
    cfg: &ServeConfig,
    slo_ms: f64,
    iters: usize,
) -> crate::Result<CapacityResult> {
    anyhow::ensure!(
        slo_ms > 0.0 && slo_ms.is_finite(),
        "slo_ms must be positive and finite, got {slo_ms}"
    );
    anyhow::ensure!(!cfg.ef_sweep.is_empty(), "ef_sweep is empty");
    anyhow::ensure!(cfg.k > 0, "k must be > 0");
    let ef = cfg.ef_sweep[0];
    let stream = sample_queries(ds, cfg.distinct_queries, cfg.k, cfg.seed);
    let mut closed_cfg = cfg.clone();
    closed_cfg.arrival_rate = 0.0;
    let closed = run_point(index, &stream, &closed_cfg, ef);
    let mut report = Report::new(format!("Capacity search: {}", ds.name))
        .meta("index", index.describe())
        .meta("ef", closed.ef)
        .meta("k", cfg.k)
        .meta("slo_ms", slo_ms)
        .meta("arrival", cfg.arrival.to_string())
        .meta("queries", format!("{} distinct, {} replayed", stream.qids.len(), cfg.n_queries));
    report.push(
        Row::new("closed")
            .col("rate", 0.0)
            .col("qps", closed.qps)
            .col("p99_ms", closed.p99_ms)
            .col("queue_p99_ms", 0.0)
            .col("shed", 0.0)
            .col("feasible", 1.0),
    );
    let feasible = |s: &ServeStats| !s.overload && s.queue_p99_ms <= slo_ms && s.shed == 0;
    // bisect on the highest feasible rate; `lo` is always known-good
    let mut lo = 0.0f64;
    let mut hi = closed.qps * 1.25;
    for i in 0..iters.max(1) {
        let rate = 0.5 * (lo + hi);
        let mut point_cfg = cfg.clone();
        point_cfg.arrival_rate = rate;
        let s = run_point(index, &stream, &point_cfg, ef);
        let ok = feasible(&s);
        report.push(
            Row::new(format!("probe{i}"))
                .col("rate", rate)
                .col("qps", s.qps)
                .col("p99_ms", s.p99_ms)
                .col("queue_p99_ms", s.queue_p99_ms)
                .col("shed", s.shed as f64)
                .col("feasible", if ok { 1.0 } else { 0.0 }),
        );
        if ok {
            lo = rate;
        } else {
            hi = rate;
        }
    }
    Ok(CapacityResult { max_rate: lo, closed_loop_qps: closed.qps, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::search::SearchScratch;

    /// A trait-only exact-scan index: serve.rs is written against
    /// [`AnnIndex`] alone, so its tests exercise the harness through a
    /// layout the module never heard of.
    struct Flat {
        ds: Dataset,
    }

    impl AnnIndex for Flat {
        fn len(&self) -> usize {
            self.ds.len()
        }

        fn dim(&self) -> usize {
            self.ds.d
        }

        fn metric(&self) -> crate::config::Metric {
            self.ds.metric
        }

        fn vector(&self, id: u32) -> Vec<f32> {
            self.ds.vec(id as usize).to_vec()
        }

        fn default_ef(&self) -> usize {
            10
        }

        fn describe(&self) -> String {
            "flat".into()
        }

        fn make_scratch(&self) -> SearchScratch {
            SearchScratch::new()
        }

        fn search_ef_into_excluding(
            &self,
            q: &[f32],
            k: usize,
            _ef: usize,
            exclude: u32,
            _scratch: &mut SearchScratch,
            out: &mut Vec<(f32, u32)>,
        ) {
            let mut all: Vec<(f32, u32)> = (0..self.ds.len() as u32)
                .filter(|&i| i != exclude)
                .map(|i| (self.ds.dist_to(i as usize, q), i))
                .collect();
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            out.clear();
            out.extend(all.into_iter().take(k));
        }
    }

    #[test]
    fn recall_of_exact_results_is_one() {
        let truth = vec![vec![1u32, 2, 3], vec![4, 5, 6]];
        let results = vec![
            vec![(0.1f32, 1u32), (0.2, 2), (0.3, 3)],
            vec![(0.1, 4), (0.2, 5), (0.3, 6)],
        ];
        assert!((recall_of(&results, &truth, 3) - 1.0).abs() < 1e-12);
        let miss = vec![
            vec![(0.1f32, 9u32), (0.2, 2), (0.3, 3)],
            vec![(0.1, 4), (0.2, 5), (0.3, 6)],
        ];
        assert!((recall_of(&miss, &truth, 3) - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates_instead_of_collapsing_onto_max() {
        // n = 1: every percentile is the single sample
        assert!((percentile_ms(&[0.010], 50.0) - 10.0).abs() < 1e-9);
        assert!((percentile_ms(&[0.010], 99.0) - 10.0).abs() < 1e-9);
        // n = 2: p50 is the midpoint, p99 interpolates toward (but does
        // not reach) the max — the nearest-rank bug this replaces
        // reported the max for both
        let two = [0.010, 0.020];
        assert!((percentile_ms(&two, 0.0) - 10.0).abs() < 1e-9);
        assert!((percentile_ms(&two, 50.0) - 15.0).abs() < 1e-9);
        assert!((percentile_ms(&two, 99.0) - 19.9).abs() < 1e-9);
        assert!((percentile_ms(&two, 100.0) - 20.0).abs() < 1e-9);
        // n = 100: 1..=100 ms ascending
        let hundred: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-3).collect();
        assert!((percentile_ms(&hundred, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile_ms(&hundred, 95.0) - 95.05).abs() < 1e-9);
        assert!((percentile_ms(&hundred, 99.0) - 99.01).abs() < 1e-9);
        assert!((percentile_ms(&hundred, 100.0) - 100.0).abs() < 1e-9);
        // empty stays 0 and p is monotone
        assert_eq!(percentile_ms(&[], 99.0), 0.0);
        assert!(percentile_ms(&hundred, 99.0) >= percentile_ms(&hundred, 50.0));
    }

    #[test]
    fn ef_below_k_is_clamped() {
        assert_eq!(clamp_ef(4, 10), (10, true));
        assert_eq!(clamp_ef(10, 10), (10, false));
        assert_eq!(clamp_ef(64, 10), (64, false));
        let ds = synth::uniform(80, 4, 7);
        let flat = Flat { ds };
        let stream = sample_queries(&flat.ds, 20, 10, 3);
        let cfg = ServeConfig {
            n_queries: 20,
            distinct_queries: 20,
            threads: 1,
            ..Default::default()
        };
        let s = run_point(&flat, &stream, &cfg, 4);
        assert_eq!(s.ef, 10, "ef < k must run (and report) at ef = k");
        assert!(s.recall > 0.999, "exact scan recall {}", s.recall);
        // closed loop: no offered rate, no queue, never overloaded
        assert_eq!(s.offered_rate, 0.0);
        assert_eq!(s.queue_p50_ms, 0.0);
        assert!(!s.overload);
    }

    #[test]
    fn sweep_rows_report_effective_ef() {
        let ds = synth::uniform(60, 4, 8);
        let corpus = ds.clone();
        let flat = Flat { ds };
        let cfg = ServeConfig {
            // 2 and 4 both clamp to k=10 -> one deduped ef=10 row
            ef_sweep: vec![2, 4, 16],
            n_queries: 10,
            distinct_queries: 10,
            threads: 1,
            ..Default::default()
        };
        let report = run_sweep_on(&flat, &corpus, &cfg).unwrap();
        assert_eq!(report.rows.len(), 2, "clamped duplicates must dedupe");
        assert_eq!(report.rows[0].label, "ef=10");
        assert_eq!(report.rows[1].label, "ef=16");
        let ef_of = |i: usize| report.rows[i].cols.iter().find(|(n, _)| n == "ef").unwrap().1;
        assert_eq!(ef_of(0), 10.0);
        assert_eq!(ef_of(1), 16.0);
        for row in &report.rows {
            let get = |name: &str| row.cols.iter().find(|(n, _)| n == name).unwrap().1;
            assert!(get("qps") > 0.0);
            assert!(get("p99_ms") >= get("p50_ms"));
            assert!((0.0..=1.0).contains(&get("recall@10")));
            // closed-loop rows carry no open-loop columns
            assert!(row.cols.iter().all(|(n, _)| n != "rate" && n != "overload"));
        }
    }

    #[test]
    fn open_loop_sweep_rows_carry_rate_queue_and_overload_columns() {
        let ds = synth::uniform(60, 4, 9);
        let corpus = ds.clone();
        let flat = Flat { ds };
        let cfg = ServeConfig {
            ef_sweep: vec![16],
            n_queries: 30,
            distinct_queries: 30,
            threads: 2,
            // far beyond a flat scan's capacity: the point must trip
            // the overload flag (and still finish — open loop never
            // drops queries, it queues them)
            arrival_rate: 1e9,
            ..Default::default()
        };
        let report = run_sweep_on(&flat, &corpus, &cfg).unwrap();
        assert_eq!(report.rows.len(), 1);
        let row = &report.rows[0];
        let get = |name: &str| row.cols.iter().find(|(n, _)| n == name).unwrap().1;
        assert_eq!(get("rate"), 1e9);
        assert!(get("queue_p99_ms") >= get("queue_p50_ms"));
        assert_eq!(get("overload"), 1.0, "1e9 qps offered must overload");
        assert!(get("qps") < 1e9);
    }

    #[test]
    fn trace_sampling_collects_every_nth_query() {
        let ds = synth::uniform(50, 4, 11);
        let flat = Flat { ds };
        let stream = sample_queries(&flat.ds, 10, 5, 3);
        let cfg = ServeConfig {
            k: 5,
            n_queries: 10,
            distinct_queries: 10,
            threads: 2,
            trace_sample: 3,
            ..Default::default()
        };
        let mut traces = Vec::new();
        let s = run_point_traced(&flat, &stream, &cfg, 16, &mut traces);
        // queries 0, 3, 6, 9 of the 10-query pass, in query order
        assert_eq!(traces.len(), 4, "{traces:?}");
        assert!(traces.windows(2).all(|w| w[0].query < w[1].query));
        for t in &traces {
            assert_eq!(t.query % 3, 0);
            assert_eq!(t.ef, 16);
            assert!(t.service_ms >= 0.0);
            assert_eq!(t.queue_ms, 0.0, "closed loop has no queue");
        }
        assert!(s.qps > 0.0);
        // run_point (the untraced wrapper) still works and reports means
        let s2 = run_point(&flat, &stream, &cfg, 16);
        assert_eq!(s2.ef, 16);
    }

    #[test]
    fn capacity_search_bisects_within_bracket_and_rejects_bad_slo() {
        let ds = synth::uniform(60, 4, 13);
        let corpus = ds.clone();
        let flat = Flat { ds };
        let cfg = ServeConfig {
            ef_sweep: vec![16],
            n_queries: 20,
            distinct_queries: 20,
            threads: 2,
            ..Default::default()
        };
        let cap = capacity_search(&flat, &corpus, &cfg, 50.0, 4).unwrap();
        assert!(cap.closed_loop_qps > 0.0);
        assert!(cap.max_rate >= 0.0);
        assert!(cap.max_rate <= cap.closed_loop_qps * 1.25);
        assert_eq!(cap.report.rows.len(), 5, "closed point + 4 probes");
        assert_eq!(cap.report.rows[0].label, "closed");
        assert!(capacity_search(&flat, &corpus, &cfg, 0.0, 2).is_err(), "slo 0 must be rejected");
        assert!(
            capacity_search(&flat, &corpus, &cfg, f64::NAN, 2).is_err(),
            "non-finite slo must be rejected"
        );
        // in-process serving never sheds: the column exists and is 0
        let s = run_point(&flat, &sample_queries(&corpus, 10, 10, 1), &cfg, 16);
        assert_eq!(s.shed, 0);
    }

    #[test]
    fn sweep_sinks_collect_per_point_snapshots_and_work_columns() {
        let ds = synth::uniform(60, 4, 12);
        let corpus = ds.clone();
        let flat = Flat { ds };
        let cfg = ServeConfig {
            ef_sweep: vec![16, 32],
            n_queries: 10,
            distinct_queries: 10,
            threads: 1,
            ..Default::default()
        };
        let mut sinks = ServeSinks::default();
        let report = run_sweep_with(&flat, &corpus, &cfg, &mut sinks).unwrap();
        assert_eq!(sinks.metrics_points.len(), 2);
        assert_eq!(sinks.metrics_points[0].0, "ef=16");
        assert_eq!(sinks.metrics_points[1].0, "ef=32");
        for row in &report.rows {
            for col in ["dist_evals", "hops", "rerank_evals", "probe_mean"] {
                assert!(row.cols.iter().any(|(n, _)| n == col), "row missing {col}");
            }
        }
        // the timing pass records a service-time histogram; each
        // point's delta holds (at least) its own timing-pass queries
        // (the registry is process-global, so only >= is assertable)
        let (_, cum, delta) = &sinks.metrics_points[1];
        let total = cfg.n_queries as u64;
        assert!(cum.hist("query.service_us").unwrap().count >= 2 * total);
        assert!(delta.hist("query.service_us").unwrap().count >= total);
    }
}
