//! Closed-loop serving harness: replay a query stream against any
//! [`AnnIndex`] and measure what a serving deployment cares about —
//! throughput (QPS), tail latency (p50/p95/p99) and quality (recall@k
//! against exact ground truth) — across an `ef` sweep, emitting a
//! [`Report`] of the recall-vs-QPS operating curve. The harness never
//! sees the index layout, so the same sweep produces the
//! monolithic-vs-sharded operating curves — including budget-
//! constrained sharded indexes, whose residency knobs
//! (`--memory-budget`, `--search-threads`) surface in the report's
//! `index` metadata via [`AnnIndex::describe`].
//!
//! Two passes per operating point:
//! 1. a *quality* pass through [`BatchExecutor`] computing recall@k;
//! 2. a *timing* pass where `threads` closed-loop workers pull query
//!    indices from a shared cursor (each with its own warm scratch)
//!    and record per-query wall latencies.
//!
//! Operating points with `ef < k` are clamped up to `k` (with a printed
//! warning): beam search caps the result pool at `max(ef, k)` anyway,
//! so a sub-`k` point would silently run — and be reported — at a
//! different `ef` than its label claims.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::dataset::{groundtruth, Dataset};
use crate::metrics::{Report, Row};
use crate::util::rng::Rng;
use crate::util::timer::Timer;

use super::batch::BatchExecutor;
use super::{AnnIndex, SearchParams};

/// Configuration of a serving benchmark.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Neighbors per query (recall is measured at this k).
    pub k: usize,
    /// `ef` operating points, one report row each (points below `k`
    /// clamp to `k`, see [`clamp_ef`]).
    pub ef_sweep: Vec<usize>,
    /// Total queries replayed per operating point (closed loop).
    pub n_queries: usize,
    /// Distinct query vectors sampled from the dataset (ground truth is
    /// computed for exactly these, so keep it moderate).
    pub distinct_queries: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Base search parameters; `ef` is overridden by the sweep.
    pub params: SearchParams,
    /// Query-sampling seed.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            k: 10,
            ef_sweep: vec![8, 16, 32, 64, 128],
            n_queries: 2_000,
            distinct_queries: 1_000,
            threads: 0,
            params: SearchParams::default(),
            seed: 0x5E27E,
        }
    }
}

/// Measured behaviour of one operating point. `ef` is the *effective*
/// width the point ran at (requested, clamped up to `k`).
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub ef: usize,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub recall: f64,
}

/// The sampled query stream: flat query matrix + the object ids the
/// rows came from (each query excludes itself from its results) + the
/// exact ground truth rows for recall.
pub struct QueryStream {
    pub d: usize,
    pub qbuf: Vec<f32>,
    pub qids: Vec<usize>,
    pub truth: Vec<Vec<u32>>,
}

/// Sample `m` distinct dataset objects as queries and compute their
/// exact top-`k` ground truth.
pub fn sample_queries(ds: &Dataset, m: usize, k: usize, seed: u64) -> QueryStream {
    let m = m.clamp(1, ds.len());
    let mut rng = Rng::new(seed ^ 0x9E27);
    let qids = rng.distinct(ds.len(), m);
    let mut qbuf = Vec::with_capacity(m * ds.d);
    for &q in &qids {
        qbuf.extend_from_slice(ds.vec(q));
    }
    let truth = groundtruth::exact_topk_for(ds, &qids, k);
    QueryStream { d: ds.d, qbuf, qids, truth }
}

/// Recall@k of per-query results against exact truth rows.
pub fn recall_of(results: &[Vec<(f32, u32)>], truth: &[Vec<u32>], k: usize) -> f64 {
    assert_eq!(results.len(), truth.len());
    let mut hit = 0usize;
    let mut total = 0usize;
    for (got, want) in results.iter().zip(truth) {
        let t = k.min(want.len());
        if t == 0 {
            continue;
        }
        let want_set: std::collections::HashSet<u32> = want[..t].iter().copied().collect();
        hit += got.iter().take(k).filter(|&&(_, id)| want_set.contains(&id)).count();
        total += t;
    }
    if total == 0 {
        0.0
    } else {
        hit as f64 / total as f64
    }
}

/// `ef < k` silently caps the result pool at `k` inside beam search, so
/// a sub-`k` operating point would be mislabeled. Returns the effective
/// `ef` and whether clamping happened.
pub fn clamp_ef(ef: usize, k: usize) -> (usize, bool) {
    if ef < k {
        (k, true)
    } else {
        (ef, false)
    }
}

/// [`clamp_ef`] plus the operator-facing warning — the single place the
/// clamp message lives (used by both [`run_point`] and the sweep).
fn clamp_ef_warn(ef: usize, k: usize) -> usize {
    let (eff, clamped) = clamp_ef(ef, k);
    if clamped {
        eprintln!(
            "[serve] warning: ef={ef} < k={k}; clamped to ef={eff} \
             (ef below k silently caps the result pool and recall)"
        );
    }
    eff
}

fn percentile_ms(sorted_secs: &[f64], p: f64) -> f64 {
    if sorted_secs.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_secs.len() - 1) as f64).round() as usize;
    sorted_secs[idx.min(sorted_secs.len() - 1)] * 1e3
}

/// Measure one operating point (`ef`) of the sweep against any index.
pub fn run_point(
    index: &dyn AnnIndex,
    stream: &QueryStream,
    cfg: &ServeConfig,
    ef: usize,
) -> ServeStats {
    let ef = clamp_ef_warn(ef, cfg.k);
    let threads = if cfg.threads == 0 { crate::util::num_threads() } else { cfg.threads };
    let exclude: Vec<u32> = stream.qids.iter().map(|&q| q as u32).collect();

    // ---- quality pass ----
    let results = BatchExecutor::new(index, threads).with_ef(ef).run_excluding(
        &stream.qbuf,
        stream.d,
        cfg.k,
        &exclude,
    );
    let recall = recall_of(&results, &stream.truth, cfg.k);

    // ---- closed-loop timing pass ----
    let nq = stream.qids.len();
    let total = cfg.n_queries.max(nq);
    let cursor = AtomicUsize::new(0);
    let lat = Mutex::new(Vec::with_capacity(total));
    let d = stream.d;
    let k = cfg.k;
    let qbuf = stream.qbuf.as_slice();
    let exclude_ref = exclude.as_slice();
    let wall = Timer::start();
    crossbeam_utils::thread::scope(|s| {
        for _ in 0..threads {
            let cursor = &cursor;
            let lat = &lat;
            s.spawn(move |_| {
                let mut scratch = index.make_scratch();
                let mut out = Vec::with_capacity(k);
                let mut local = Vec::new();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let qi = i % nq;
                    let t = Timer::start();
                    index.search_ef_into_excluding(
                        &qbuf[qi * d..(qi + 1) * d],
                        k,
                        ef,
                        exclude_ref[qi],
                        &mut scratch,
                        &mut out,
                    );
                    local.push(t.secs());
                    std::hint::black_box(&out);
                }
                lat.lock().unwrap().extend_from_slice(&local);
            });
        }
    })
    .unwrap();
    let wall_secs = wall.secs();
    let mut lats = lat.into_inner().unwrap();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());

    ServeStats {
        ef,
        qps: total as f64 / wall_secs.max(1e-9),
        p50_ms: percentile_ms(&lats, 50.0),
        p95_ms: percentile_ms(&lats, 95.0),
        p99_ms: percentile_ms(&lats, 99.0),
        recall,
    }
}

/// Run the whole `ef` sweep against an already-constructed index,
/// returning the recall-vs-QPS table. `ds` supplies the query stream
/// (sampled objects + exact ground truth) and must be the corpus the
/// index serves — for a sharded index, the un-split original dataset.
pub fn run_sweep_on(
    index: &dyn AnnIndex,
    ds: &Dataset,
    cfg: &ServeConfig,
) -> crate::Result<Report> {
    anyhow::ensure!(!cfg.ef_sweep.is_empty(), "ef_sweep is empty");
    anyhow::ensure!(cfg.k > 0, "k must be > 0");
    anyhow::ensure!(
        index.len() == ds.len(),
        "index covers {} objects but query corpus has {}",
        index.len(),
        ds.len()
    );
    anyhow::ensure!(
        index.dim() == ds.d,
        "index dim {} != query corpus dim {}",
        index.dim(),
        ds.d
    );
    anyhow::ensure!(
        index.metric() == ds.metric,
        "index metric {} != query corpus metric {}",
        index.metric(),
        ds.metric
    );
    let stream = sample_queries(ds, cfg.distinct_queries, cfg.k, cfg.seed);
    let threads = if cfg.threads == 0 { crate::util::num_threads() } else { cfg.threads };
    let mut report = Report::new(format!("Serve bench: {}", ds.name))
        .meta("index", index.describe())
        .meta("n", ds.len())
        .meta("d", ds.d)
        .meta("k", cfg.k)
        .meta("threads", threads)
        .meta("entry", format!("{}x{}", cfg.params.n_entry, cfg.params.entry))
        .meta("queries", format!("{} distinct, {} replayed", stream.qids.len(), cfg.n_queries));
    let recall_col = format!("recall@{}", cfg.k);
    // clamp sub-k points up front and dedupe: ef=2,4,8 at k=10 are all
    // the same operating point — measure (and report) it once
    let mut sweep: Vec<usize> = Vec::with_capacity(cfg.ef_sweep.len());
    for &ef in &cfg.ef_sweep {
        let eff = clamp_ef_warn(ef, cfg.k);
        if !sweep.contains(&eff) {
            sweep.push(eff);
        }
    }
    for &ef in &sweep {
        let s = run_point(index, &stream, cfg, ef);
        report.push(
            Row::new(format!("ef={}", s.ef))
                .col("ef", s.ef as f64)
                .col("qps", s.qps)
                .col("p50_ms", s.p50_ms)
                .col("p95_ms", s.p95_ms)
                .col("p99_ms", s.p99_ms)
                .col(&recall_col, s.recall),
        );
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::search::SearchScratch;

    /// A trait-only exact-scan index: serve.rs is written against
    /// [`AnnIndex`] alone, so its tests exercise the harness through a
    /// layout the module never heard of.
    struct Flat {
        ds: Dataset,
    }

    impl AnnIndex for Flat {
        fn len(&self) -> usize {
            self.ds.len()
        }

        fn dim(&self) -> usize {
            self.ds.d
        }

        fn metric(&self) -> crate::config::Metric {
            self.ds.metric
        }

        fn vector(&self, id: u32) -> Vec<f32> {
            self.ds.vec(id as usize).to_vec()
        }

        fn default_ef(&self) -> usize {
            10
        }

        fn describe(&self) -> String {
            "flat".into()
        }

        fn make_scratch(&self) -> SearchScratch {
            SearchScratch::new()
        }

        fn search_ef_into_excluding(
            &self,
            q: &[f32],
            k: usize,
            _ef: usize,
            exclude: u32,
            _scratch: &mut SearchScratch,
            out: &mut Vec<(f32, u32)>,
        ) {
            let mut all: Vec<(f32, u32)> = (0..self.ds.len() as u32)
                .filter(|&i| i != exclude)
                .map(|i| (self.ds.dist_to(i as usize, q), i))
                .collect();
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            out.clear();
            out.extend(all.into_iter().take(k));
        }
    }

    #[test]
    fn recall_of_exact_results_is_one() {
        let truth = vec![vec![1u32, 2, 3], vec![4, 5, 6]];
        let results = vec![
            vec![(0.1f32, 1u32), (0.2, 2), (0.3, 3)],
            vec![(0.1, 4), (0.2, 5), (0.3, 6)],
        ];
        assert!((recall_of(&results, &truth, 3) - 1.0).abs() < 1e-12);
        let miss = vec![
            vec![(0.1f32, 9u32), (0.2, 2), (0.3, 3)],
            vec![(0.1, 4), (0.2, 5), (0.3, 6)],
        ];
        assert!((recall_of(&miss, &truth, 3) - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn ef_below_k_is_clamped() {
        assert_eq!(clamp_ef(4, 10), (10, true));
        assert_eq!(clamp_ef(10, 10), (10, false));
        assert_eq!(clamp_ef(64, 10), (64, false));
        let ds = synth::uniform(80, 4, 7);
        let flat = Flat { ds };
        let stream = sample_queries(&flat.ds, 20, 10, 3);
        let cfg = ServeConfig {
            n_queries: 20,
            distinct_queries: 20,
            threads: 1,
            ..Default::default()
        };
        let s = run_point(&flat, &stream, &cfg, 4);
        assert_eq!(s.ef, 10, "ef < k must run (and report) at ef = k");
        assert!(s.recall > 0.999, "exact scan recall {}", s.recall);
    }

    #[test]
    fn sweep_rows_report_effective_ef() {
        let ds = synth::uniform(60, 4, 8);
        let corpus = ds.clone();
        let flat = Flat { ds };
        let cfg = ServeConfig {
            // 2 and 4 both clamp to k=10 -> one deduped ef=10 row
            ef_sweep: vec![2, 4, 16],
            n_queries: 10,
            distinct_queries: 10,
            threads: 1,
            ..Default::default()
        };
        let report = run_sweep_on(&flat, &corpus, &cfg).unwrap();
        assert_eq!(report.rows.len(), 2, "clamped duplicates must dedupe");
        assert_eq!(report.rows[0].label, "ef=10");
        assert_eq!(report.rows[1].label, "ef=16");
        let ef_of = |i: usize| report.rows[i].cols.iter().find(|(n, _)| n == "ef").unwrap().1;
        assert_eq!(ef_of(0), 10.0);
        assert_eq!(ef_of(1), 16.0);
        for row in &report.rows {
            let get = |name: &str| row.cols.iter().find(|(n, _)| n == name).unwrap().1;
            assert!(get("qps") > 0.0);
            assert!(get("p99_ms") >= get("p50_ms"));
            assert!((0.0..=1.0).contains(&get("recall@10")));
        }
    }
}
