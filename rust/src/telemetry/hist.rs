//! Fixed-bucket log2 histograms for work/latency distributions.
//!
//! [`BUCKETS`] = 65 buckets over `u64`: bucket 0 holds the value 0,
//! bucket `i` (1..=63) holds `[2^(i-1), 2^i - 1]`, bucket 64 holds
//! everything from `2^63` up. Log2 bucketing keeps
//! [`Histogram::record`] allocation-free and O(1) — one atomic add per
//! observation — while resolving order of magnitude from 1 µs to
//! hours, which is what a latency/work distribution needs. Exact
//! `count`/`sum`/`max` ride along, so means are exact even though
//! percentiles are bucket-resolution upper bounds.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

use super::{stripe_index, STRIPES};

/// Bucket count: the zero bucket, 63 power-of-two ranges, overflow top.
pub const BUCKETS: usize = 65;

/// Bucket index of `v`: 0 for 0, else `64 - leading_zeros(v)`.
pub fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i`.
pub fn bucket_upper(i: usize) -> u64 {
    match i {
        0 => 0,
        1..=63 => (1u64 << i) - 1,
        _ => u64::MAX,
    }
}

struct Stripe {
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Stripe {
    fn new() -> Self {
        Stripe {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Thread-striped log2 histogram; see the module doc for the layout.
/// Obtain through [`super::MetricsRegistry::histogram`].
pub struct Histogram {
    stripes: Vec<Stripe>,
}

impl Histogram {
    pub(crate) fn new() -> Self {
        Histogram { stripes: (0..STRIPES).map(|_| Stripe::new()).collect() }
    }

    /// Record one observation (Relaxed, on this thread's stripe).
    pub fn record(&self, v: u64) {
        let s = &self.stripes[stripe_index()];
        s.count.fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(v, Ordering::Relaxed);
        s.max.fetch_max(v, Ordering::Relaxed);
        s.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time merge of all stripes.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut out = HistSnapshot::default();
        for s in &self.stripes {
            out.count += s.count.load(Ordering::Relaxed);
            out.sum += s.sum.load(Ordering::Relaxed);
            out.max = out.max.max(s.max.load(Ordering::Relaxed));
            for (b, v) in out.buckets.iter_mut().zip(&s.buckets) {
                *b += v.load(Ordering::Relaxed);
            }
        }
        out
    }
}

/// Owned, mergeable copy of a [`Histogram`]'s state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    /// [`BUCKETS`] entries, indexed by [`bucket_of`].
    pub buckets: Vec<u64>,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { count: 0, sum: 0, max: 0, buckets: vec![0; BUCKETS] }
    }
}

impl HistSnapshot {
    /// Exact mean of the recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Bucket-wise addition of `other` into `self`.
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        for (b, v) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += v;
        }
    }

    /// What was recorded since `prev` (bucket-wise subtraction). `max`
    /// keeps the lifetime max: a window max is not recoverable from
    /// two cumulative snapshots.
    pub fn delta(&self, prev: &HistSnapshot) -> HistSnapshot {
        let mut out = self.clone();
        out.count = self.count.saturating_sub(prev.count);
        out.sum = self.sum.saturating_sub(prev.sum);
        for (b, p) in out.buckets.iter_mut().zip(&prev.buckets) {
            *b = b.saturating_sub(*p);
        }
        out
    }

    /// Upper bound of the bucket holding the `p`-th percentile
    /// observation (nearest rank over buckets) — a log2-resolution
    /// upper estimate, monotone in `p`. 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0).clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= rank {
                return bucket_upper(i);
            }
        }
        self.max
    }

    /// Scalar stats plus sparse `[bucket_index, count]` pairs — empty
    /// buckets are elided so a 65-bucket histogram stays a short line.
    pub fn to_json(&self) -> Json {
        let pairs: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::from(i), Json::from(c)]))
            .collect();
        Json::obj()
            .set("count", self.count)
            .set("sum", self.sum)
            .set("mean", self.mean())
            .set("max", self.max)
            .set("p50", self.percentile(50.0))
            .set("p99", self.percentile(99.0))
            .set("buckets", Json::Arr(pairs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(7), 3);
        assert_eq!(bucket_of(8), 4);
        assert_eq!(bucket_of((1 << 63) - 1), 63);
        assert_eq!(bucket_of(1 << 63), 64);
        assert_eq!(bucket_of(u64::MAX), 64);
        // every bucket's upper bound lands back in that bucket
        for i in 0..BUCKETS {
            assert_eq!(bucket_of(bucket_upper(i)), i, "bucket {i}");
        }
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(64), u64::MAX);
    }

    #[test]
    fn record_snapshot_mean_max() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1006);
        assert_eq!(s.max, 1000);
        assert!((s.mean() - 201.2).abs() < 1e-9);
        assert_eq!(s.buckets[0], 1); // 0
        assert_eq!(s.buckets[1], 1); // 1
        assert_eq!(s.buckets[2], 2); // 2, 3
        assert_eq!(s.buckets[10], 1); // 1000 in [512, 1023]
    }

    #[test]
    fn percentile_walks_buckets() {
        let mut s = HistSnapshot::default();
        // 50x value 1, 49x value ~1000, 1x value ~100000
        s.buckets[1] = 50;
        s.buckets[10] = 49;
        s.buckets[17] = 1;
        s.count = 100;
        s.max = 100_000;
        assert_eq!(s.percentile(0.0), 1);
        assert_eq!(s.percentile(50.0), 1);
        assert_eq!(s.percentile(51.0), 1023);
        assert_eq!(s.percentile(99.0), 1023);
        assert_eq!(s.percentile(100.0), (1 << 17) - 1);
        assert!(s.percentile(99.0) >= s.percentile(50.0));
        assert_eq!(HistSnapshot::default().percentile(99.0), 0);
    }

    #[test]
    fn merge_and_delta_are_bucketwise() {
        let a = Histogram::new();
        a.record(1);
        a.record(100);
        let b = Histogram::new();
        b.record(100);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 3);
        assert_eq!(m.sum, 201);
        assert_eq!(m.buckets[bucket_of(100)], 2);

        let before = a.snapshot();
        a.record(7);
        let d = a.snapshot().delta(&before);
        assert_eq!(d.count, 1);
        assert_eq!(d.sum, 7);
        assert_eq!(d.buckets[bucket_of(7)], 1);
        assert_eq!(d.buckets[bucket_of(100)], 0);
    }

    #[test]
    fn json_is_sparse_and_parseable() {
        let h = Histogram::new();
        h.record(5);
        h.record(5);
        let j = h.snapshot().to_json();
        assert_eq!(j.get("count").and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.get("sum").and_then(Json::as_f64), Some(10.0));
        assert_eq!(j.get("mean").and_then(Json::as_f64), Some(5.0));
        let pairs = j.get("buckets").and_then(Json::as_arr).unwrap();
        assert_eq!(pairs.len(), 1, "only the populated bucket is emitted");
        let pair = pairs[0].as_arr().unwrap();
        assert_eq!(pair[0].as_usize(), Some(bucket_of(5)));
        assert_eq!(pair[1].as_usize(), Some(2));
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }
}
