//! Sampled per-query traces: the full scatter-gather timeline of one
//! served query, appended as JSONL (`--trace-sample N` traces every
//! Nth query of the timing pass). `gnnd trace <file>` renders the
//! aggregate distributions and the slowest queries' span timelines.
//!
//! # `traces.jsonl` record format
//!
//! One JSON object per line, one line per sampled query:
//!
//! ```text
//! field          type   meaning
//! query          int    index of the query in the replayed stream
//! ef             int    effective beam width the query ran at
//! queue_ms       float  open-loop queue delay (arrival -> claim); 0 closed loop
//! service_ms     float  wall time of the search call itself
//! route_ms       float  centroid routing (sharded index; 0 monolithic)
//! gather_ms      float  merge of per-shard top-k lists (0 monolithic)
//! dist_evals     int    distance evaluations across all probed shards
//! hops           int    beam-search hops across all probed shards
//! shards         array  per-shard spans, sorted by shard id:
//!   .shard          int    shard index
//!   .wait_ms        float  pin wait (home-shard resolve, incl. faulting)
//!   .search_ms      float  wall time of this shard's walk
//!   .dist_evals     int    distance evaluations inside this shard
//!   .hops           int    hops inside this shard
//!   .block_fetches  int    block-cache misses faulted from disk
//!   .block_hits     int    block-cache hits
//! ```
//!
//! Tracing is observation-only: a traced query returns bit-identical
//! results to an untraced one (`tests/telemetry.rs` proves it across
//! the probe × budget × threads grid), so spans never lie about the
//! work the untraced path would have done.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::json::Json;

/// Per-shard section of a [`QueryTrace`]; field meanings in the module
/// doc's format table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardSpan {
    pub shard: usize,
    pub wait_ms: f64,
    pub search_ms: f64,
    pub dist_evals: usize,
    pub hops: usize,
    pub block_fetches: u64,
    pub block_hits: u64,
}

impl ShardSpan {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("shard", self.shard)
            .set("wait_ms", self.wait_ms)
            .set("search_ms", self.search_ms)
            .set("dist_evals", self.dist_evals)
            .set("hops", self.hops)
            .set("block_fetches", self.block_fetches)
            .set("block_hits", self.block_hits)
    }

    fn from_json(j: &Json) -> crate::Result<ShardSpan> {
        let num = |k: &str| {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow::anyhow!("span missing {k:?}"))
        };
        Ok(ShardSpan {
            shard: num("shard")? as usize,
            wait_ms: num("wait_ms")?,
            search_ms: num("search_ms")?,
            dist_evals: num("dist_evals")? as usize,
            hops: num("hops")? as usize,
            block_fetches: num("block_fetches")? as u64,
            block_hits: num("block_hits")? as u64,
        })
    }
}

/// One sampled query's timeline; see the module doc's format table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QueryTrace {
    pub query: usize,
    pub ef: usize,
    pub queue_ms: f64,
    pub service_ms: f64,
    pub route_ms: f64,
    pub gather_ms: f64,
    pub dist_evals: usize,
    pub hops: usize,
    pub shards: Vec<ShardSpan>,
}

impl QueryTrace {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("query", self.query)
            .set("ef", self.ef)
            .set("queue_ms", self.queue_ms)
            .set("service_ms", self.service_ms)
            .set("route_ms", self.route_ms)
            .set("gather_ms", self.gather_ms)
            .set("dist_evals", self.dist_evals)
            .set("hops", self.hops)
            .set("shards", Json::Arr(self.shards.iter().map(ShardSpan::to_json).collect()))
    }

    pub fn from_json(j: &Json) -> crate::Result<QueryTrace> {
        let num = |k: &str| {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow::anyhow!("trace missing {k:?}"))
        };
        let shards = j
            .get("shards")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("trace missing \"shards\""))?
            .iter()
            .map(ShardSpan::from_json)
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(QueryTrace {
            query: num("query")? as usize,
            ef: num("ef")? as usize,
            queue_ms: num("queue_ms")?,
            service_ms: num("service_ms")?,
            route_ms: num("route_ms")?,
            gather_ms: num("gather_ms")?,
            dist_evals: num("dist_evals")? as usize,
            hops: num("hops")? as usize,
            shards,
        })
    }
}

/// Per-scratch trace collection point, embedded in
/// [`crate::search::SearchScratch`]. The serve harness arms it per
/// sampled query ([`begin`](TraceSink::begin)), the index
/// implementations fill it, the harness harvests it into a
/// [`QueryTrace`]. Disabled (the default), every instrumentation site
/// is a single branch — and armed or not, the sink never influences
/// results.
#[derive(Debug, Default)]
pub struct TraceSink {
    /// Collect spans for the current query.
    pub enabled: bool,
    /// Centroid routing time (set by the sharded index).
    pub route_ms: f64,
    /// Top-k merge time across shard lists.
    pub gather_ms: f64,
    /// One span per probed shard.
    pub shards: Vec<ShardSpan>,
}

impl TraceSink {
    /// Arm for the next query, clearing the previous query's spans.
    pub fn begin(&mut self) {
        self.enabled = true;
        self.clear();
    }

    /// Disarm (after harvesting into a [`QueryTrace`]).
    pub fn end(&mut self) {
        self.enabled = false;
    }

    pub fn clear(&mut self) {
        self.route_ms = 0.0;
        self.gather_ms = 0.0;
        self.shards.clear();
    }
}

/// Append-only JSONL writer for sampled traces.
pub struct TraceWriter {
    w: BufWriter<File>,
    path: PathBuf,
    written: usize,
}

impl TraceWriter {
    /// Open `path` for appending, creating it if absent.
    pub fn append_to(path: impl AsRef<Path>) -> crate::Result<TraceWriter> {
        let path = path.as_ref().to_path_buf();
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("open trace file {}", path.display()))?;
        Ok(TraceWriter { w: BufWriter::new(f), path, written: 0 })
    }

    pub fn append(&mut self, t: &QueryTrace) -> crate::Result<()> {
        writeln!(self.w, "{}", t.to_json())
            .with_context(|| format!("append trace to {}", self.path.display()))?;
        self.written += 1;
        Ok(())
    }

    pub fn flush(&mut self) -> crate::Result<()> {
        self.w.flush().with_context(|| format!("flush trace file {}", self.path.display()))
    }

    /// Traces appended through this writer (not lines already in the file).
    pub fn written(&self) -> usize {
        self.written
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Parse a `traces.jsonl` file, one [`QueryTrace`] per non-empty line.
pub fn read_traces(path: impl AsRef<Path>) -> crate::Result<Vec<QueryTrace>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read trace file {}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(line)
            .and_then(|j| QueryTrace::from_json(&j))
            .with_context(|| format!("{}:{}", path.display(), ln + 1))?;
        out.push(parsed);
    }
    Ok(out)
}

/// Linear-interpolated percentile of ascending values (0 when empty).
fn pctl(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
}

fn dist_line(out: &mut String, name: &str, values: &mut [f64]) {
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = if values.is_empty() {
        0.0
    } else {
        values.iter().sum::<f64>() / values.len() as f64
    };
    let max = values.last().copied().unwrap_or(0.0);
    out.push_str(&format!(
        "{name:<16} {mean:>10.3} {p50:>10.3} {p99:>10.3} {max:>10.3}\n",
        p50 = pctl(values, 50.0),
        p99 = pctl(values, 99.0),
    ));
}

/// Human-readable report over parsed traces: exact aggregate
/// distributions (these are the sampled values themselves, not log2
/// buckets) plus the span timeline of the `top` slowest queries.
pub fn render_report(traces: &[QueryTrace], top: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("{} sampled queries\n\n", traces.len()));
    out.push_str(&format!(
        "{:<16} {:>10} {:>10} {:>10} {:>10}\n",
        "metric", "mean", "p50", "p99", "max"
    ));
    let mut col = |name: &str, f: &dyn Fn(&QueryTrace) -> f64| {
        let mut v: Vec<f64> = traces.iter().map(f).collect();
        dist_line(&mut out, name, &mut v);
    };
    col("service_ms", &|t| t.service_ms);
    col("queue_ms", &|t| t.queue_ms);
    col("route_ms", &|t| t.route_ms);
    col("gather_ms", &|t| t.gather_ms);
    col("dist_evals", &|t| t.dist_evals as f64);
    col("hops", &|t| t.hops as f64);
    col("block_fetches", &|t| {
        t.shards.iter().map(|s| s.block_fetches).sum::<u64>() as f64
    });
    col("block_hits", &|t| t.shards.iter().map(|s| s.block_hits).sum::<u64>() as f64);

    let mut slowest: Vec<&QueryTrace> = traces.iter().collect();
    slowest.sort_by(|a, b| {
        b.service_ms.partial_cmp(&a.service_ms).unwrap().then(a.query.cmp(&b.query))
    });
    slowest.truncate(top);
    out.push_str(&format!("\nslowest {} queries:\n", slowest.len()));
    for t in slowest {
        out.push_str(&format!(
            "#{} ef={}: queue {:.3} ms | route {:.3} ms | {} shard spans | gather {:.3} ms \
             | service {:.3} ms, {} evals, {} hops\n",
            t.query,
            t.ef,
            t.queue_ms,
            t.route_ms,
            t.shards.len(),
            t.gather_ms,
            t.service_ms,
            t.dist_evals,
            t.hops
        ));
        for s in &t.shards {
            out.push_str(&format!(
                "  shard {}: wait {:.3} ms, search {:.3} ms, {} evals, {} hops, \
                 blocks {} fetched / {} hit\n",
                s.shard, s.wait_ms, s.search_ms, s.dist_evals, s.hops, s.block_fetches,
                s.block_hits
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(q: usize, service_ms: f64) -> QueryTrace {
        QueryTrace {
            query: q,
            ef: 32,
            queue_ms: 0.25,
            service_ms,
            route_ms: 0.01,
            gather_ms: 0.02,
            dist_evals: 120,
            hops: 9,
            shards: vec![
                ShardSpan {
                    shard: 0,
                    wait_ms: 0.05,
                    search_ms: service_ms / 2.0,
                    dist_evals: 70,
                    hops: 5,
                    block_fetches: 3,
                    block_hits: 11,
                },
                ShardSpan {
                    shard: 2,
                    wait_ms: 0.0,
                    search_ms: service_ms / 3.0,
                    dist_evals: 50,
                    hops: 4,
                    block_fetches: 0,
                    block_hits: 14,
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let t = sample(7, 1.5);
        let text = t.to_json().to_string();
        let back = QueryTrace::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let j = Json::parse("{\"query\":1}").unwrap();
        assert!(QueryTrace::from_json(&j).is_err());
    }

    #[test]
    fn writer_appends_and_reader_parses() {
        let dir = std::env::temp_dir().join(format!(
            "gnnd-trace-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("traces.jsonl");
        let mut w = TraceWriter::append_to(&path).unwrap();
        w.append(&sample(0, 1.0)).unwrap();
        w.append(&sample(4, 3.0)).unwrap();
        assert_eq!(w.written(), 2);
        w.flush().unwrap();
        drop(w);
        // append mode: a second writer extends the same file
        let mut w = TraceWriter::append_to(&path).unwrap();
        w.append(&sample(8, 2.0)).unwrap();
        w.flush().unwrap();
        drop(w);
        let got = read_traces(&path).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], sample(0, 1.0));
        assert_eq!(got[2].query, 8);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn sink_begin_clears_previous_query() {
        let mut sink = TraceSink::default();
        assert!(!sink.enabled);
        sink.begin();
        sink.shards.push(ShardSpan { shard: 1, ..Default::default() });
        sink.route_ms = 9.0;
        sink.end();
        assert!(!sink.enabled);
        sink.begin();
        assert!(sink.enabled);
        assert!(sink.shards.is_empty());
        assert_eq!(sink.route_ms, 0.0);
    }

    #[test]
    fn report_ranks_slowest_and_prints_spans() {
        let traces = vec![sample(0, 1.0), sample(4, 3.0), sample(8, 2.0)];
        let r = render_report(&traces, 2);
        assert!(r.contains("3 sampled queries"), "{r}");
        assert!(r.contains("slowest 2 queries"), "{r}");
        // slowest first, and only `top` of them
        let q4 = r.find("#4 ").unwrap();
        let q8 = r.find("#8 ").unwrap();
        assert!(q4 < q8, "{r}");
        assert!(!r.contains("#0 "), "{r}");
        assert!(r.contains("shard 2:"), "{r}");
        for m in ["service_ms", "dist_evals", "block_fetches"] {
            assert!(r.contains(m), "missing {m}: {r}");
        }
    }
}
