//! Query-path telemetry: a process-wide [`MetricsRegistry`] of named
//! counters, gauges and log2 work/latency histograms ([`hist`]), plus
//! sampled per-query traces ([`trace`]). The paper's central argument
//! is *counting work* — memory accesses and distance evaluations are
//! the costs its GPU redesign minimizes — so the serving stack reports
//! the same counters live instead of only as end-of-run aggregates.
//!
//! Design constraints, in order:
//!
//! 1. **No hot-path contention.** Counters and histograms are striped
//!    across [`STRIPES`] cache-padded atomics; each thread bumps its
//!    own stripe (Relaxed ordering), so the scatter pool and the serve
//!    workers never fight over a line. The registry's map lock is
//!    taken only at registration — instrumented subsystems cache
//!    `Arc` handles at construction time.
//! 2. **Observation only.** Nothing in this module may influence query
//!    results; tracing on vs off is bit-identical (proven by
//!    `tests/telemetry.rs` across the probe × budget × threads grid).
//! 3. **Same export path as everything else.** [`Snapshot::to_json`]
//!    produces [`crate::util::json::Json`], so snapshots fold into the
//!    shard directory's `stats.json` through the existing
//!    `save_stats_with_block` and print as JSONL for `--metrics-out`.
//!
//! Registered names (the README "Observability" section carries the
//! same table):
//!
//! | name | kind | meaning |
//! |---|---|---|
//! | `query.count` | counter | queries served through any index |
//! | `query.dist_evals` | histogram | distance evaluations per query |
//! | `query.hops` | histogram | beam-search hops per query |
//! | `query.rerank_evals` | histogram | exact f32 re-scores per query (quantized two-phase) |
//! | `quant.bytes_saved` | counter | bytes kept off the heap by u8 codes vs f32 rows |
//! | `pq.bytes_saved` | counter | bytes kept off the heap by PQ codes vs f32 rows |
//! | `query.lut_build_us` | counter | cumulative µs building per-query ADC lookup tables |
//! | `query.service_us` | histogram | search wall time per query (µs) |
//! | `query.queue_wait_us` | histogram | open-loop queue delay (µs) |
//! | `scatter.jobs` | counter | scatter-gather jobs dispatched |
//! | `scatter.queue_depth` | gauge | jobs waiting in the pool queue |
//! | `scatter.worker{N}.busy_us` | counter | per-worker time running jobs |
//! | `scatter.worker{N}.idle_us` | counter | per-worker time blocked on the queue |
//! | `block_cache.hits` | counter | block reads served from cache |
//! | `block_cache.fetches` | counter | block reads faulted from disk |
//! | `block_cache.evictions` | counter | blocks evicted under budget |
//! | `block_cache.rejected_admissions` | counter | one-shot blocks the doorkeeper kept out |
//! | `block_cache.bytes_read` | counter | bytes faulted from disk |
//! | `block_cache.resident_bytes` | gauge | bytes currently cached |
//! | `shard_cache.hits` / `.misses` / `.evictions` / `.rejected_admissions` / `.bytes_read` | counter | whole-shard residency, same meanings |
//! | `server.accepted` | counter | requests admitted by the TCP front end |
//! | `server.shed_total` | counter | requests shed with `Overloaded` (queue at `--queue-limit`) |
//! | `server.connections` | counter | TCP connections accepted |
//! | `server.coalesced_batch_size` | histogram | queries per coalesced executor batch |
//! | `server.queue_wait_us` | histogram | pending-queue wait per admitted query (µs) |
//! | `client.shed_total` | counter | `Overloaded` responses a `RemoteIndex` client observed |
//! | `warnings_total` | counter | operator warnings emitted ([`warn!`]) |

pub mod hist;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crossbeam_utils::CachePadded;

use crate::util::json::Json;

pub use hist::{HistSnapshot, Histogram};

/// Stripes per counter/histogram: enough that the scatter workers and
/// serve threads (both bounded by core count) rarely share one.
pub(crate) const STRIPES: usize = 16;

/// Stable per-thread stripe assignment, round-robin at first use.
pub(crate) fn stripe_index() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static STRIPE: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    STRIPE.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed) % STRIPES;
            s.set(v);
        }
        v
    })
}

/// Monotone event counter, striped across cache lines.
pub struct Counter {
    stripes: Vec<CachePadded<AtomicU64>>,
}

impl Counter {
    fn new() -> Self {
        Counter { stripes: (0..STRIPES).map(|_| CachePadded::new(AtomicU64::new(0))).collect() }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.stripes[stripe_index()].fetch_add(n, Ordering::Relaxed);
    }

    /// Point-in-time total across all stripes.
    pub fn get(&self) -> u64 {
        self.stripes.iter().map(|s| s.load(Ordering::Relaxed)).sum()
    }
}

/// Instantaneous signed value (queue depth, resident bytes). A single
/// atomic: gauges are set/adjusted at queue transitions, not in the
/// per-distance hot path.
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    fn new() -> Self {
        Gauge { v: AtomicI64::new(0) }
    }

    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<Histogram>),
}

/// Named metrics, registered on first use. The map lock guards only
/// registration/lookup and [`snapshot`](MetricsRegistry::snapshot) —
/// hot paths hold `Arc` handles and never touch it.
#[derive(Default)]
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Counter handle for `name`, registering it on first use. Panics
    /// if `name` is already registered as a different metric kind —
    /// that is a naming bug, not a runtime condition.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("metric {name:?} is not a counter"),
        }
    }

    /// Gauge handle for `name` (see [`counter`](Self::counter)).
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new()))) {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("metric {name:?} is not a gauge"),
        }
    }

    /// Histogram handle for `name` (see [`counter`](Self::counter)).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Hist(Arc::new(Histogram::new())))
        {
            Metric::Hist(h) => Arc::clone(h),
            _ => panic!("metric {name:?} is not a histogram"),
        }
    }

    /// Point-in-time copy of every registered metric, ordered by name.
    pub fn snapshot(&self) -> Snapshot {
        let m = self.metrics.lock().unwrap();
        let mut snap = Snapshot::default();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => snap.counters.push((name.clone(), c.get())),
                Metric::Gauge(g) => snap.gauges.push((name.clone(), g.get())),
                Metric::Hist(h) => snap.hists.push((name.clone(), h.snapshot())),
            }
        }
        snap
    }
}

/// The process-wide registry every instrumented subsystem reports to.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// Point-in-time values of every metric in a registry, ordered by name.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<(String, HistSnapshot)>,
}

impl Snapshot {
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<i64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// What happened since `prev`: counters and histograms subtract
    /// (metrics absent from `prev` count from zero); gauges keep their
    /// current instantaneous value — a gauge has no "since".
    pub fn delta(&self, prev: &Snapshot) -> Snapshot {
        let counters = self
            .counters
            .iter()
            .map(|(n, v)| (n.clone(), v.saturating_sub(prev.counter(n).unwrap_or(0))))
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(n, h)| {
                let d = match prev.hist(n) {
                    Some(p) => h.delta(p),
                    None => h.clone(),
                };
                (n.clone(), d)
            })
            .collect();
        Snapshot { counters, gauges: self.gauges.clone(), hists }
    }

    /// `{"counters": {...}, "gauges": {...}, "histograms": {...}}`.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (n, v) in &self.counters {
            counters = counters.set(n, *v);
        }
        let mut gauges = Json::obj();
        for (n, v) in &self.gauges {
            gauges = gauges.set(n, *v);
        }
        let mut hists = Json::obj();
        for (n, h) in &self.hists {
            hists = hists.set(n, h.to_json());
        }
        Json::obj().set("counters", counters).set("gauges", gauges).set("histograms", hists)
    }
}

/// Format + route a message through [`emit_warning`]: one `[warn]`
/// prefix and one `warnings_total` counter for every warning site.
#[macro_export]
macro_rules! tele_warn {
    ($($arg:tt)*) => {
        $crate::telemetry::emit_warning(&format!($($arg)*))
    };
}
pub use crate::tele_warn as warn;

fn warn_counter() -> &'static Arc<Counter> {
    static C: OnceLock<Arc<Counter>> = OnceLock::new();
    C.get_or_init(|| global().counter("warnings_total"))
}

/// Print an operator-facing warning with the uniform `[warn]` prefix
/// and count it. Use through [`warn!`].
pub fn emit_warning(msg: &str) {
    warn_counter().inc();
    eprintln!("[warn] {msg}");
}

/// Total warnings this process has emitted so far.
pub fn warnings_total() -> u64 {
    warn_counter().get()
}

struct QueryMetrics {
    queries: Arc<Counter>,
    dist_evals: Arc<Histogram>,
    hops: Arc<Histogram>,
    rerank_evals: Arc<Histogram>,
}

fn query_metrics() -> &'static QueryMetrics {
    static M: OnceLock<QueryMetrics> = OnceLock::new();
    M.get_or_init(|| QueryMetrics {
        queries: global().counter("query.count"),
        dist_evals: global().histogram("query.dist_evals"),
        hops: global().histogram("query.hops"),
        rerank_evals: global().histogram("query.rerank_evals"),
    })
}

/// Record one served query's work counters — the paper's scanning-rate
/// metric — into the global registry. Called by the [`crate::search::AnnIndex`]
/// query entry points, *not* by raw beam search: the same walk runs
/// inside graph construction, which must not pollute serving metrics.
/// On a quantized index `dist_evals` counts cheap code-space
/// evaluations and `rerank_evals` the full-precision re-scores; their
/// ratio is the two-phase speedup argument, so both are exported.
pub fn record_query(dist_evals: usize, hops: usize, rerank_evals: usize) {
    let m = query_metrics();
    m.queries.inc();
    m.dist_evals.record(dist_evals as u64);
    m.hops.record(hops as u64);
    m.rerank_evals.record(rerank_evals as u64);
}

fn probe_metrics() -> &'static Arc<Histogram> {
    static M: OnceLock<Arc<Histogram>> = OnceLock::new();
    M.get_or_init(|| global().histogram("query.shards_probed"))
}

/// Record how many shards one sharded query probed. Separate from
/// [`record_query`] because only the scatter-gather path has a probe
/// phase — a monolithic index never touches this histogram. With
/// adaptive routing (`route_slack > 0`) the distribution below the
/// fixed `--probe-shards` cap *is* the routing win; with fixed probing
/// it degenerates to a single bucket.
pub fn record_probe(shards_probed: usize) {
    probe_metrics().record(shards_probed as u64);
}

/// Microseconds of a duration in seconds, clamped non-negative — the
/// unit every `*_us` histogram records.
pub fn us(secs: f64) -> u64 {
    (secs * 1e6).max(0.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_hammer_is_exact() {
        // N threads x M increments each: striping must lose nothing.
        let reg = MetricsRegistry::new();
        let c = reg.counter("hammer");
        let (threads, per) = (8usize, 10_000u64);
        std::thread::scope(|s| {
            for _ in 0..threads {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..per {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), threads as u64 * per);
        assert_eq!(reg.snapshot().counter("hammer"), Some(threads as u64 * per));
    }

    #[test]
    fn histogram_hammer_is_exact() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("work");
        let (threads, per) = (8usize, 5_000u64);
        std::thread::scope(|s| {
            for _ in 0..threads {
                let h = Arc::clone(&h);
                s.spawn(move || {
                    for v in 0..per {
                        h.record(v % 7);
                    }
                });
            }
        });
        let snap = h.snapshot();
        assert_eq!(snap.count, threads as u64 * per);
        let per_sum: u64 = (0..per).map(|v| v % 7).sum();
        assert_eq!(snap.sum, threads as u64 * per_sum);
        assert_eq!(snap.max, 6);
    }

    #[test]
    fn gauge_tracks_instantaneous_value() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("depth");
        g.add(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
        g.set(7);
        assert_eq!(reg.snapshot().gauge("depth"), Some(7));
    }

    #[test]
    fn handles_are_shared_not_cloned() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("same");
        let b = reg.counter("same");
        a.add(2);
        b.add(3);
        assert_eq!(reg.counter("same").get(), 5);
    }

    #[test]
    #[should_panic(expected = "not a gauge")]
    fn name_collision_across_kinds_panics() {
        let reg = MetricsRegistry::new();
        let _c = reg.counter("x");
        let _g = reg.gauge("x");
    }

    #[test]
    fn snapshot_delta_subtracts_counters_and_keeps_gauges() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("events");
        let g = reg.gauge("level");
        let h = reg.histogram("lat");
        c.add(10);
        g.set(4);
        h.record(3);
        let a = reg.snapshot();
        c.add(7);
        g.set(9);
        h.record(100);
        let b = reg.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.counter("events"), Some(7));
        assert_eq!(d.gauge("level"), Some(9));
        let dh = d.hist("lat").unwrap();
        assert_eq!(dh.count, 1);
        assert_eq!(dh.sum, 100);
        // a metric born after the baseline counts from zero
        let c2 = reg.counter("late");
        c2.add(2);
        let d2 = reg.snapshot().delta(&a);
        assert_eq!(d2.counter("late"), Some(2));
    }

    #[test]
    fn snapshot_json_has_all_sections() {
        let reg = MetricsRegistry::new();
        reg.counter("c").add(1);
        reg.gauge("g").set(-2);
        reg.histogram("h").record(5);
        let j = reg.snapshot().to_json();
        assert_eq!(j.get("counters").and_then(|o| o.get("c")).and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("gauges").and_then(|o| o.get("g")).and_then(Json::as_f64), Some(-2.0));
        let h = j.get("histograms").and_then(|o| o.get("h")).unwrap();
        assert_eq!(h.get("count").and_then(Json::as_f64), Some(1.0));
        assert_eq!(h.get("sum").and_then(Json::as_f64), Some(5.0));
        // round-trips through the strict parser (the --metrics-out path)
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }

    #[test]
    fn warnings_are_counted() {
        let before = warnings_total();
        tele_warn!("test warning {}", 42);
        assert!(warnings_total() >= before + 1);
    }

    #[test]
    fn record_query_feeds_global_histograms() {
        record_query(123, 9, 17);
        let snap = global().snapshot();
        assert!(snap.counter("query.count").unwrap() >= 1);
        assert!(snap.hist("query.dist_evals").unwrap().sum >= 123);
        assert!(snap.hist("query.hops").unwrap().sum >= 9);
        assert!(snap.hist("query.rerank_evals").unwrap().sum >= 17);
    }

    #[test]
    fn us_converts_and_clamps() {
        assert_eq!(us(0.001), 1000);
        assert_eq!(us(-1.0), 0);
    }
}
