//! Small self-contained utilities.
//!
//! The execution environment is offline with only the `xla` dependency
//! closure vendored, so the crate hand-rolls the few pieces that would
//! normally come from crates.io: a counter-free PRNG ([`rng::Rng`]),
//! wall-clock timers ([`timer`]), a minimal JSON writer ([`json`]), and a
//! tiny property-testing harness ([`prop`]) used across the test suite, and
//! a closeable MPMC queue ([`mpmc`]) shared by the scatter pool and the
//! network server.

pub mod json;
pub mod mpmc;
pub mod prop;
pub mod rng;
pub mod timer;

/// Ceil division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Round `a` up to a multiple of `m`.
#[inline]
pub fn ceil_to(a: usize, m: usize) -> usize {
    ceil_div(a, m) * m
}

/// Split `0..n` into at most `parts` contiguous ranges of near-equal size.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Number of worker threads to use: `GNND_THREADS` env override, else
/// available parallelism, else 4.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("GNND_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_helpers() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_to(10, 8), 16);
        assert_eq!(ceil_to(16, 8), 16);
    }

    #[test]
    fn ranges_cover_exactly() {
        for n in [0usize, 1, 7, 64, 100] {
            for parts in [1usize, 2, 3, 8, 200] {
                let rs = split_ranges(n, parts);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n, "n={n} parts={parts}");
                let mut prev = 0;
                for r in &rs {
                    assert_eq!(r.start, prev);
                    assert!(!r.is_empty());
                    prev = r.end;
                }
            }
        }
    }
}
