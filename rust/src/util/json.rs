//! Minimal JSON reader/writer (no serde in the vendored dependency
//! closure). The writer covers what the experiment reports need:
//! objects, arrays, strings, numbers, bools. The parser ([`Json::parse`])
//! exists so on-disk metadata — the shard manifest and out-of-core build
//! stats of [`crate::merge::outofcore`] — can round-trip through the
//! same representation.

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert a field into an object (panics on non-objects).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), val.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Field lookup on an object (`None` on non-objects / missing key).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document (strict on structure, lenient on number
    /// syntax). Numbers land as [`Json::Num`] (f64), so round-trips of
    /// the writer's own output are exact.
    pub fn parse(s: &str) -> crate::Result<Json> {
        let b = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos, 0)?;
        skip_ws(b, &mut pos);
        anyhow::ensure!(pos == b.len(), "trailing garbage at byte {pos}");
        Ok(v)
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Compact serialization (`to_string()` comes via `Display`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect_lit(b: &[u8], pos: &mut usize, lit: &str) -> crate::Result<()> {
    let l = lit.as_bytes();
    let end = *pos + l.len();
    anyhow::ensure!(end <= b.len() && &b[*pos..end] == l, "invalid literal (expected {lit})");
    *pos = end;
    Ok(())
}

fn parse_string(b: &[u8], pos: &mut usize) -> crate::Result<String> {
    *pos += 1; // opening quote
    let mut out: Vec<u8> = Vec::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(String::from_utf8(out)?);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "unterminated escape");
                let c = b[*pos];
                *pos += 1;
                match c {
                    b'"' => out.push(b'"'),
                    b'\\' => out.push(b'\\'),
                    b'/' => out.push(b'/'),
                    b'n' => out.push(b'\n'),
                    b't' => out.push(b'\t'),
                    b'r' => out.push(b'\r'),
                    b'b' => out.push(0x08),
                    b'f' => out.push(0x0C),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 <= b.len(), "truncated \\u escape");
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        *pos += 4;
                        let ch = char::from_u32(code).unwrap_or('\u{FFFD}');
                        let mut buf = [0u8; 4];
                        out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                    }
                    other => anyhow::bail!("unknown escape \\{}", other as char),
                }
            }
            c => {
                out.push(c);
                *pos += 1;
            }
        }
    }
    anyhow::bail!("unterminated string")
}

fn parse_number(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    anyhow::ensure!(*pos > start, "expected a JSON value at byte {start}");
    let s = std::str::from_utf8(&b[start..*pos])?;
    let x: f64 = s.parse().map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?;
    Ok(Json::Num(x))
}

/// Recursion guard: manifests/stats nest 2-3 levels; anything deeper
/// than this is corrupt input, rejected instead of overflowing the
/// stack.
const MAX_DEPTH: usize = 128;

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> crate::Result<Json> {
    anyhow::ensure!(depth < MAX_DEPTH, "JSON nested deeper than {MAX_DEPTH}");
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of JSON");
    match b[*pos] {
        b'n' => {
            expect_lit(b, pos, "null")?;
            Ok(Json::Null)
        }
        b't' => {
            expect_lit(b, pos, "true")?;
            Ok(Json::Bool(true))
        }
        b'f' => {
            expect_lit(b, pos, "false")?;
            Ok(Json::Bool(false))
        }
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                anyhow::ensure!(*pos < b.len(), "unterminated array");
                match b[*pos] {
                    b',' => *pos += 1,
                    b']' => {
                        *pos += 1;
                        break;
                    }
                    c => anyhow::bail!("unexpected {:?} in array", c as char),
                }
            }
            Ok(Json::Arr(items))
        }
        b'{' => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                anyhow::ensure!(*pos < b.len() && b[*pos] == b'"', "expected object key");
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                anyhow::ensure!(
                    *pos < b.len() && b[*pos] == b':',
                    "expected ':' after key {key:?}"
                );
                *pos += 1;
                fields.push((key, parse_value(b, pos, depth + 1)?));
                skip_ws(b, pos);
                anyhow::ensure!(*pos < b.len(), "unterminated object");
                match b[*pos] {
                    b',' => *pos += 1,
                    b'}' => {
                        *pos += 1;
                        break;
                    }
                    c => anyhow::bail!("unexpected {:?} in object", c as char),
                }
            }
            Ok(Json::Obj(fields))
        }
        _ => parse_number(b, pos),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig6")
            .set("recall", 0.991)
            .set("n", 1000usize)
            .set("series", vec![1.0f64, 2.5, 3.0])
            .set("ok", true);
        let s = j.to_string();
        assert!(s.starts_with('{') && s.ends_with('}'));
        assert!(s.contains("\"recall\":0.991"));
        assert!(s.contains("\"n\":1000"));
        assert!(s.contains("[1,2.5,3]"));
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj()
            .set("name", "shard manifest")
            .set("shards", 4usize)
            .set("offsets", vec![0.0f64, 120.0, 240.0])
            .set("nested", Json::obj().set("ok", true).set("x", -2.5))
            .set("nothing", Json::Null);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.to_string(), text);
        assert_eq!(back.get("shards").and_then(Json::as_usize), Some(4));
        assert_eq!(back.get("name").and_then(Json::as_str), Some("shard manifest"));
        let offs: Vec<usize> = back
            .get("offsets")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        assert_eq!(offs, vec![0, 120, 240]);
        assert_eq!(back.get("nested").and_then(|n| n.get("x")).and_then(Json::as_f64), Some(-2.5));
    }

    #[test]
    fn parse_handles_ws_escapes_and_floats() {
        let j = Json::parse(" { \"a\\n\\\"b\" : [ 1.5e2 , -0.25, \"\\u0041\" ] } ").unwrap();
        let arr = j.get("a\n\"b").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0].as_f64(), Some(150.0));
        assert_eq!(arr[1].as_f64(), Some(-0.25));
        assert_eq!(arr[2].as_str(), Some("A"));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "{\"a\":1} x", "nul", "\"open"] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
        // pathological nesting errors out instead of overflowing the stack
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err().to_string();
        assert!(err.contains("deep"), "unhelpful error: {err}");
    }

    #[test]
    fn f32_roundtrip_is_exact() {
        // manifest centroids are f32; f64 shortest-roundtrip printing
        // must bring every value back bit-exact
        for x in [0.1f32, 1.0 / 3.0, -7.25e-3, 1234.5678] {
            let text = Json::Num(x as f64).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap() as f32;
            assert_eq!(back.to_bits(), x.to_bits(), "{x} -> {text}");
        }
    }
}
