//! Minimal JSON writer for experiment reports (no serde in the vendored
//! dependency closure). Only what the reports need: objects, arrays,
//! strings, numbers, bools.

use std::fmt::Write as _;

/// A JSON value builder.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert a field into an object (panics on non-objects).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), val.into())),
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig6")
            .set("recall", 0.991)
            .set("n", 1000usize)
            .set("series", vec![1.0f64, 2.5, 3.0])
            .set("ok", true);
        let s = j.to_string();
        assert!(s.starts_with('{') && s.ends_with('}'));
        assert!(s.contains("\"recall\":0.991"));
        assert!(s.contains("\"n\":1000"));
        assert!(s.contains("[1,2.5,3]"));
    }

    #[test]
    fn escapes_strings() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
