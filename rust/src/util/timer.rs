//! Wall-clock timing + per-phase accounting.
//!
//! The §Perf pass (EXPERIMENTS.md) relies on [`PhaseTimers`] to attribute
//! construction time to the paper's phases (sampling / cross-matching /
//! update / runtime-marshalling), mirroring the paper's observation that
//! >90% of NN-Descent time is distance evaluation.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Simple stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Thread-safe accumulator of named phase durations.
#[derive(Default)]
pub struct PhaseTimers {
    phases: Mutex<BTreeMap<&'static str, f64>>,
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `secs` to phase `name`.
    pub fn add(&self, name: &'static str, secs: f64) {
        *self.phases.lock().unwrap().entry(name).or_insert(0.0) += secs;
    }

    /// Time a closure and attribute it to `name`.
    pub fn scope<T>(&self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.secs());
        out
    }

    /// Snapshot of (phase, seconds), sorted by name.
    pub fn snapshot(&self) -> Vec<(&'static str, f64)> {
        self.phases
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (*k, *v))
            .collect()
    }

    /// Human-readable one-line summary with percentages.
    pub fn summary(&self) -> String {
        let snap = self.snapshot();
        let total: f64 = snap.iter().map(|(_, s)| s).sum();
        let mut parts = Vec::new();
        for (name, secs) in &snap {
            let pct = if total > 0.0 { 100.0 * secs / total } else { 0.0 };
            parts.push(format!("{name}={secs:.3}s ({pct:.1}%)"));
        }
        parts.join("  ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let p = PhaseTimers::new();
        p.add("a", 1.0);
        p.add("a", 0.5);
        p.add("b", 2.0);
        let snap = p.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "a");
        assert!((snap[0].1 - 1.5).abs() < 1e-12);
        assert!(p.summary().contains("a=1.500s"));
    }

    #[test]
    fn scope_returns_value() {
        let p = PhaseTimers::new();
        let v = p.scope("x", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(p.snapshot().len(), 1);
    }
}
