//! A tiny property-testing harness (proptest is not in the vendored
//! dependency closure). Runs a predicate over many seeded random cases
//! and reports the failing seed so the case replays deterministically:
//!
//! ```
//! use gnnd::util::{prop, rng::Rng};
//! prop::check("sorted-after-sort", 64, |rng: &mut Rng| {
//!     let mut v: Vec<u32> = (0..rng.below(100)).map(|_| rng.next_u64() as u32).collect();
//!     v.sort_unstable();
//!     prop::assert_prop(v.windows(2).all(|w| w[0] <= w[1]), "not sorted")
//! });
//! ```

use super::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Helper: turn a boolean into a `CaseResult` with a message.
pub fn assert_prop(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` seeded cases of property `f`; panic with the seed on the
/// first failure. The base seed can be overridden with `GNND_PROP_SEED`
/// to replay a specific failure.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng) -> CaseResult) {
    let base: u64 = std::env::var("GNND_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000);
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {i} (seed {seed}): {msg}\n\
                 replay with GNND_PROP_SEED={seed} and cases=1"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 10, |rng| {
            assert_prop(rng.below(10) < 10, "below out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn failing_property_panics_with_seed() {
        check("falsum", 3, |_| assert_prop(false, "nope"));
    }
}
