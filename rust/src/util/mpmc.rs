//! A tiny closeable multi-producer/multi-consumer queue.
//!
//! Hand-rolled on `Mutex` + `Condvar` (no channel crate in the dependency
//! closure). The scatter pool ([`crate::search::pool`]) and the network
//! server ([`crate::search::server`]) both sit on top of it: producers push
//! work items, a set of consumer threads block in [`Queue::pop`] (or
//! [`Queue::pop_deadline`] for the server's coalescing window), and
//! [`Queue::close`] drains the queue then releases every blocked consumer.
//!
//! [`Queue::push_all_within`] is the admission-control primitive: it accepts
//! a whole batch only if the post-push depth stays within a limit, under a
//! single lock acquisition, so the observable queue depth never overshoots
//! the configured bound.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::Instant;

/// Outcome of a bounded push ([`Queue::push_all_within`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// All items were enqueued.
    Pushed,
    /// Enqueuing would exceed the depth limit; nothing was enqueued.
    OverLimit,
    /// The queue has been closed; nothing was enqueued.
    Closed,
}

/// Outcome of a deadline-bounded pop ([`Queue::pop_deadline`]).
#[derive(Debug)]
pub enum Pop<T> {
    /// An item was dequeued.
    Item(T),
    /// The deadline passed with the queue still empty.
    TimedOut,
    /// The queue is closed and drained.
    Closed,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Closeable MPMC FIFO queue.
pub struct Queue<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
}

impl<T> Default for Queue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Queue<T> {
    pub fn new() -> Self {
        Queue {
            state: Mutex::new(State {
                items: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Enqueue one item. Returns `false` (dropping the item) if the queue is
    /// closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.lock();
        if st.closed {
            return false;
        }
        st.items.push_back(item);
        drop(st);
        self.ready.notify_one();
        true
    }

    /// Enqueue all of `items` iff the resulting depth stays `<= limit`
    /// (`limit == 0` means unbounded). All-or-nothing under one lock.
    pub fn push_all_within(&self, items: Vec<T>, limit: usize) -> PushOutcome {
        let n = items.len();
        let mut st = self.lock();
        if st.closed {
            return PushOutcome::Closed;
        }
        if limit > 0 && st.items.len() + n > limit {
            return PushOutcome::OverLimit;
        }
        st.items.extend(items);
        drop(st);
        if n == 1 {
            self.ready.notify_one();
        } else if n > 1 {
            self.ready.notify_all();
        }
        PushOutcome::Pushed
    }

    /// Blocking dequeue. Returns `None` once the queue is closed *and*
    /// drained (items pushed before `close` are still delivered).
    pub fn pop(&self) -> Option<T> {
        let mut st = self.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.ready.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking dequeue.
    pub fn try_pop(&self) -> Option<T> {
        self.lock().items.pop_front()
    }

    /// Dequeue, waiting until `deadline` at most.
    pub fn pop_deadline(&self, deadline: Instant) -> Pop<T> {
        let mut st = self.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Pop::Item(item);
            }
            if st.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, _timeout) = self
                .ready
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
    }

    /// Close the queue: future pushes are rejected; consumers drain the
    /// remaining items and then observe closure.
    pub fn close(&self) {
        let mut st = self.lock();
        st.closed = true;
        drop(st);
        self.ready.notify_all();
    }

    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order_and_close_drains() {
        let q = Queue::new();
        assert!(q.push(1));
        assert!(q.push(2));
        q.close();
        assert!(!q.push(3), "push after close must be rejected");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn push_all_within_is_all_or_nothing() {
        let q = Queue::new();
        assert_eq!(q.push_all_within(vec![1, 2, 3], 4), PushOutcome::Pushed);
        assert_eq!(q.push_all_within(vec![4, 5], 4), PushOutcome::OverLimit);
        assert_eq!(q.len(), 3, "rejected batch must not be partially enqueued");
        assert_eq!(q.push_all_within(vec![4], 4), PushOutcome::Pushed);
        assert_eq!(q.push_all_within(vec![5], 0), PushOutcome::Pushed);
        q.close();
        assert_eq!(q.push_all_within(vec![6], 0), PushOutcome::Closed);
    }

    #[test]
    fn pop_deadline_times_out_then_delivers() {
        let q: Queue<u32> = Queue::new();
        let t0 = Instant::now();
        match q.pop_deadline(t0 + Duration::from_millis(10)) {
            Pop::TimedOut => {}
            other => panic!("expected TimedOut, got {other:?}"),
        }
        assert!(t0.elapsed() >= Duration::from_millis(10));
        q.push(7);
        match q.pop_deadline(Instant::now() + Duration::from_millis(10)) {
            Pop::Item(v) => assert_eq!(v, 7),
            other => panic!("expected Item, got {other:?}"),
        }
        q.close();
        match q.pop_deadline(Instant::now() + Duration::from_millis(10)) {
            Pop::Closed => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn blocking_pop_wakes_across_threads() {
        let q: Arc<Queue<usize>> = Arc::new(Queue::new());
        let n = 64;
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = 0usize;
                    while q.pop().is_some() {
                        got += 1;
                    }
                    got
                })
            })
            .collect();
        for i in 0..n {
            assert!(q.push(i));
        }
        q.close();
        let total: usize = consumers.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, n, "every pushed item is delivered exactly once");
    }
}
