//! Deterministic PRNG: xoshiro256++ seeded via splitmix64.
//!
//! Every stochastic component of the library (dataset synthesis, random
//! graph init, sampling tie-breaks, k-means seeding, property tests)
//! draws from this generator, so whole experiments replay bit-for-bit
//! from a single `u64` seed.

/// xoshiro256++ generator (public-domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box–Muller transform.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; distinct seeds give decorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-thread determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply rejection-free mapping (Lemire); bias is
        // negligible for n << 2^64 and irrelevant for our uses.
        (((self.next_u64() as u128) * (n as u128)) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `m` distinct indices sampled from `[0, n)` (m <= n), in random order.
    pub fn distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        if m * 4 >= n {
            // dense: partial Fisher–Yates
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..m {
                let j = self.range(i, n);
                idx.swap(i, j);
            }
            idx.truncate(m);
            idx
        } else {
            // sparse: rejection with a small set
            let mut seen = std::collections::HashSet::with_capacity(m * 2);
            let mut out = Vec::with_capacity(m);
            while out.len() < m {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn distinct_has_no_duplicates() {
        let mut r = Rng::new(11);
        for (n, m) in [(10, 10), (100, 5), (1000, 400)] {
            let xs = r.distinct(n, m);
            assert_eq!(xs.len(), m);
            let set: std::collections::HashSet<_> = xs.iter().collect();
            assert_eq!(set.len(), m);
            assert!(xs.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
