//! Cross-matching engines: the pluggable evaluator of one GNND
//! cross-matching step (paper §4.2 + Algorithm 2).
//!
//! Two implementations share exact semantics (pair masking by group id,
//! first-minimum argmin):
//!
//! * [`NativeEngine`] — pure Rust; the correctness oracle and fallback.
//! * [`crate::runtime::PjrtEngine`] — executes the AOT-compiled
//!   `crossmatch` XLA artifact (Pallas kernels inside) on the PJRT CPU
//!   client; the paper's "on-device" path.
//!
//! Semantics contract (mirrors `python/compile/model.py::crossmatch`):
//! a pair is *masked* iff either slot is empty (group < 0) or both
//! slots carry the same group id. In normal construction groups are
//! object ids (masks self/duplicate pairs); in GGM merge mode groups
//! are subset labels (masks same-subgraph pairs — the paper's
//! restricted refinement).

use anyhow::bail;

use crate::dataset::Dataset;
use crate::graph::EMPTY;

/// One batch of object locals handed to an engine.
///
/// `new_ids` / `old_ids` are the *object* ids of the sampled neighbors
/// (`EMPTY` = vacant slot), flattened `[rows][s]` for owners
/// `owners.start..owners.end`. `groups_*` carry the masking ids the
/// engine compares (same shape, `-1` = vacant).
pub struct Batch<'a> {
    pub s: usize,
    pub rows: usize,
    pub new_ids: &'a [u32],
    pub old_ids: &'a [u32],
    pub groups_new: &'a [i32],
    pub groups_old: &'a [i32],
}

impl Batch<'_> {
    pub fn validate(&self) {
        debug_assert_eq!(self.new_ids.len(), self.rows * self.s);
        debug_assert_eq!(self.old_ids.len(), self.rows * self.s);
        debug_assert_eq!(self.groups_new.len(), self.rows * self.s);
        debug_assert_eq!(self.groups_old.len(), self.rows * self.s);
    }
}

/// Algorithm-2 reductions for a batch: per slot, the local column index
/// of the nearest valid partner (`-1` = none) and its distance.
/// Layout matches the batch: `[rows][s]`.
#[derive(Debug, Default)]
pub struct CrossmatchResult {
    /// Per NEW sample: nearest *other* NEW sample.
    pub nn_idx: Vec<i32>,
    pub nn_dist: Vec<f32>,
    /// Per NEW sample: nearest OLD sample.
    pub no_idx: Vec<i32>,
    pub no_dist: Vec<f32>,
    /// Per OLD sample: nearest NEW sample.
    pub on_idx: Vec<i32>,
    pub on_dist: Vec<f32>,
}

impl CrossmatchResult {
    fn sized(len: usize) -> Self {
        CrossmatchResult {
            nn_idx: vec![-1; len],
            nn_dist: vec![f32::INFINITY; len],
            no_idx: vec![-1; len],
            no_dist: vec![f32::INFINITY; len],
            on_idx: vec![-1; len],
            on_dist: vec![f32::INFINITY; len],
        }
    }
}

/// Full pairwise distances of a batch (GNND-r1 ablation path only; the
/// selective-update artifacts deliberately never materialize this on the
/// host — that is the paper's memory-traffic saving).
pub struct FullDists {
    /// `[rows][s][s]` NEW x NEW distances, `INFINITY` where masked.
    pub nn: Vec<f32>,
    /// `[rows][s][s]` NEW x OLD distances.
    pub no: Vec<f32>,
}

/// A cross-matching evaluator.
pub trait CrossmatchEngine: Sync + Send {
    /// Evaluate the Algorithm-2 reductions for one batch.
    fn crossmatch(&self, ds: &Dataset, batch: &Batch) -> crate::Result<CrossmatchResult>;

    /// Full distance matrices (r1 path). Engines may not support it.
    fn crossmatch_full(&self, _ds: &Dataset, _batch: &Batch) -> crate::Result<FullDists> {
        bail!("{}: full cross-matching (r1) not supported", self.name())
    }

    /// Batch size the engine dispatches most efficiently (e.g. the AOT
    /// artifact's leading dimension). `None` = no preference.
    fn preferred_batch(&self) -> Option<usize> {
        None
    }

    fn name(&self) -> &'static str;
}

/// Pure-Rust engine, semantics-identical to the XLA artifact.
pub struct NativeEngine;

#[inline]
fn masked(gi: i32, gj: i32) -> bool {
    gi < 0 || gj < 0 || gi == gj
}

impl CrossmatchEngine for NativeEngine {
    fn crossmatch(&self, ds: &Dataset, batch: &Batch) -> crate::Result<CrossmatchResult> {
        batch.validate();
        let s = batch.s;
        let mut out = CrossmatchResult::sized(batch.rows * s);
        let metric = ds.metric;
        for r in 0..batch.rows {
            let base = r * s;
            let nids = &batch.new_ids[base..base + s];
            let oids = &batch.old_ids[base..base + s];
            let gn = &batch.groups_new[base..base + s];
            let go = &batch.groups_old[base..base + s];
            // NEW x NEW: one distance per unordered pair, updating both
            // ends. Ascending iteration + strict '<' reproduces the
            // artifact's first-minimum argmin tie-breaking.
            for i in 0..s {
                if nids[i] == EMPTY {
                    continue;
                }
                let vi = ds.vec(nids[i] as usize);
                for j in (i + 1)..s {
                    if nids[j] == EMPTY || masked(gn[i], gn[j]) {
                        continue;
                    }
                    let d = crate::distance::distance(metric, vi, ds.vec(nids[j] as usize));
                    if d < out.nn_dist[base + i] {
                        out.nn_dist[base + i] = d;
                        out.nn_idx[base + i] = j as i32;
                    }
                    if d < out.nn_dist[base + j] {
                        out.nn_dist[base + j] = d;
                        out.nn_idx[base + j] = i as i32;
                    }
                }
                // NEW x OLD
                for j in 0..s {
                    if oids[j] == EMPTY || masked(gn[i], go[j]) {
                        continue;
                    }
                    let d = crate::distance::distance(metric, vi, ds.vec(oids[j] as usize));
                    if d < out.no_dist[base + i] {
                        out.no_dist[base + i] = d;
                        out.no_idx[base + i] = j as i32;
                    }
                    if d < out.on_dist[base + j] {
                        out.on_dist[base + j] = d;
                        out.on_idx[base + j] = i as i32;
                    }
                }
            }
        }
        Ok(out)
    }

    fn crossmatch_full(&self, ds: &Dataset, batch: &Batch) -> crate::Result<FullDists> {
        batch.validate();
        let s = batch.s;
        let len = batch.rows * s * s;
        let mut nn = vec![f32::INFINITY; len];
        let mut no = vec![f32::INFINITY; len];
        let metric = ds.metric;
        for r in 0..batch.rows {
            let base = r * s;
            for i in 0..s {
                let ni = batch.new_ids[base + i];
                if ni == EMPTY {
                    continue;
                }
                let vi = ds.vec(ni as usize);
                for j in (i + 1)..s {
                    let njd = batch.new_ids[base + j];
                    if njd == EMPTY
                        || masked(batch.groups_new[base + i], batch.groups_new[base + j])
                    {
                        continue;
                    }
                    let d = crate::distance::distance(metric, vi, ds.vec(njd as usize));
                    nn[(r * s + i) * s + j] = d;
                    nn[(r * s + j) * s + i] = d;
                }
                for j in 0..s {
                    let oj = batch.old_ids[base + j];
                    if oj == EMPTY
                        || masked(batch.groups_new[base + i], batch.groups_old[base + j])
                    {
                        continue;
                    }
                    no[(r * s + i) * s + j] =
                        crate::distance::distance(metric, vi, ds.vec(oj as usize));
                }
            }
        }
        Ok(FullDists { nn, no })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    fn mk_batch<'a>(
        s: usize,
        rows: usize,
        new_ids: &'a [u32],
        old_ids: &'a [u32],
        gn: &'a [i32],
        go: &'a [i32],
    ) -> Batch<'a> {
        Batch { s, rows, new_ids, old_ids, groups_new: gn, groups_old: go }
    }

    #[test]
    fn native_selects_true_nearest() {
        let ds = synth::uniform(30, 6, 1);
        let s = 4;
        let new_ids: Vec<u32> = vec![1, 2, 3, 4];
        let old_ids: Vec<u32> = vec![5, 6, 7, EMPTY];
        let gn: Vec<i32> = new_ids.iter().map(|&x| x as i32).collect();
        let go: Vec<i32> = vec![5, 6, 7, -1];
        let b = mk_batch(s, 1, &new_ids, &old_ids, &gn, &go);
        let out = NativeEngine.crossmatch(&ds, &b).unwrap();
        // brute-force oracle for new sample 0 (object 1)
        let mut best = (f32::INFINITY, -1i32);
        for (j, &v) in new_ids.iter().enumerate() {
            if j != 0 {
                let d = ds.dist(1, v as usize);
                if d < best.0 {
                    best = (d, j as i32);
                }
            }
        }
        assert_eq!(out.nn_idx[0], best.1);
        assert!((out.nn_dist[0] - best.0).abs() < 1e-5);
        // empty old slot never selected
        assert!(out.no_idx.iter().all(|&i| i != 3));
        assert_eq!(out.on_idx[3], -1);
    }

    #[test]
    fn group_masking_blocks_same_group() {
        let ds = synth::uniform(10, 4, 2);
        let new_ids: Vec<u32> = vec![0, 1, 2, 3];
        let old_ids: Vec<u32> = vec![4, 5, 6, 7];
        // groups: two subsets — same-subset pairs masked
        let gn = vec![0, 0, 1, 1];
        let go = vec![0, 1, 1, 0];
        let b = mk_batch(4, 1, &new_ids, &old_ids, &gn, &go);
        let out = NativeEngine.crossmatch(&ds, &b).unwrap();
        for i in 0..4 {
            if out.nn_idx[i] >= 0 {
                assert_ne!(gn[out.nn_idx[i] as usize], gn[i]);
            }
            if out.no_idx[i] >= 0 {
                assert_ne!(go[out.no_idx[i] as usize], gn[i]);
            }
            if out.on_idx[i] >= 0 {
                assert_ne!(gn[out.on_idx[i] as usize], go[i]);
            }
        }
    }

    #[test]
    fn all_masked_yields_sentinels() {
        let ds = synth::uniform(8, 4, 3);
        let ids: Vec<u32> = vec![0, 1];
        let gn = vec![7, 7]; // same group -> masked
        let b = mk_batch(2, 1, &ids, &ids, &gn, &gn);
        let out = NativeEngine.crossmatch(&ds, &b).unwrap();
        assert!(out.nn_idx.iter().all(|&i| i == -1));
        assert!(out.no_idx.iter().all(|&i| i == -1));
        assert!(out.on_idx.iter().all(|&i| i == -1));
    }

    #[test]
    fn full_matches_reduced() {
        let ds = synth::uniform(40, 5, 4);
        let s = 6;
        let rows = 3;
        let mut rng = crate::util::rng::Rng::new(5);
        let mut new_ids = Vec::new();
        let mut old_ids = Vec::new();
        for _ in 0..rows * s {
            new_ids.push(rng.below(40) as u32);
            old_ids.push(rng.below(40) as u32);
        }
        let gn: Vec<i32> = new_ids.iter().map(|&x| x as i32).collect();
        let go: Vec<i32> = old_ids.iter().map(|&x| x as i32).collect();
        let b = mk_batch(s, rows, &new_ids, &old_ids, &gn, &go);
        let red = NativeEngine.crossmatch(&ds, &b).unwrap();
        let full = NativeEngine.crossmatch_full(&ds, &b).unwrap();
        for r in 0..rows {
            for i in 0..s {
                let row = &full.nn[(r * s + i) * s..(r * s + i + 1) * s];
                let min = row.iter().cloned().fold(f32::INFINITY, f32::min);
                let got = red.nn_dist[r * s + i];
                if min.is_finite() {
                    assert!((min - got).abs() < 1e-5, "r={r} i={i}");
                } else {
                    assert_eq!(red.nn_idx[r * s + i], -1);
                }
            }
        }
    }
}
