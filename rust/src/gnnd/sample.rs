//! Fixed-size sampling (paper §4.1 "Sampling on Close Neighbors").
//!
//! Per object `u` the first (= closest, lists are sorted) `p` NEW and
//! `p` OLD neighbors are copied into two fixed-degree adjacency graphs
//! `G_new` / `G_old`; sampled NEW entries are flipped to OLD (Alg. 1
//! line 32). Then each forward sample `v` of `u` appends the *reverse*
//! edge `u` into `v`'s sampled list, bounded at capacity `2p` with an
//! atomic size counter — the paper's replacement for dynamic arrays
//! ("the cost of maintaining n dynamic arrays is prohibitively high").
//! Finally each list is sorted by id and deduplicated.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::graph::{KnnGraph, EMPTY};
use crate::util::split_ranges;

/// The fixed-degree sampled adjacency lists for one iteration.
pub struct SampledLists {
    /// Capacity per list (= 2p).
    pub cap: usize,
    pub n: usize,
    /// `[n][cap]`, `EMPTY`-padded.
    pub new_ids: Vec<u32>,
    pub old_ids: Vec<u32>,
}

impl SampledLists {
    #[inline]
    pub fn new_row(&self, u: usize) -> &[u32] {
        &self.new_ids[u * self.cap..(u + 1) * self.cap]
    }

    #[inline]
    pub fn old_row(&self, u: usize) -> &[u32] {
        &self.old_ids[u * self.cap..(u + 1) * self.cap]
    }
}

/// Run the sampling phase (paper Algorithm 1 line 8, `ParallelSample`).
pub fn parallel_sample(graph: &mut KnnGraph, p: usize, threads: usize) -> SampledLists {
    let n = graph.n();
    let k = graph.k();
    let cap = 2 * p;
    let mut new_ids = vec![EMPTY; n * cap];
    let mut old_ids = vec![EMPTY; n * cap];
    let new_len: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let old_len: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();

    // Phase A: forward sampling + flag flip. Parallel over disjoint
    // object ranges; each thread mutates only its own objects' graph
    // lists and writes rows new_ids[u], so slices can be split safely.
    let ranges = split_ranges(n, threads.max(1));
    {
        struct Ptrs {
            lists: *mut crate::graph::Neighbor,
            new_ids: *mut u32,
            old_ids: *mut u32,
        }
        unsafe impl Send for Ptrs {}
        unsafe impl Sync for Ptrs {}
        let ptrs = Ptrs {
            lists: graph.list_mut(0).as_mut_ptr(),
            new_ids: new_ids.as_mut_ptr(),
            old_ids: old_ids.as_mut_ptr(),
        };
        let (new_len, old_len) = (&new_len, &old_len);
        crossbeam_utils::thread::scope(|s| {
            for r in &ranges {
                let r = r.clone();
                let ptrs = &ptrs;
                s.spawn(move |_| {
                    for u in r {
                        // SAFETY: object ranges are disjoint.
                        let list = unsafe {
                            std::slice::from_raw_parts_mut(ptrs.lists.add(u * k), k)
                        };
                        let nrow = unsafe {
                            std::slice::from_raw_parts_mut(ptrs.new_ids.add(u * cap), cap)
                        };
                        let orow = unsafe {
                            std::slice::from_raw_parts_mut(ptrs.old_ids.add(u * cap), cap)
                        };
                        let (mut nn, mut no) = (0usize, 0usize);
                        for e in list.iter_mut() {
                            if e.is_empty() {
                                break;
                            }
                            if e.new && nn < p {
                                nrow[nn] = e.id;
                                nn += 1;
                                e.new = false; // sampled -> mark OLD
                            } else if !e.new && no < p {
                                orow[no] = e.id;
                                no += 1;
                            }
                            if nn == p && no == p {
                                break;
                            }
                        }
                        new_len[u].store(nn as u32, Ordering::Relaxed);
                        old_len[u].store(no as u32, Ordering::Relaxed);
                    }
                });
            }
        })
        .unwrap();
    }

    // Phase B: bounded reverse append (atomic slot reservation).
    {
        struct Ptrs {
            new_ids: *mut u32,
            old_ids: *mut u32,
        }
        unsafe impl Send for Ptrs {}
        unsafe impl Sync for Ptrs {}
        let ptrs = Ptrs { new_ids: new_ids.as_mut_ptr(), old_ids: old_ids.as_mut_ptr() };
        // Snapshot forward lengths: reverse edges derive from forward
        // samples only (G_new's own content, as in the paper).
        let fwd_new: Vec<u32> = new_len.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let fwd_old: Vec<u32> = old_len.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let (new_len, old_len) = (&new_len, &old_len);
        let (fwd_new, fwd_old) = (&fwd_new, &fwd_old);
        let ranges = split_ranges(n, threads.max(1));
        crossbeam_utils::thread::scope(|s| {
            for r in &ranges {
                let r = r.clone();
                let ptrs = &ptrs;
                s.spawn(move |_| {
                    for u in r {
                        for slot in 0..fwd_new[u] as usize {
                            // SAFETY: reads of forward region [0, fwd)
                            // are stable; appends only touch [fwd, cap).
                            let v = unsafe { *ptrs.new_ids.add(u * cap + slot) } as usize;
                            let pos = new_len[v].fetch_add(1, Ordering::Relaxed) as usize;
                            if pos < cap {
                                unsafe {
                                    *ptrs.new_ids.add(v * cap + pos) = u as u32;
                                }
                            } else {
                                new_len[v].store(cap as u32, Ordering::Relaxed);
                            }
                        }
                        for slot in 0..fwd_old[u] as usize {
                            let v = unsafe { *ptrs.old_ids.add(u * cap + slot) } as usize;
                            let pos = old_len[v].fetch_add(1, Ordering::Relaxed) as usize;
                            if pos < cap {
                                unsafe {
                                    *ptrs.old_ids.add(v * cap + pos) = u as u32;
                                }
                            } else {
                                old_len[v].store(cap as u32, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        })
        .unwrap();
    }

    // Phase C: per-list sort + dedup (paper: a warp sorts each list).
    let mut lists = SampledLists { cap, n, new_ids, old_ids };
    let ranges = split_ranges(n, threads.max(1));
    {
        struct Ptrs {
            new_ids: *mut u32,
            old_ids: *mut u32,
        }
        unsafe impl Send for Ptrs {}
        unsafe impl Sync for Ptrs {}
        let ptrs = Ptrs {
            new_ids: lists.new_ids.as_mut_ptr(),
            old_ids: lists.old_ids.as_mut_ptr(),
        };
        let (new_len, old_len) = (&new_len, &old_len);
        crossbeam_utils::thread::scope(|s| {
            for r in &ranges {
                let r = r.clone();
                let ptrs = &ptrs;
                s.spawn(move |_| {
                    for u in r {
                        let nl = (new_len[u].load(Ordering::Relaxed) as usize).min(cap);
                        let ol = (old_len[u].load(Ordering::Relaxed) as usize).min(cap);
                        unsafe {
                            dedup_row(
                                std::slice::from_raw_parts_mut(ptrs.new_ids.add(u * cap), cap),
                                nl,
                            );
                            dedup_row(
                                std::slice::from_raw_parts_mut(ptrs.old_ids.add(u * cap), cap),
                                ol,
                            );
                        }
                    }
                });
            }
        })
        .unwrap();
    }
    lists
}

/// Sort the first `len` ids, dedup, EMPTY-pad the tail.
fn dedup_row(row: &mut [u32], len: usize) {
    let live = &mut row[..len];
    live.sort_unstable();
    let mut w = 0;
    for i in 0..len {
        if i == 0 || row[i] != row[w - 1] {
            row[w] = row[i];
            w += 1;
        }
    }
    for slot in row[w..].iter_mut() {
        *slot = EMPTY;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::util::{prop, rng::Rng};

    fn live(row: &[u32]) -> Vec<u32> {
        row.iter().copied().filter(|&x| x != EMPTY).collect()
    }

    #[test]
    fn sampling_respects_bounds_and_flags() {
        let ds = synth::uniform(100, 4, 1);
        let mut rng = Rng::new(2);
        let mut g = KnnGraph::random_init(&ds, 10, &mut rng);
        let p = 4;
        let s = parallel_sample(&mut g, p, 4);
        assert_eq!(s.cap, 2 * p);
        for u in 0..100 {
            let nrow = live(s.new_row(u));
            let orow = live(s.old_row(u));
            assert!(nrow.len() <= s.cap);
            assert!(orow.len() <= s.cap);
            // dedup: no repeated ids
            let set: std::collections::HashSet<_> = nrow.iter().collect();
            assert_eq!(set.len(), nrow.len(), "u={u} has dup new samples");
        }
        // after the first sampling pass, each list has exactly
        // min(p, live) entries flipped to OLD.
        for u in 0..100 {
            let old_cnt = g.list(u).iter().filter(|e| !e.is_empty() && !e.new).count();
            assert_eq!(old_cnt, p.min(g.len_of(u)), "u={u}");
        }
        // second sampling pass: OLD entries now exist and get sampled.
        let s2 = parallel_sample(&mut g, p, 4);
        let some_old = (0..100).any(|u| !live(s2.old_row(u)).is_empty());
        assert!(some_old);
    }

    #[test]
    fn reverse_edges_present() {
        // With p >= k and a tiny graph every neighbor is sampled, so if
        // v in G[u], then u must appear in v's sampled new row (cap
        // permitting). Use n small enough that caps don't overflow.
        let ds = synth::uniform(10, 3, 3);
        let mut rng = Rng::new(4);
        let mut g = KnnGraph::random_init(&ds, 3, &mut rng);
        let fwd: Vec<Vec<u32>> = (0..10).map(|u| g.ids(u).collect()).collect();
        let s = parallel_sample(&mut g, 3, 2);
        let mut found_reverse = 0;
        for u in 0..10 {
            for &v in &fwd[u] {
                if live(s.new_row(v as usize)).contains(&(u as u32)) {
                    found_reverse += 1;
                }
            }
        }
        assert!(found_reverse > 0, "no reverse edges appended");
    }

    #[test]
    fn sampled_ids_are_graph_or_reverse_edges() {
        prop::check("sample-provenance", 10, |rng| {
            let n = 40 + rng.below(40);
            let ds = synth::uniform(n, 4, rng.next_u64());
            let mut g = KnnGraph::random_init(&ds, 6, &mut Rng::new(rng.next_u64()));
            let fwd: Vec<Vec<u32>> = (0..n).map(|u| g.ids(u).collect()).collect();
            let s = parallel_sample(&mut g, 3, 3);
            for u in 0..n {
                for &v in &live(s.new_row(u)) {
                    let forward = fwd[u].contains(&v);
                    let reverse = fwd[v as usize].contains(&(u as u32));
                    prop::assert_prop(
                        forward || reverse,
                        format!("sample {v} of {u} is neither forward nor reverse"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_same_graph_single_thread() {
        let ds = synth::uniform(50, 4, 5);
        let mut rng = Rng::new(6);
        let g0 = KnnGraph::random_init(&ds, 8, &mut rng);
        let mut g1 = g0.clone();
        let mut g2 = g0.clone();
        let s1 = parallel_sample(&mut g1, 4, 1);
        let s2 = parallel_sample(&mut g2, 4, 1);
        assert_eq!(s1.new_ids, s2.new_ids);
        assert_eq!(s1.old_ids, s2.old_ids);
    }

    #[test]
    fn dedup_row_works() {
        let mut row = [5u32, 1, 5, 3, 1, EMPTY, EMPTY, EMPTY];
        dedup_row(&mut row, 5);
        assert_eq!(&row[..3], &[1, 3, 5]);
        assert!(row[3..].iter().all(|&x| x == EMPTY));
    }
}
