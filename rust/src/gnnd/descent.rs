//! The GNND iteration loop (paper Algorithm 1).
//!
//! Each iteration: fixed-size sampling (§4.1) -> batched cross-matching
//! through an engine (§4.2, the AOT artifact or the native oracle) ->
//! graph update under the configured Fig.-5 strategy (§4.3) ->
//! end-of-iteration segment merge. Worker threads pull batches of object
//! locals from an atomic cursor, so the engine evaluates many locals per
//! dispatch (the paper launches all objects in one kernel; the batch
//! dimension of the artifact plays that role here).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::{GnndParams, UpdateStrategy};
use crate::dataset::Dataset;
use crate::graph::{concurrent::ConcurrentGraph, KnnGraph, EMPTY};
use crate::util::timer::{PhaseTimers, Timer};

use super::engine::{Batch, CrossmatchEngine};
use super::sample::{parallel_sample, SampledLists};

/// Statistics of one build/refinement run.
#[derive(Clone, Debug, Default)]
pub struct BuildStats {
    pub iters: usize,
    /// Accepted insertions per iteration.
    pub updates: Vec<usize>,
    /// phi(G) after each iteration (only when `trace_phi`).
    pub phi_trace: Vec<f64>,
    /// Total wall-clock seconds.
    pub seconds: f64,
    /// Per-phase seconds (sample / crossmatch / update / normalize).
    pub phases: Vec<(&'static str, f64)>,
    pub engine: &'static str,
}

/// Refine `graph` in place by GNND iterations.
///
/// `group_fn` maps an object id to the masking group the engines
/// compare: `None` uses the object id itself (normal construction);
/// GGM merge passes the subset label so same-subgraph pairs are skipped
/// (paper §5.1).
pub fn refine(
    ds: &Dataset,
    graph: &mut KnnGraph,
    engine: &dyn CrossmatchEngine,
    params: &GnndParams,
    group_fn: Option<&(dyn Fn(u32) -> i32 + Sync)>,
) -> crate::Result<BuildStats> {
    params.validate()?;
    let total = Timer::start();
    let timers = PhaseTimers::new();
    let n = graph.n();
    let threads = if params.threads == 0 {
        crate::util::num_threads()
    } else {
        params.threads
    };
    let mut stats = BuildStats { engine: engine.name(), ..Default::default() };

    // Dispatch in the engine's preferred batch (the AOT artifact's
    // leading dimension) when it is larger than the configured one:
    // sub-artifact batches waste the padded compute anyway.
    let batch = params.batch.max(engine.preferred_batch().unwrap_or(0));

    let seg_width = match params.update {
        // r1/r2 use a single whole-list lock.
        UpdateStrategy::InsertAll | UpdateStrategy::SelectiveSingleLock => graph.k(),
        UpdateStrategy::SelectiveSegmented => params.segment_width,
    };

    if params.trace_phi {
        stats.phi_trace.push(graph.phi());
    }

    for _iter in 0..params.max_iter {
        // ---- sampling ----
        let lists = timers.scope("1.sample", || parallel_sample(graph, params.p, threads));

        // ---- cross-matching + update ----
        let iter_updates;
        {
            let cg = ConcurrentGraph::new(graph, seg_width);
            let cursor = AtomicUsize::new(0);
            let nbatches = crate::util::ceil_div(n, batch);
            let err: std::sync::Mutex<Option<anyhow::Error>> = std::sync::Mutex::new(None);
            crossbeam_utils::thread::scope(|scope| {
                for _ in 0..threads {
                    let cg = &cg;
                    let cursor = &cursor;
                    let lists = &lists;
                    let timers = &timers;
                    let err = &err;
                    scope.spawn(move |_| loop {
                        let b = cursor.fetch_add(1, Ordering::Relaxed);
                        if b >= nbatches || err.lock().unwrap().is_some() {
                            return;
                        }
                        let start = b * batch;
                        let end = (start + batch).min(n);
                        if let Err(e) =
                            process_batch(ds, cg, lists, start, end, engine, params, group_fn, timers)
                        {
                            *err.lock().unwrap() = Some(e);
                            return;
                        }
                    });
                }
            })
            .unwrap();
            if let Some(e) = err.into_inner().unwrap() {
                return Err(e);
            }
            iter_updates = cg.updates();
        }

        // ---- end-of-iteration segment merge ----
        timers.scope("4.normalize", || graph.normalize_all(threads));

        stats.iters += 1;
        stats.updates.push(iter_updates);
        if params.trace_phi {
            stats.phi_trace.push(graph.phi());
        }
        // classic NN-Descent early termination
        if (iter_updates as f64) < params.delta * (n * graph.k()) as f64 {
            break;
        }
    }

    stats.seconds = total.secs();
    stats.phases = timers.snapshot();
    Ok(stats)
}

/// Evaluate + apply one batch of object locals.
#[allow(clippy::too_many_arguments)]
fn process_batch(
    ds: &Dataset,
    cg: &ConcurrentGraph,
    lists: &SampledLists,
    start: usize,
    end: usize,
    engine: &dyn CrossmatchEngine,
    params: &GnndParams,
    group_fn: Option<&(dyn Fn(u32) -> i32 + Sync)>,
    timers: &PhaseTimers,
) -> crate::Result<()> {
    let s = lists.cap;
    let rows = end - start;
    let new_ids = &lists.new_ids[start * s..end * s];
    let old_ids = &lists.old_ids[start * s..end * s];
    let to_group = |id: u32| -> i32 {
        if id == EMPTY {
            -1
        } else {
            match group_fn {
                Some(f) => f(id),
                None => id as i32,
            }
        }
    };
    let groups_new: Vec<i32> = new_ids.iter().map(|&id| to_group(id)).collect();
    let groups_old: Vec<i32> = old_ids.iter().map(|&id| to_group(id)).collect();
    let batch = Batch { s, rows, new_ids, old_ids, groups_new: &groups_new, groups_old: &groups_old };

    match params.update {
        UpdateStrategy::InsertAll => {
            // GNND-r1: full distance matrices, every produced pair
            // updates the graph in both directions (classic semantics).
            let t = Timer::start();
            let full = engine.crossmatch_full(ds, &batch)?;
            timers.add("2.crossmatch", t.secs());
            let t = Timer::start();
            for r in 0..rows {
                let base = r * s;
                for i in 0..s {
                    let u = new_ids[base + i];
                    if u == EMPTY {
                        continue;
                    }
                    for j in (i + 1)..s {
                        let d = full.nn[(r * s + i) * s + j];
                        if d.is_finite() {
                            let v = new_ids[base + j];
                            cg.insert(u as usize, v, d);
                            cg.insert(v as usize, u, d);
                        }
                    }
                    for j in 0..s {
                        let d = full.no[(r * s + i) * s + j];
                        if d.is_finite() {
                            let v = old_ids[base + j];
                            cg.insert(u as usize, v, d);
                            cg.insert(v as usize, u, d);
                        }
                    }
                }
            }
            timers.add("3.update", t.secs());
        }
        UpdateStrategy::SelectiveSingleLock | UpdateStrategy::SelectiveSegmented => {
            // Selective update (paper §4.3): only the Algorithm-2
            // winners are inserted.
            let t = Timer::start();
            let out = engine.crossmatch(ds, &batch)?;
            timers.add("2.crossmatch", t.secs());
            let t = Timer::start();
            for r in 0..rows {
                let base = r * s;
                for i in 0..s {
                    let u = new_ids[base + i];
                    if u != EMPTY {
                        let li = base + i;
                        if out.nn_idx[li] >= 0 {
                            let v = new_ids[base + out.nn_idx[li] as usize];
                            cg.insert(u as usize, v, out.nn_dist[li]);
                        }
                        if out.no_idx[li] >= 0 {
                            let v = old_ids[base + out.no_idx[li] as usize];
                            cg.insert(u as usize, v, out.no_dist[li]);
                        }
                    }
                    let uo = old_ids[base + i];
                    if uo != EMPTY {
                        let li = base + i;
                        if out.on_idx[li] >= 0 {
                            let v = new_ids[base + out.on_idx[li] as usize];
                            cg.insert(uo as usize, v, out.on_dist[li]);
                        }
                    }
                }
            }
            timers.add("3.update", t.secs());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::gnnd::engine::NativeEngine;
    use crate::metrics::recall_at;
    use crate::util::rng::Rng;

    fn build_with(params: &GnndParams, ds: &Dataset) -> (KnnGraph, BuildStats) {
        let mut rng = Rng::new(params.seed);
        let mut g = KnnGraph::random_init(ds, params.k, &mut rng);
        let stats = refine(ds, &mut g, &NativeEngine, params, None).unwrap();
        (g, stats)
    }

    #[test]
    fn converges_to_high_recall_on_clustered_data() {
        let ds = synth::clustered(600, 8, 1);
        let params = GnndParams::default().with_k(10).with_p(5).with_iters(10);
        let (g, stats) = build_with(&params, &ds);
        g.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 10);
        let r = recall_at(&g, &truth, None, 10);
        assert!(r > 0.90, "recall {r} too low (stats {stats:?})");
    }

    #[test]
    fn all_strategies_reach_similar_quality() {
        let ds = synth::clustered(400, 8, 2);
        let truth = groundtruth::exact_topk(&ds, 10);
        let mut recalls = Vec::new();
        for update in [
            UpdateStrategy::InsertAll,
            UpdateStrategy::SelectiveSingleLock,
            UpdateStrategy::SelectiveSegmented,
        ] {
            let params = GnndParams::default()
                .with_k(16)
                .with_p(8)
                .with_iters(8)
                .with_update(update);
            let (g, _) = build_with(&params, &ds);
            g.check_invariants().unwrap();
            recalls.push(recall_at(&g, &truth, None, 10));
        }
        for (i, r) in recalls.iter().enumerate() {
            assert!(*r > 0.85, "strategy {i} recall {r}");
        }
    }

    #[test]
    fn phi_is_monotone_nonincreasing() {
        let ds = synth::clustered(300, 6, 3);
        let mut params = GnndParams::default().with_k(8).with_p(4).with_iters(6);
        params.trace_phi = true;
        let mut rng = Rng::new(9);
        let mut g = KnnGraph::random_init(&ds, params.k, &mut rng);
        let stats = refine(&ds, &mut g, &NativeEngine, &params, None).unwrap();
        assert!(stats.phi_trace.len() >= 2);
        for w in stats.phi_trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "phi increased: {:?}", stats.phi_trace);
        }
    }

    #[test]
    fn early_termination_on_convergence() {
        let ds = synth::clustered(200, 4, 4);
        let params = GnndParams::default().with_k(8).with_p(4).with_iters(50);
        let (_, stats) = build_with(&params, &ds);
        assert!(stats.iters < 50, "did not early-terminate: {}", stats.iters);
    }

    #[test]
    fn single_thread_matches_quality_of_multi() {
        let ds = synth::clustered(300, 6, 5);
        let truth = groundtruth::exact_topk(&ds, 10);
        let p1 = GnndParams::default().with_k(12).with_p(6).with_threads(1);
        let p4 = GnndParams::default().with_k(12).with_p(6).with_threads(4);
        let (g1, _) = build_with(&p1, &ds);
        let (g4, _) = build_with(&p4, &ds);
        let r1 = recall_at(&g1, &truth, None, 10);
        let r4 = recall_at(&g4, &truth, None, 10);
        assert!((r1 - r4).abs() < 0.08, "r1={r1} r4={r4}");
    }

    #[test]
    fn merge_mode_group_fn_restricts_pairs() {
        // With all objects in ONE group, every pair is masked: the graph
        // must not change at all.
        let ds = synth::clustered(120, 4, 6);
        let params = GnndParams::default().with_k(6).with_p(3).with_iters(2);
        let mut rng = Rng::new(11);
        let mut g = KnnGraph::random_init(&ds, params.k, &mut rng);
        let before = g.phi();
        let all_same: &(dyn Fn(u32) -> i32 + Sync) = &|_| 0;
        let stats = refine(&ds, &mut g, &NativeEngine, &params, Some(all_same)).unwrap();
        assert_eq!(stats.updates.iter().sum::<usize>(), 0);
        assert!((g.phi() - before).abs() < 1e-9);
    }
}
