//! GNND — the GPU-architecture redesign of NN-Descent (paper §4),
//! executed by the Rust coordinator over AOT-compiled XLA artifacts.
//!
//! Public API:
//!
//! ```no_run
//! use gnnd::dataset::synth;
//! use gnnd::gnnd::{build, build_with_stats, GnndParams};
//!
//! let ds = synth::sift_like(50_000, 7);
//! let params = GnndParams::default().with_k(32).with_p(16);
//! let out = build_with_stats(&ds, &params).unwrap();
//! println!("{} iterations, phi={}", out.stats.iters, out.graph.phi());
//! ```

pub mod descent;
pub mod engine;
pub mod sample;

pub use crate::config::{EngineKind, GnndParams, UpdateStrategy};
pub use descent::{refine, BuildStats};
pub use engine::{Batch, CrossmatchEngine, CrossmatchResult, NativeEngine};

use crate::dataset::Dataset;
use crate::graph::KnnGraph;
use crate::util::rng::Rng;

/// A finished build: the graph plus its statistics.
pub struct BuildOutput {
    pub graph: KnnGraph,
    pub stats: BuildStats,
}

/// Instantiate the engine selected by `params` for dataset shape
/// `(s, d, metric)` where `s = 2p` is the sampled-list width.
pub fn make_engine(
    params: &GnndParams,
    ds: &Dataset,
) -> crate::Result<Box<dyn CrossmatchEngine>> {
    match params.engine {
        EngineKind::Native => Ok(Box::new(NativeEngine)),
        EngineKind::Pjrt => {
            // pool size ~ worker threads (capped: each pool slot costs
            // one compile + one client); see PjrtEngine docs.
            let threads = if params.threads == 0 {
                crate::util::num_threads()
            } else {
                params.threads
            };
            let eng = crate::runtime::PjrtEngine::load_pooled(
                &params.artifacts_dir,
                2 * params.p,
                ds.d,
                ds.metric,
                threads.min(8),
            )?;
            Ok(Box::new(eng))
        }
    }
}

/// Build a k-NN graph for `ds` (paper Algorithm 1, end to end).
pub fn build(ds: &Dataset, params: &GnndParams) -> crate::Result<KnnGraph> {
    Ok(build_with_stats(ds, params)?.graph)
}

/// Build, returning statistics (phi traces, per-phase timing).
pub fn build_with_stats(ds: &Dataset, params: &GnndParams) -> crate::Result<BuildOutput> {
    let engine = make_engine(params, ds)?;
    build_with_engine(ds, params, engine.as_ref())
}

/// Build with a caller-provided engine (lets callers amortize PJRT
/// compilation across many builds — shards, benches).
pub fn build_with_engine(
    ds: &Dataset,
    params: &GnndParams,
    engine: &dyn CrossmatchEngine,
) -> crate::Result<BuildOutput> {
    params.validate()?;
    let mut rng = Rng::new(params.seed);
    let mut graph = KnnGraph::random_init(ds, params.k.min(ds.len() - 1), &mut rng);
    let stats = refine(ds, &mut graph, engine, params, None)?;
    Ok(BuildOutput { graph, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::metrics::recall_at;

    #[test]
    fn build_end_to_end_native() {
        let ds = synth::clustered(500, 8, 7);
        let params = GnndParams::default().with_k(10).with_p(5);
        let out = build_with_stats(&ds, &params).unwrap();
        out.graph.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 10);
        let r = recall_at(&out.graph, &truth, None, 10);
        assert!(r > 0.9, "recall {r}");
        assert!(out.stats.seconds > 0.0);
        assert!(!out.stats.updates.is_empty());
    }

    #[test]
    fn k_clamped_for_tiny_datasets() {
        let ds = synth::uniform(5, 3, 8);
        let params = GnndParams::default().with_k(32).with_p(16).with_iters(2);
        let out = build_with_stats(&ds, &params).unwrap();
        assert_eq!(out.graph.k(), 4);
        out.graph.check_invariants().unwrap();
    }

    #[test]
    fn deterministic_for_fixed_seed_single_thread() {
        let ds = synth::clustered(200, 6, 9);
        let params = GnndParams::default()
            .with_k(8)
            .with_p(4)
            .with_threads(1)
            .with_seed(123);
        let a = build(&ds, &params).unwrap();
        let b = build(&ds, &params).unwrap();
        for u in 0..a.n() {
            assert_eq!(a.list(u), b.list(u), "u={u}");
        }
    }
}
