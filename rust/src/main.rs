//! `gnnd` — the command-line launcher for the GNND k-NN graph
//! construction system.
//!
//! Subcommands:
//!
//! ```text
//! gnnd gen-data     --name sift --n 20000 --out data.dsb [--seed S]
//! gnnd ground-truth --data data.dsb --k 10 --out gt.ivecs [--sample M]
//! gnnd build        --data data.dsb --out graph.knng [--config cfg] [--set k=v ...]
//! gnnd merge        --data data.dsb --n1 N --g1 a.knng --g2 b.knng --out graph.knng
//! gnnd ooc-build    --data data.dsb --dir shards/ --shards 8 --workers 2 --out graph.knng
//!                   [--quantize f32|scalar|pq [--pq-m M]]
//! gnnd quantize     <in.dsb out.dsb | shard-dir/> [--pq-m M]
//! gnnd eval         --data data.dsb --graph graph.knng --truth gt.ivecs [--at 10]
//! gnnd search       (--data data.dsb --graph graph.knng | --shards dir/ [--probe-shards P]
//!                   [--route-slack S] [--memory-budget MB] [--residency shard|block]
//!                   [--block-size KiB] [--search-threads N] [--quantize f32|scalar|pq])
//!                   (--query-id N | --queries q.dsb [--out res.ivecs])
//!                   [--k 10] [--ef 64] [--rerank 1] [--entries 8]
//!                   [--entry-strategy random|kmeans|hierarchy]
//!                   [--beam-width 0] [--max-hops 0] [--search-seed S] [--threads 0]
//! gnnd serve-bench  (--data data.dsb --graph graph.knng | --shards dir/ [--probe-shards P]
//!                   [--route-slack S] [--memory-budget MB] [--residency shard|block]
//!                   [--block-size KiB] [--search-threads N] [--quantize f32|scalar|pq]
//!                   [--data data.dsb])
//!                   [--k 10] [--ef 8,16,32,64,128] [--rerank 1]
//!                   [--queries 2000] [--distinct 1000] [--threads 0]
//!                   [--arrival-rate R] [--arrival poisson|uniform]
//!                   [--entries 8] [--entry-strategy random|kmeans|hierarchy]
//!                   [--beam-width 0]
//!                   [--max-hops 0] [--search-seed S] [--seed S]
//!                   [--trace-sample N] [--trace-out traces.jsonl] [--metrics-out m.jsonl]
//! gnnd serve        (--data data.dsb --graph graph.knng | --shards dir/ [shard flags])
//!                   --listen 127.0.0.1:7700 [--coalesce-window 100] [--queue-limit 1024]
//!                   [--exec-threads 0] [--ef 64] [--k-flags as search]
//!                   [--stats-out stats.json] [--debug-slow-shard-ms 0]
//! gnnd capacity     (--target host:port --data data.dsb
//!                   | --data data.dsb --graph graph.knng | --shards dir/)
//!                   [--slo-ms 50] [--iters 7] [--ef 64] [--k 10] [--queries 2000]
//!                   [--distinct 1000] [--threads 0] [--arrival poisson|uniform] [--seed S]
//! gnnd trace        traces.jsonl [--top 5]
//! gnnd experiment   fig4|fig5|fig6|fig7|table2|all [--scale quick|standard|full]
//! ```
//!
//! `serve` runs the real network front end: a TCP listener speaking
//! the length-prefixed binary protocol of `gnnd::search::proto`,
//! coalescing queries that arrive within `--coalesce-window <µs>` into
//! one batched executor pass (bit-identical to serving them one at a
//! time) and shedding load with an explicit `overloaded` response once
//! the pending-query queue hits `--queue-limit` (0 = unbounded). It
//! serves the same index layouts as `search` and takes the same search
//! knobs; `--stats-out <file>` keeps an atomically-rewritten telemetry
//! snapshot on disk (refreshed twice a second, so it survives a hard
//! kill). `serve-bench --target <addr>` repoints the whole bench
//! harness at such a live server as a network client (requires
//! `--data` for queries and ground truth — the corpus stays local),
//! and `gnnd capacity` binary-searches the highest offered arrival
//! rate whose accepted-query `queue_p99` stays under `--slo-ms`
//! without overload or shedding, printing a parseable
//! `capacity_qps=<rate>` line.
//!
//! `search` answers ANN queries over a finished graph (single query or
//! a batched `.dsb` query file); `serve-bench` replays a query stream
//! and prints the recall-vs-QPS table over an `ef` sweep — closed loop
//! by default (workers issue as fast as they can, measuring capacity),
//! or *open loop* with `--arrival-rate R`: queries arrive on a seeded
//! deterministic schedule (`--arrival poisson|uniform`) at R qps
//! whether or not a worker is free, so the rows additionally report
//! the offered `rate`, queue-delay percentiles (`queue_p50_ms` /
//! `queue_p99_ms`) and an `overload` flag when the achieved rate falls
//! short of the offered one. Both serve either a monolithic graph
//! (`--data` + `--graph`) or an `ooc-build` shard directory
//! (`--shards`, scatter-gather across the per-shard graphs;
//! `--probe-shards` limits each query to the P nearest shards by
//! centroid, clamped to the manifest shard count). Shard residency is
//! managed: `--memory-budget <MB>` caps resident bytes (LRU eviction,
//! 0 = unbounded) so shard directories larger than RAM stay servable.
//! `--residency` picks the granularity: `shard` (default) faults in
//! whole `.dsb`/`.knng` pairs; `block` serves shards straight from
//! disk in `--block-size <KiB>` (default 64) row-aligned blocks
//! through a shared budget-capped block cache — cold-start cost
//! proportional to the rows a query actually visits, budgets smaller
//! than one shard allowed, results bit-identical either way.
//! `--search-threads <N>` fans the scatter phase across a persistent
//! worker pool spawned once at open (0 clamps to 1 with a warning).
//!
//! Quantized serving: `gnnd quantize` converts a `.dsb` file (two
//! positionals: in, out) or an `ooc-build` shard directory (one
//! positional; writes `quant_<i>.dsb` sidecars next to the f32 shards)
//! to u8 scalar-quantized codes — ~4x less vector payload per byte of
//! residency budget. With `--pq-m M` it instead product-quantizes to
//! `M` bytes per row (`pq_<i>.dsb` sidecars in the shard-dir form):
//! `M` subquantizers of 256 k-means centroids each, beam distances
//! computed from a per-query ADC lookup table. `--quantize
//! scalar|pq` on `search`/`serve-bench --shards` serves from the
//! matching sidecars (the f32 shards stay on disk as the exact-rerank
//! source; `true`/`false` still parse as scalar/f32), and `--rerank R`
//! re-scores the best `R*k` beam survivors at full f32 precision so
//! recall recovers to within points of the f32 index while the beam
//! itself runs on cheap compressed distances. `ooc-build --quantize
//! scalar|pq` fits and writes the sidecars immediately after the
//! build, and every ooc-build now also pre-builds the per-shard
//! `hier_<s>.bin` entry-hierarchy sidecars so the first
//! `--entry-strategy hierarchy` open is a file read, not a rebuild.
//! Distance kernels (f32, u8 and the PQ LUT gather loop) have
//! explicit AVX2/NEON implementations behind the `simd` cargo
//! feature — runtime-detected, bit-identical to the scalar paths.
//!
//! Entry & routing: `--entry-strategy hierarchy` seeds every beam from
//! a GGNN-style coarse-to-fine descent instead of fixed entries — the
//! hierarchy persists as a `<graph>.hier.bin` sidecar next to a
//! monolithic graph (`hier_<s>.bin` per shard in a shard directory)
//! and is rebuilt automatically when stale. `--route-slack S` (sharded
//! only, `S >= 1.0`) makes `--probe-shards` a *cap*: each query probes
//! only the shards whose best route-centroid distance is within
//! `S x d_best` of the nearest shard's. Shard manifests carry
//! per-shard k-means `route_centroids` (fit by `ooc-build`; older
//! manifests are backfilled by `gnnd quantize <shard-dir>` or fall
//! back to the single mean centroid).
//!
//! `serve-bench --shards` prints the residency counters
//! (hits/misses/evictions/hit rate, block fetches, bytes read,
//! doorkeeper rejections) and folds them — plus the sweep rows as a
//! `"serve"` block and the full metrics-registry snapshot as a
//! `"telemetry"` block — into the directory's `stats.json`.
//!
//! Observability ([`gnnd::telemetry`]): `serve-bench --trace-sample N`
//! records a full per-query trace (route/scatter/gather spans with
//! per-shard hops, distance evals and block traffic) for every Nth
//! query, appended as JSON Lines to `--trace-out` (default
//! `traces.jsonl`); `gnnd trace <file>` pretty-prints the collected
//! traces. `--metrics-out <file>` writes one JSON line per sweep
//! operating point with the cumulative registry snapshot and the
//! per-point delta. Tracing is observation-only: results are
//! bit-identical with it on or off.
//!
//! Flat `key=value` config files (see `configs/`) plus `--set` overrides
//! configure every GnndParams knob; `--set engine=pjrt` switches the
//! cross-matching hot path onto the AOT artifacts (`make artifacts`;
//! requires the `pjrt` cargo feature).

use std::collections::VecDeque;

use anyhow::{bail, Context};

use gnnd::config::{ConfigMap, GnndParams};
use gnnd::dataset::{groundtruth, io, synth};
use gnnd::experiments::{self, Scale};
use gnnd::graph::KnnGraph;
use gnnd::merge::outofcore::{
    build_out_of_core, pq_quantize_store, quantize_store, OutOfCoreConfig, ResidencyMode,
    ShardCompression, ShardStore, STATS_FILE,
};
use gnnd::metrics::{recall_at, Report};
use gnnd::search::server::{self, RemoteIndex, Server};
use gnnd::search::sharded::{clamp_probe, clamp_search_threads, ShardedIndex};
use gnnd::search::{
    batch::BatchExecutor, hierarchy, serve, AnnIndex, EntryStrategy, SearchIndex, SearchParams,
};
use gnnd::telemetry::{self, trace::read_traces, trace::render_report, trace::TraceWriter};
use gnnd::util::json::Json;
use gnnd::util::timer::Timer;

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, Vec<String>>,
}

fn parse_args(mut argv: VecDeque<String>) -> Args {
    let mut positional = Vec::new();
    let mut flags: std::collections::BTreeMap<String, Vec<String>> = Default::default();
    while let Some(a) = argv.pop_front() {
        if let Some(name) = a.strip_prefix("--") {
            let val = argv.pop_front().unwrap_or_default();
            flags.entry(name.to_string()).or_default().push(val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    fn req(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name).with_context(|| format!("missing required --{name}"))
    }

    fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    /// Shared search knobs. `--ef` is intentionally not parsed here:
    /// `search` takes a single value, `serve-bench` a CSV sweep.
    fn search_params(&self) -> anyhow::Result<SearchParams> {
        let d = SearchParams::default();
        let p = SearchParams {
            ef: d.ef,
            beam_width: self.parse_or("beam-width", d.beam_width)?,
            max_hops: self.parse_or("max-hops", d.max_hops)?,
            n_entry: self.parse_or("entries", d.n_entry)?,
            entry: self.parse_or("entry-strategy", d.entry)?,
            seed: self.parse_or("search-seed", d.seed)?,
            rerank: self.parse_or("rerank", d.rerank)?,
            route_slack: self.parse_or("route-slack", d.route_slack)?,
        };
        p.validate()?;
        Ok(p)
    }

    fn params(&self) -> anyhow::Result<GnndParams> {
        let mut cfg = match self.get("config") {
            Some(path) => ConfigMap::from_file(path)?,
            None => ConfigMap::default(),
        };
        if let Some(sets) = self.flags.get("set") {
            cfg.apply_overrides(sets.iter().map(|s| s.as_str()))?;
        }
        GnndParams::from_config(&cfg)
    }
}

fn main() {
    let argv: VecDeque<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    eprintln!(
        "gnnd — GPU-architecture NN-Descent on a Rust+XLA stack\n\
         usage: gnnd <gen-data|ground-truth|build|merge|ooc-build|quantize|eval|search|serve|capacity|serve-bench|trace|experiment> [flags]\n\
         see rust/src/main.rs header or README.md for full flag reference"
    );
}

fn run(mut argv: VecDeque<String>) -> anyhow::Result<()> {
    let cmd = argv.pop_front().unwrap();
    let args = parse_args(argv);
    match cmd.as_str() {
        "gen-data" => {
            let name = args.req("name")?;
            let n: usize = args.req("n")?.parse()?;
            let seed: u64 = args.parse_or("seed", 42u64)?;
            let out = args.req("out")?;
            let ds = synth::by_name(name, n, seed)?;
            io::write_dsb(&ds, out)?;
            println!("wrote {out}: {} x {} ({})", ds.len(), ds.d, ds.metric);
        }
        "ground-truth" => {
            let ds = io::read_dsb(args.req("data")?)?;
            let k: usize = args.parse_or("k", 10usize)?;
            let out = args.req("out")?;
            let t = Timer::start();
            let rows = match args.get("sample") {
                Some(m) => {
                    let m: usize = m.parse()?;
                    let (ids, rows) = groundtruth::sampled_truth(&ds, m, k, 0xE7A1);
                    let idpath = format!("{out}.ids");
                    io::write_ivecs(
                        &[ids.iter().map(|&i| i as u32).collect::<Vec<_>>()],
                        &idpath,
                    )?;
                    println!("sampled ids -> {idpath}");
                    rows
                }
                None => groundtruth::exact_topk(&ds, k),
            };
            io::write_ivecs(&rows, out)?;
            println!("ground truth ({} rows, k={k}) in {:.2}s -> {out}", rows.len(), t.secs());
        }
        "build" => {
            let ds = io::read_dsb(args.req("data")?)?;
            let params = args.params()?;
            let t = Timer::start();
            let out = gnnd::gnnd::build_with_stats(&ds, &params)?;
            println!(
                "built {} x k={} in {:.2}s ({} iters, engine={}, phases: {:?})",
                out.graph.n(),
                out.graph.k(),
                t.secs(),
                out.stats.iters,
                out.stats.engine,
                out.stats.phases
            );
            out.graph.save(args.req("out")?)?;
        }
        "merge" => {
            let ds = io::read_dsb(args.req("data")?)?;
            let n1: usize = args.req("n1")?.parse()?;
            let g1 = KnnGraph::load(args.req("g1")?)?;
            let g2 = KnnGraph::load(args.req("g2")?)?;
            let params = args.params()?;
            let engine = gnnd::gnnd::make_engine(&params, &ds)?;
            let t = Timer::start();
            let (g, stats) = gnnd::merge::merge(&ds, n1, &g1, &g2, &params, engine.as_ref())?;
            println!("merged in {:.2}s ({} refinement iters)", t.secs(), stats.iters);
            g.save(args.req("out")?)?;
        }
        "ooc-build" => {
            let ds = io::read_dsb(args.req("data")?)?;
            let params = args.params()?;
            let cfg = OutOfCoreConfig {
                shards: args.parse_or("shards", 4usize)?,
                workers: args.parse_or("workers", 1usize)?,
                params: params.clone(),
            };
            let engine = gnnd::gnnd::make_engine(&params, &ds)?;
            let t = Timer::start();
            let (g, stats) =
                build_out_of_core(&ds, args.req("dir")?, &cfg, engine.as_ref())?;
            println!(
                "out-of-core build in {:.2}s (shard builds {:.2}s, {} merges over {} rounds in {:.2}s)",
                t.secs(),
                stats.build_secs,
                stats.merges,
                stats.rounds,
                stats.merge_secs
            );
            println!("stats -> {}/{STATS_FILE}", args.req("dir")?);
            g.save(args.req("out")?)?;
            match args.parse_or("quantize", ShardCompression::F32)? {
                ShardCompression::F32 => {}
                ShardCompression::Scalar => {
                    let qp = quantize_store(args.req("dir")?)?;
                    println!(
                        "quantized {} shards (d={}) -> {}/quant_*.dsb",
                        cfg.shards,
                        qp.d(),
                        args.req("dir")?
                    );
                }
                ShardCompression::Pq => {
                    let m: usize = args.parse_or("pq-m", (ds.d / 8).max(1))?;
                    let pp = pq_quantize_store(args.req("dir")?, m)?;
                    println!(
                        "pq-quantized {} shards (d={}, m={}) -> {}/pq_*.dsb",
                        cfg.shards,
                        pp.d(),
                        pp.m(),
                        args.req("dir")?
                    );
                }
            }
        }
        "quantize" => {
            let input = args
                .positional
                .first()
                .map(|s| s.as_str())
                .context("usage: gnnd quantize <in.dsb> <out.dsb>  |  gnnd quantize <shard-dir>")?;
            let t = Timer::start();
            let pq_m: Option<usize> = match args.get("pq-m") {
                None => None,
                Some(v) => Some(v.parse().map_err(|e| anyhow::anyhow!("--pq-m {v:?}: {e}"))?),
            };
            if std::path::Path::new(input).join("manifest.json").is_file() {
                // an ooc-build shard directory: fit one shared code
                // space over every shard, write the per-shard sidecars
                anyhow::ensure!(
                    args.positional.len() == 1,
                    "quantize <shard-dir> takes no output path (sidecars land in the directory)"
                );
                match pq_m {
                    Some(m) => {
                        let pp = pq_quantize_store(input, m)?;
                        println!(
                            "pq-quantized shard directory {input} (d={}, m={}) in {:.2}s \
                             -> {input}/pq_*.dsb",
                            pp.d(),
                            pp.m(),
                            t.secs()
                        );
                    }
                    None => {
                        let qp = quantize_store(input)?;
                        println!(
                            "quantized shard directory {input} (d={}) in {:.2}s \
                             -> {input}/quant_*.dsb",
                            qp.d(),
                            t.secs()
                        );
                    }
                }
            } else {
                let out = args
                    .positional
                    .get(1)
                    .map(|s| s.as_str())
                    .context("quantize <in.dsb> needs an output path (second positional)")?;
                let ds = io::read_dsb(input)?;
                anyhow::ensure!(
                    !ds.is_compressed(),
                    "{input} is already quantized ({} backing)",
                    ds.backing_kind()
                );
                match pq_m {
                    Some(m) => {
                        io::write_dsb_pq(&ds, m, out)?;
                        println!(
                            "pq-quantized {input} ({} x {}, m={m}) in {:.2}s -> {out} \
                             ({m} bytes/row + shared codebooks)",
                            ds.len(),
                            ds.d,
                            t.secs()
                        );
                    }
                    None => {
                        io::write_dsb_quantized(&ds, out)?;
                        println!(
                            "quantized {input} ({} x {}) in {:.2}s -> {out} \
                             (u8 codes, ~4x smaller)",
                            ds.len(),
                            ds.d,
                            t.secs()
                        );
                    }
                }
            }
        }
        "eval" => {
            let ds = io::read_dsb(args.req("data")?)?;
            let g = KnnGraph::load(args.req("graph")?)?;
            let truth = io::read_ivecs(args.req("truth")?)?;
            let at: usize = args.parse_or("at", 10usize)?;
            let ids: Option<Vec<usize>> = match args.get("truth-ids") {
                Some(p) => Some(
                    io::read_ivecs(p)?
                        .first()
                        .map(|r| r.iter().map(|&x| x as usize).collect())
                        .unwrap_or_default(),
                ),
                None => None,
            };
            let r = recall_at(&g, &truth, ids.as_deref(), at);
            println!("recall@{at} = {r:.4}   phi(G) = {:.4e}", g.phi());
            let _ = ds;
        }
        "search" => {
            let k: usize = args.parse_or("k", 10usize)?;
            let params = args.search_params()?.with_ef(args.parse_or("ef", 64usize)?);
            match args.get("shards") {
                Some(dir) => {
                    let index = open_sharded_index(&args, dir, params)?;
                    run_search(&args, &index, k)?;
                }
                None => {
                    let ds = io::read_dsb(args.req("data")?)?;
                    let graph_path = args.req("graph")?;
                    let g = KnnGraph::load(graph_path)?;
                    let index = open_monolithic_index(&ds, &g, graph_path, params)?;
                    run_search(&args, &index, k)?;
                }
            }
        }
        "serve" => {
            let listen = args.req("listen")?;
            let params = args.search_params()?.with_ef(args.parse_or("ef", 64usize)?);
            let dcfg = server::ServerConfig::default();
            let window_us: u64 = args.parse_or("coalesce-window", dcfg.coalesce_window_us)?;
            let scfg = server::ServerConfig {
                coalesce_window_us: server::clamp_coalesce_window_warn(window_us),
                queue_limit: args.parse_or("queue-limit", dcfg.queue_limit)?,
                exec_threads: args.parse_or("exec-threads", dcfg.exec_threads)?,
                debug_slow_shard_ms: args.parse_or("debug-slow-shard-ms", 0u64)?,
                stats_out: args.get("stats-out").map(|s| s.to_string()),
            };
            match args.get("shards") {
                Some(dir) => {
                    let index = open_sharded_index(&args, dir, params)?;
                    run_serve(listen, scfg, &index)?;
                }
                None => {
                    let ds = io::read_dsb(args.req("data")?)?;
                    let graph_path = args.req("graph")?;
                    let g = KnnGraph::load(graph_path)?;
                    let index = open_monolithic_index(&ds, &g, graph_path, params)?;
                    run_serve(listen, scfg, &index)?;
                }
            }
        }
        "capacity" => {
            let dcfg = serve::ServeConfig::default();
            let slo_ms: f64 = args.parse_or("slo-ms", 50.0f64)?;
            anyhow::ensure!(
                slo_ms > 0.0 && slo_ms.is_finite(),
                "--slo-ms must be a positive finite latency bound in ms, got {slo_ms}"
            );
            let iters: usize = args.parse_or("iters", 7usize)?;
            anyhow::ensure!(iters >= 1, "--iters must be >= 1 (bisection needs a probe)");
            let cfg = serve::ServeConfig {
                k: args.parse_or("k", dcfg.k)?,
                ef_sweep: vec![args.parse_or("ef", 64usize)?],
                n_queries: args.parse_or("queries", dcfg.n_queries)?,
                distinct_queries: args.parse_or("distinct", dcfg.distinct_queries)?,
                threads: args.parse_or("threads", dcfg.threads)?,
                params: args.search_params()?,
                seed: args.parse_or("seed", dcfg.seed)?,
                arrival_rate: 0.0, // each bisection probe sets its own
                arrival: args.parse_or("arrival", dcfg.arrival)?,
                trace_sample: 0,
            };
            let res = if let Some(target) = args.get("target") {
                anyhow::ensure!(
                    args.get("shards").is_none() && args.get("graph").is_none(),
                    "--target is mutually exclusive with --shards/--graph \
                     (the server owns the index)"
                );
                let ds = io::read_dsb(args.req("data").context(
                    "--target needs --data for queries and ground truth \
                     (the corpus stays local)",
                )?)?;
                let index =
                    RemoteIndex::connect_with_retries(target, std::time::Duration::from_secs(10))?;
                serve::capacity_search(&index, &ds, &cfg, slo_ms, iters)?
            } else {
                match args.get("shards") {
                    Some(dir) => {
                        let index = open_sharded_index(&args, dir, cfg.params.clone())?;
                        let ds = match args.get("data") {
                            Some(p) => io::read_dsb(p)?,
                            None => index.concat_dataset()?,
                        };
                        serve::capacity_search(&index, &ds, &cfg, slo_ms, iters)?
                    }
                    None => {
                        let ds = io::read_dsb(args.req("data")?)?;
                        let graph_path = args.req("graph")?;
                        let g = KnnGraph::load(graph_path)?;
                        let index =
                            open_monolithic_index(&ds, &g, graph_path, cfg.params.clone())?;
                        serve::capacity_search(&index, &ds, &cfg, slo_ms, iters)?
                    }
                }
            };
            println!("{}", res.report.render());
            println!("closed_loop_qps={:.1}", res.closed_loop_qps);
            // the line CI greps: highest SLO-feasible offered rate
            println!("capacity_qps={:.1}", res.max_rate);
        }
        "serve-bench" => {
            let dcfg = serve::ServeConfig::default();
            let ef_sweep = match args.get("ef") {
                None => dcfg.ef_sweep.clone(),
                Some(spec) => spec
                    .split(',')
                    .map(|s| {
                        s.trim()
                            .parse::<usize>()
                            .map_err(|e| anyhow::anyhow!("--ef {spec:?}: {e}"))
                    })
                    .collect::<anyhow::Result<Vec<usize>>>()?,
            };
            let arrival_rate: f64 = args.parse_or("arrival-rate", dcfg.arrival_rate)?;
            anyhow::ensure!(
                arrival_rate >= 0.0 && arrival_rate.is_finite(),
                "--arrival-rate must be a finite rate >= 0 (0 = closed loop)"
            );
            let cfg = serve::ServeConfig {
                k: args.parse_or("k", dcfg.k)?,
                ef_sweep,
                n_queries: args.parse_or("queries", dcfg.n_queries)?,
                distinct_queries: args.parse_or("distinct", dcfg.distinct_queries)?,
                threads: args.parse_or("threads", dcfg.threads)?,
                params: args.search_params()?,
                seed: args.parse_or("seed", dcfg.seed)?,
                arrival_rate,
                arrival: args.parse_or("arrival", dcfg.arrival)?,
                trace_sample: args.parse_or("trace-sample", dcfg.trace_sample)?,
            };
            let mut sinks = serve::ServeSinks::default();
            if cfg.trace_sample > 0 {
                let trace_out = args.get("trace-out").unwrap_or("traces.jsonl");
                sinks.trace = Some(TraceWriter::append_to(trace_out)?);
            }
            let t = Timer::start();
            let report = if let Some(target) = args.get("target") {
                // network-client mode: the index lives in a running
                // `gnnd serve` process; this side supplies queries and
                // ground truth, so the corpus must be local
                anyhow::ensure!(
                    args.get("shards").is_none() && args.get("graph").is_none(),
                    "--target is mutually exclusive with --shards/--graph \
                     (the server owns the index)"
                );
                let ds = io::read_dsb(args.req("data").context(
                    "--target needs --data for queries and ground truth \
                     (the corpus stays local)",
                )?)?;
                let index =
                    RemoteIndex::connect_with_retries(target, std::time::Duration::from_secs(10))?;
                serve::run_sweep_with(&index, &ds, &cfg, &mut sinks)?
            } else {
                match args.get("shards") {
                    Some(dir) => {
                        let index = open_sharded_index(&args, dir, cfg.params.clone())?;
                        // queries + ground truth come from the original
                        // corpus; without --data it is re-assembled from
                        // the shards (identical rows, identical order —
                        // except under --quantize, where re-assembly
                        // dequantizes and the measured recall drifts from
                        // the true-corpus number)
                        let ds = match args.get("data") {
                            Some(p) => io::read_dsb(p)?,
                            None => {
                                if index.store().quantized() {
                                    telemetry::warn!(
                                        "serve: no --data with a quantized store; queries and \
                                         ground truth use dequantized rows — pass --data for \
                                         true-corpus recall"
                                    );
                                }
                                index.concat_dataset()?
                            }
                        };
                        let report = serve::run_sweep_with(&index, &ds, &cfg, &mut sinks)?;
                        // serve-time residency counters: printed and folded
                        // into the directory's stats.json next to the
                        // build stats. The last queries' pins have released
                        // but no eviction pass has run since — shed to the
                        // budget first so the snapshot reflects steady state
                        index.store().evict_to_budget();
                        let res = index.residency();
                        println!("residency: {}", res.to_json());
                        // a side-file problem should not discard the sweep
                        match index.store().save_stats_with_residency(&res) {
                            Ok(()) => println!("[residency folded into {dir}/{STATS_FILE}]"),
                            Err(e) => telemetry::warn!(
                                "serve: residency not folded into stats.json: {e:#}"
                            ),
                        }
                        // the sweep rows themselves (including the open-loop
                        // rate/queue_p50_ms/queue_p99_ms/overload columns)
                        // also land in stats.json, so one file carries the
                        // build cost, cache behavior and operating curve
                        let block = serve_block(&report, &cfg);
                        match index.store().save_stats_with_block("serve", block) {
                            Ok(()) => println!("[serve sweep folded into {dir}/{STATS_FILE}]"),
                            Err(e) => telemetry::warn!(
                                "serve: sweep not folded into stats.json: {e:#}"
                            ),
                        }
                        // and the registry itself — counters, gauges and
                        // histograms for the whole sweep in one snapshot
                        let snap = telemetry::global().snapshot().to_json();
                        match index.store().save_stats_with_block("telemetry", snap) {
                            Ok(()) => println!("[telemetry folded into {dir}/{STATS_FILE}]"),
                            Err(e) => telemetry::warn!(
                                "serve: telemetry not folded into stats.json: {e:#}"
                            ),
                        }
                        report
                    }
                    None => {
                        let ds = io::read_dsb(args.req("data")?)?;
                        let graph_path = args.req("graph")?;
                        let g = KnnGraph::load(graph_path)?;
                        let index =
                            open_monolithic_index(&ds, &g, graph_path, cfg.params.clone())?;
                        serve::run_sweep_with(&index, &ds, &cfg, &mut sinks)?
                    }
                }
            };
            println!("{}", report.render());
            if let Some(w) = sinks.trace.as_ref() {
                println!("[{} sampled traces -> {}]", w.written(), w.path().display());
            }
            if let Some(mpath) = args.get("metrics-out") {
                write_metrics_jsonl(mpath, &sinks.metrics_points)?;
                println!("[{} metric points -> {mpath}]", sinks.metrics_points.len());
            }
            match report.save_json("results") {
                Ok(p) => println!("[saved {} — {:.1}s total]", p.display(), t.secs()),
                Err(e) => println!("[save failed: {e}]"),
            }
        }
        "trace" => {
            let path = args
                .positional
                .first()
                .map(|s| s.as_str())
                .context("usage: gnnd trace <traces.jsonl> [--top N]")?;
            let top: usize = args.parse_or("top", 5usize)?;
            let traces = read_traces(path)?;
            print!("{}", render_report(&traces, top));
        }
        "experiment" => {
            let name = args
                .positional
                .first()
                .map(|s| s.as_str())
                .context("experiment name required (fig4|fig5|fig6|fig7|table2|all)")?;
            let scale = match args.get("scale") {
                Some("quick") => Scale::Quick,
                Some("full") => Scale::Full,
                Some("standard") | None => Scale::from_env(),
                Some(other) => bail!("unknown scale {other:?}"),
            };
            experiments::run_by_name(name, scale)?;
        }
        "help" | "--help" | "-h" => print_usage(),
        other => {
            print_usage();
            bail!("unknown subcommand {other:?}");
        }
    }
    Ok(())
}

/// The serve sweep as a JSON block for the shard directory's
/// `stats.json`: one object per operating-point row carrying every
/// column (closed loop: ef/qps/latency/recall; open loop additionally
/// rate, queue_p50_ms, queue_p99_ms and the overload flag), plus the
/// load model that produced them. A closed-loop run is recorded as
/// `"arrival": "closed"` — the configured arrival process never ran,
/// so writing it would misdescribe the sweep to downstream tooling.
fn serve_block(report: &Report, cfg: &serve::ServeConfig) -> Json {
    let rows: Vec<Json> = report
        .rows
        .iter()
        .map(|r| {
            let mut o = Json::obj().set("label", r.label.as_str());
            for (name, v) in &r.cols {
                o = o.set(name, *v);
            }
            o
        })
        .collect();
    let arrival = if cfg.arrival_rate > 0.0 { cfg.arrival.to_string() } else { "closed".into() };
    Json::obj()
        .set("arrival", arrival)
        .set("arrival_rate", cfg.arrival_rate)
        .set("rows", Json::Arr(rows))
}

/// `--metrics-out` payload: one JSON line per sweep operating point
/// carrying the row label, the cumulative registry snapshot taken
/// after that point, and the delta against the previous point (so a
/// point's own block fetches / query work can be read off directly).
fn write_metrics_jsonl(
    path: &str,
    points: &[(String, telemetry::Snapshot, telemetry::Snapshot)],
) -> anyhow::Result<()> {
    use std::io::Write;
    let f = std::fs::File::create(path).with_context(|| format!("create {path}"))?;
    let mut w = std::io::BufWriter::new(f);
    for (label, cum, delta) in points {
        let line = Json::obj()
            .set("point", label.as_str())
            .set("cumulative", cum.to_json())
            .set("delta", delta.to_json());
        writeln!(w, "{line}").with_context(|| format!("write {path}"))?;
    }
    w.flush().with_context(|| format!("flush {path}"))?;
    Ok(())
}

/// The `gnnd serve` body: bind, announce the resolved address on a
/// flushed stdout line (scripts race the listener and parse this —
/// under a pipe stdout is block-buffered, so an unflushed line would
/// sit invisible until exit), then serve until killed.
fn run_serve(
    listen: &str,
    cfg: server::ServerConfig,
    index: &dyn AnnIndex,
) -> anyhow::Result<()> {
    use std::io::Write;
    let srv = Server::bind(listen, cfg)?;
    println!("listening on {}", srv.local_addr()?);
    println!("index: {}", index.describe());
    std::io::stdout().flush().context("flush stdout")?;
    srv.run(index)
}

/// Open a monolithic index over `--data` + `--graph`. Under
/// `--entry-strategy hierarchy` the entry hierarchy is loaded from (or
/// built and persisted to) the `<graph>.hier.bin` sidecar — the same
/// load-or-rebuild gate the sharded path applies to its per-shard
/// `hier_<s>.bin` files.
fn open_monolithic_index<'a>(
    ds: &'a gnnd::dataset::Dataset,
    g: &'a KnnGraph,
    graph_path: &str,
    params: SearchParams,
) -> anyhow::Result<SearchIndex<'a>> {
    if params.entry == EntryStrategy::Hierarchy {
        let cfg = hierarchy::HierConfig { seed: params.seed, ..Default::default() };
        let sidecar = format!("{graph_path}.hier.bin");
        let hier = hierarchy::load_or_build(&sidecar, ds, &cfg);
        SearchIndex::with_hierarchy(ds, g, params, std::sync::Arc::new(hier))
    } else {
        SearchIndex::new(ds, g, params)
    }
}

/// Open `--shards <dir>` with the serving knobs shared by `search` and
/// `serve-bench`: `--probe-shards` (validated against the manifest
/// shard count — phantom shards clamp with a warning), `--memory-budget
/// <MB>` (resident byte budget, 0 = unbounded), `--residency
/// shard|block` with `--block-size <KiB>` (block-granular paging of
/// shard files under the same budget), `--search-threads <N>`
/// (persistent scatter pool participants, 1 = sequential; 0 clamps to
/// 1 with a warning) and `--quantize scalar|pq` (serve from the
/// `quant_<i>.dsb` u8 sidecars or `pq_<i>.dsb` product-quantized
/// sidecars written by `gnnd quantize`, with the f32 shards as the
/// exact-rerank source — pair with `--rerank`; `true`/`false` still
/// parse as scalar/f32).
fn open_sharded_index(
    args: &Args,
    dir: &str,
    params: SearchParams,
) -> anyhow::Result<ShardedIndex> {
    anyhow::ensure!(
        args.get("graph").is_none(),
        "--graph and --shards are mutually exclusive"
    );
    let budget_mb: f64 = args.parse_or("memory-budget", 0.0f64)?;
    anyhow::ensure!(budget_mb >= 0.0, "--memory-budget must be >= 0");
    let budget_bytes = (budget_mb * 1024.0 * 1024.0) as usize;
    let mode: ResidencyMode = args.parse_or("residency", ResidencyMode::Shard)?;
    let block_kib: usize = args.parse_or("block-size", 0usize)?;
    let mode = match (mode, block_kib) {
        (ResidencyMode::Block { .. }, kib) if kib > 0 => {
            ResidencyMode::Block { block_bytes: kib * 1024 }
        }
        (m, kib) => {
            if kib > 0 {
                telemetry::warn!(
                    "search: --block-size only applies with --residency block; ignored"
                );
            }
            m
        }
    };
    let threads: usize = args.parse_or("search-threads", 1usize)?;
    // 0 threads would mean "no scatter workers at all"; previously only
    // scatter_threads()'s max(1) masked it at query time — clamp where
    // the operator can see it, mirroring the --probe-shards clamp
    let (threads, tclamped) = clamp_search_threads(threads);
    if tclamped {
        telemetry::warn!(
            "search: --search-threads 0 would leave no scatter workers; \
             clamped to {threads} (sequential scatter)"
        );
    }
    let compression: ShardCompression = args.parse_or("quantize", ShardCompression::F32)?;
    let store = ShardStore::with_compression(dir, budget_bytes, mode, compression)?;
    let manifest = store.load_manifest()?;
    let probe: usize = args.parse_or("probe-shards", 0usize)?;
    let (probe, clamped) = clamp_probe(probe, manifest.shards);
    if clamped {
        telemetry::warn!(
            "search: --probe-shards exceeds the {} shards in the manifest; \
             clamped to {} (phantom shards cannot be probed)",
            manifest.shards,
            manifest.shards
        );
    }
    // under whole-shard residency a query pins the full data of every
    // probed shard, so peak residency is bounded by the probe set, not
    // the budget; warn when the two disagree. Block residency pins
    // only cheap paged handles — no warning needed (that configuration
    // is exactly what --residency block is for).
    if budget_bytes > 0 && mode == ResidencyMode::Shard {
        let eff = if probe == 0 { manifest.shards } else { probe };
        let mut sizes: Vec<usize> = (0..manifest.shards).map(|s| manifest.shard_bytes(s)).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        let probed_bytes: usize = sizes.iter().take(eff).sum();
        if probed_bytes > budget_bytes {
            telemetry::warn!(
                "search: probing {eff} shards can pin ~{:.1} MB per query, above \
                 --memory-budget {budget_mb} MB; peak residency is bounded by the probe set \
                 — lower --probe-shards or switch to --residency block",
                probed_bytes as f64 / (1024.0 * 1024.0)
            );
        }
    }
    ShardedIndex::from_store(store, params, probe, threads)
}

/// The `search` subcommand body, written against [`AnnIndex`] only —
/// identical behaviour over a monolithic graph or a shard directory.
fn run_search(args: &Args, index: &dyn AnnIndex, k: usize) -> anyhow::Result<()> {
    match (args.get("query-id"), args.get("queries")) {
        (Some(_), Some(_)) => {
            bail!("--query-id and --queries are mutually exclusive")
        }
        (Some(qid), None) => {
            let q: usize = qid.parse()?;
            anyhow::ensure!(q < index.len(), "--query-id {q} out of range (n={})", index.len());
            let t = Timer::start();
            let mut scratch = index.make_scratch();
            let mut out = Vec::new();
            let qv = index.vector(q as u32);
            index.search_ef_into_excluding(&qv, k, 0, q as u32, &mut scratch, &mut out);
            println!(
                "query {q}: top-{k} in {:.3} ms ({} distance evals, {} hops, ef={})",
                t.ms(),
                scratch.dist_evals,
                scratch.hops,
                index.default_ef()
            );
            for (rank, (d, id)) in out.iter().enumerate() {
                println!("  {:>3}. id={id:<10} dist={d}", rank + 1);
            }
        }
        (None, Some(qfile)) => {
            let qs = io::read_dsb(qfile)?;
            anyhow::ensure!(
                qs.d == index.dim(),
                "query dim {} != index dim {}",
                qs.d,
                index.dim()
            );
            anyhow::ensure!(
                qs.metric == index.metric(),
                "query metric {} != index metric {} (cosine queries must be \
                 written with the cosine metric so rows are normalized)",
                qs.metric,
                index.metric()
            );
            let threads: usize = args.parse_or("threads", 0usize)?;
            let t = Timer::start();
            let results = BatchExecutor::new(index, threads).run(qs.raw(), qs.d, k);
            let secs = t.secs();
            println!(
                "{} queries x top-{k} in {:.3}s ({:.0} qps)",
                qs.len(),
                secs,
                qs.len() as f64 / secs.max(1e-9)
            );
            if let Some(out_path) = args.get("out") {
                let rows: Vec<Vec<u32>> = results
                    .iter()
                    .map(|r| r.iter().map(|&(_, id)| id).collect())
                    .collect();
                io::write_ivecs(&rows, out_path)?;
                println!("wrote {out_path}");
            }
        }
        (None, None) => bail!("search needs --query-id <id> or --queries <file.dsb>"),
    }
    Ok(())
}
