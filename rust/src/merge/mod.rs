//! GGM — GPU-based graph merge (paper §5.1, Algorithm 3).
//!
//! Two fully-baked sub-graphs are joined into one half-baked graph: each
//! list keeps its best `k - k/2` entries, the tail `k/2` entries are
//! stashed and replaced with random *cross-subset* samples marked NEW.
//! GNND then refines the joined graph with the subset-label group
//! function, so cross-matching only ever evaluates pairs from different
//! sub-graphs ("the distances between NEW samples will not be
//! calculated" — both NEW samples of an object lie in the other subset).
//! Finally the stashed tails are merged back and each list re-sorted.
//!
//! [`merge`] operates on a combined in-memory dataset; the out-of-core
//! pipeline ([`outofcore`]) generalizes it to global id spaces where
//! list entries may reference objects in shards that are *not* resident
//! (they are stashed for the final re-merge, preserving the paper's
//! "each k-NN list retains the top-k of the whole dataset" invariant).

pub mod outofcore;

use crate::config::GnndParams;
use crate::dataset::Dataset;
use crate::gnnd::engine::CrossmatchEngine;
use crate::gnnd::{self, BuildStats};
use crate::graph::{concurrent::normalize_slice, KnnGraph, Neighbor};
use crate::util::rng::Rng;

/// Merge two k-NN graphs over a combined dataset (paper Algorithm 3).
///
/// `ds` holds the rows of `S1` followed by the rows of `S2`
/// (`n1 = |S1|`); `g1`/`g2` are the sub-graphs in their local id spaces
/// (`g2` ids are offset by `n1` internally). Returns the refined graph
/// over `0..n1+n2` plus the refinement stats.
pub fn merge(
    ds: &Dataset,
    n1: usize,
    g1: &KnnGraph,
    g2: &KnnGraph,
    params: &GnndParams,
    engine: &dyn CrossmatchEngine,
) -> crate::Result<(KnnGraph, BuildStats)> {
    anyhow::ensure!(g1.k() == g2.k(), "sub-graphs must share k");
    anyhow::ensure!(g1.n() == n1, "g1 size mismatch");
    anyhow::ensure!(
        g1.n() + g2.n() == ds.len(),
        "combined dataset must cover both subsets"
    );
    let n2 = g2.n();
    let k = g1.k();
    let half = (k / 2).max(1);
    let keep = k - half;
    let mut rng = Rng::new(params.seed ^ 0x66_6D); // "gm"

    // ---- join into one half-baked graph + stash tails ----
    let mut joined = KnnGraph::empty(n1 + n2, k);
    let mut stash: Vec<Vec<Neighbor>> = vec![Vec::new(); n1 + n2];
    for u in 0..n1 + n2 {
        let (src, off, cross_lo, cross_n): (&KnnGraph, u32, usize, usize) = if u < n1 {
            (g1, 0, n1, n2)
        } else {
            (g2, n1 as u32, 0, n1)
        };
        let local = if u < n1 { u } else { u - n1 };
        let list = joined.list_mut(u);
        let mut w = 0;
        for (i, e) in src.list(local).iter().enumerate() {
            if e.is_empty() {
                break;
            }
            let e = Neighbor { id: e.id + off, dist: e.dist, new: false };
            if i < keep {
                list[w] = e;
                w += 1;
            } else {
                stash[u].push(e);
            }
        }
        // tail: k/2 random objects from the OTHER subset, marked NEW
        let m = half.min(cross_n);
        for v in rng.distinct(cross_n, m) {
            let vid = (cross_lo + v) as u32;
            if list[..w].iter().any(|e| e.id == vid) {
                continue;
            }
            list[w] = Neighbor { id: vid, dist: ds.dist(u, vid as usize), new: true };
            w += 1;
            if w == k {
                break;
            }
        }
        normalize_slice(list);
    }

    // ---- restricted GNND refinement (same-subset pairs masked) ----
    let boundary = n1 as u32;
    let subset: &(dyn Fn(u32) -> i32 + Sync) = &move |id| i32::from(id >= boundary);
    let stats = gnnd::refine(ds, &mut joined, engine, params, Some(subset))?;

    // ---- fold the stashed tails back in ----
    for (u, st) in stash.into_iter().enumerate() {
        if st.is_empty() {
            continue;
        }
        let list = joined.list_mut(u);
        // candidates = refined list + stash; keep best k distinct
        let mut cands: Vec<Neighbor> = list.iter().copied().filter(|e| !e.is_empty()).collect();
        cands.extend(st);
        cands.sort_unstable_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
        let mut seen = std::collections::HashSet::new();
        let mut w = 0;
        for e in cands {
            if w == k {
                break;
            }
            if e.id as usize != u && seen.insert(e.id) {
                list[w] = Neighbor { new: false, ..e };
                w += 1;
            }
        }
        for slot in list[w..].iter_mut() {
            *slot = Neighbor::empty();
        }
    }
    Ok((joined, stats))
}

/// Incremental construction (paper §5.1): `existing` covers rows
/// `0..n_old` of `ds`; the remaining rows are new data. A sub-graph is
/// built for the new rows with GNND, then GGM joins it into the
/// existing graph.
pub fn incremental_add(
    ds: &Dataset,
    n_old: usize,
    existing: &KnnGraph,
    params: &GnndParams,
    engine: &dyn CrossmatchEngine,
) -> crate::Result<(KnnGraph, BuildStats)> {
    anyhow::ensure!(existing.n() == n_old, "existing graph size mismatch");
    let n_new = ds.len() - n_old;
    anyhow::ensure!(n_new > 0, "no new rows to add");
    let new_ids: Vec<usize> = (n_old..ds.len()).collect();
    let new_ds = ds.select(&new_ids, "incremental-batch");
    let sub = gnnd::build_with_engine(&new_ds, params, engine)?;
    merge(ds, n_old, existing, &sub.graph, params, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::gnnd::NativeEngine;
    use crate::metrics::recall_at;

    fn build_halves(ds: &Dataset, params: &GnndParams) -> (usize, KnnGraph, KnnGraph) {
        let n1 = ds.len() / 2;
        let ids1: Vec<usize> = (0..n1).collect();
        let ids2: Vec<usize> = (n1..ds.len()).collect();
        let d1 = ds.select(&ids1, "h1");
        let d2 = ds.select(&ids2, "h2");
        let g1 = gnnd::build(&d1, params).unwrap();
        let g2 = gnnd::build(&d2, params).unwrap();
        (n1, g1, g2)
    }

    #[test]
    fn merge_recovers_cross_subset_neighbors() {
        let ds = synth::clustered(400, 8, 21);
        let params = GnndParams::default().with_k(12).with_p(6).with_iters(8);
        let (n1, g1, g2) = build_halves(&ds, &params);
        let (g, stats) = merge(&ds, n1, &g1, &g2, &params, &NativeEngine).unwrap();
        g.check_invariants().unwrap();
        assert!(stats.iters >= 1);
        let truth = groundtruth::exact_topk(&ds, 10);
        let r = recall_at(&g, &truth, None, 10);
        assert!(r > 0.85, "merged recall {r}");
        // merged must beat the padded halves (which know nothing of the
        // other subset): their cross-subset recall contribution is 0,
        // so anything close to full recall proves the merge worked.
        let joined_naive = {
            let mut g2r = g2.clone();
            g2r.remap_ids(|id| id + n1 as u32);
            g1.stack(&g2r)
        };
        let r_naive = recall_at(&joined_naive, &truth, None, 10);
        assert!(r > r_naive + 0.05, "merge ({r}) barely beats naive ({r_naive})");
    }

    #[test]
    fn merge_is_no_worse_than_subgraphs_within_subsets() {
        let ds = synth::clustered(300, 6, 22);
        let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
        let (n1, g1, g2) = build_halves(&ds, &params);
        let phi_before = g1.phi() + g2.phi();
        let (g, _) = merge(&ds, n1, &g1, &g2, &params, &NativeEngine).unwrap();
        // phi over the merged graph counts k entries per object drawn
        // from the whole set, so it must not exceed the sum of sub-graph
        // phis by more than the tail slack.
        assert!(g.phi() <= phi_before, "phi grew: {} > {}", g.phi(), phi_before);
    }

    #[test]
    fn incremental_matches_from_scratch_quality() {
        let ds = synth::clustered(360, 6, 23);
        let params = GnndParams::default().with_k(10).with_p(5).with_iters(8);
        let n_old = 240;
        let old_ids: Vec<usize> = (0..n_old).collect();
        let old_ds = ds.select(&old_ids, "old");
        let g_old = gnnd::build(&old_ds, &params).unwrap();
        let (g, _) = incremental_add(&ds, n_old, &g_old, &params, &NativeEngine).unwrap();
        g.check_invariants().unwrap();
        let truth = groundtruth::exact_topk(&ds, 10);
        let r_inc = recall_at(&g, &truth, None, 10);
        let g_scratch = gnnd::build(&ds, &params).unwrap();
        let r_scr = recall_at(&g_scratch, &truth, None, 10);
        assert!(r_inc > r_scr - 0.1, "incremental {r_inc} vs scratch {r_scr}");
    }

    #[test]
    fn rejects_mismatched_inputs() {
        let ds = synth::uniform(40, 4, 24);
        let g1 = KnnGraph::empty(20, 8);
        let g2 = KnnGraph::empty(20, 6);
        let params = GnndParams::default().with_k(8).with_p(4);
        assert!(merge(&ds, 20, &g1, &g2, &params, &NativeEngine).is_err());
    }
}
