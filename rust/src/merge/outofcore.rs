//! Out-of-core k-NN graph construction (paper §5, billion-scale recipe).
//!
//! The dataset is partitioned into shards small enough for one "device";
//! GNND builds a sub-graph per shard; then every pair of shards is
//! merged exactly once by GGM ("merge is carried out between sub-graphs
//! pairwisely"), with sub-graphs spilled to disk between merges. Pairs
//! are scheduled in round-robin-tournament rounds whose pairs are
//! disjoint, so `workers` merges run concurrently (the paper's
//! multi-GPU mode) and disk I/O overlaps compute through a prefetch
//! thread (the paper: "we can read and write the disk while merging
//! graphs on GPU").
//!
//! Only the shard pairs in flight are memory-resident — the framework
//! handles datasets that exceed "device" memory by construction.
//!
//! The same [`ShardStore`] doubles as the *serving-side* residency
//! manager: [`ShardStore::get_shard`] serves shards out of a pinned
//! (`Arc`-handle) LRU cache under a configurable byte budget, so
//! corpora built out-of-core can also be *served* out-of-core (see
//! [`crate::search::sharded`]).

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::str::FromStr;
use std::sync::{mpsc, Arc, Condvar, Mutex};

use anyhow::Context;

use crate::config::{GnndParams, Metric};
use crate::dataset::store::{
    BlockCache, Doorkeeper, PqParams, QuantFitter, QuantParams, DEFAULT_BLOCK_BYTES,
};
use crate::dataset::{io, Dataset};
use crate::gnnd::{self, engine::CrossmatchEngine};
use crate::graph::{KnnGraph, Neighbor};
use crate::util::json::Json;
use crate::util::timer::Timer;

/// File name of the persisted [`ShardManifest`] inside a shard dir.
pub const MANIFEST_FILE: &str = "manifest.json";

/// File name of the persisted [`OutOfCoreStats`] inside a shard dir.
pub const STATS_FILE: &str = "stats.json";

/// One resident shard: its vectors, its merged sub-graph (neighbor ids
/// in the global id space) and the in-memory byte cost the residency
/// budget accounts it at. Handed out by [`ShardStore::get_shard`]
/// behind an `Arc` — holding the handle *pins* the shard: the cache
/// never frees a shard a search is still reading.
///
/// Under [`ResidencyMode::Shard`] the dataset and graph are fully
/// materialized; under [`ResidencyMode::Block`] they are *paged*
/// handles — `bytes` then covers only the handles themselves, and the
/// actual row data moves through the store's shared [`BlockCache`]
/// under the same byte budget.
pub struct ResidentShard {
    pub ds: Dataset,
    pub graph: KnnGraph,
    /// Bytes this shard itself occupies while resident (vectors +
    /// graph when owned; handle overhead when paged).
    pub bytes: usize,
}

/// In-memory byte cost of a (vectors, graph) pair — the unit the
/// residency budget is accounted in. Paged backings report only their
/// handle overhead (their blocks are accounted by the shared cache).
pub fn resident_cost(ds: &Dataset, graph: &KnnGraph) -> usize {
    ds.resident_bytes() + graph.resident_bytes()
}

/// How [`ShardStore::get_shard`] makes shard data resident.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidencyMode {
    /// Whole-shard granularity (the PR 3 cache): a miss deserializes
    /// the full `.dsb` + `.knng` pair; the byte budget evicts whole
    /// shards, LRU-first.
    Shard,
    /// Block granularity: shards are served straight from disk through
    /// paged handles; the byte budget is enforced over fixed-size
    /// blocks of *all* open shards at once, so cold-start cost is
    /// proportional to rows actually visited and budgets smaller than
    /// one shard still serve. v1-format shard files fall back to
    /// whole-shard residency (and are evicted like [`ResidencyMode::Shard`]
    /// entries).
    Block {
        /// Target block payload size in bytes.
        block_bytes: usize,
    },
}

impl ResidencyMode {
    /// Block mode at the default block size.
    pub fn block() -> Self {
        ResidencyMode::Block { block_bytes: DEFAULT_BLOCK_BYTES }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ResidencyMode::Shard => "shard",
            ResidencyMode::Block { .. } => "block",
        }
    }
}

impl std::fmt::Display for ResidencyMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for ResidencyMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "shard" => Ok(ResidencyMode::Shard),
            "block" => Ok(ResidencyMode::block()),
            _ => anyhow::bail!("unknown residency mode {s:?} (expected shard|block)"),
        }
    }
}

/// Which shard files [`ShardStore::get_shard`] serves vectors from —
/// orthogonal to [`ResidencyMode`] (any compression serves under
/// either residency granularity).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardCompression {
    /// The f32 `shard_<i>.dsb` files (the build output).
    F32,
    /// The scalar-quantized `quant_<i>.dsb` sidecars written by
    /// [`quantize_store`]: 1 byte/dim resident, f32 rerank sidecar.
    Scalar,
    /// The product-quantized `pq_<i>.dsb` sidecars written by
    /// [`pq_quantize_store`]: m bytes/row resident, per-query ADC
    /// lookup tables in the beam phase, f32 rerank sidecar.
    Pq,
}

impl ShardCompression {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardCompression::F32 => "f32",
            ShardCompression::Scalar => "scalar",
            ShardCompression::Pq => "pq",
        }
    }
}

impl std::fmt::Display for ShardCompression {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for ShardCompression {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            // true/false are the historical values of the boolean
            // --quantize flag; keep them parsing so existing invocations
            // and scripts stay valid
            "f32" | "false" => Ok(ShardCompression::F32),
            "scalar" | "true" => Ok(ShardCompression::Scalar),
            "pq" => Ok(ShardCompression::Pq),
            _ => anyhow::bail!(
                "unknown shard compression {s:?} (expected f32|scalar|pq, or true|false)"
            ),
        }
    }
}

/// Counters of the shard residency cache, exposed as a JSON block by
/// serve-time tooling and folded into `stats.json`
/// ([`ShardStore::save_stats_with_residency`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResidencyStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Shards currently held by the cache.
    pub resident_shards: usize,
    /// Bytes currently held (shard entries plus, in block mode, cached
    /// blocks). Can exceed `budget_bytes` while pinned handles block
    /// eviction; drops back under the budget at the next eviction pass
    /// after the pins release.
    pub resident_bytes: usize,
    pub peak_resident_bytes: usize,
    /// Configured budget (0 = unbounded).
    pub budget_bytes: usize,
    /// Residency granularity ("shard" or "block").
    pub mode: String,
    /// Blocks fetched from disk (block mode only).
    pub block_fetches: u64,
    /// Block requests served from the block cache (block mode only).
    pub block_hits: u64,
    /// Blocks evicted from the block cache (block mode only).
    pub block_evictions: u64,
    /// Cache inserts declined by the two-visit admission doorkeeper
    /// (shard-level and block-level combined) — the scan-protection
    /// counter.
    pub rejected_admissions: u64,
    /// Payload bytes actually read from disk (whole-shard loads plus
    /// block fetches). Under block-granular residency with a selective
    /// probe set this stays *below* the total shard bytes — the
    /// partial-shard-read proof the ROADMAP asked for.
    pub bytes_read: u64,
    /// Bytes the resident shards' *vector data* holds in memory right
    /// now (graph bytes excluded). This is the number quantization
    /// shrinks: u8 codes report ~1/4 the f32 figure under whole-shard
    /// residency, which `resident_bytes` — dominated by graph rows —
    /// would hide.
    pub dataset_bytes: u64,
}

impl ResidencyStats {
    /// Fraction of [`ShardStore::get_shard`] calls served from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("mode", self.mode.as_str())
            .set("hits", self.hits)
            .set("misses", self.misses)
            .set("evictions", self.evictions)
            .set("hit_rate", self.hit_rate())
            .set("resident_shards", self.resident_shards)
            .set("resident_bytes", self.resident_bytes)
            .set("peak_resident_bytes", self.peak_resident_bytes)
            .set("budget_bytes", self.budget_bytes)
            .set("block_fetches", self.block_fetches)
            .set("block_hits", self.block_hits)
            .set("block_evictions", self.block_evictions)
            .set("rejected_admissions", self.rejected_admissions)
            .set("bytes_read", self.bytes_read)
            .set("dataset_bytes", self.dataset_bytes)
    }

    pub fn from_json(j: &Json) -> crate::Result<ResidencyStats> {
        let u64_of = |key: &str| -> crate::Result<u64> {
            Ok(jfield(j, key)?
                .as_f64()
                .with_context(|| format!("residency field {key:?} is not a number"))?
                as u64)
        };
        // fields added by the block-residency work default when absent,
        // so stats.json files written by older builds stay readable
        let u64_opt = |key: &str| -> crate::Result<u64> {
            match j.get(key) {
                None => Ok(0),
                Some(_) => u64_of(key),
            }
        };
        Ok(ResidencyStats {
            hits: u64_of("hits")?,
            misses: u64_of("misses")?,
            evictions: u64_of("evictions")?,
            resident_shards: jusize(j, "resident_shards")?,
            resident_bytes: jusize(j, "resident_bytes")?,
            peak_resident_bytes: jusize(j, "peak_resident_bytes")?,
            budget_bytes: jusize(j, "budget_bytes")?,
            mode: j
                .get("mode")
                .and_then(Json::as_str)
                .unwrap_or("shard")
                .to_string(),
            block_fetches: u64_opt("block_fetches")?,
            block_hits: u64_opt("block_hits")?,
            block_evictions: u64_opt("block_evictions")?,
            rejected_admissions: u64_opt("rejected_admissions")?,
            bytes_read: u64_opt("bytes_read")?,
            dataset_bytes: u64_opt("dataset_bytes")?,
        })
    }
}

/// A cached resident shard + its LRU stamp.
struct CacheEntry {
    shard: Arc<ResidentShard>,
    last_used: u64,
}

/// Interior-mutable state of the residency cache; every field is
/// guarded by one mutex (operations are short: map lookups and counter
/// bumps — disk reads happen with the lock released).
#[derive(Default)]
struct ShardCache {
    resident: HashMap<usize, CacheEntry>,
    /// Shards a thread is currently faulting in from disk — other
    /// threads wait on the store's condvar instead of duplicating the
    /// read (and its transient memory) on a concurrent cold start.
    loading: HashSet<usize>,
    /// Shards invalidated (saved over) *while* an in-flight load was
    /// reading them; the loader discards its possibly-torn read and
    /// retries instead of caching stale data.
    dirty: HashSet<usize>,
    /// Monotonic access clock driving LRU ordering.
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    resident_bytes: usize,
    peak_resident_bytes: usize,
    /// Two-visit admission gate: a loaded shard that would force an
    /// eviction is served to its caller but only *cached* on its
    /// second recent visit, so a scan-shaped probe set larger than the
    /// budget cannot churn the hot set out.
    door: Doorkeeper,
    rejected_admissions: u64,
    /// Payload bytes read from disk by whole-shard loads.
    bytes_read: u64,
}

/// Global-registry mirrors of the shard-cache counters. The
/// authoritative counts stay in [`ShardCache`] under its mutex (and
/// keep feeding [`ResidencyStats`]); these handles make the same
/// events visible live through [`crate::telemetry::global`] snapshots
/// mid-run. Handles are resolved once per store, not per access.
struct ShardTele {
    hits: Arc<crate::telemetry::Counter>,
    misses: Arc<crate::telemetry::Counter>,
    evictions: Arc<crate::telemetry::Counter>,
    rejected_admissions: Arc<crate::telemetry::Counter>,
    bytes_read: Arc<crate::telemetry::Counter>,
}

impl ShardTele {
    fn new() -> Self {
        let g = crate::telemetry::global();
        ShardTele {
            hits: g.counter("shard_cache.hits"),
            misses: g.counter("shard_cache.misses"),
            evictions: g.counter("shard_cache.evictions"),
            rejected_admissions: g.counter("shard_cache.rejected_admissions"),
            bytes_read: g.counter("shard_cache.bytes_read"),
        }
    }
}

/// On-disk shard layout under `dir`: `shard_<i>.dsb` + `graph_<i>.knng`
/// per shard, plus `manifest.json` (shard geometry, see
/// [`ShardManifest`]) and `stats.json` (the last build's
/// [`OutOfCoreStats`]).
///
/// Beyond the save/load path mapping, the store is a *residency
/// manager*: [`ShardStore::get_shard`] returns shards from an LRU
/// cache with a configurable byte budget, so a serving process touches
/// disk only on cache misses and never holds more than the budget in
/// unpinned shard memory. Handles are `Arc`-pinned — an in-flight
/// search can never have its shard evicted underneath it; pinned
/// shards survive eviction passes and are shed once the last handle
/// drops and the next pass runs.
pub struct ShardStore {
    dir: PathBuf,
    /// Byte budget of the residency cache (0 = unbounded: every shard
    /// stays resident after first touch — the pre-residency behavior).
    budget_bytes: usize,
    /// Residency granularity: whole shards or fixed-size blocks.
    mode: ResidencyMode,
    /// Which shard files [`ShardStore::get_shard`] serves vectors from:
    /// the f32 `shard_<i>.dsb` build output, the scalar-quantized
    /// `quant_<i>.dsb` sidecars, or the product-quantized `pq_<i>.dsb`
    /// sidecars. Under either compression the f32 files stay on disk as
    /// the exact-rerank sidecar: resident memory holds code rows and
    /// the rerank phase pages exact rows in block by block through the
    /// shared [`BlockCache`].
    compression: ShardCompression,
    /// The shared block cache behind [`ResidencyMode::Block`] paged
    /// handles (constructed unbounded-and-unused in shard mode).
    blocks: Arc<BlockCache>,
    cache: Mutex<ShardCache>,
    tele: ShardTele,
    /// Signalled when an in-flight shard load completes (or fails), so
    /// threads parked on a `loading` shard re-check the cache.
    loaded: Condvar,
}

impl ShardStore {
    /// Open a store with an unbounded residency budget.
    pub fn new(dir: impl AsRef<Path>) -> crate::Result<Self> {
        Self::with_budget(dir, 0)
    }

    /// Open a store whose resident shards are LRU-evicted down to
    /// `budget_bytes` (0 = unbounded), at whole-shard granularity.
    pub fn with_budget(dir: impl AsRef<Path>, budget_bytes: usize) -> crate::Result<Self> {
        Self::with_residency(dir, budget_bytes, ResidencyMode::Shard)
    }

    /// Open a store with an explicit residency mode. In
    /// [`ResidencyMode::Block`] the byte budget is enforced over the
    /// blocks of all open shards at once (a budget smaller than one
    /// shard serves fine); in [`ResidencyMode::Shard`] it evicts whole
    /// shards as before.
    pub fn with_residency(
        dir: impl AsRef<Path>,
        budget_bytes: usize,
        mode: ResidencyMode,
    ) -> crate::Result<Self> {
        Self::with_options(dir, budget_bytes, mode, false)
    }

    /// Open with every serving knob explicit. `quantized` switches
    /// [`ShardStore::get_shard`] to the `quant_<i>.dsb` files written by
    /// [`quantize_store`]: resident rows are 1-byte codes (~4x more
    /// rows per byte of budget) and the f32 `shard_<i>.dsb` files are
    /// attached as a paged exact-rows sidecar for the rerank phase.
    /// Kept boolean for compatibility — product-quantized serving goes
    /// through [`ShardStore::with_compression`].
    pub fn with_options(
        dir: impl AsRef<Path>,
        budget_bytes: usize,
        mode: ResidencyMode,
        quantized: bool,
    ) -> crate::Result<Self> {
        let compression =
            if quantized { ShardCompression::Scalar } else { ShardCompression::F32 };
        Self::with_compression(dir, budget_bytes, mode, compression)
    }

    /// Open with an explicit [`ShardCompression`]: which shard files
    /// vectors are served from (f32, scalar-quantized codes, or
    /// product-quantized codes — the latter two need their sidecar
    /// files written by [`quantize_store`] / [`pq_quantize_store`]
    /// first).
    pub fn with_compression(
        dir: impl AsRef<Path>,
        budget_bytes: usize,
        mode: ResidencyMode,
        compression: ShardCompression,
    ) -> crate::Result<Self> {
        std::fs::create_dir_all(dir.as_ref())?;
        let blocks = match mode {
            ResidencyMode::Block { block_bytes } => BlockCache::new(budget_bytes, block_bytes),
            // shard mode pages nothing itself, but a quantized store
            // still streams exact-rerank rows through this cache —
            // unbounded here, the shard budget governs
            ResidencyMode::Shard => BlockCache::new(0, DEFAULT_BLOCK_BYTES),
        };
        Ok(ShardStore {
            dir: dir.as_ref().to_path_buf(),
            budget_bytes,
            mode,
            compression,
            blocks,
            cache: Mutex::new(ShardCache::default()),
            tele: ShardTele::new(),
            loaded: Condvar::new(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    pub fn mode(&self) -> ResidencyMode {
        self.mode
    }

    /// Whether [`ShardStore::get_shard`] serves *compressed* (scalar-
    /// or product-quantized) shard files — the gate for two-phase
    /// rerank serving (see [`ShardStore::with_compression`]).
    pub fn quantized(&self) -> bool {
        self.compression != ShardCompression::F32
    }

    /// Which shard files vectors are served from.
    pub fn compression(&self) -> ShardCompression {
        self.compression
    }

    /// The shared block cache (meaningful under [`ResidencyMode::Block`]).
    pub fn block_cache(&self) -> &Arc<BlockCache> {
        &self.blocks
    }

    fn shard_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("shard_{i}.dsb"))
    }

    fn graph_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("graph_{i}.knng"))
    }

    fn quant_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("quant_{i}.dsb"))
    }

    fn pq_path(&self, i: usize) -> PathBuf {
        self.dir.join(format!("pq_{i}.dsb"))
    }

    pub fn save_shard(&self, i: usize, ds: &Dataset) -> crate::Result<()> {
        io::write_dsb(ds, self.shard_path(i))?;
        self.invalidate(i);
        Ok(())
    }

    /// Uncached disk read (the construction pipeline's path — builds
    /// stream shards through once and must not accumulate residency).
    pub fn load_shard(&self, i: usize) -> crate::Result<Dataset> {
        io::read_dsb(self.shard_path(i))
    }

    pub fn save_graph(&self, i: usize, g: &KnnGraph) -> crate::Result<()> {
        g.save(self.graph_path(i))?;
        self.invalidate(i);
        Ok(())
    }

    /// Uncached disk read; see [`ShardStore::load_shard`].
    pub fn load_graph(&self, i: usize) -> crate::Result<KnnGraph> {
        KnnGraph::load(self.graph_path(i))
    }

    /// The serving path: shard `i`'s vectors + graph through the
    /// residency cache. Hits bump the LRU stamp; misses read from disk
    /// *outside* the cache lock (a cold load never blocks queries
    /// hitting warm shards) and then run an eviction pass. Concurrent
    /// misses on the same shard coalesce: one thread loads while the
    /// rest wait on the condvar, so a cold start never duplicates the
    /// disk read or its transient memory. The returned handle pins the
    /// shard until dropped.
    ///
    /// Under a non-zero budget, a freshly loaded shard that would force
    /// an eviction passes the two-visit admission gate first: on its
    /// first recent visit it is handed to the caller but *not cached*
    /// (`rejected_admissions` counts these), so one-shot scans cannot
    /// evict the hot set. In [`ResidencyMode::Block`] the load opens
    /// *paged* handles (header reads only) instead of materializing the
    /// files; v1-format files fall back to owned loads.
    pub fn get_shard(&self, i: usize) -> crate::Result<Arc<ResidentShard>> {
        loop {
            {
                let mut c = self.cache.lock().unwrap();
                loop {
                    c.tick += 1;
                    let tick = c.tick;
                    if let Some(e) = c.resident.get_mut(&i) {
                        e.last_used = tick;
                        let out = Arc::clone(&e.shard);
                        c.hits += 1;
                        self.tele.hits.inc();
                        // enforce the budget on hits too: shards pinned
                        // past the budget at insert time are shed here,
                        // on the first access after their pins release
                        Self::evict_locked(&mut c, self.budget_bytes, &self.blocks, &self.tele);
                        return Ok(out);
                    }
                    if c.loading.contains(&i) {
                        c = self.loaded.wait(c).unwrap();
                        continue;
                    }
                    c.misses += 1;
                    self.tele.misses.inc();
                    c.loading.insert(i);
                    break;
                }
            }
            let read: crate::Result<(Dataset, KnnGraph)> = match self.compression {
                ShardCompression::Scalar | ShardCompression::Pq => (|| {
                    // code rows from the compression sidecar (owned in
                    // shard mode, paged in block mode); the f32 shard
                    // file — when still present — rides along as the
                    // paged exact-rows sidecar the rerank phase reads
                    let exact = self.shard_path(i);
                    let exact = exact.exists().then_some(exact);
                    let paged = matches!(self.mode, ResidencyMode::Block { .. });
                    let ds = match self.compression {
                        ShardCompression::Scalar => io::read_dsb_quantized(
                            self.quant_path(i),
                            exact.as_deref(),
                            &self.blocks,
                            paged,
                        )
                        .with_context(|| {
                            format!(
                                "shard {i}: no quantized shard file (run `gnnd quantize` first?)"
                            )
                        })?,
                        _ => io::read_dsb_pq(
                            self.pq_path(i),
                            exact.as_deref(),
                            &self.blocks,
                            paged,
                        )
                        .with_context(|| {
                            format!(
                                "shard {i}: no pq shard file (run `gnnd quantize --pq-m` first?)"
                            )
                        })?,
                    };
                    let graph = match self.mode {
                        ResidencyMode::Shard => self.load_graph(i)?,
                        ResidencyMode::Block { .. } => {
                            KnnGraph::load_paged(self.graph_path(i), &self.blocks)?
                        }
                    };
                    Ok((ds, graph))
                })(),
                ShardCompression::F32 => match self.mode {
                    ResidencyMode::Shard => (|| Ok((self.load_shard(i)?, self.load_graph(i)?)))(),
                    ResidencyMode::Block { .. } => (|| {
                        Ok((
                            io::read_dsb_paged(self.shard_path(i), &self.blocks)?,
                            KnnGraph::load_paged(self.graph_path(i), &self.blocks)?,
                        ))
                    })(),
                },
            };
            let mut c = self.cache.lock().unwrap();
            c.loading.remove(&i);
            let (ds, graph) = match read {
                Ok(pair) => pair,
                Err(e) => {
                    // waiters must wake and retry (they will become the
                    // loader and surface the error themselves)
                    c.dirty.remove(&i);
                    self.loaded.notify_all();
                    return Err(e);
                }
            };
            if c.dirty.remove(&i) {
                // a save overlapped our read: the bytes may be stale or
                // torn — discard and re-read the post-save files
                drop((ds, graph));
                self.loaded.notify_all();
                continue;
            }
            // payload bytes a materialized load pulled off disk (paged
            // handles read only headers here; their block fetches are
            // accounted by the block cache as they happen)
            // materialized rows only: paged f32 rows and paged u8
            // codes (`block_store_id` is Some) are accounted block by
            // block by the cache as they fault in
            if !ds.is_paged() && ds.block_store_id().is_none() {
                // stored row width off disk: u8 codes 1 byte/dim, pq
                // codes m bytes/row, f32 rows 4 bytes/dim
                let row = ds.stored_row_bytes();
                c.bytes_read += (ds.len() * row) as u64;
                self.tele.bytes_read.add((ds.len() * row) as u64);
            }
            if !graph.is_paged() {
                c.bytes_read += (graph.n() * graph.k() * 8) as u64;
                self.tele.bytes_read.add((graph.n() * graph.k() * 8) as u64);
            }
            let loaded =
                Arc::new(ResidentShard { bytes: resident_cost(&ds, &graph), ds, graph });
            c.tick += 1;
            let tick = c.tick;
            let admit = self.budget_bytes == 0
                || c.resident_bytes + loaded.bytes <= self.budget_bytes
                || c.door.admit(i as u64);
            if admit {
                c.resident_bytes += loaded.bytes;
                c.peak_resident_bytes = c.peak_resident_bytes.max(c.resident_bytes);
                c.resident.insert(i, CacheEntry { shard: Arc::clone(&loaded), last_used: tick });
                Self::evict_locked(&mut c, self.budget_bytes, &self.blocks, &self.tele);
            } else {
                // served but not cached: the handle stays alive for the
                // caller's query and is freed when the pin drops
                c.rejected_admissions += 1;
                self.tele.rejected_admissions.inc();
            }
            self.loaded.notify_all();
            return Ok(loaded);
        }
    }

    /// Evict least-recently-used *unpinned* shards until the cache fits
    /// the budget (also run internally by every [`ShardStore::get_shard`]).
    /// Pinned shards (a handle is still held outside the cache) are
    /// never evicted, so the cache can transiently exceed the budget
    /// while queries are in flight; calling this after the pins drop
    /// brings it back under.
    pub fn evict_to_budget(&self) {
        let mut c = self.cache.lock().unwrap();
        Self::evict_locked(&mut c, self.budget_bytes, &self.blocks, &self.tele);
    }

    fn evict_locked(c: &mut ShardCache, budget: usize, blocks: &BlockCache, tele: &ShardTele) {
        if budget == 0 {
            return;
        }
        while c.resident_bytes > budget {
            let victim = c
                .resident
                .iter()
                .filter(|(_, e)| Arc::strong_count(&e.shard) == 1)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&i, _)| i);
            let Some(i) = victim else { break };
            if let Some(e) = c.resident.remove(&i) {
                c.resident_bytes -= e.shard.bytes;
                c.evictions += 1;
                tele.evictions.inc();
                // a paged victim's cached blocks are unreachable once
                // its handle leaves the map (a reload registers a fresh
                // store id) — drop them so orphans never consume the
                // block budget. The victim had no outside pins
                // (strong_count == 1), so no reader loses data.
                for id in [
                    e.shard.ds.block_store_id(),
                    e.shard.ds.exact_block_store_id(),
                    e.shard.graph.block_store_id(),
                ]
                .into_iter()
                .flatten()
                {
                    blocks.forget_store(id);
                }
            }
        }
    }

    /// Drop shard `i` from the cache (stale after a save; pinned
    /// handles keep the old data alive until they release). An
    /// in-flight load of `i` is flagged dirty so its possibly-torn
    /// read is discarded and retried rather than cached.
    fn invalidate(&self, i: usize) {
        let mut c = self.cache.lock().unwrap();
        if let Some(e) = c.resident.remove(&i) {
            c.resident_bytes -= e.shard.bytes;
            // a paged shard's cached blocks are stale garbage now —
            // drop them from the shared cache (live handles re-fetch
            // the new bytes; saving over a shard while paged handles
            // are live is unsupported, as documented on ResidentShard)
            for id in [
                e.shard.ds.block_store_id(),
                e.shard.ds.exact_block_store_id(),
                e.shard.graph.block_store_id(),
            ]
            .into_iter()
            .flatten()
            {
                self.blocks.forget_store(id);
            }
        }
        if c.loading.contains(&i) {
            c.dirty.insert(i);
        }
    }

    /// Snapshot of the residency counters (shard-level cache merged
    /// with the block cache: in shard mode the block side is all
    /// zeros, so legacy fields read exactly as before).
    pub fn residency(&self) -> ResidencyStats {
        let b = self.blocks.stats();
        let c = self.cache.lock().unwrap();
        let dataset_bytes: u64 = c
            .resident
            .values()
            .map(|e| e.shard.ds.resident_bytes() as u64)
            .sum();
        ResidencyStats {
            hits: c.hits,
            misses: c.misses,
            evictions: c.evictions,
            resident_shards: c.resident.len(),
            resident_bytes: c.resident_bytes + b.resident_bytes,
            peak_resident_bytes: c.peak_resident_bytes + b.peak_resident_bytes,
            budget_bytes: self.budget_bytes,
            mode: self.mode.as_str().to_string(),
            block_fetches: b.fetches,
            block_hits: b.hits,
            block_evictions: b.evictions,
            rejected_admissions: c.rejected_admissions + b.rejected_admissions,
            bytes_read: c.bytes_read + b.bytes_read,
            dataset_bytes,
        }
    }

    pub fn save_manifest(&self, m: &ShardManifest) -> crate::Result<()> {
        std::fs::write(self.dir.join(MANIFEST_FILE), m.to_json().to_string())?;
        Ok(())
    }

    pub fn load_manifest(&self) -> crate::Result<ShardManifest> {
        let path = self.dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("no shard manifest at {path:?} (run ooc-build first)"))?;
        ShardManifest::from_json(&Json::parse(&text)?)
    }

    pub fn save_stats(&self, stats: &OutOfCoreStats) -> crate::Result<()> {
        std::fs::write(self.dir.join(STATS_FILE), stats.to_json().to_string())?;
        Ok(())
    }

    /// Read back the build stats from `stats.json` if the directory has
    /// them. Extra fields (e.g. a folded-in residency block) are
    /// ignored; a `stats.json` *without* build fields (a residency-only
    /// fold on a directory that never ran `ooc-build`) reads as `None`
    /// rather than an error.
    pub fn load_stats(&self) -> crate::Result<Option<OutOfCoreStats>> {
        let path = self.dir.join(STATS_FILE);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)?;
        let j = Json::parse(&text)?;
        if j.get("build_secs").is_none() {
            return Ok(None);
        }
        Ok(Some(OutOfCoreStats::from_json(&j)?))
    }

    /// Fold serve-time residency counters into `stats.json` next to the
    /// build stats, so one file tracks both the build cost and the
    /// serving cache behavior of the directory.
    pub fn save_stats_with_residency(&self, res: &ResidencyStats) -> crate::Result<()> {
        self.save_stats_with_block("residency", res.to_json())
    }

    /// Fold a named JSON block into `stats.json` next to the build
    /// stats (serve tooling folds a `"residency"` block, the open-loop
    /// serve bench a `"serve"` block). Existing fields — build stats
    /// and every other block — are preserved verbatim; only the named
    /// block is replaced. A `stats.json` that exists but does not
    /// parse is an error (never silently overwritten).
    pub fn save_stats_with_block(&self, name: &str, block: Json) -> crate::Result<()> {
        let path = self.dir.join(STATS_FILE);
        let mut fields = if path.exists() {
            let text = std::fs::read_to_string(&path)?;
            match Json::parse(&text)
                .with_context(|| format!("corrupt {path:?}; refusing to overwrite"))?
            {
                Json::Obj(fields) => fields,
                _ => anyhow::bail!("{path:?} is not a JSON object; refusing to overwrite"),
            }
        } else {
            Vec::new()
        };
        fields.retain(|(k, _)| k != name);
        fields.push((name.to_string(), block));
        std::fs::write(path, Json::Obj(fields).to_string())?;
        Ok(())
    }
}

/// Write the u8-quantized sidecar files (`quant_<i>.dsb`) of a built
/// shard directory, so it can be opened with
/// [`ShardStore::with_options`]`(.., quantized = true)`.
///
/// Quantization params are fit over the *union* of all shards (two
/// streaming passes, one shard resident at a time): every shard shares
/// one code space, so code-space distances of candidates from
/// different shards stay comparable at the gather phase. The f32
/// `shard_<i>.dsb` files are left in place — they are the exact-rows
/// sidecar the rerank phase reads. Returns the fitted params.
pub fn quantize_store(dir: impl AsRef<Path>) -> crate::Result<QuantParams> {
    let store = ShardStore::new(&dir)?;
    let manifest = store.load_manifest()?;
    let shards = manifest.shards;
    let mut fit = QuantFitter::new(manifest.d);
    for s in 0..shards {
        let ds = store.load_shard(s)?;
        anyhow::ensure!(
            !ds.is_compressed(),
            "shard {s} of {:?} is already quantized",
            store.dir()
        );
        for i in 0..ds.len() {
            ds.with_vec(i, |row| fit.observe(row));
        }
    }
    let params = fit.finish();
    for s in 0..shards {
        let ds = store.load_shard(s)?;
        io::write_dsb_quantized_with(&ds, &params, store.quant_path(s))
            .with_context(|| format!("quantizing shard {s}"))?;
    }
    backfill_route_centroids(&store, manifest)?;
    refresh_hier_sidecars(&store, shards)?;
    Ok(params)
}

/// Write the product-quantized sidecar files (`pq_<i>.dsb`) of a built
/// shard directory, so it can be opened with
/// [`ShardStore::with_compression`]`(.., ShardCompression::Pq)`.
///
/// Codebooks (m subquantizers x 256 centroids) are fitted over a
/// bounded sample drawn across *all* shards: every shard shares one
/// code space, so ADC distances of candidates from different shards
/// stay comparable at the gather phase — the same invariant
/// [`quantize_store`] maintains for scalar codes. The f32
/// `shard_<i>.dsb` files are left in place as the exact-rows rerank
/// sidecar. Returns the fitted params.
pub fn pq_quantize_store(dir: impl AsRef<Path>, m: usize) -> crate::Result<PqParams> {
    let store = ShardStore::new(&dir)?;
    let manifest = store.load_manifest()?;
    let shards = manifest.shards;
    anyhow::ensure!(
        m >= 1 && m <= manifest.d,
        "pq subquantizer count {m} out of range for dimension {}",
        manifest.d
    );
    // bounded training sample, stride-sampled per shard so every shard
    // contributes regardless of the store's size
    let per_shard = io::PQ_TRAIN_MAX_ROWS.div_ceil(shards).max(1);
    let mut sample = Vec::new();
    for s in 0..shards {
        let ds = store.load_shard(s)?;
        anyhow::ensure!(
            !ds.is_compressed(),
            "shard {s} of {:?} is already compressed",
            store.dir()
        );
        let take = ds.len().min(per_shard).max(1);
        let stride = ds.len().div_ceil(take).max(1);
        let mut i = 0;
        while i < ds.len() {
            ds.with_vec(i, |row| sample.extend_from_slice(row));
            i += stride;
        }
    }
    let threads = crate::util::num_threads();
    let params = PqParams::fit(&sample, manifest.d, m, io::PQ_FIT_SEED, threads)?;
    for s in 0..shards {
        let ds = store.load_shard(s)?;
        io::write_dsb_pq_with(&ds, &params, store.pq_path(s))
            .with_context(|| format!("pq-quantizing shard {s}"))?;
    }
    backfill_route_centroids(&store, manifest)?;
    refresh_hier_sidecars(&store, shards)?;
    Ok(params)
}

/// Opportunistic backfill shared by the quantization passes: a pre-PR8
/// manifest (no route_centroids) passing through quantization is
/// already streaming every shard, so fit the routing centroids now and
/// upgrade the manifest in place — old stores gain adaptive routing
/// without a rebuild.
fn backfill_route_centroids(store: &ShardStore, manifest: ShardManifest) -> crate::Result<()> {
    if manifest.route_centroids.iter().all(Vec::is_empty) {
        let mut m = manifest;
        m.route_centroids = (0..m.shards)
            .map(|s| Ok(fit_route_centroids(&store.load_shard(s)?)))
            .collect::<crate::Result<_>>()?;
        store.save_manifest(&m)?;
    }
    Ok(())
}

/// Build (or validate) every per-shard `hier_<s>.bin` entry-hierarchy
/// sidecar of a store — the build-time half of hierarchy serving.
/// `ooc-build` calls this so the first `--entry hierarchy` open pays a
/// file read instead of the O(sample^2) build, and the quantization
/// passes call it so a store whose shards were re-saved gets its stale
/// sidecars refreshed alongside the code files. Sidecars are keyed to
/// the default search seed (via
/// [`crate::search::sharded::shard_hier_config`]); serving with a
/// custom `--seed` rebuilds per shard at open, as before. Hierarchies
/// are always built from the f32 shard rows — the `matches` gate does
/// not key on backing, so the same sidecar serves f32, scalar and pq
/// compression.
pub(crate) fn refresh_hier_sidecars(store: &ShardStore, shards: usize) -> crate::Result<()> {
    let base_seed = crate::search::SearchParams::default().seed;
    for s in 0..shards {
        let ds = store.load_shard(s)?;
        let cfg = crate::search::sharded::shard_hier_config(base_seed, s);
        let path = store.dir().join(format!("hier_{s}.bin"));
        crate::search::hierarchy::load_or_build(&path, &ds, &cfg);
    }
    Ok(())
}

/// Geometry of a shard directory, persisted as `manifest.json` so a
/// sharded index can be opened from disk without re-running the build:
/// shard count, the global-id offset of every shard (the same offsets
/// [`build_out_of_core`] remaps the sub-graphs with), vector dims, the
/// graph degree, and per-shard centroids (routing hints for serving
/// with `probe_shards < shards`).
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    pub shards: usize,
    /// Total objects across all shards (= original dataset size).
    pub total: usize,
    pub d: usize,
    /// Graph degree of the per-shard `.knng` files.
    pub k: usize,
    pub metric: Metric,
    /// Global id of each shard's first object, ascending.
    pub offsets: Vec<usize>,
    /// Per-shard mean vectors (normalized under cosine).
    pub centroids: Vec<Vec<f32>>,
    /// Per-shard k-means routing centroids ([`fit_route_centroids`]):
    /// multi-centroid routing scores a shard by its *nearest* centroid,
    /// so multi-modal shards route correctly where the single mean
    /// misleads. Optional in serialized manifests — pre-PR8 stores
    /// read back with one empty list per shard (serving then falls
    /// back to `centroids`, bit-identical to the old route).
    pub route_centroids: Vec<Vec<Vec<f32>>>,
}

fn jfield<'a>(j: &'a Json, key: &str) -> crate::Result<&'a Json> {
    j.get(key).with_context(|| format!("missing field {key:?}"))
}

fn jusize(j: &Json, key: &str) -> crate::Result<usize> {
    jfield(j, key)?
        .as_usize()
        .with_context(|| format!("field {key:?} is not a number"))
}

fn jf64(j: &Json, key: &str) -> crate::Result<f64> {
    jfield(j, key)?
        .as_f64()
        .with_context(|| format!("field {key:?} is not a number"))
}

impl ShardManifest {
    pub fn to_json(&self) -> Json {
        let offsets: Vec<Json> = self.offsets.iter().map(|&o| Json::Num(o as f64)).collect();
        let centroids: Vec<Json> = self
            .centroids
            .iter()
            .map(|c| Json::Arr(c.iter().map(|&x| Json::Num(x as f64)).collect()))
            .collect();
        let route: Vec<Json> = self
            .route_centroids
            .iter()
            .map(|cs| {
                Json::Arr(
                    cs.iter()
                        .map(|c| Json::Arr(c.iter().map(|&x| Json::Num(x as f64)).collect()))
                        .collect(),
                )
            })
            .collect();
        Json::obj()
            .set("shards", self.shards)
            .set("total", self.total)
            .set("d", self.d)
            .set("k", self.k)
            .set("metric", self.metric.as_str())
            .set("offsets", Json::Arr(offsets))
            .set("centroids", Json::Arr(centroids))
            .set("route_centroids", Json::Arr(route))
    }

    pub fn from_json(j: &Json) -> crate::Result<ShardManifest> {
        let metric: Metric = jfield(j, "metric")?
            .as_str()
            .context("manifest field \"metric\" is not a string")?
            .parse()?;
        let offsets = jfield(j, "offsets")?
            .as_arr()
            .context("manifest field \"offsets\" is not an array")?
            .iter()
            .map(|v| v.as_usize().context("offset is not a number"))
            .collect::<crate::Result<Vec<usize>>>()?;
        let centroids = jfield(j, "centroids")?
            .as_arr()
            .context("manifest field \"centroids\" is not an array")?
            .iter()
            .map(|c| {
                let row = c.as_arr().context("centroid is not an array")?;
                row.iter()
                    .map(|x| {
                        let v = x.as_f64().context("centroid component is not a number")?;
                        Ok(v as f32)
                    })
                    .collect::<crate::Result<Vec<f32>>>()
            })
            .collect::<crate::Result<Vec<Vec<f32>>>>()?;
        // optional (pre-PR8 manifests): absent reads as one empty
        // centroid list per shard — the single-centroid fallback
        let route_centroids = match j.get("route_centroids") {
            None => Vec::new(),
            Some(r) => r
                .as_arr()
                .context("manifest field \"route_centroids\" is not an array")?
                .iter()
                .map(|cs| {
                    cs.as_arr()
                        .context("route_centroids entry is not an array")?
                        .iter()
                        .map(|c| {
                            let row = c.as_arr().context("route centroid is not an array")?;
                            row.iter()
                                .map(|x| {
                                    let v = x
                                        .as_f64()
                                        .context("route centroid component is not a number")?;
                                    Ok(v as f32)
                                })
                                .collect::<crate::Result<Vec<f32>>>()
                        })
                        .collect::<crate::Result<Vec<Vec<f32>>>>()
                })
                .collect::<crate::Result<Vec<Vec<Vec<f32>>>>>()?,
        };
        let mut m = ShardManifest {
            shards: jusize(j, "shards")?,
            total: jusize(j, "total")?,
            d: jusize(j, "d")?,
            k: jusize(j, "k")?,
            metric,
            offsets,
            centroids,
            route_centroids,
        };
        anyhow::ensure!(
            m.offsets.len() == m.shards && m.centroids.len() == m.shards,
            "manifest lists {} offsets / {} centroids for {} shards",
            m.offsets.len(),
            m.centroids.len(),
            m.shards
        );
        if m.route_centroids.is_empty() {
            m.route_centroids = vec![Vec::new(); m.shards];
        }
        anyhow::ensure!(
            m.route_centroids.len() == m.shards,
            "manifest lists {} route_centroids entries for {} shards",
            m.route_centroids.len(),
            m.shards
        );
        Ok(m)
    }

    /// Objects owned by shard `s` (derived from the offsets + total).
    pub fn shard_len(&self, s: usize) -> usize {
        let end = self.offsets.get(s + 1).copied().unwrap_or(self.total);
        end - self.offsets[s]
    }

    /// Estimated resident bytes of shard `s` (vectors + graph) — what
    /// [`resident_cost`] will report once the shard is loaded.
    pub fn shard_bytes(&self, s: usize) -> usize {
        let len = self.shard_len(s);
        len * self.d * std::mem::size_of::<f32>()
            + len * self.k * std::mem::size_of::<Neighbor>()
    }

    /// Estimated bytes of the whole store when fully resident — the
    /// reference point for sizing `--memory-budget`.
    pub fn estimated_resident_bytes(&self) -> usize {
        (0..self.shards).map(|s| self.shard_bytes(s)).sum()
    }
}

/// Mean vector of a shard (normalized under cosine so routing compares
/// in the same geometry as the data) — the [`ShardManifest`] routing
/// hint used by centroid-based shard selection at serve time.
pub fn shard_centroid(ds: &Dataset) -> Vec<f32> {
    let mut c = vec![0.0f32; ds.d];
    for i in 0..ds.len() {
        // accessor-based: also works on a paged shard (the manifest
        // fallback path at index open)
        ds.with_vec(i, |row| {
            for (acc, &x) in c.iter_mut().zip(row) {
                *acc += x;
            }
        });
    }
    let n = ds.len().max(1) as f32;
    for acc in c.iter_mut() {
        *acc /= n;
    }
    if ds.metric == Metric::Cosine {
        crate::distance::normalize(&mut c);
    }
    c
}

/// Routing centroids per shard. A module constant rather than an
/// [`OutOfCoreConfig`] field: every call site constructs the config as
/// a full struct literal, and 4 centroids per shard is enough to
/// separate the modes of a multi-modal shard while keeping the route
/// phase O(shards × 4) distance evaluations.
pub const ROUTE_CENTROIDS: usize = 4;

/// Per-shard k-means routing centroids ([`ShardManifest`]
/// `route_centroids`): [`ROUTE_CENTROIDS`] clusters fitted inside the
/// shard (reusing [`crate::baselines::kmeans`], deterministic for any
/// thread count), normalized under cosine like [`shard_centroid`].
/// Accessor-based row copy, so it fits paged shards too.
pub fn fit_route_centroids(ds: &Dataset) -> Vec<Vec<f32>> {
    let k = ROUTE_CENTROIDS.min(ds.len()).max(1);
    let mut data = Vec::with_capacity(ds.len() * ds.d);
    ds.extend_flat_into(&mut data);
    let threads = crate::util::num_threads();
    let book = crate::baselines::kmeans::train(&data, ds.d, k, 6, ds.metric, 0x2085_0C5, threads);
    (0..book.k)
        .map(|c| {
            let mut v = book.centroid(c).to_vec();
            if ds.metric == Metric::Cosine {
                crate::distance::normalize(&mut v);
            }
            v
        })
        .collect()
}

/// Round-robin tournament schedule: all C(s,2) pairs in `s-1` (or `s`)
/// rounds of pairwise-disjoint pairs.
pub fn tournament_rounds(s: usize) -> Vec<Vec<(usize, usize)>> {
    if s < 2 {
        return Vec::new();
    }
    let even = s + (s % 2); // odd -> add a bye slot
    let mut ring: Vec<usize> = (0..even).collect();
    let mut rounds = Vec::new();
    for _ in 0..even - 1 {
        let mut round = Vec::new();
        for i in 0..even / 2 {
            let (a, b) = (ring[i], ring[even - 1 - i]);
            if a < s && b < s {
                round.push((a.min(b), a.max(b)));
            }
        }
        rounds.push(round);
        // rotate all but the first element
        ring[1..].rotate_right(1);
    }
    rounds
}

/// Configuration of the out-of-core pipeline.
#[derive(Clone, Debug)]
pub struct OutOfCoreConfig {
    /// Number of shards to partition into (the paper uses "several
    /// hundreds" at billion scale; each must fit one device).
    pub shards: usize,
    /// Concurrent merge workers (= devices in the paper's multi-GPU mode).
    pub workers: usize,
    /// GNND parameters shared by shard builds and merge refinement.
    pub params: GnndParams,
}

impl Default for OutOfCoreConfig {
    fn default() -> Self {
        OutOfCoreConfig { shards: 4, workers: 1, params: GnndParams::default() }
    }
}

/// Statistics of an out-of-core build. Persisted as `stats.json` next
/// to the shards ([`ShardStore::save_stats`]) so bench trajectories can
/// track merge cost per run.
#[derive(Clone, Debug, Default)]
pub struct OutOfCoreStats {
    pub build_secs: f64,
    pub merge_secs: f64,
    pub merges: usize,
    pub rounds: usize,
    pub io_secs: f64,
}

impl OutOfCoreStats {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("build_secs", self.build_secs)
            .set("merge_secs", self.merge_secs)
            .set("merges", self.merges)
            .set("rounds", self.rounds)
            .set("io_secs", self.io_secs)
    }

    /// Inverse of [`OutOfCoreStats::to_json`], so `stats.json` is
    /// readable back by tooling. Unknown fields (e.g. a folded-in
    /// `"residency"` block) are ignored.
    pub fn from_json(j: &Json) -> crate::Result<OutOfCoreStats> {
        Ok(OutOfCoreStats {
            build_secs: jf64(j, "build_secs")?,
            merge_secs: jf64(j, "merge_secs")?,
            merges: jusize(j, "merges")?,
            rounds: jusize(j, "rounds")?,
            io_secs: jf64(j, "io_secs")?,
        })
    }
}

/// Build the k-NN graph of `ds` out-of-core under `dir`.
///
/// The input dataset is only used to *write the shards*; all subsequent
/// reads go through the [`ShardStore`], so the pipeline touches at most
/// `2 * (workers + 1)` shards of vectors at a time.
pub fn build_out_of_core(
    ds: &Dataset,
    dir: impl AsRef<Path>,
    cfg: &OutOfCoreConfig,
    engine: &dyn CrossmatchEngine,
) -> crate::Result<(KnnGraph, OutOfCoreStats)> {
    anyhow::ensure!(cfg.shards >= 2, "need at least 2 shards");
    let store = ShardStore::new(&dir)?;
    let mut stats = OutOfCoreStats::default();

    // ---- partition + spill (+ manifest, so the dir is servable) ----
    let t = Timer::start();
    let shards = ds.split(cfg.shards);
    let mut offsets = Vec::with_capacity(cfg.shards);
    let mut centroids = Vec::with_capacity(cfg.shards);
    let mut route_centroids = Vec::with_capacity(cfg.shards);
    let mut off = 0usize;
    for (i, sh) in shards.iter().enumerate() {
        offsets.push(off);
        off += sh.len();
        centroids.push(shard_centroid(sh));
        route_centroids.push(fit_route_centroids(sh));
        store.save_shard(i, sh)?;
    }
    drop(shards); // from here on, everything is re-read from disk
    store.save_manifest(&ShardManifest {
        shards: cfg.shards,
        total: ds.len(),
        d: ds.d,
        k: cfg.params.k,
        metric: ds.metric,
        offsets: offsets.clone(),
        centroids,
        route_centroids,
    })?;
    stats.io_secs += t.secs();

    // ---- per-shard GNND builds (sequential per worker budget) ----
    let t = Timer::start();
    for i in 0..cfg.shards {
        let sh = store.load_shard(i)?;
        let mut out = gnnd::build_with_engine(&sh, &cfg.params, engine)
            .with_context(|| format!("building shard {i}"))?;
        let o = offsets[i] as u32;
        out.graph.remap_ids(|id| id + o); // store in global id space
        store.save_graph(i, &out.graph)?;
    }
    stats.build_secs = t.secs();

    // ---- pairwise GGM merges, round by round ----
    let t = Timer::start();
    let rounds = tournament_rounds(cfg.shards);
    stats.rounds = rounds.len();
    for round in &rounds {
        run_round(&store, round, &offsets, cfg, engine)?;
        stats.merges += round.len();
    }
    stats.merge_secs = t.secs();

    // ---- assemble the final graph (evaluation convenience; at true
    //      scale consumers stream the per-shard files) ----
    let mut final_g: Option<KnnGraph> = None;
    for i in 0..cfg.shards {
        let g = store.load_graph(i)?;
        final_g = Some(match final_g {
            None => g,
            Some(acc) => acc.stack(&g),
        });
    }

    // ---- serving prep: pre-build the per-shard entry-hierarchy
    //      sidecars so the first `--entry hierarchy` open pays one file
    //      read per shard instead of the O(sample^2) build ----
    let t = Timer::start();
    refresh_hier_sidecars(&store, cfg.shards)?;
    stats.io_secs += t.secs();

    store.save_stats(&stats)?;
    Ok((final_g.unwrap(), stats))
}

/// Payload flowing through the prefetch pipeline.
struct PairData {
    i: usize,
    j: usize,
    dsi: Dataset,
    dsj: Dataset,
    gi: KnnGraph,
    gj: KnnGraph,
}

/// Execute one disjoint round: a loader thread prefetches pair data
/// while `workers` merge workers consume and write back.
fn run_round(
    store: &ShardStore,
    round: &[(usize, usize)],
    offsets: &[usize],
    cfg: &OutOfCoreConfig,
    engine: &dyn CrossmatchEngine,
) -> crate::Result<()> {
    // Bounded channel: at most workers+1 pairs resident.
    let (tx, rx) = mpsc::sync_channel::<PairData>(1);
    let rx = std::sync::Mutex::new(rx);
    let err: std::sync::Mutex<Option<anyhow::Error>> = std::sync::Mutex::new(None);
    let err_ref = &err;
    crossbeam_utils::thread::scope(|scope| {
        // loader (overlaps disk reads with merging); `tx` is MOVED in so
        // it drops when loading finishes and workers' recv() unblocks.
        scope.spawn(move |_| {
            for &(i, j) in round {
                let load = (|| -> crate::Result<PairData> {
                    Ok(PairData {
                        i,
                        j,
                        dsi: store.load_shard(i)?,
                        dsj: store.load_shard(j)?,
                        gi: store.load_graph(i)?,
                        gj: store.load_graph(j)?,
                    })
                })();
                match load {
                    Ok(p) => {
                        if tx.send(p).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        *err_ref.lock().unwrap() = Some(e);
                        return;
                    }
                }
            }
        });
        // merge workers
        for _ in 0..cfg.workers.max(1) {
            scope.spawn(|_| loop {
                let pair = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                let Ok(p) = pair else { return };
                let res = merge_pair_global(
                    &p.dsi,
                    &p.dsj,
                    &p.gi,
                    &p.gj,
                    offsets[p.i],
                    offsets[p.j],
                    &cfg.params,
                    engine,
                )
                .and_then(|(gi, gj)| {
                    store.save_graph(p.i, &gi)?;
                    store.save_graph(p.j, &gj)
                });
                if let Err(e) = res {
                    *err.lock().unwrap() = Some(e);
                    return;
                }
            });
        }
    })
    .unwrap();
    match err.into_inner().unwrap() {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// GGM over two shards whose graphs live in the *global* id space.
///
/// Entries referencing non-resident shards cannot be cross-matched
/// (their vectors are on disk); they are stashed and folded back after
/// refinement, so earlier merge gains are never lost.
#[allow(clippy::too_many_arguments)]
pub fn merge_pair_global(
    dsi: &Dataset,
    dsj: &Dataset,
    gi: &KnnGraph,
    gj: &KnnGraph,
    oi: usize,
    oj: usize,
    params: &GnndParams,
    engine: &dyn CrossmatchEngine,
) -> crate::Result<(KnnGraph, KnnGraph)> {
    let (ni, nj) = (gi.n(), gj.n());
    let k = gi.k();
    anyhow::ensure!(gj.k() == k, "k mismatch");
    let to_local = |gid: u32| -> Option<u32> {
        let g = gid as usize;
        if (oi..oi + ni).contains(&g) {
            Some((g - oi) as u32)
        } else if (oj..oj + nj).contains(&g) {
            Some((ni + g - oj) as u32)
        } else {
            None
        }
    };

    // Localize both graphs; stash external entries (global ids).
    let mut ext: Vec<Vec<Neighbor>> = vec![Vec::new(); ni + nj];
    let mut l1 = KnnGraph::empty(ni, k);
    let mut l2 = KnnGraph::empty(nj, k);
    for u in 0..ni + nj {
        let src_list = if u < ni { gi.list(u) } else { gj.list(u - ni) };
        let dst = if u < ni { l1.list_mut(u) } else { l2.list_mut(u - ni) };
        let mut w = 0;
        for e in src_list {
            if e.is_empty() {
                break;
            }
            match to_local(e.id) {
                Some(lid) => {
                    // merge() expects each sub-graph in its own local
                    // space: l2 ids get de-offset below via remap.
                    dst[w] = Neighbor { id: lid, dist: e.dist, new: false };
                    w += 1;
                }
                None => ext[u].push(*e),
            }
        }
    }
    // l2 currently holds combined-space ids (>= ni for own subset is
    // wrong — its entries may point into subset i too). merge() takes
    // g2 in *local* space; entries of l2 pointing into subset i cannot
    // be represented there, so run merge() in combined space directly:
    // treat l1 ∪ l2 as the joined graph by passing the sub-graphs as-is
    // after splitting combined ids. Entries of l1 pointing into subset j
    // (from earlier merges) are equally fine: merge() only *reads*
    // sub-graph lists to seed the joined graph.
    let l2 = {
        // remap combined ids back to g2-local where possible; entries
        // into subset i stay as cross links — stash them for refold.
        let mut out = KnnGraph::empty(nj, k);
        for u in 0..nj {
            let mut w = 0;
            for e in l2.list(u) {
                if e.is_empty() {
                    break;
                }
                if e.id as usize >= ni {
                    out.list_mut(u)[w] = Neighbor { id: e.id - ni as u32, ..*e };
                    w += 1;
                } else {
                    // cross entry already known: keep via stash (combined id)
                    ext[ni + u].push(Neighbor { id: (e.id as usize + oi) as u32, ..*e });
                }
            }
        }
        out
    };
    let l1 = {
        let mut out = KnnGraph::empty(ni, k);
        for u in 0..ni {
            let mut w = 0;
            for e in l1.list(u) {
                if e.is_empty() {
                    break;
                }
                if (e.id as usize) < ni {
                    out.list_mut(u)[w] = *e;
                    w += 1;
                } else {
                    ext[u].push(Neighbor { id: (e.id as usize - ni + oj) as u32, ..*e });
                }
            }
        }
        out
    };

    let combined = dsi.concat(dsj, "merge-pair");
    let (mut merged, _stats) = super::merge(&combined, ni, &l1, &l2, params, engine)?;

    // Fold external stashes back, then translate to global ids.
    for u in 0..ni + nj {
        let k = merged.k();
        let list = merged.list_mut(u);
        if !ext[u].is_empty() {
            let mut cands: Vec<Neighbor> = list
                .iter()
                .filter(|e| !e.is_empty())
                .map(|e| {
                    // local combined -> global
                    let gid = if (e.id as usize) < ni {
                        e.id as usize + oi
                    } else {
                        e.id as usize - ni + oj
                    };
                    Neighbor { id: gid as u32, dist: e.dist, new: false }
                })
                .collect();
            cands.extend(ext[u].iter().copied());
            cands.sort_unstable_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
            let mut seen = std::collections::HashSet::new();
            let mut w = 0;
            for e in cands {
                if w == k {
                    break;
                }
                if seen.insert(e.id) {
                    list[w] = e;
                    w += 1;
                }
            }
            for slot in list[w..].iter_mut() {
                *slot = Neighbor::empty();
            }
        } else {
            for e in list.iter_mut() {
                if e.is_empty() {
                    continue;
                }
                let gid = if (e.id as usize) < ni {
                    e.id as usize + oi
                } else {
                    e.id as usize - ni + oj
                };
                e.id = gid as u32;
            }
        }
    }

    // Split back into per-shard graphs (global id space).
    let mut out_i = KnnGraph::empty(ni, k);
    let mut out_j = KnnGraph::empty(nj, k);
    for u in 0..ni {
        out_i.list_mut(u).copy_from_slice(merged.list(u));
    }
    for u in 0..nj {
        out_j.list_mut(u).copy_from_slice(merged.list(ni + u));
    }
    Ok((out_i, out_j))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{groundtruth, synth};
    use crate::gnnd::NativeEngine;
    use crate::metrics::recall_at;

    #[test]
    fn tournament_covers_all_pairs_disjointly() {
        for s in [2usize, 3, 4, 5, 8, 9] {
            let rounds = tournament_rounds(s);
            let mut seen = std::collections::HashSet::new();
            for round in &rounds {
                let mut used = std::collections::HashSet::new();
                for &(a, b) in round {
                    assert!(a < b && b < s);
                    assert!(used.insert(a), "shard {a} reused in round");
                    assert!(used.insert(b), "shard {b} reused in round");
                    assert!(seen.insert((a, b)), "pair repeated");
                }
            }
            assert_eq!(seen.len(), s * (s - 1) / 2, "s={s}");
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "gnnd-ooc-{tag}-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn out_of_core_matches_in_memory_quality() {
        let ds = synth::clustered(480, 8, 31);
        let params = GnndParams::default().with_k(12).with_p(6).with_iters(8);
        let cfg = OutOfCoreConfig { shards: 4, workers: 2, params: params.clone() };
        let dir = tmpdir("quality");
        let (g, stats) = build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
        assert_eq!(g.n(), ds.len());
        g.check_invariants().unwrap();
        assert_eq!(stats.merges, 6);
        let truth = groundtruth::exact_topk(&ds, 10);
        let r_ooc = recall_at(&g, &truth, None, 10);
        let g_mem = gnnd::build(&ds, &params).unwrap();
        let r_mem = recall_at(&g_mem, &truth, None, 10);
        assert!(
            r_ooc > r_mem - 0.12,
            "out-of-core recall {r_ooc} too far below in-memory {r_mem}"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn shard_store_roundtrip() {
        let dir = tmpdir("store");
        let store = ShardStore::new(&dir).unwrap();
        let ds = synth::uniform(30, 4, 32);
        store.save_shard(3, &ds).unwrap();
        let back = store.load_shard(3).unwrap();
        assert_eq!(back.raw(), ds.raw());
        let g = KnnGraph::empty(30, 4);
        store.save_graph(3, &g).unwrap();
        assert_eq!(store.load_graph(3).unwrap().n(), 30);
        std::fs::remove_dir_all(dir).ok();
    }

    /// Write `shards` identical-size shard/graph pairs for cache tests.
    fn write_shards(dir: &Path, shards: usize) {
        let store = ShardStore::new(dir).unwrap();
        for i in 0..shards {
            let ds = synth::uniform(50, 4, 100 + i as u64);
            store.save_shard(i, &ds).unwrap();
            store.save_graph(i, &KnnGraph::empty(50, 6)).unwrap();
        }
    }

    #[test]
    fn residency_cache_lru_eviction_pinning_and_admission() {
        let dir = tmpdir("residency");
        write_shards(&dir, 4);
        // one-shard byte cost, measured through an unbounded store
        let one = ShardStore::new(&dir).unwrap().get_shard(0).unwrap().bytes;

        // budget fits exactly one shard
        let store = ShardStore::with_budget(&dir, one).unwrap();
        let h0 = store.get_shard(0).unwrap();
        assert_eq!(store.residency().misses, 1);
        assert_eq!(store.residency().resident_bytes, one);

        // a second shard would force an eviction: the doorkeeper serves
        // its first recent visit without caching it (scan protection)
        let h1 = store.get_shard(1).unwrap();
        let res = store.residency();
        assert_eq!(res.misses, 2);
        assert_eq!(res.rejected_admissions, 1);
        assert_eq!(res.evictions, 0);
        assert_eq!(res.resident_bytes, one, "rejected shard must not be cached");
        assert_eq!(h1.ds.raw().len(), 50 * 4, "rejected shard still serves its data");

        // the second visit admits; shard 0 is pinned by h0, so the
        // cache legitimately runs past the budget until pins release
        let h1b = store.get_shard(1).unwrap();
        let res = store.residency();
        assert_eq!(res.misses, 3);
        assert_eq!(res.evictions, 0, "pinned shards must survive eviction passes");
        assert!(res.resident_bytes > store.budget_bytes());
        drop(h1);
        drop(h1b);

        // shard 0 is still pinned by h0: a hit, and its data is intact
        let h0b = store.get_shard(0).unwrap();
        assert_eq!(store.residency().hits, 1);
        assert_eq!(h0b.ds.raw(), h0.ds.raw());
        // the hit's eviction pass shed the now-unpinned shard 1
        let res = store.residency();
        assert_eq!(res.evictions, 1);
        assert_eq!(res.resident_bytes, one);

        // after unpinning, an eviction pass brings the cache to budget
        drop(h0);
        drop(h0b);
        store.evict_to_budget();
        let res = store.residency();
        assert!(
            res.resident_bytes <= store.budget_bytes(),
            "resident {} > budget {} after unpin",
            res.resident_bytes,
            store.budget_bytes()
        );
        assert!(res.peak_resident_bytes >= 2 * one);

        // a fresh shard passes the doorkeeper on its second visit and
        // LRU-evicts the older resident; it is then a hit
        let hits_before = store.residency().hits;
        drop(store.get_shard(2).unwrap()); // first visit: rejected
        drop(store.get_shard(2).unwrap()); // second: admitted, evicts 0
        let r = store.residency();
        assert_eq!(r.resident_shards, 1, "budget fits one shard");
        let h2b = store.get_shard(2).unwrap();
        drop(h2b);
        assert_eq!(store.residency().hits, hits_before + 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn shard_mode_counts_bytes_read() {
        let dir = tmpdir("bytesread");
        write_shards(&dir, 2);
        let store = ShardStore::new(&dir).unwrap();
        assert_eq!(store.residency().bytes_read, 0);
        store.get_shard(0).unwrap();
        let per_shard = (50 * 4 * 4 + 50 * 6 * 8) as u64; // vectors + graph payload
        assert_eq!(store.residency().bytes_read, per_shard);
        store.get_shard(0).unwrap(); // hit: no new disk bytes
        assert_eq!(store.residency().bytes_read, per_shard);
        store.get_shard(1).unwrap();
        assert_eq!(store.residency().bytes_read, 2 * per_shard);
        assert_eq!(store.residency().mode, "shard");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn block_mode_pages_rows_instead_of_shards() {
        let dir = tmpdir("blockmode");
        write_shards(&dir, 3);
        let total_payload = 3 * (50 * 4 * 4 + 50 * 6 * 8) as u64;
        let store = ShardStore::with_residency(&dir, 8 * 1024, ResidencyMode::block()).unwrap();
        let h = store.get_shard(0).unwrap();
        assert!(h.ds.is_paged() && h.graph.is_paged(), "block mode must open paged handles");
        assert!(h.bytes < 4096, "paged handle cost {} should be tiny", h.bytes);
        // touching one row pages in one vector block + nothing else
        let v = h.ds.vector(7);
        assert_eq!(v.len(), 4);
        let mut nbuf = Vec::new();
        h.graph.neighbors_into(7, &mut nbuf);
        let res = store.residency();
        assert_eq!(res.mode, "block");
        assert!(res.block_fetches >= 1);
        assert!(
            res.bytes_read < total_payload / 2,
            "touching one row read {} of {total_payload} total bytes — not partial",
            res.bytes_read
        );
        // row contents match a materialized read of the same shard
        let owned = ShardStore::new(&dir).unwrap().get_shard(0).unwrap();
        assert_eq!(v, owned.ds.vec(7));
        let mut want = Vec::new();
        owned.graph.neighbors_into(7, &mut want);
        assert_eq!(nbuf, want);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn block_mode_serves_v1_files_via_owned_fallback() {
        let dir = tmpdir("blockv1");
        write_shards(&dir, 2);
        // rewrite shard 0 in the legacy v1 formats
        let store = ShardStore::new(&dir).unwrap();
        let h = store.get_shard(0).unwrap();
        io::write_dsb_v1(&h.ds, dir.join("shard_0.dsb")).unwrap();
        h.graph.save_v1(dir.join("graph_0.knng")).unwrap();
        drop(h);
        drop(store);
        let store = ShardStore::with_residency(&dir, 0, ResidencyMode::block()).unwrap();
        let h0 = store.get_shard(0).unwrap();
        assert!(!h0.ds.is_paged(), "v1 must fall back to the owned path");
        let h1 = store.get_shard(1).unwrap();
        assert!(h1.ds.is_paged(), "v2 stays paged");
        assert_eq!(h0.ds.vec(3).to_vec(), {
            let owned = ShardStore::new(&dir).unwrap().get_shard(0).unwrap();
            owned.ds.vec(3).to_vec()
        });
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn quantized_store_serves_both_residency_modes() {
        let dir = tmpdir("quantstore");
        let ds = synth::clustered(240, 6, 71);
        let params = GnndParams::default().with_k(8).with_p(4).with_iters(3);
        let cfg = OutOfCoreConfig { shards: 3, workers: 1, params };
        build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

        // a quantized open before quantize-store ran names the missing
        // file and the fix in its error
        let early = ShardStore::with_options(&dir, 0, ResidencyMode::Shard, true).unwrap();
        let err = format!("{:#}", early.get_shard(0).unwrap_err());
        assert!(err.contains("gnnd quantize"), "unhelpful error: {err}");

        let qp = quantize_store(&dir).unwrap();
        assert_eq!(qp.d(), 6);
        assert!(dir.join("quant_0.dsb").exists());

        // shard mode: owned codes + paged exact sidecar
        let f32_store = ShardStore::new(&dir).unwrap();
        let qs = ShardStore::with_options(&dir, 0, ResidencyMode::Shard, true).unwrap();
        let h = qs.get_shard(1).unwrap();
        assert!(h.ds.is_quantized() && !h.graph.is_paged());
        let want = f32_store.get_shard(1).unwrap();
        // vector data shrinks vs the f32 store (codes + params vs f32
        // rows); dataset_bytes isolates that from graph bytes. At this
        // toy dimension the params/handle overhead keeps the ratio
        // above the asymptotic ~0.25 (the CI smoke checks < 0.3x at a
        // realistic d), so assert the conservative half
        let (dq, df) = (qs.residency().dataset_bytes, f32_store.residency().dataset_bytes);
        assert!(dq * 2 < df, "quantized dataset bytes {dq} not < 0.5x of f32 {df}");
        // codes decode to within half a quantization step per dim
        for i in [0usize, 7, 79] {
            let (got, exact) = (h.ds.vector(i), want.ds.vector(i));
            for j in 0..6 {
                assert!(
                    (got[j] - exact[j]).abs() <= qp.scale[j] / 2.0 + 1e-6,
                    "row {i} dim {j}: {} vs {}",
                    got[j],
                    exact[j]
                );
            }
        }
        // the exact sidecar serves bit-exact f32 rerank rows
        let mut buf = Vec::new();
        let q = want.ds.vector(3);
        let exact_d = h.ds.rerank_dist_to(12, &q, &mut buf);
        assert_eq!(exact_d, want.ds.dist_to(12, &q));
        // quantized codes read ~1/4 the payload bytes of an f32 load
        let per_f32 = want.ds.len() as u64 * 6 * 4;
        let loaded = qs.residency().bytes_read;
        assert!(
            loaded < per_f32,
            "quantized load read {loaded} bytes, f32 load would read {per_f32}"
        );
        drop(h);
        drop(want);

        // block mode: codes paged through the block cache, bit-identical
        // dequantized rows to the shard-mode open
        let qb = ShardStore::with_options(&dir, 16 * 1024, ResidencyMode::block(), true).unwrap();
        let hb = qb.get_shard(1).unwrap();
        assert!(hb.ds.is_quantized() && hb.graph.is_paged());
        let hs = qs.get_shard(1).unwrap();
        for i in [0usize, 5, 41] {
            assert_eq!(hb.ds.vector(i), hs.ds.vector(i), "shard vs block quantized row {i}");
        }
        assert!(qb.residency().block_fetches > 0);
        drop(hb);
        qb.evict_to_budget();
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn unbounded_store_caches_everything() {
        let dir = tmpdir("unbounded");
        write_shards(&dir, 3);
        let store = ShardStore::new(&dir).unwrap();
        for i in 0..3 {
            store.get_shard(i).unwrap();
        }
        for i in 0..3 {
            store.get_shard(i).unwrap();
        }
        let res = store.residency();
        assert_eq!((res.hits, res.misses, res.evictions), (3, 3, 0));
        assert_eq!(res.resident_shards, 3);
        assert_eq!(res.budget_bytes, 0);
        // saving over a cached shard invalidates it
        let ds = synth::uniform(50, 4, 999);
        store.save_shard(1, &ds).unwrap();
        let back = store.get_shard(1).unwrap();
        assert_eq!(back.ds.raw(), ds.raw(), "stale shard served after save");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stats_json_roundtrips() {
        let stats = OutOfCoreStats {
            build_secs: 1.5,
            merge_secs: 2.25,
            merges: 6,
            rounds: 3,
            io_secs: 0.125,
        };
        let back = OutOfCoreStats::from_json(&Json::parse(&stats.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.build_secs, stats.build_secs);
        assert_eq!(back.merge_secs, stats.merge_secs);
        assert_eq!((back.merges, back.rounds), (stats.merges, stats.rounds));
        assert_eq!(back.io_secs, stats.io_secs);

        let res = ResidencyStats {
            hits: 10,
            misses: 4,
            evictions: 2,
            resident_shards: 1,
            resident_bytes: 4096,
            peak_resident_bytes: 8192,
            budget_bytes: 5000,
            mode: "block".to_string(),
            block_fetches: 31,
            block_hits: 99,
            block_evictions: 7,
            rejected_admissions: 3,
            bytes_read: 123_456,
            dataset_bytes: 777,
        };
        let back =
            ResidencyStats::from_json(&Json::parse(&res.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, res);
        assert!((res.hit_rate() - 10.0 / 14.0).abs() < 1e-12);
        // stats.json blocks written before the block-residency fields
        // existed still parse (fields default)
        let legacy = Json::obj()
            .set("hits", 1u64)
            .set("misses", 2u64)
            .set("evictions", 0u64)
            .set("resident_shards", 1usize)
            .set("resident_bytes", 10usize)
            .set("peak_resident_bytes", 10usize)
            .set("budget_bytes", 0usize);
        let old = ResidencyStats::from_json(&legacy).unwrap();
        assert_eq!(old.mode, "shard");
        assert_eq!((old.block_fetches, old.bytes_read, old.rejected_admissions), (0, 0, 0));
        assert_eq!(old.dataset_bytes, 0);

        // the serve-time fold keeps the build stats readable and adds
        // the residency block to the same file
        let dir = tmpdir("statsfold");
        let store = ShardStore::new(&dir).unwrap();
        store.save_stats(&stats).unwrap();
        store.save_stats_with_residency(&res).unwrap();
        let text = std::fs::read_to_string(dir.join(STATS_FILE)).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("merges").and_then(Json::as_usize), Some(6));
        let folded = ResidencyStats::from_json(j.get("residency").unwrap()).unwrap();
        assert_eq!(folded, res);
        let build_back = store.load_stats().unwrap().unwrap();
        assert_eq!(build_back.merges, stats.merges);
        // repeated folds replace the residency block, never duplicate it
        store.save_stats_with_residency(&res).unwrap();
        let text = std::fs::read_to_string(dir.join(STATS_FILE)).unwrap();
        assert_eq!(text.matches("\"residency\"").count(), 1, "duplicated block: {text}");
        std::fs::remove_dir_all(dir).ok();

        // a dir that never ran ooc-build: folding works, load_stats
        // reads the residency-only file as "no build stats" (not error)
        let dir = tmpdir("statsnobuild");
        let store = ShardStore::new(&dir).unwrap();
        store.save_stats_with_residency(&res).unwrap();
        store.save_stats_with_residency(&res).unwrap();
        assert!(store.load_stats().unwrap().is_none());
        let text = std::fs::read_to_string(dir.join(STATS_FILE)).unwrap();
        assert_eq!(text.matches("\"residency\"").count(), 1);
        // a corrupt stats.json is an error, never silently overwritten
        std::fs::write(dir.join(STATS_FILE), "{truncated").unwrap();
        assert!(store.save_stats_with_residency(&res).is_err());
        assert_eq!(std::fs::read_to_string(dir.join(STATS_FILE)).unwrap(), "{truncated");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn manifest_byte_estimates_match_resident_cost() {
        let dir = tmpdir("bytes");
        let ds = synth::uniform(90, 6, 55);
        let params = GnndParams::default().with_k(8).with_p(4).with_iters(3);
        let cfg = OutOfCoreConfig { shards: 3, workers: 1, params };
        build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
        let store = ShardStore::new(&dir).unwrap();
        let m = store.load_manifest().unwrap();
        let mut total = 0usize;
        for s in 0..m.shards {
            let h = store.get_shard(s).unwrap();
            assert_eq!(m.shard_bytes(s), h.bytes, "estimate off for shard {s}");
            total += h.bytes;
        }
        assert_eq!(m.estimated_resident_bytes(), total);
        std::fs::remove_dir_all(dir).ok();
    }
}
