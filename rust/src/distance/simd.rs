//! Explicit `std::arch` distance kernels behind the `simd` cargo
//! feature (see Cargo.toml).
//!
//! Design constraints, in order:
//!
//! 1. **Bit-identical to the scalar folds.** Serving parity tests
//!    compare results across machines and feature sets, so the SIMD
//!    paths must not change a single ulp. The f32 kernels therefore
//!    mirror the scalar lane structure exactly — same per-lane
//!    multiply/add sequence (no FMA contraction; Rust never contracts,
//!    and we never emit `_mm256_fmadd_ps`), same sequential fold of the
//!    lane accumulators, same scalar tail. The integer kernels are
//!    exact by construction. The PQ kernel's scalar twin
//!    ([`super::pq_lut_sum_scalar`]) is written 8-lane chunked so the
//!    AVX2 gather is a per-lane mirror of it.
//! 2. **Runtime detection with scalar fallback.** [`enabled`] caches
//!    one feature probe; on unsupported CPUs (or non-x86/ARM targets)
//!    the dispatchers in [`super`] keep using the scalar bodies, so
//!    building with `--features simd` is always safe.
//!
//! On aarch64 NEON is a baseline feature: the f32 kernels are
//! implemented with `float32x4` arithmetic and the u8/PQ kernels fall
//! through to the scalar bodies (which autovectorize well there).

#[cfg(target_arch = "x86_64")]
pub(crate) use x86::*;

#[cfg(target_arch = "aarch64")]
pub(crate) use arm::*;

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub(crate) use fallback::*;

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::super::{LANES, PQ_KSUB, PQ_LANES};
    use std::arch::x86_64::*;
    use std::sync::atomic::{AtomicU8, Ordering};

    /// Cached AVX2 probe: 0 = unknown, 1 = available, 2 = unavailable.
    static AVX2: AtomicU8 = AtomicU8::new(0);

    #[inline]
    pub(crate) fn enabled() -> bool {
        match AVX2.load(Ordering::Relaxed) {
            1 => true,
            2 => false,
            _ => {
                let on = is_x86_feature_detected!("avx2");
                AVX2.store(if on { 1 } else { 2 }, Ordering::Relaxed);
                on
            }
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (via [`enabled`]).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        // Two 8-lane accumulators = the scalar body's 16 lanes; the
        // per-lane sub/mul/add order matches it exactly.
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let pa = a.as_ptr().add(c * LANES);
            let pb = b.as_ptr().add(c * LANES);
            let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa), _mm256_loadu_ps(pb));
            let d1 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(8)), _mm256_loadu_ps(pb.add(8)));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(d0, d0));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(d1, d1));
        }
        // Fold in the scalar body's order: acc[0] + acc[1] + ... + acc[15].
        let mut lanes = [0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc1);
        let mut sum: f32 = lanes.iter().sum();
        for i in chunks * LANES..a.len() {
            let d = a[i] - b[i];
            sum += d * d;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified AVX2 support (via [`enabled`]).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let pa = a.as_ptr().add(c * LANES);
            let pb = b.as_ptr().add(c * LANES);
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(pa), _mm256_loadu_ps(pb)));
            acc1 = _mm256_add_ps(
                acc1,
                _mm256_mul_ps(_mm256_loadu_ps(pa.add(8)), _mm256_loadu_ps(pb.add(8))),
            );
        }
        let mut lanes = [0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc1);
        let mut sum: f32 = lanes.iter().sum();
        for i in chunks * LANES..a.len() {
            sum += a[i] * b[i];
        }
        sum
    }

    /// Widen the eight i32 lanes of `v` to i64 and add them into the
    /// two 4×i64 accumulators.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn add_i32x8_to_i64(v: __m256i, lo: &mut __m256i, hi: &mut __m256i) {
        *lo = _mm256_add_epi64(*lo, _mm256_cvtepi32_epi64(_mm256_castsi256_si128(v)));
        *hi = _mm256_add_epi64(*hi, _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(v)));
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn fold_i64(lo: __m256i, hi: __m256i) -> u64 {
        let mut lanes = [0i64; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, lo);
        _mm256_storeu_si256(lanes.as_mut_ptr().add(4) as *mut __m256i, hi);
        lanes.iter().map(|&x| x as u64).sum()
    }

    /// # Safety
    /// Caller must have verified AVX2 support (via [`enabled`]).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn l2_sq_u8(a: &[u8], b: &[u8]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        // 16 bytes per step, zero-extended to i16; diff² pairs are
        // summed by madd into i32 (max 2·255² < 2^31) and widened to
        // i64 accumulators. Integer arithmetic — exact at any length.
        let mut lo = _mm256_setzero_si256();
        let mut hi = _mm256_setzero_si256();
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let va = _mm_loadu_si128(a.as_ptr().add(c * LANES) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(c * LANES) as *const __m128i);
            let d = _mm256_sub_epi16(_mm256_cvtepu8_epi16(va), _mm256_cvtepu8_epi16(vb));
            add_i32x8_to_i64(_mm256_madd_epi16(d, d), &mut lo, &mut hi);
        }
        let mut sum = fold_i64(lo, hi);
        for i in chunks * LANES..a.len() {
            let d = a[i] as i32 - b[i] as i32;
            sum += (d * d) as u64;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified AVX2 support (via [`enabled`]).
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn dot_u8(a: &[u8], b: &[u8]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let mut lo = _mm256_setzero_si256();
        let mut hi = _mm256_setzero_si256();
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let va = _mm_loadu_si128(a.as_ptr().add(c * LANES) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(c * LANES) as *const __m128i);
            let prod = _mm256_madd_epi16(_mm256_cvtepu8_epi16(va), _mm256_cvtepu8_epi16(vb));
            add_i32x8_to_i64(prod, &mut lo, &mut hi);
        }
        let mut sum = fold_i64(lo, hi);
        for i in chunks * LANES..a.len() {
            sum += a[i] as u64 * b[i] as u64;
        }
        sum
    }

    /// # Safety
    /// Caller must have verified AVX2 support (via [`enabled`]); `lut`
    /// must hold `codes.len() * 256` entries.
    #[target_feature(enable = "avx2")]
    pub(crate) unsafe fn pq_lut_sum(lut: &[f32], codes: &[u8]) -> f32 {
        debug_assert_eq!(lut.len(), codes.len() * PQ_KSUB);
        // 8 codes per step: zero-extend to i32 lane indices, offset
        // each lane into its own 256-entry table slice, one gather.
        // Per-lane adds + sequential fold mirror pq_lut_sum_scalar.
        let step = _mm256_setr_epi32(0, 256, 512, 768, 1024, 1280, 1536, 1792);
        let mut acc = _mm256_setzero_ps();
        let chunks = codes.len() / PQ_LANES;
        for c in 0..chunks {
            let raw = _mm_loadl_epi64(codes.as_ptr().add(c * PQ_LANES) as *const __m128i);
            let idx = _mm256_add_epi32(
                _mm256_add_epi32(_mm256_set1_epi32((c * PQ_LANES * PQ_KSUB) as i32), step),
                _mm256_cvtepu8_epi32(raw),
            );
            acc = _mm256_add_ps(acc, _mm256_i32gather_ps::<4>(lut.as_ptr(), idx));
        }
        let mut lanes = [0f32; PQ_LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut sum: f32 = lanes.iter().sum();
        for sub in chunks * PQ_LANES..codes.len() {
            sum += lut[sub * PQ_KSUB + codes[sub] as usize];
        }
        sum
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::super::LANES;
    use std::arch::aarch64::*;

    /// NEON is an aarch64 baseline feature — always on.
    #[inline]
    pub(crate) fn enabled() -> bool {
        true
    }

    /// # Safety
    /// Always safe on aarch64 (NEON is baseline); unsafe only for the
    /// intrinsic calls.
    #[inline]
    pub(crate) unsafe fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        // Four 4-lane accumulators = the scalar body's 16 lanes; no
        // vfmaq (fused) so results stay bit-identical to scalar.
        let mut acc = [vdupq_n_f32(0.0); 4];
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let pa = a.as_ptr().add(c * LANES);
            let pb = b.as_ptr().add(c * LANES);
            for (j, accj) in acc.iter_mut().enumerate() {
                let d = vsubq_f32(vld1q_f32(pa.add(4 * j)), vld1q_f32(pb.add(4 * j)));
                *accj = vaddq_f32(*accj, vmulq_f32(d, d));
            }
        }
        let mut lanes = [0f32; LANES];
        for (j, accj) in acc.iter().enumerate() {
            vst1q_f32(lanes.as_mut_ptr().add(4 * j), *accj);
        }
        let mut sum: f32 = lanes.iter().sum();
        for i in chunks * LANES..a.len() {
            let d = a[i] - b[i];
            sum += d * d;
        }
        sum
    }

    /// # Safety
    /// Always safe on aarch64 (NEON is baseline); unsafe only for the
    /// intrinsic calls.
    #[inline]
    pub(crate) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [vdupq_n_f32(0.0); 4];
        let chunks = a.len() / LANES;
        for c in 0..chunks {
            let pa = a.as_ptr().add(c * LANES);
            let pb = b.as_ptr().add(c * LANES);
            for (j, accj) in acc.iter_mut().enumerate() {
                let prod = vmulq_f32(vld1q_f32(pa.add(4 * j)), vld1q_f32(pb.add(4 * j)));
                *accj = vaddq_f32(*accj, prod);
            }
        }
        let mut lanes = [0f32; LANES];
        for (j, accj) in acc.iter().enumerate() {
            vst1q_f32(lanes.as_mut_ptr().add(4 * j), *accj);
        }
        let mut sum: f32 = lanes.iter().sum();
        for i in chunks * LANES..a.len() {
            sum += a[i] * b[i];
        }
        sum
    }

    /// u8 kernels: the scalar integer folds autovectorize cleanly on
    /// aarch64; keep them as the "SIMD" path rather than hand-rolling.
    ///
    /// # Safety
    /// Always safe (delegates to safe scalar code).
    #[inline]
    pub(crate) unsafe fn l2_sq_u8(a: &[u8], b: &[u8]) -> u64 {
        super::super::l2_sq_u8_scalar(a, b)
    }

    /// # Safety
    /// Always safe (delegates to safe scalar code).
    #[inline]
    pub(crate) unsafe fn dot_u8(a: &[u8], b: &[u8]) -> u64 {
        super::super::dot_u8_scalar(a, b)
    }

    /// # Safety
    /// Always safe (delegates to safe scalar code).
    #[inline]
    pub(crate) unsafe fn pq_lut_sum(lut: &[f32], codes: &[u8]) -> f32 {
        super::super::pq_lut_sum_scalar(lut, codes)
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod fallback {
    /// No explicit kernels on this target — dispatchers stay scalar.
    #[inline]
    pub(crate) fn enabled() -> bool {
        false
    }

    /// # Safety
    /// Always safe (delegates to safe scalar code); unreachable anyway
    /// since [`enabled`] is false.
    #[inline]
    pub(crate) unsafe fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        super::super::l2_sq_scalar(a, b)
    }

    /// # Safety
    /// Always safe (delegates to safe scalar code).
    #[inline]
    pub(crate) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        super::super::dot_scalar(a, b)
    }

    /// # Safety
    /// Always safe (delegates to safe scalar code).
    #[inline]
    pub(crate) unsafe fn l2_sq_u8(a: &[u8], b: &[u8]) -> u64 {
        super::super::l2_sq_u8_scalar(a, b)
    }

    /// # Safety
    /// Always safe (delegates to safe scalar code).
    #[inline]
    pub(crate) unsafe fn dot_u8(a: &[u8], b: &[u8]) -> u64 {
        super::super::dot_u8_scalar(a, b)
    }

    /// # Safety
    /// Always safe (delegates to safe scalar code).
    #[inline]
    pub(crate) unsafe fn pq_lut_sum(lut: &[f32], codes: &[u8]) -> f32 {
        super::super::pq_lut_sum_scalar(lut, codes)
    }
}
