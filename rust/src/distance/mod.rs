//! Native distance evaluation (the CPU mirror of the L1 kernels).
//!
//! Used by: graph init, the native cross-matching engine (oracle for the
//! PJRT path), the classic NN-Descent baseline, and ground-truth
//! computation. The inner loops are written as chunked slice folds the
//! compiler auto-vectorizes.

use crate::config::Metric;

/// Squared euclidean distance.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // Process in 8-lane chunks with independent accumulators so LLVM can
    // vectorize; tail handled scalar.
    let mut acc = [0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let ao = &a[c * 8..c * 8 + 8];
        let bo = &b[c * 8..c * 8 + 8];
        for i in 0..8 {
            let d = ao[i] - bo[i];
            acc[i] += d * d;
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        let d = a[i] - b[i];
        sum += d * d;
    }
    sum
}

/// Inner product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let ao = &a[c * 8..c * 8 + 8];
        let bo = &b[c * 8..c * 8 + 8];
        for i in 0..8 {
            acc[i] += ao[i] * bo[i];
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        sum += a[i] * b[i];
    }
    sum
}

/// Distance under `metric` (Cosine assumes pre-normalized inputs and is
/// evaluated as negated inner product — see [`Metric::kernel_metric`]).
#[inline]
pub fn distance(metric: Metric, a: &[f32], b: &[f32]) -> f32 {
    match metric.kernel_metric() {
        Metric::L2 => l2_sq(a, b),
        Metric::Ip => -dot(a, b),
        Metric::Cosine => unreachable!("kernel_metric lowers cosine"),
    }
}

/// L2-normalize a vector in place; zero vectors are left unchanged.
pub fn normalize(v: &mut [f32]) {
    let n = dot(v, v).sqrt();
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in v {
            *x *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn l2_naive(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn l2_matches_naive_all_lengths() {
        prop::check("l2-vs-naive", 200, |rng: &mut Rng| {
            let d = rng.below(70) + 1;
            let a: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 10.0).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 10.0).collect();
            let got = l2_sq(&a, &b);
            let want = l2_naive(&a, &b);
            prop::assert_prop(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                format!("d={d} got={got} want={want}"),
            )
        });
    }

    #[test]
    fn dot_matches_naive() {
        prop::check("dot-vs-naive", 200, |rng: &mut Rng| {
            let d = rng.below(70) + 1;
            let a: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            prop::assert_prop(
                (dot(&a, &b) - want).abs() <= 1e-3 * want.abs().max(1.0),
                "dot mismatch",
            )
        });
    }

    #[test]
    fn l2_identity_and_symmetry() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let b = [9.0f32, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(l2_sq(&a, &a), 0.0);
        assert_eq!(l2_sq(&a, &b), l2_sq(&b, &a));
    }

    #[test]
    fn normalize_unit_norm() {
        let mut v = vec![3.0f32, 4.0];
        normalize(&mut v);
        assert!((dot(&v, &v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0f32; 5];
        normalize(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn cosine_lowers_to_ip() {
        let a = [0.6f32, 0.8];
        let b = [1.0f32, 0.0];
        let d = distance(Metric::Cosine, &a, &b);
        assert!((d - (-0.6)).abs() < 1e-6);
    }
}
