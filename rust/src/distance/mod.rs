//! Native distance evaluation (the CPU mirror of the L1 kernels).
//!
//! Used by: graph init, the native cross-matching engine (oracle for the
//! PJRT path), the classic NN-Descent baseline, and ground-truth
//! computation. The inner loops are written as chunked slice folds the
//! compiler auto-vectorizes; with the `simd` cargo feature the public
//! entry points dispatch to explicit `std::arch` kernels (AVX2 on
//! x86_64, NEON on aarch64) that are runtime-detected and bit-identical
//! to the scalar folds (see [`simd`] and the equivalence property
//! tests below).
//!
//! Three kernel families:
//!
//! * **f32** ([`l2_sq`], [`dot`]) — 16-lane chunked folds over
//!   full-precision rows; the exact kernels every build path and the
//!   rerank phase of quantized serving use.
//! * **u8 code space** ([`l2_sq_u8`], [`dot_u8`], [`dot_dequant`]) —
//!   integer-accumulating kernels over scalar-quantized rows
//!   ([`crate::dataset::store::QuantParams`]). A u8 row is 4x smaller
//!   than its f32 original, so these kernels move 4x fewer bytes per
//!   candidate — the lever of quantized serving's beam phase.
//! * **PQ ADC** ([`pq_lut_sum`]) — sums one lookup-table entry per
//!   subquantizer given an m-byte PQ code row and a per-query m×256
//!   asymmetric-distance table ([`crate::dataset::store::PqParams`]).
//!   The beam inner loop of PQ serving is m gathers instead of a
//!   d-wide dot.

use crate::config::Metric;

#[cfg(feature = "simd")]
pub(crate) mod simd;

/// Lane width of the chunked f32 folds: two 256-bit vectors (or one
/// 512-bit) of independent accumulators, wide enough that the load is
/// the bottleneck, not the reduction dependency chain.
pub(crate) const LANES: usize = 16;

/// Lane width of the chunked PQ LUT fold — one 256-bit gather of 8
/// table entries per step, mirrored exactly by the AVX2 path.
pub(crate) const PQ_LANES: usize = 8;

/// Entries per subquantizer in a PQ lookup table (codes are u8).
pub(crate) const PQ_KSUB: usize = 256;

/// Squared euclidean distance.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(feature = "simd")]
    if simd::enabled() {
        // SAFETY: enabled() verified the required CPU features.
        return unsafe { simd::l2_sq(a, b) };
    }
    l2_sq_scalar(a, b)
}

/// Inner product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(feature = "simd")]
    if simd::enabled() {
        // SAFETY: enabled() verified the required CPU features.
        return unsafe { simd::dot(a, b) };
    }
    dot_scalar(a, b)
}

/// Squared euclidean distance between two u8 code rows, accumulated in
/// integers (no float rounding in the loop). The value is in *code
/// space* — per-dimension differences are in quantization steps, not
/// metric units — so it ranks candidates encoded with the same
/// [`QuantParams`](crate::dataset::store::QuantParams) but is not
/// comparable to an f32 [`l2_sq`]. Max per-dim term is 255² = 65 025;
/// 16 u32 lane accumulators folded into a u64 keep the sum exact for
/// any realistic dimensionality.
#[inline]
pub fn l2_sq_u8(a: &[u8], b: &[u8]) -> u64 {
    #[cfg(feature = "simd")]
    if simd::enabled() {
        // SAFETY: enabled() verified the required CPU features.
        return unsafe { simd::l2_sq_u8(a, b) };
    }
    l2_sq_u8_scalar(a, b)
}

/// Integer inner product of two u8 code rows (code space, see
/// [`l2_sq_u8`]).
#[inline]
pub fn dot_u8(a: &[u8], b: &[u8]) -> u64 {
    #[cfg(feature = "simd")]
    if simd::enabled() {
        // SAFETY: enabled() verified the required CPU features.
        return unsafe { simd::dot_u8(a, b) };
    }
    dot_u8_scalar(a, b)
}

/// Asymmetric PQ distance: sum `lut[sub * 256 + codes[sub]]` over the
/// m subquantizers of one code row. `lut` is the query's precomputed
/// m×256 table (`codes.len() * 256` entries); the result is in metric
/// units (each table entry already is), so PQ distances are directly
/// comparable to exact distances of *reconstructed* rows.
#[inline]
pub fn pq_lut_sum(lut: &[f32], codes: &[u8]) -> f32 {
    #[cfg(feature = "simd")]
    if simd::enabled() {
        // SAFETY: enabled() verified the required CPU features.
        return unsafe { simd::pq_lut_sum(lut, codes) };
    }
    pq_lut_sum_scalar(lut, codes)
}

/// Scalar body of [`l2_sq`] (public so the SIMD equivalence tests and
/// the kernel-throughput bench can pin the baseline).
#[inline]
pub fn l2_sq_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // Process in LANES-wide chunks with independent accumulators so
    // LLVM can vectorize; tail handled scalar.
    let mut acc = [0f32; LANES];
    let chunks = a.len() / LANES;
    for c in 0..chunks {
        let ao = &a[c * LANES..c * LANES + LANES];
        let bo = &b[c * LANES..c * LANES + LANES];
        for i in 0..LANES {
            let d = ao[i] - bo[i];
            acc[i] += d * d;
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for i in chunks * LANES..a.len() {
        let d = a[i] - b[i];
        sum += d * d;
    }
    sum
}

/// Scalar body of [`dot`].
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; LANES];
    let chunks = a.len() / LANES;
    for c in 0..chunks {
        let ao = &a[c * LANES..c * LANES + LANES];
        let bo = &b[c * LANES..c * LANES + LANES];
        for i in 0..LANES {
            acc[i] += ao[i] * bo[i];
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for i in chunks * LANES..a.len() {
        sum += a[i] * b[i];
    }
    sum
}

/// Scalar body of [`l2_sq_u8`].
#[inline]
pub fn l2_sq_u8_scalar(a: &[u8], b: &[u8]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0u32; LANES];
    let chunks = a.len() / LANES;
    for c in 0..chunks {
        let ao = &a[c * LANES..c * LANES + LANES];
        let bo = &b[c * LANES..c * LANES + LANES];
        for i in 0..LANES {
            let d = ao[i] as i32 - bo[i] as i32;
            acc[i] += (d * d) as u32;
        }
    }
    let mut sum: u64 = acc.iter().map(|&x| x as u64).sum();
    for i in chunks * LANES..a.len() {
        let d = a[i] as i32 - b[i] as i32;
        sum += (d * d) as u64;
    }
    sum
}

/// Scalar body of [`dot_u8`].
#[inline]
pub fn dot_u8_scalar(a: &[u8], b: &[u8]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0u32; LANES];
    let chunks = a.len() / LANES;
    for c in 0..chunks {
        let ao = &a[c * LANES..c * LANES + LANES];
        let bo = &b[c * LANES..c * LANES + LANES];
        for i in 0..LANES {
            acc[i] += ao[i] as u32 * bo[i] as u32;
        }
    }
    let mut sum: u64 = acc.iter().map(|&x| x as u64).sum();
    for i in chunks * LANES..a.len() {
        sum += a[i] as u64 * b[i] as u64;
    }
    sum
}

/// Scalar body of [`pq_lut_sum`]. The 8-lane chunking mirrors the AVX2
/// gather width lane for lane (same per-lane adds, same fold order), so
/// the two paths produce bit-identical sums.
#[inline]
pub fn pq_lut_sum_scalar(lut: &[f32], codes: &[u8]) -> f32 {
    debug_assert_eq!(lut.len(), codes.len() * PQ_KSUB);
    let mut acc = [0f32; PQ_LANES];
    let chunks = codes.len() / PQ_LANES;
    for c in 0..chunks {
        let co = &codes[c * PQ_LANES..c * PQ_LANES + PQ_LANES];
        let base = c * PQ_LANES * PQ_KSUB;
        for i in 0..PQ_LANES {
            acc[i] += lut[base + i * PQ_KSUB + co[i] as usize];
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for sub in chunks * PQ_LANES..codes.len() {
        sum += lut[sub * PQ_KSUB + codes[sub] as usize];
    }
    sum
}

/// Inner product of an f32 query against a u8 code row dequantized on
/// the fly (`offset[i] + scale[i] * code[i]`). Per-dimension scales
/// cannot be factored out of an integer dot, so inner-product metrics
/// pay an f32 multiply-add per element — but still move only 1 byte of
/// row data per dimension, which is the serving win. (Stays scalar even
/// under `simd`: the autovectorized fold is already load-bound.)
#[inline]
pub fn dot_dequant(codes: &[u8], q: &[f32], scale: &[f32], offset: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), q.len());
    debug_assert_eq!(codes.len(), scale.len());
    debug_assert_eq!(codes.len(), offset.len());
    let mut acc = [0f32; LANES];
    let chunks = codes.len() / LANES;
    for c in 0..chunks {
        let co = &codes[c * LANES..c * LANES + LANES];
        let qo = &q[c * LANES..c * LANES + LANES];
        let so = &scale[c * LANES..c * LANES + LANES];
        let oo = &offset[c * LANES..c * LANES + LANES];
        for i in 0..LANES {
            acc[i] += qo[i] * (oo[i] + so[i] * co[i] as f32);
        }
    }
    let mut sum: f32 = acc.iter().sum();
    for i in chunks * LANES..codes.len() {
        sum += q[i] * (offset[i] + scale[i] * codes[i] as f32);
    }
    sum
}

/// Distance under `metric` (Cosine assumes pre-normalized inputs and is
/// evaluated as negated inner product — see [`Metric::kernel_metric`]).
#[inline]
pub fn distance(metric: Metric, a: &[f32], b: &[f32]) -> f32 {
    match metric.kernel_metric() {
        Metric::L2 => l2_sq(a, b),
        Metric::Ip => -dot(a, b),
        Metric::Cosine => unreachable!("kernel_metric lowers cosine"),
    }
}

/// L2-normalize a vector in place; zero vectors are left unchanged.
pub fn normalize(v: &mut [f32]) {
    let n = dot(v, v).sqrt();
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in v {
            *x *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn l2_naive(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn l2_matches_naive_all_lengths() {
        prop::check("l2-vs-naive", 200, |rng: &mut Rng| {
            let d = rng.below(70) + 1;
            let a: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 10.0).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 10.0).collect();
            let got = l2_sq(&a, &b);
            let want = l2_naive(&a, &b);
            prop::assert_prop(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                format!("d={d} got={got} want={want}"),
            )
        });
    }

    #[test]
    fn dot_matches_naive() {
        prop::check("dot-vs-naive", 200, |rng: &mut Rng| {
            let d = rng.below(70) + 1;
            let a: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            prop::assert_prop(
                (dot(&a, &b) - want).abs() <= 1e-3 * want.abs().max(1.0),
                "dot mismatch",
            )
        });
    }

    #[test]
    fn l2_u8_matches_naive_all_lengths() {
        // integer accumulation is exact, so the check is equality —
        // including lengths straddling the 16-lane chunk boundary
        prop::check("l2u8-vs-naive", 200, |rng: &mut Rng| {
            let d = rng.below(70) + 1;
            let a: Vec<u8> = (0..d).map(|_| rng.below(256) as u8).collect();
            let b: Vec<u8> = (0..d).map(|_| rng.below(256) as u8).collect();
            let want: u64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| {
                    let diff = x as i64 - y as i64;
                    (diff * diff) as u64
                })
                .sum();
            prop::assert_prop(
                l2_sq_u8(&a, &b) == want,
                format!("d={d} got={} want={want}", l2_sq_u8(&a, &b)),
            )
        });
    }

    #[test]
    fn dot_u8_matches_naive_all_lengths() {
        prop::check("dotu8-vs-naive", 200, |rng: &mut Rng| {
            let d = rng.below(70) + 1;
            let a: Vec<u8> = (0..d).map(|_| rng.below(256) as u8).collect();
            let b: Vec<u8> = (0..d).map(|_| rng.below(256) as u8).collect();
            let want: u64 = a.iter().zip(&b).map(|(&x, &y)| x as u64 * y as u64).sum();
            prop::assert_prop(dot_u8(&a, &b) == want, format!("d={d} dot_u8 mismatch"))
        });
    }

    #[test]
    fn u8_kernels_saturate_without_overflow() {
        // worst case per dimension: 255 vs 0 (l2) and 255*255 (dot)
        let d = 4096;
        let hi = vec![255u8; d];
        let lo = vec![0u8; d];
        assert_eq!(l2_sq_u8(&hi, &lo), d as u64 * 255 * 255);
        assert_eq!(dot_u8(&hi, &hi), d as u64 * 255 * 255);
        assert_eq!(l2_sq_u8(&hi, &hi), 0);
    }

    #[test]
    fn dot_dequant_matches_explicit_dequantize() {
        prop::check("dot-dequant-vs-naive", 200, |rng: &mut Rng| {
            let d = rng.below(70) + 1;
            let codes: Vec<u8> = (0..d).map(|_| rng.below(256) as u8).collect();
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let scale: Vec<f32> = (0..d).map(|_| rng.normal_f32().abs() * 0.1 + 1e-3).collect();
            let offset: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let want: f32 = (0..d)
                .map(|i| q[i] * (offset[i] + scale[i] * codes[i] as f32))
                .sum();
            let got = dot_dequant(&codes, &q, &scale, &offset);
            prop::assert_prop(
                (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                format!("d={d} got={got} want={want}"),
            )
        });
    }

    #[test]
    fn pq_lut_sum_matches_naive_all_lengths() {
        // covers m below, at, and straddling the 8-lane gather width
        prop::check("pq-lut-vs-naive", 200, |rng: &mut Rng| {
            let m = rng.below(40) + 1;
            let lut: Vec<f32> = (0..m * PQ_KSUB).map(|_| rng.normal_f32()).collect();
            let codes: Vec<u8> = (0..m).map(|_| rng.below(256) as u8).collect();
            let want: f32 = codes
                .iter()
                .enumerate()
                .map(|(sub, &c)| lut[sub * PQ_KSUB + c as usize])
                .sum();
            let got = pq_lut_sum(&lut, &codes);
            prop::assert_prop(
                (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                format!("m={m} got={got} want={want}"),
            )
        });
    }

    #[test]
    fn l2_identity_and_symmetry() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let b = [9.0f32, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(l2_sq(&a, &a), 0.0);
        assert_eq!(l2_sq(&a, &b), l2_sq(&b, &a));
    }

    #[test]
    fn normalize_unit_norm() {
        let mut v = vec![3.0f32, 4.0];
        normalize(&mut v);
        assert!((dot(&v, &v) - 1.0).abs() < 1e-6);
        let mut z = vec![0.0f32; 5];
        normalize(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn cosine_lowers_to_ip() {
        let a = [0.6f32, 0.8];
        let b = [1.0f32, 0.0];
        let d = distance(Metric::Cosine, &a, &b);
        assert!((d - (-0.6)).abs() < 1e-6);
    }

    // --- scalar-vs-SIMD equivalence (bit-exact, enforced whenever the
    // feature is on; with SIMD unavailable at runtime the dispatchers
    // fall back to the scalar bodies and the checks are trivially true).
    #[cfg(feature = "simd")]
    mod simd_equivalence {
        use super::*;

        #[test]
        fn f32_kernels_bit_identical() {
            prop::check("simd-f32-bits", 300, |rng: &mut Rng| {
                let d = rng.below(300) + 1;
                let a: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 4.0).collect();
                let b: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 4.0).collect();
                prop::assert_prop(
                    l2_sq(&a, &b).to_bits() == l2_sq_scalar(&a, &b).to_bits()
                        && dot(&a, &b).to_bits() == dot_scalar(&a, &b).to_bits(),
                    format!("d={d} simd f32 kernel diverged from scalar"),
                )
            });
        }

        #[test]
        fn u8_kernels_exactly_equal() {
            prop::check("simd-u8-exact", 300, |rng: &mut Rng| {
                let d = rng.below(300) + 1;
                let a: Vec<u8> = (0..d).map(|_| rng.below(256) as u8).collect();
                let b: Vec<u8> = (0..d).map(|_| rng.below(256) as u8).collect();
                prop::assert_prop(
                    l2_sq_u8(&a, &b) == l2_sq_u8_scalar(&a, &b)
                        && dot_u8(&a, &b) == dot_u8_scalar(&a, &b),
                    format!("d={d} simd u8 kernel diverged from scalar"),
                )
            });
        }

        #[test]
        fn pq_lut_kernel_bit_identical() {
            prop::check("simd-pq-bits", 300, |rng: &mut Rng| {
                let m = rng.below(48) + 1;
                let lut: Vec<f32> = (0..m * PQ_KSUB).map(|_| rng.normal_f32()).collect();
                let codes: Vec<u8> = (0..m).map(|_| rng.below(256) as u8).collect();
                prop::assert_prop(
                    pq_lut_sum(&lut, &codes).to_bits() == pq_lut_sum_scalar(&lut, &codes).to_bits(),
                    format!("m={m} simd pq kernel diverged from scalar"),
                )
            });
        }
    }
}
