//! The fixed-degree k-NN graph (paper §4): `n` lists of `k` neighbors,
//! each sorted ascending by distance, each entry carrying the NEW/OLD
//! flag that drives NN-Descent sampling.
//!
//! Like [`Dataset`](crate::dataset::Dataset), a graph's rows live
//! behind one of two backings: fully in memory (`Owned`, every
//! construction path — mutation is owned-only) or paged from a `.knng`
//! v2 file through a shared
//! [`BlockCache`](crate::dataset::store::BlockCache) (the
//! block-residency serving path). [`KnnGraph::list`] /
//! [`KnnGraph::list_mut`] borrow and exist only for owned graphs;
//! [`KnnGraph::neighbors_into`] copies a row's live prefix out and
//! works on either backing (a borrow could dangle past the block's
//! next eviction).
//!
//! # `.knng` format spec (mirrors the `.dsb` spec in
//! [`crate::dataset::io`])
//!
//! **v2** (written by [`KnnGraph::save`]): magic 0x4B4E_4732 ("KNG2"),
//! n, k, row_stride (= 8*k bytes), block_rows hint, then `n` rows of
//! `k` entries, each `(id_with_flag: u32, dist: f32)` little-endian,
//! row `u` at `20 + u*row_stride`. **v1** (legacy; read-only, written
//! by [`KnnGraph::save_v1`]): magic 0x4B4E_4731 ("KNG1"), n, k, then
//! the same entry stream. Both readers validate the header against the
//! actual file length on open.

pub mod concurrent;

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context};

use crate::dataset::store::{Block, BlockCache, PagedRows, DEFAULT_BLOCK_BYTES, PAGED_HANDLE_BYTES};
use crate::dataset::Dataset;
use crate::util::rng::Rng;

/// Sentinel id for an empty slot.
pub const EMPTY: u32 = u32::MAX;

/// Flag bit stored in the serialized id (ids stay < 2^31; the paper's
/// largest benchmark is 1e9 < 2^31).
const FLAG_BIT: u32 = 1 << 31;

const KNNG_MAGIC_V1: u32 = 0x4B4E_4731; // "KNG1"
const KNNG_MAGIC_V2: u32 = 0x4B4E_4732; // "KNG2"
const KNNG_V1_HEADER: u64 = 12;
const KNNG_V2_HEADER: u64 = 20;
/// On-disk bytes per neighbor entry (u32 id_with_flag + f32 dist).
const ENTRY_BYTES: usize = 8;

/// One k-NN list entry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub id: u32,
    pub dist: f32,
    /// True if inserted during the current iteration (paper's NEW mark).
    pub new: bool,
}

impl Neighbor {
    pub const fn empty() -> Neighbor {
        Neighbor { id: EMPTY, dist: f32::INFINITY, new: false }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.id == EMPTY
    }
}

/// Where a graph's neighbor lists live.
#[derive(Clone, Debug)]
enum GraphRows {
    Owned(Vec<Neighbor>),
    Paged(PagedRows),
}

/// A fixed-degree approximate k-NN graph.
#[derive(Clone, Debug)]
pub struct KnnGraph {
    n: usize,
    k: usize,
    lists: GraphRows,
}

impl KnnGraph {
    /// All-empty graph.
    pub fn empty(n: usize, k: usize) -> Self {
        assert!(n > 0 && k > 0);
        KnnGraph { n, k, lists: GraphRows::Owned(vec![Neighbor::empty(); n * k]) }
    }

    /// True when lists are paged from disk rather than memory-resident.
    pub fn is_paged(&self) -> bool {
        matches!(self.lists, GraphRows::Paged(_))
    }

    /// Bytes this graph holds resident itself (paged graphs keep only
    /// a handle; their blocks are accounted by the shared cache).
    pub fn resident_bytes(&self) -> usize {
        match &self.lists {
            GraphRows::Owned(v) => v.len() * std::mem::size_of::<Neighbor>(),
            GraphRows::Paged(_) => PAGED_HANDLE_BYTES,
        }
    }

    #[inline]
    fn owned(&self) -> &Vec<Neighbor> {
        match &self.lists {
            GraphRows::Owned(v) => v,
            GraphRows::Paged(_) => {
                panic!("borrowing row access on a paged graph; use neighbors_into")
            }
        }
    }

    #[inline]
    fn owned_mut(&mut self) -> &mut Vec<Neighbor> {
        match &mut self.lists {
            GraphRows::Owned(v) => v,
            GraphRows::Paged(_) => panic!("paged graphs are read-only"),
        }
    }

    /// Paper Algorithm 1 lines 1–5: k random distinct neighbors per
    /// object with computed distances, sorted ascending, all marked NEW.
    pub fn random_init(ds: &Dataset, k: usize, rng: &mut Rng) -> Self {
        let n = ds.len();
        let mut g = KnnGraph::empty(n, k);
        let kk = k.min(n - 1);
        for u in 0..n {
            let mut picked = Vec::with_capacity(kk);
            let mut guard = 0;
            while picked.len() < kk && guard < 100 * kk {
                guard += 1;
                let v = rng.below(n);
                if v != u && !picked.contains(&(v as u32)) {
                    picked.push(v as u32);
                }
            }
            let list = g.list_mut(u);
            for (slot, &v) in picked.iter().enumerate() {
                list[slot] = Neighbor { id: v, dist: ds.dist(u, v as usize), new: true };
            }
            list[..picked.len()]
                .sort_unstable_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
        }
        g
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The (sorted) neighbor list of `u`, including empty tail slots.
    /// Owned backing only (a paged row cannot be borrowed past the
    /// access — use [`KnnGraph::neighbors_into`]).
    #[inline]
    pub fn list(&self, u: usize) -> &[Neighbor] {
        &self.owned()[u * self.k..(u + 1) * self.k]
    }

    #[inline]
    pub fn list_mut(&mut self, u: usize) -> &mut [Neighbor] {
        let k = self.k;
        &mut self.owned_mut()[u * k..(u + 1) * k]
    }

    /// Copy `u`'s live neighbor prefix (sorted, no empty slots) into
    /// `out` (cleared first). Works on either backing — the serving hot
    /// path's row accessor: on owned it is a short memcpy, on paged one
    /// block-cache access plus the copy.
    pub fn neighbors_into(&self, u: usize, out: &mut Vec<Neighbor>) {
        out.clear();
        match &self.lists {
            GraphRows::Owned(_) => {
                out.extend(self.list(u).iter().take_while(|e| !e.is_empty()).copied())
            }
            GraphRows::Paged(p) => p.neighbors_into(u, out),
        }
    }

    /// Number of live entries in `u`'s list.
    pub fn len_of(&self, u: usize) -> usize {
        self.list(u).iter().take_while(|e| !e.is_empty()).count()
    }

    /// Neighbor ids of `u` (live entries only, ascending distance).
    pub fn ids(&self, u: usize) -> impl Iterator<Item = u32> + '_ {
        self.list(u).iter().take_while(|e| !e.is_empty()).map(|e| e.id)
    }

    /// Sorted-insert `(<id>, dist)` into `u`'s list if it improves it.
    /// Rejects duplicates and self-edges. Returns true if inserted.
    /// (Single-threaded path; the concurrent paths live in
    /// [`concurrent::ConcurrentGraph`].)
    pub fn insert(&mut self, u: usize, id: u32, dist: f32, new: bool) -> bool {
        debug_assert!(id != EMPTY);
        if id as usize == u {
            return false;
        }
        let k = self.k;
        let list = self.list_mut(u);
        if dist >= list[k - 1].dist {
            return false; // worse than current worst (or list full of better)
        }
        // duplicate check + insertion point in one pass
        let mut pos = k;
        for (i, e) in list.iter().enumerate() {
            if e.id == id {
                return false;
            }
            if pos == k && dist < e.dist {
                pos = i;
            }
            if e.is_empty() {
                break;
            }
        }
        if pos == k {
            return false;
        }
        // check tail after pos for duplicate before shifting
        if list[pos..].iter().take_while(|e| !e.is_empty()).any(|e| e.id == id) {
            return false;
        }
        list[pos..].rotate_right(1);
        list[pos] = Neighbor { id, dist, new };
        true
    }

    /// φ(G) — Eq. 3: the sum of all neighbor distances. Monotonically
    /// non-increasing across NN-Descent iterations (Fig. 4 traces).
    pub fn phi(&self) -> f64 {
        self.owned()
            .iter()
            .filter(|e| !e.is_empty())
            .map(|e| e.dist as f64)
            .sum()
    }

    /// Verify structural invariants (used by tests / debug assertions):
    /// sorted ascending, no duplicate ids, no self-edges, live prefix.
    pub fn check_invariants(&self) -> crate::Result<()> {
        for u in 0..self.n {
            let list = self.list(u);
            let mut seen = std::collections::HashSet::new();
            let mut prev = f32::NEG_INFINITY;
            let mut tail = false;
            for e in list {
                if e.is_empty() {
                    tail = true;
                    continue;
                }
                if tail {
                    bail!("u={u}: live entry after empty slot");
                }
                if e.id as usize == u {
                    bail!("u={u}: self edge");
                }
                if e.id as usize >= self.n {
                    bail!("u={u}: id {} out of range", e.id);
                }
                if !seen.insert(e.id) {
                    bail!("u={u}: duplicate id {}", e.id);
                }
                if e.dist < prev {
                    bail!("u={u}: not sorted ({} < {prev})", e.dist);
                }
                prev = e.dist;
            }
        }
        Ok(())
    }

    /// Extract plain id rows (for recall evaluation / serialization).
    pub fn id_rows(&self) -> Vec<Vec<u32>> {
        (0..self.n).map(|u| self.ids(u).collect()).collect()
    }

    /// Remap all neighbor ids through `f` (GGM id-space stitching).
    pub fn remap_ids(&mut self, f: impl Fn(u32) -> u32) {
        for e in self.owned_mut().iter_mut() {
            if !e.is_empty() {
                e.id = f(e.id);
            }
        }
    }

    /// Append the lists of `other` (over a disjoint id space) after ours;
    /// ids are taken as-is. Used by GGM to join two sub-graphs.
    pub fn stack(&self, other: &KnnGraph) -> KnnGraph {
        assert_eq!(self.k, other.k);
        let mut lists = self.owned().clone();
        lists.extend_from_slice(other.owned());
        KnnGraph { n: self.n + other.n, k: self.k, lists: GraphRows::Owned(lists) }
    }

    /// Serialize entry `e` into its on-disk 8 bytes.
    fn encode_entry(e: &Neighbor, out: &mut Vec<u8>) {
        let id = if e.is_empty() {
            EMPTY
        } else {
            e.id | if e.new { FLAG_BIT } else { 0 }
        };
        out.extend_from_slice(&id.to_le_bytes());
        out.extend_from_slice(&e.dist.to_le_bytes());
    }

    /// Serialize in the `.knng` v2 fixed-stride layout (see the module
    /// spec). Rows are staged into bulk buffers, not written entry by
    /// entry.
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let mut w = BufWriter::new(File::create(path.as_ref())?);
        let row_stride = (self.k * ENTRY_BYTES) as u32;
        let block_rows = (DEFAULT_BLOCK_BYTES as u32 / row_stride).max(1);
        w.write_all(&KNNG_MAGIC_V2.to_le_bytes())?;
        w.write_all(&(self.n as u32).to_le_bytes())?;
        w.write_all(&(self.k as u32).to_le_bytes())?;
        w.write_all(&row_stride.to_le_bytes())?;
        w.write_all(&block_rows.to_le_bytes())?;
        self.write_entries_bulk(&mut w)
    }

    /// Serialize in the legacy v1 layout (compatibility coverage; new
    /// files should use [`KnnGraph::save`]).
    pub fn save_v1(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let mut w = BufWriter::new(File::create(path.as_ref())?);
        w.write_all(&KNNG_MAGIC_V1.to_le_bytes())?;
        w.write_all(&(self.n as u32).to_le_bytes())?;
        w.write_all(&(self.k as u32).to_le_bytes())?;
        self.write_entries_bulk(&mut w)
    }

    fn write_entries_bulk(&self, w: &mut impl Write) -> crate::Result<()> {
        const CHUNK_ENTRIES: usize = 32 * 1024; // 256 KiB staging buffer
        let lists = self.owned();
        let mut buf = Vec::with_capacity(CHUNK_ENTRIES.min(lists.len()) * ENTRY_BYTES);
        for chunk in lists.chunks(CHUNK_ENTRIES) {
            buf.clear();
            for e in chunk {
                Self::encode_entry(e, &mut buf);
            }
            w.write_all(&buf)?;
        }
        Ok(())
    }

    /// Parse a `.knng` header (either version) and validate the file
    /// length against it. The probe / word-extraction / checked-length
    /// machinery is shared with the `.dsb` reader
    /// ([`crate::dataset::io`]), so hardening applied there covers both
    /// mirrored formats.
    fn read_header(file: &mut File, path: &Path) -> crate::Result<(u32, usize, usize, u64)> {
        use crate::dataset::io::{check_file_len, expected_file_len, header_word, probe_header};
        let (actual, head) = probe_header(file, path, KNNG_V2_HEADER as usize)?;
        let word = |i: usize| header_word(&head, i);
        match word(0) {
            KNNG_MAGIC_V1 => {
                anyhow::ensure!(
                    head.len() as u64 >= KNNG_V1_HEADER,
                    "truncated .knng header: {path:?}"
                );
                let (n, k) = (word(1) as usize, word(2) as usize);
                check_file_len(
                    path,
                    actual,
                    expected_file_len(path, KNNG_V1_HEADER, n, k.saturating_mul(ENTRY_BYTES))?,
                    &format!("v1, n={n} k={k}"),
                )?;
                Ok((1, n, k, KNNG_V1_HEADER))
            }
            KNNG_MAGIC_V2 => {
                anyhow::ensure!(
                    head.len() as u64 >= KNNG_V2_HEADER,
                    "truncated .knng header: {path:?}"
                );
                let (n, k) = (word(1) as usize, word(2) as usize);
                let row_stride = word(3) as usize;
                anyhow::ensure!(
                    row_stride == k.saturating_mul(ENTRY_BYTES),
                    "{path:?}: row stride {row_stride} != 8*k — unsupported layout"
                );
                check_file_len(
                    path,
                    actual,
                    expected_file_len(path, KNNG_V2_HEADER, n, row_stride)?,
                    &format!("v2, n={n} k={k} stride={row_stride}"),
                )?;
                Ok((2, n, k, KNNG_V2_HEADER))
            }
            _ => bail!("not a knn-graph file: {path:?}"),
        }
    }

    /// Read a `.knng` (v1 or v2) fully into memory.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<KnnGraph> {
        let path = path.as_ref();
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let (_, n, k, data_off) = Self::read_header(&mut file, path)?;
        file.seek(SeekFrom::Start(data_off))?;
        let mut r = BufReader::new(file);
        let mut bytes = vec![0u8; n * k * ENTRY_BYTES];
        r.read_exact(&mut bytes)?;
        let lists = decode_entries(&bytes);
        Ok(KnnGraph { n, k, lists: GraphRows::Owned(lists) })
    }

    /// Open a `.knng` for paged row access through `cache` (nothing
    /// read eagerly beyond the header). v1 files fall back to the
    /// fully-resident owned path, mirroring
    /// [`crate::dataset::io::read_dsb_paged`].
    pub fn load_paged(path: impl AsRef<Path>, cache: &Arc<BlockCache>) -> crate::Result<KnnGraph> {
        let path = path.as_ref();
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let (version, n, k, data_off) = Self::read_header(&mut file, path)?;
        if version == 1 {
            return Self::load(path);
        }
        let rows = PagedRows::new(
            file,
            path.to_path_buf(),
            data_off,
            n,
            k * ENTRY_BYTES,
            k,
            cache,
            decode_neigh_block,
        );
        Ok(KnnGraph { n, k, lists: GraphRows::Paged(rows) })
    }

    /// The paged backing's cache namespace id, if paged (lets the shard
    /// store drop a re-saved shard's stale blocks).
    pub(crate) fn block_store_id(&self) -> Option<u64> {
        match &self.lists {
            GraphRows::Owned(_) => None,
            GraphRows::Paged(p) => Some(p.store_id()),
        }
    }
}

fn decode_entries(bytes: &[u8]) -> Vec<Neighbor> {
    bytes
        .chunks_exact(ENTRY_BYTES)
        .map(|c| {
            let raw = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            let dist = f32::from_le_bytes([c[4], c[5], c[6], c[7]]);
            if raw == EMPTY {
                Neighbor::empty()
            } else {
                Neighbor { id: raw & !FLAG_BIT, dist, new: raw & FLAG_BIT != 0 }
            }
        })
        .collect()
}

/// Decode a raw `.knng` v2 block payload into neighbor entries.
fn decode_neigh_block(bytes: &[u8]) -> Block {
    Block::Neigh(decode_entries(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::util::prop;

    #[test]
    fn random_init_valid() {
        let ds = synth::uniform(60, 4, 1);
        let mut rng = Rng::new(5);
        let g = KnnGraph::random_init(&ds, 8, &mut rng);
        g.check_invariants().unwrap();
        for u in 0..g.n() {
            assert_eq!(g.len_of(u), 8);
            assert!(g.list(u).iter().all(|e| e.new || e.is_empty()));
        }
    }

    #[test]
    fn insert_keeps_sorted_and_dedups() {
        let ds = synth::uniform(30, 4, 2);
        let mut rng = Rng::new(6);
        let mut g = KnnGraph::random_init(&ds, 5, &mut rng);
        prop::check("insert-invariants", 300, |rng| {
            let u = rng.below(30);
            let v = rng.below(30) as u32;
            if v as usize != u {
                let d = ds.dist(u, v as usize);
                g.insert(u, v, d, true);
            }
            prop::assert_prop(g.check_invariants().is_ok(), "invariants broken")
        });
    }

    #[test]
    fn insert_against_sort_oracle() {
        // The list after arbitrary inserts must equal: all offered
        // candidates + initials, dedup by id (best dist), sorted, top-k.
        prop::check("insert-vs-oracle", 50, |rng| {
            let k = 1 + rng.below(8);
            let mut g = KnnGraph::empty(21, k); // ids drawn from [1, 20]
            let mut offered: Vec<(u32, f32)> = Vec::new();
            for _ in 0..rng.below(60) {
                let id = 1 + rng.below(20) as u32; // avoid self (u=0)
                let dist = (rng.below(1000) as f32) / 10.0;
                offered.push((id, dist));
                g.insert(0, id, dist, true);
            }
            // oracle: first-offered wins on duplicate id (insert rejects
            // duplicates regardless of distance), then stable sort by
            // dist, top-k... but rejection only happens while the old
            // entry is still resident; evicted ids can re-enter. The
            // robust invariant: resulting list is sorted, dedup, and its
            // worst distance <= the (k)th best of the distinct-best offers.
            g.check_invariants().unwrap();
            let mut best: std::collections::HashMap<u32, f32> = Default::default();
            for &(id, d) in &offered {
                let e = best.entry(id).or_insert(d);
                if d < *e {
                    *e = d;
                }
            }
            let mut bests: Vec<f32> = best.values().copied().collect();
            bests.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let live = g.len_of(0);
            prop::assert_prop(
                live == bests.len().min(k),
                format!("live={live} want={}", bests.len().min(k)),
            )?;
            // each resident distance is at least as good as the worst
            // of the top-live best offers
            if live > 0 {
                let worst = g.list(0)[live - 1].dist;
                prop::assert_prop(
                    worst >= bests[live - 1] - 1e-6,
                    "list better than physically possible",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn insert_rejects_self_dup_worse() {
        let mut g = KnnGraph::empty(2, 2);
        assert!(!g.insert(0, 0, 0.0, true)); // self
        assert!(g.insert(0, 1, 5.0, true));
        assert!(!g.insert(0, 1, 1.0, true)); // dup id
        let mut g2 = KnnGraph::empty(5, 2);
        assert!(g2.insert(0, 1, 1.0, true));
        assert!(g2.insert(0, 2, 2.0, true));
        assert!(!g2.insert(0, 3, 3.0, true)); // worse than worst, full
        assert!(g2.insert(0, 4, 0.5, true)); // evicts 2
        assert_eq!(g2.ids(0).collect::<Vec<_>>(), vec![4, 1]);
    }

    #[test]
    fn phi_decreases_with_better_neighbors() {
        let mut g = KnnGraph::empty(4, 2);
        g.insert(0, 1, 10.0, true);
        g.insert(0, 2, 8.0, true);
        let before = g.phi();
        g.insert(0, 3, 1.0, true);
        assert!(g.phi() < before);
    }

    #[test]
    fn save_load_roundtrip() {
        let ds = synth::uniform(20, 4, 3);
        let mut rng = Rng::new(7);
        let g = KnnGraph::random_init(&ds, 4, &mut rng);
        let dir = std::env::temp_dir().join(format!("gnnd-graph-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.knng");
        g.save(&p).unwrap();
        let back = KnnGraph::load(&p).unwrap();
        assert_eq!(back.n(), g.n());
        assert_eq!(back.k(), g.k());
        for u in 0..g.n() {
            assert_eq!(back.list(u), g.list(u));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn save_v1_load_roundtrip_and_truncation_errors() {
        let ds = synth::uniform(25, 4, 11);
        let mut rng = Rng::new(9);
        let g = KnnGraph::random_init(&ds, 5, &mut rng);
        let dir = std::env::temp_dir().join(format!(
            "gnnd-graph-fmt-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("legacy.knng");
        g.save_v1(&p).unwrap();
        let back = KnnGraph::load(&p).unwrap();
        for u in 0..g.n() {
            assert_eq!(back.list(u), g.list(u));
        }
        // v1 paged open falls back to the owned path
        let cache = crate::dataset::store::BlockCache::new(0, 256);
        let paged = KnnGraph::load_paged(&p, &cache).unwrap();
        assert!(!paged.is_paged());
        // truncated files (both versions) name the path and sizes
        for v2 in [true, false] {
            let p = dir.join(if v2 { "t2.knng" } else { "t1.knng" });
            if v2 {
                g.save(&p).unwrap();
            } else {
                g.save_v1(&p).unwrap();
            }
            let full = std::fs::read(&p).unwrap();
            std::fs::write(&p, &full[..full.len() - 5]).unwrap();
            let err = format!("{:#}", KnnGraph::load(&p).unwrap_err());
            assert!(
                err.contains("truncated") && err.contains("bytes"),
                "unhelpful truncation error: {err}"
            );
            assert!(KnnGraph::load_paged(&p, &cache).is_err());
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn paged_graph_matches_owned_across_block_boundaries() {
        let ds = synth::uniform(40, 4, 12);
        let mut rng = Rng::new(10);
        let g = KnnGraph::random_init(&ds, 6, &mut rng);
        let dir = std::env::temp_dir().join(format!(
            "gnnd-graph-paged-{}-{:x}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.knng");
        g.save(&p).unwrap();
        // row stride = 48 bytes; 100-byte blocks -> 2 rows per block
        // (k does not divide the block size), short tail block
        let cache = crate::dataset::store::BlockCache::new(0, 100);
        let paged = KnnGraph::load_paged(&p, &cache).unwrap();
        assert!(paged.is_paged());
        assert_eq!((paged.n(), paged.k()), (g.n(), g.k()));
        let mut got = Vec::new();
        let mut want = Vec::new();
        for u in 0..g.n() {
            paged.neighbors_into(u, &mut got);
            g.neighbors_into(u, &mut want);
            assert_eq!(got, want, "row {u}");
        }
        assert!(cache.stats().fetches > 1);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stack_and_remap() {
        let ds = synth::uniform(10, 4, 4);
        let mut rng = Rng::new(8);
        let g1 = KnnGraph::random_init(&ds, 3, &mut rng);
        let mut g2 = KnnGraph::random_init(&ds, 3, &mut rng);
        g2.remap_ids(|id| id + 10);
        let g = g1.stack(&g2);
        assert_eq!(g.n(), 20);
        for u in 10..20 {
            assert!(g.ids(u).all(|id| (10..20).contains(&(id as usize))));
        }
    }
}
