//! The fixed-degree k-NN graph (paper §4): `n` lists of `k` neighbors,
//! each sorted ascending by distance, each entry carrying the NEW/OLD
//! flag that drives NN-Descent sampling.

pub mod concurrent;

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context};

use crate::dataset::Dataset;
use crate::util::rng::Rng;

/// Sentinel id for an empty slot.
pub const EMPTY: u32 = u32::MAX;

/// Flag bit stored in the serialized id (ids stay < 2^31; the paper's
/// largest benchmark is 1e9 < 2^31).
const FLAG_BIT: u32 = 1 << 31;

/// One k-NN list entry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    pub id: u32,
    pub dist: f32,
    /// True if inserted during the current iteration (paper's NEW mark).
    pub new: bool,
}

impl Neighbor {
    pub const fn empty() -> Neighbor {
        Neighbor { id: EMPTY, dist: f32::INFINITY, new: false }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.id == EMPTY
    }
}

/// A fixed-degree approximate k-NN graph.
#[derive(Clone, Debug)]
pub struct KnnGraph {
    n: usize,
    k: usize,
    lists: Vec<Neighbor>,
}

impl KnnGraph {
    /// All-empty graph.
    pub fn empty(n: usize, k: usize) -> Self {
        assert!(n > 0 && k > 0);
        KnnGraph { n, k, lists: vec![Neighbor::empty(); n * k] }
    }

    /// Paper Algorithm 1 lines 1–5: k random distinct neighbors per
    /// object with computed distances, sorted ascending, all marked NEW.
    pub fn random_init(ds: &Dataset, k: usize, rng: &mut Rng) -> Self {
        let n = ds.len();
        let mut g = KnnGraph::empty(n, k);
        let kk = k.min(n - 1);
        for u in 0..n {
            let mut picked = Vec::with_capacity(kk);
            let mut guard = 0;
            while picked.len() < kk && guard < 100 * kk {
                guard += 1;
                let v = rng.below(n);
                if v != u && !picked.contains(&(v as u32)) {
                    picked.push(v as u32);
                }
            }
            let list = g.list_mut(u);
            for (slot, &v) in picked.iter().enumerate() {
                list[slot] = Neighbor { id: v, dist: ds.dist(u, v as usize), new: true };
            }
            list[..picked.len()]
                .sort_unstable_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
        }
        g
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The (sorted) neighbor list of `u`, including empty tail slots.
    #[inline]
    pub fn list(&self, u: usize) -> &[Neighbor] {
        &self.lists[u * self.k..(u + 1) * self.k]
    }

    #[inline]
    pub fn list_mut(&mut self, u: usize) -> &mut [Neighbor] {
        &mut self.lists[u * self.k..(u + 1) * self.k]
    }

    /// Number of live entries in `u`'s list.
    pub fn len_of(&self, u: usize) -> usize {
        self.list(u).iter().take_while(|e| !e.is_empty()).count()
    }

    /// Neighbor ids of `u` (live entries only, ascending distance).
    pub fn ids(&self, u: usize) -> impl Iterator<Item = u32> + '_ {
        self.list(u).iter().take_while(|e| !e.is_empty()).map(|e| e.id)
    }

    /// Sorted-insert `(<id>, dist)` into `u`'s list if it improves it.
    /// Rejects duplicates and self-edges. Returns true if inserted.
    /// (Single-threaded path; the concurrent paths live in
    /// [`concurrent::ConcurrentGraph`].)
    pub fn insert(&mut self, u: usize, id: u32, dist: f32, new: bool) -> bool {
        debug_assert!(id != EMPTY);
        if id as usize == u {
            return false;
        }
        let k = self.k;
        let list = self.list_mut(u);
        if dist >= list[k - 1].dist {
            return false; // worse than current worst (or list full of better)
        }
        // duplicate check + insertion point in one pass
        let mut pos = k;
        for (i, e) in list.iter().enumerate() {
            if e.id == id {
                return false;
            }
            if pos == k && dist < e.dist {
                pos = i;
            }
            if e.is_empty() {
                break;
            }
        }
        if pos == k {
            return false;
        }
        // check tail after pos for duplicate before shifting
        if list[pos..].iter().take_while(|e| !e.is_empty()).any(|e| e.id == id) {
            return false;
        }
        list[pos..].rotate_right(1);
        list[pos] = Neighbor { id, dist, new };
        true
    }

    /// φ(G) — Eq. 3: the sum of all neighbor distances. Monotonically
    /// non-increasing across NN-Descent iterations (Fig. 4 traces).
    pub fn phi(&self) -> f64 {
        self.lists
            .iter()
            .filter(|e| !e.is_empty())
            .map(|e| e.dist as f64)
            .sum()
    }

    /// Verify structural invariants (used by tests / debug assertions):
    /// sorted ascending, no duplicate ids, no self-edges, live prefix.
    pub fn check_invariants(&self) -> crate::Result<()> {
        for u in 0..self.n {
            let list = self.list(u);
            let mut seen = std::collections::HashSet::new();
            let mut prev = f32::NEG_INFINITY;
            let mut tail = false;
            for e in list {
                if e.is_empty() {
                    tail = true;
                    continue;
                }
                if tail {
                    bail!("u={u}: live entry after empty slot");
                }
                if e.id as usize == u {
                    bail!("u={u}: self edge");
                }
                if e.id as usize >= self.n {
                    bail!("u={u}: id {} out of range", e.id);
                }
                if !seen.insert(e.id) {
                    bail!("u={u}: duplicate id {}", e.id);
                }
                if e.dist < prev {
                    bail!("u={u}: not sorted ({} < {prev})", e.dist);
                }
                prev = e.dist;
            }
        }
        Ok(())
    }

    /// Extract plain id rows (for recall evaluation / serialization).
    pub fn id_rows(&self) -> Vec<Vec<u32>> {
        (0..self.n).map(|u| self.ids(u).collect()).collect()
    }

    /// Remap all neighbor ids through `f` (GGM id-space stitching).
    pub fn remap_ids(&mut self, f: impl Fn(u32) -> u32) {
        for e in self.lists.iter_mut() {
            if !e.is_empty() {
                e.id = f(e.id);
            }
        }
    }

    /// Append the lists of `other` (over a disjoint id space) after ours;
    /// ids are taken as-is. Used by GGM to join two sub-graphs.
    pub fn stack(&self, other: &KnnGraph) -> KnnGraph {
        assert_eq!(self.k, other.k);
        let mut lists = self.lists.clone();
        lists.extend_from_slice(&other.lists);
        KnnGraph { n: self.n + other.n, k: self.k, lists }
    }

    /// Serialize (binary: magic, n, k, then n*k (id_with_flag, dist)).
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let mut w = BufWriter::new(File::create(path.as_ref())?);
        w.write_all(&0x4B4E_4731u32.to_le_bytes())?; // "KNG1"
        w.write_all(&(self.n as u32).to_le_bytes())?;
        w.write_all(&(self.k as u32).to_le_bytes())?;
        for e in &self.lists {
            let id = if e.is_empty() {
                EMPTY
            } else {
                e.id | if e.new { FLAG_BIT } else { 0 }
            };
            w.write_all(&id.to_le_bytes())?;
            w.write_all(&e.dist.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> crate::Result<KnnGraph> {
        let mut r = BufReader::new(
            File::open(path.as_ref()).with_context(|| format!("open {:?}", path.as_ref()))?,
        );
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        if u32::from_le_bytes(b4) != 0x4B4E_4731 {
            bail!("not a knn-graph file: {:?}", path.as_ref());
        }
        r.read_exact(&mut b4)?;
        let n = u32::from_le_bytes(b4) as usize;
        r.read_exact(&mut b4)?;
        let k = u32::from_le_bytes(b4) as usize;
        let mut lists = Vec::with_capacity(n * k);
        for _ in 0..n * k {
            r.read_exact(&mut b4)?;
            let raw = u32::from_le_bytes(b4);
            r.read_exact(&mut b4)?;
            let dist = f32::from_le_bytes(b4);
            if raw == EMPTY {
                lists.push(Neighbor::empty());
            } else {
                lists.push(Neighbor {
                    id: raw & !FLAG_BIT,
                    dist,
                    new: raw & FLAG_BIT != 0,
                });
            }
        }
        Ok(KnnGraph { n, k, lists })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::util::prop;

    #[test]
    fn random_init_valid() {
        let ds = synth::uniform(60, 4, 1);
        let mut rng = Rng::new(5);
        let g = KnnGraph::random_init(&ds, 8, &mut rng);
        g.check_invariants().unwrap();
        for u in 0..g.n() {
            assert_eq!(g.len_of(u), 8);
            assert!(g.list(u).iter().all(|e| e.new || e.is_empty()));
        }
    }

    #[test]
    fn insert_keeps_sorted_and_dedups() {
        let ds = synth::uniform(30, 4, 2);
        let mut rng = Rng::new(6);
        let mut g = KnnGraph::random_init(&ds, 5, &mut rng);
        prop::check("insert-invariants", 300, |rng| {
            let u = rng.below(30);
            let v = rng.below(30) as u32;
            if v as usize != u {
                let d = ds.dist(u, v as usize);
                g.insert(u, v, d, true);
            }
            prop::assert_prop(g.check_invariants().is_ok(), "invariants broken")
        });
    }

    #[test]
    fn insert_against_sort_oracle() {
        // The list after arbitrary inserts must equal: all offered
        // candidates + initials, dedup by id (best dist), sorted, top-k.
        prop::check("insert-vs-oracle", 50, |rng| {
            let k = 1 + rng.below(8);
            let mut g = KnnGraph::empty(21, k); // ids drawn from [1, 20]
            let mut offered: Vec<(u32, f32)> = Vec::new();
            for _ in 0..rng.below(60) {
                let id = 1 + rng.below(20) as u32; // avoid self (u=0)
                let dist = (rng.below(1000) as f32) / 10.0;
                offered.push((id, dist));
                g.insert(0, id, dist, true);
            }
            // oracle: first-offered wins on duplicate id (insert rejects
            // duplicates regardless of distance), then stable sort by
            // dist, top-k... but rejection only happens while the old
            // entry is still resident; evicted ids can re-enter. The
            // robust invariant: resulting list is sorted, dedup, and its
            // worst distance <= the (k)th best of the distinct-best offers.
            g.check_invariants().unwrap();
            let mut best: std::collections::HashMap<u32, f32> = Default::default();
            for &(id, d) in &offered {
                let e = best.entry(id).or_insert(d);
                if d < *e {
                    *e = d;
                }
            }
            let mut bests: Vec<f32> = best.values().copied().collect();
            bests.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let live = g.len_of(0);
            prop::assert_prop(
                live == bests.len().min(k),
                format!("live={live} want={}", bests.len().min(k)),
            )?;
            // each resident distance is at least as good as the worst
            // of the top-live best offers
            if live > 0 {
                let worst = g.list(0)[live - 1].dist;
                prop::assert_prop(
                    worst >= bests[live - 1] - 1e-6,
                    "list better than physically possible",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn insert_rejects_self_dup_worse() {
        let mut g = KnnGraph::empty(2, 2);
        assert!(!g.insert(0, 0, 0.0, true)); // self
        assert!(g.insert(0, 1, 5.0, true));
        assert!(!g.insert(0, 1, 1.0, true)); // dup id
        let mut g2 = KnnGraph::empty(5, 2);
        assert!(g2.insert(0, 1, 1.0, true));
        assert!(g2.insert(0, 2, 2.0, true));
        assert!(!g2.insert(0, 3, 3.0, true)); // worse than worst, full
        assert!(g2.insert(0, 4, 0.5, true)); // evicts 2
        assert_eq!(g2.ids(0).collect::<Vec<_>>(), vec![4, 1]);
    }

    #[test]
    fn phi_decreases_with_better_neighbors() {
        let mut g = KnnGraph::empty(4, 2);
        g.insert(0, 1, 10.0, true);
        g.insert(0, 2, 8.0, true);
        let before = g.phi();
        g.insert(0, 3, 1.0, true);
        assert!(g.phi() < before);
    }

    #[test]
    fn save_load_roundtrip() {
        let ds = synth::uniform(20, 4, 3);
        let mut rng = Rng::new(7);
        let g = KnnGraph::random_init(&ds, 4, &mut rng);
        let dir = std::env::temp_dir().join(format!("gnnd-graph-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.knng");
        g.save(&p).unwrap();
        let back = KnnGraph::load(&p).unwrap();
        assert_eq!(back.n(), g.n());
        assert_eq!(back.k(), g.k());
        for u in 0..g.n() {
            assert_eq!(back.list(u), g.list(u));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stack_and_remap() {
        let ds = synth::uniform(10, 4, 4);
        let mut rng = Rng::new(8);
        let g1 = KnnGraph::random_init(&ds, 3, &mut rng);
        let mut g2 = KnnGraph::random_init(&ds, 3, &mut rng);
        g2.remap_ids(|id| id + 10);
        let g = g1.stack(&g2);
        assert_eq!(g.n(), 20);
        for u in 10..20 {
            assert!(g.ids(u).all(|id| (10..20).contains(&(id as usize))));
        }
    }
}
