//! Concurrent graph update — the paper's *multiple spinlocks* scheme
//! (§4.3) adapted from CUDA warps to CPU worker threads.
//!
//! A k-NN list is divided into `nseg` positional segments. A produced
//! neighbor `v` is inserted into segment `v % nseg`, guarded by that
//! segment's spinlock only, so several threads can update one list in
//! parallel and each insertion touches a single warp-sized slot range
//! (the paper inserts with one warp per 32-wide segment). When an
//! iteration completes, [`KnnGraph::normalize_list`] merges the segments
//! back into one sorted, deduplicated list — exactly the paper's
//! "as the iteration is completed, all the segments of one k-NN list
//! will be merged into one".
//!
//! `nseg = 1` degenerates to one spinlock per list — the GNND-r2
//! configuration of the Fig. 5 ablation.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

use super::{KnnGraph, Neighbor, EMPTY};

/// A borrow of a [`KnnGraph`] that allows locked concurrent insertion.
pub struct ConcurrentGraph<'g> {
    ptr: *mut Neighbor,
    n: usize,
    k: usize,
    nseg: usize,
    locks: Vec<AtomicU32>,
    updates: AtomicUsize,
    _marker: PhantomData<&'g mut KnnGraph>,
}

// SAFETY: every access to the slot range of segment (u, s) happens while
// holding `locks[u * nseg + s]`; segments partition the storage.
unsafe impl Sync for ConcurrentGraph<'_> {}
unsafe impl Send for ConcurrentGraph<'_> {}

impl<'g> ConcurrentGraph<'g> {
    /// Wrap a graph for concurrent updates with `nseg` segments per list
    /// of width `>= segment_width` (the last segment absorbs the
    /// remainder). `nseg` is derived as `max(1, k / segment_width)`.
    pub fn new(graph: &'g mut KnnGraph, segment_width: usize) -> Self {
        let n = graph.n();
        let k = graph.k();
        let nseg = (k / segment_width.max(1)).max(1);
        let locks = (0..n * nseg).map(|_| AtomicU32::new(0)).collect();
        ConcurrentGraph {
            ptr: graph.list_mut(0).as_mut_ptr(),
            n,
            k,
            nseg,
            locks,
            updates: AtomicUsize::new(0),
            _marker: PhantomData,
        }
    }

    pub fn nseg(&self) -> usize {
        self.nseg
    }

    /// Number of accepted insertions since construction (the NN-Descent
    /// convergence counter).
    pub fn updates(&self) -> usize {
        self.updates.load(Ordering::Relaxed)
    }

    /// Slot range `[start, end)` of segment `s` within a list.
    #[inline]
    fn seg_range(&self, s: usize) -> (usize, usize) {
        let w = self.k / self.nseg;
        let start = s * w;
        let end = if s + 1 == self.nseg { self.k } else { start + w };
        (start, end)
    }

    #[inline]
    fn lock(&self, u: usize, s: usize) {
        let l = &self.locks[u * self.nseg + s];
        while l
            .compare_exchange_weak(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            std::hint::spin_loop();
        }
    }

    #[inline]
    fn unlock(&self, u: usize, s: usize) {
        self.locks[u * self.nseg + s].store(0, Ordering::Release);
    }

    /// Selective insertion of `(id, dist)` into `u`'s list (marked NEW).
    ///
    /// The candidate is routed to segment `id % nseg` (paper: "The
    /// object v will be inserted into the v%(k/32)-th segment"), and
    /// only that segment is locked. Within the segment the entries stay
    /// sorted; the segment-worst entry is evicted. Returns true if
    /// inserted.
    pub fn insert(&self, u: usize, id: u32, dist: f32) -> bool {
        debug_assert!(u < self.n && id != EMPTY);
        if id as usize == u {
            return false;
        }
        let s = (id as usize) % self.nseg;
        let (start, end) = self.seg_range(s);
        self.lock(u, s);
        // SAFETY: slots [u*k+start, u*k+end) are exclusively ours while
        // the segment lock is held.
        let seg = unsafe {
            std::slice::from_raw_parts_mut(self.ptr.add(u * self.k + start), end - start)
        };
        let inserted = insert_sorted_segment(seg, id, dist);
        self.unlock(u, s);
        if inserted {
            self.updates.fetch_add(1, Ordering::Relaxed);
        }
        inserted
    }

    /// Insert a *batch* of produced neighbor pairs into `u`'s list under
    /// a whole-list lock — the GNND-r1 path (classic "insert everything"
    /// semantics; the paper's r1 run sorts candidates with a bitonic
    /// network and merges, which is what `sort + merge` mirrors here).
    ///
    /// Requires `nseg == 1` (r1 is only meaningful without segmenting).
    pub fn insert_batch(&self, u: usize, cands: &mut Vec<(u32, f32)>) -> usize {
        assert_eq!(self.nseg, 1, "insert_batch requires an unsegmented list");
        if cands.is_empty() {
            return 0;
        }
        cands.sort_unstable_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        self.lock(u, 0);
        let seg =
            unsafe { std::slice::from_raw_parts_mut(self.ptr.add(u * self.k), self.k) };
        let mut accepted = 0;
        for &(id, dist) in cands.iter() {
            if id as usize == u {
                continue;
            }
            if insert_sorted_segment(seg, id, dist) {
                accepted += 1;
            }
        }
        self.unlock(u, 0);
        if accepted > 0 {
            self.updates.fetch_add(accepted, Ordering::Relaxed);
        }
        accepted
    }
}

/// Sorted insertion into one segment slice: duplicate ids rejected,
/// worst entry evicted, ascending order maintained. Marked NEW.
fn insert_sorted_segment(seg: &mut [Neighbor], id: u32, dist: f32) -> bool {
    let len = seg.len();
    if dist >= seg[len - 1].dist && !seg[len - 1].is_empty() {
        return false;
    }
    let mut pos = len;
    for (i, e) in seg.iter().enumerate() {
        if e.id == id {
            return false;
        }
        if pos == len && (e.is_empty() || dist < e.dist) {
            pos = i;
        }
    }
    if pos == len {
        return false;
    }
    if seg[pos..].iter().take_while(|e| !e.is_empty()).any(|e| e.id == id) {
        return false;
    }
    seg[pos..].rotate_right(1);
    seg[pos] = Neighbor { id, dist, new: true };
    true
}

impl KnnGraph {
    /// Merge the segments of `u`'s list back into a single sorted,
    /// deduplicated list (paper §4.3, end-of-iteration merge).
    pub fn normalize_list(&mut self, u: usize) {
        let list = self.list_mut(u);
        list.sort_unstable_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
        // drop duplicate ids (keep the best-distance copy = first seen)
        let k = list.len();
        let mut seen = std::collections::HashSet::with_capacity(k);
        let mut w = 0;
        for i in 0..k {
            let e = list[i];
            if e.is_empty() {
                break;
            }
            if seen.insert(e.id) {
                list[w] = e;
                w += 1;
            }
        }
        for slot in list[w..].iter_mut() {
            *slot = Neighbor::empty();
        }
    }

    /// Normalize every list, in parallel partitions.
    pub fn normalize_all(&mut self, threads: usize) {
        let n = self.n();
        let k = self.k();
        let ranges = crate::util::split_ranges(n, threads.max(1));
        let lists = self.list_mut(0).as_mut_ptr();
        struct SendPtr(*mut Neighbor);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let sp = SendPtr(lists);
        crossbeam_utils::thread::scope(|s| {
            for r in &ranges {
                let r = r.clone();
                let sp = &sp;
                s.spawn(move |_| {
                    for u in r {
                        // SAFETY: object ranges are disjoint across threads.
                        let list = unsafe {
                            std::slice::from_raw_parts_mut(sp.0.add(u * k), k)
                        };
                        normalize_slice(list);
                    }
                });
            }
        })
        .unwrap();
    }
}

/// Free-function list normalization over a raw slice (used by the
/// parallel path; same semantics as [`KnnGraph::normalize_list`]).
pub(crate) fn normalize_slice(list: &mut [Neighbor]) {
    list.sort_unstable_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap());
    let k = list.len();
    let mut seen = std::collections::HashSet::with_capacity(k);
    let mut w = 0;
    for i in 0..k {
        let e = list[i];
        if e.is_empty() {
            break;
        }
        if seen.insert(e.id) {
            list[w] = e;
            w += 1;
        }
    }
    for slot in list[w..].iter_mut() {
        *slot = Neighbor::empty();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn segmented_insert_respects_segments() {
        let mut g = KnnGraph::empty(8, 8);
        {
            let cg = ConcurrentGraph::new(&mut g, 4); // nseg = 2
            assert_eq!(cg.nseg(), 2);
            assert!(cg.insert(0, 2, 1.0)); // 2 % 2 = 0 -> segment 0
            assert!(cg.insert(0, 3, 0.5)); // segment 1
            assert!(cg.insert(0, 5, 0.1)); // segment 1
            assert!(!cg.insert(0, 3, 0.01)); // dup within segment
            assert_eq!(cg.updates(), 3);
        }
        // segment 0 = slots 0..4, segment 1 = slots 4..8
        assert_eq!(g.list(0)[0].id, 2);
        let seg1: Vec<u32> = g.list(0)[4..].iter().filter(|e| !e.is_empty()).map(|e| e.id).collect();
        assert_eq!(seg1, vec![5, 3]);
        g.normalize_list(0);
        g.check_invariants().unwrap();
        assert_eq!(g.ids(0).collect::<Vec<_>>(), vec![5, 3, 2]);
    }

    #[test]
    fn concurrent_inserts_lose_nothing_single_segment() {
        // With nseg=1 the list behaves like a locked top-k: after many
        // concurrent offers, the resident worst must be <= the k-th best
        // distinct offer overall.
        prop::check("concurrent-topk", 12, |rng: &mut Rng| {
            let k = 8;
            let n_threads = 4;
            let per = 200;
            // ids live in [1, 10_000]; size the graph to keep the
            // id-range invariant while only list 0 is exercised.
            let mut g = KnnGraph::empty(10_001, k);
            let mut offers: Vec<Vec<(u32, f32)>> = Vec::new();
            let mut all: Vec<(u32, f32)> = Vec::new();
            for _ in 0..n_threads {
                let mut v = Vec::new();
                for _ in 0..per {
                    let id = 1 + rng.below(10_000) as u32;
                    let dist = rng.f32() * 100.0;
                    v.push((id, dist));
                    all.push((id, dist));
                }
                offers.push(v);
            }
            {
                let cg = ConcurrentGraph::new(&mut g, k); // nseg = 1
                crossbeam_utils::thread::scope(|s| {
                    for t in 0..n_threads {
                        let cg = &cg;
                        let offers = &offers[t];
                        s.spawn(move |_| {
                            for &(id, d) in offers {
                                cg.insert(0, id, d);
                            }
                        });
                    }
                })
                .unwrap();
            }
            g.normalize_list(0);
            g.check_invariants().map_err(|e| e.to_string())?;
            let mut best: std::collections::HashMap<u32, f32> = Default::default();
            for &(id, d) in &all {
                let e = best.entry(id).or_insert(d);
                if d < *e {
                    *e = d;
                }
            }
            let mut bests: Vec<f32> = best.values().copied().collect();
            bests.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let live = g.len_of(0);
            prop::assert_prop(live == k.min(bests.len()), format!("live={live}"))?;
            let worst = g.list(0)[live - 1].dist;
            // A locked sequential top-k would end at bests[live-1]; the
            // concurrent version may keep slightly worse entries only if
            // duplicates raced, but never better than physically possible.
            prop::assert_prop(worst + 1e-6 >= bests[live - 1], "impossible best")
        });
    }

    #[test]
    fn concurrent_segmented_stress_keeps_invariants() {
        prop::check("segmented-stress", 6, |rng: &mut Rng| {
            let n = 32;
            let k = 16;
            let mut g = KnnGraph::empty(n, k);
            let mut jobs: Vec<Vec<(usize, u32, f32)>> = vec![Vec::new(); 4];
            for t in 0..4 {
                for _ in 0..500 {
                    let u = rng.below(n);
                    let id = rng.below(n) as u32;
                    jobs[t].push((u, id, rng.f32() * 10.0));
                }
            }
            {
                let cg = ConcurrentGraph::new(&mut g, 4); // nseg = 4
                crossbeam_utils::thread::scope(|s| {
                    for t in 0..4 {
                        let cg = &cg;
                        let job = &jobs[t];
                        s.spawn(move |_| {
                            for &(u, id, d) in job {
                                if id as usize != u {
                                    cg.insert(u, id, d);
                                }
                            }
                        });
                    }
                })
                .unwrap();
            }
            g.normalize_all(2);
            g.check_invariants().map_err(|e| e.to_string())
        });
    }

    #[test]
    fn insert_batch_matches_sequential() {
        let mut g = KnnGraph::empty(6, 4);
        {
            let cg = ConcurrentGraph::new(&mut g, 64); // nseg = 1
            let mut cands = vec![(3u32, 3.0f32), (1, 1.0), (2, 2.0), (1, 0.5), (4, 4.0), (5, 0.1)];
            cg.insert_batch(0, &mut cands);
        }
        g.normalize_list(0);
        assert_eq!(g.ids(0).collect::<Vec<_>>(), vec![5, 1, 2, 3]);
    }
}
