//! Integration: the telemetry layer against real `ooc-build` output —
//! tracing must be observation-only (bit-identical results with the
//! sink armed or not), span accounting must reconcile exactly with the
//! query's work counters, and the serve sweep must stream sampled
//! traces through the JSONL writer and collect per-point registry
//! snapshots. Global-registry assertions use `>=` only: the registry
//! is process-wide and tests in this binary run concurrently.

use std::path::{Path, PathBuf};

use gnnd::dataset::synth;
use gnnd::gnnd::{GnndParams, NativeEngine};
use gnnd::merge::outofcore::{build_out_of_core, OutOfCoreConfig, ResidencyMode, ShardStore};
use gnnd::search::serve::{self, ServeConfig};
use gnnd::search::sharded::ShardedIndex;
use gnnd::search::{AnnIndex, SearchParams};
use gnnd::telemetry::{self, trace::read_traces, trace::render_report, trace::TraceWriter};

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "gnnd-telemetry-{tag}-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn build_store(dir: &Path, n: usize, seed: u64) -> gnnd::dataset::Dataset {
    let ds = synth::clustered(n, 8, seed);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    build_out_of_core(&ds, dir, &cfg, &NativeEngine).unwrap();
    ds
}

/// The tentpole acceptance shape: arming the trace sink must not change
/// a single bit of output, eval count or hop count across the
/// probe x budget x threads grid — and the spans a traced query records
/// must reconcile exactly with its work counters (route centroid
/// distances are not beam work, so per-shard spans sum to the totals).
#[test]
fn tracing_is_observation_only_across_probe_budget_threads() {
    let dir = tmpdir("parity");
    let ds = build_store(&dir, 480, 52);
    let manifest = ShardStore::new(&dir).unwrap().load_manifest().unwrap();
    let sub_shard = manifest.shard_bytes(0) / 2;

    let sp = SearchParams::default().with_ef(48);
    for probe in [0usize, 2] {
        for budget in [0usize, sub_shard] {
            for threads in [1usize, 3] {
                let open = || {
                    ShardedIndex::open_with_residency(
                        &dir,
                        sp.clone(),
                        probe,
                        budget,
                        threads,
                        ResidencyMode::block(),
                    )
                    .unwrap()
                };
                let plain = open();
                let traced = open();
                let mut s_plain = plain.make_scratch();
                let mut s_traced = traced.make_scratch();
                let (mut o_plain, mut o_traced) = (Vec::new(), Vec::new());
                for q in (0..ds.len()).step_by(53) {
                    plain.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_plain,
                        &mut o_plain,
                    );
                    s_traced.trace.begin();
                    traced.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_traced,
                        &mut o_traced,
                    );
                    s_traced.trace.end();
                    assert_eq!(
                        o_plain, o_traced,
                        "tracing changed results (probe={probe} budget={budget} \
                         threads={threads}) on query {q}"
                    );
                    assert_eq!(s_plain.dist_evals, s_traced.dist_evals, "evals on query {q}");
                    assert_eq!(s_plain.hops, s_traced.hops, "hops on query {q}");

                    // span accounting: one span per probed shard, in
                    // shard order, summing exactly to the query totals
                    let spans = &s_traced.trace.shards;
                    let expect = if probe == 0 { 4 } else { probe };
                    assert_eq!(spans.len(), expect, "span count on query {q}");
                    assert!(
                        spans.windows(2).all(|w| w[0].shard < w[1].shard),
                        "spans unsorted on query {q}: {spans:?}"
                    );
                    let span_evals: usize = spans.iter().map(|s| s.dist_evals).sum();
                    let span_hops: usize = spans.iter().map(|s| s.hops).sum();
                    assert_eq!(span_evals, s_traced.dist_evals, "span evals on query {q}");
                    assert_eq!(span_hops, s_traced.hops, "span hops on query {q}");
                    assert!(
                        spans.iter().all(|s| s.search_ms >= 0.0 && s.wait_ms >= 0.0),
                        "negative span time on query {q}: {spans:?}"
                    );
                    // untraced queries must leave no spans behind
                    assert!(s_plain.trace.shards.is_empty());
                }
            }
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

/// The sweep-level export path end to end: `run_sweep_with` streams
/// every `trace_sample`-th query of the timing pass to the JSONL
/// writer, the file round-trips through `read_traces`, block-residency
/// traces carry block traffic in their spans, and `metrics_points`
/// holds one (cumulative, delta) snapshot pair per operating point.
#[test]
fn sweep_streams_sampled_traces_and_per_point_snapshots() {
    let dir = tmpdir("sweep");
    let ds = build_store(&dir, 400, 53);

    let sp = SearchParams::default().with_ef(32);
    let index =
        ShardedIndex::open_with_residency(&dir, sp.clone(), 0, 0, 2, ResidencyMode::block())
            .unwrap();
    let cfg = ServeConfig {
        k: 10,
        ef_sweep: vec![16, 32],
        n_queries: 12,
        distinct_queries: 12,
        threads: 2,
        params: sp,
        trace_sample: 3,
        ..ServeConfig::default()
    };
    let trace_path = dir.join("traces.jsonl");
    let mut sinks = serve::ServeSinks {
        trace: Some(TraceWriter::append_to(&trace_path).unwrap()),
        ..Default::default()
    };
    let report = serve::run_sweep_with(&index, &ds, &cfg, &mut sinks).unwrap();
    assert_eq!(report.rows.len(), 2);

    // queries 0, 3, 6, 9 of each of the two points
    let traces = read_traces(&trace_path).unwrap();
    assert_eq!(sinks.trace.as_ref().unwrap().written(), 8);
    assert_eq!(traces.len(), 8);
    for (i, t) in traces.iter().enumerate() {
        assert_eq!(t.query % 3, 0, "trace {i} is not a sampled query: {t:?}");
        assert_eq!(t.ef, if i < 4 { 16 } else { 32 });
        assert_eq!(t.queue_ms, 0.0, "closed loop must not queue");
        assert_eq!(t.shards.len(), 4, "probe=all over 4 shards");
        let span_evals: usize = t.shards.iter().map(|s| s.dist_evals).sum();
        assert_eq!(span_evals, t.dist_evals);
    }
    // block residency: the traced walks touched the block cache
    let traffic: u64 = traces
        .iter()
        .flat_map(|t| t.shards.iter())
        .map(|s| s.block_fetches + s.block_hits)
        .sum();
    assert!(traffic > 0, "no block traffic in any span");
    // the human report renders without panicking and names the format
    let rendered = render_report(&traces, 3);
    assert!(rendered.contains("8 sampled queries"), "{rendered}");
    assert!(rendered.contains("slowest 3 queries:"), "{rendered}");

    // per-point snapshots: one pair per ef, labelled in sweep order,
    // each point's delta attributing at least its own timed queries
    let labels: Vec<&str> =
        sinks.metrics_points.iter().map(|(l, _, _)| l.as_str()).collect();
    assert_eq!(labels, ["ef=16", "ef=32"]);
    for (label, cum, delta) in &sinks.metrics_points {
        let d = delta.counter("query.count").unwrap_or(0);
        assert!(d >= 12, "{label}: delta query.count {d} < 12");
        assert!(cum.counter("query.count").unwrap_or(0) >= d);
        assert!(cum.hist("query.service_us").is_some(), "{label}: no service histogram");
    }
    // the sweep rows grew the work columns
    for row in &report.rows {
        assert!(row.cols.iter().any(|(n, v)| n == "dist_evals" && *v > 0.0), "{row:?}");
        assert!(row.cols.iter().any(|(n, v)| n == "hops" && *v > 0.0), "{row:?}");
    }
    std::fs::remove_dir_all(dir).ok();
}

/// `telemetry::warn!` goes through the counted `[warn]` funnel: the
/// process-wide warning total advances by at least the warnings this
/// test emits (other tests may emit their own concurrently).
#[test]
fn warn_macro_counts_warnings() {
    let before = telemetry::warnings_total();
    telemetry::warn!("telemetry test: {} of {}", 1, 2);
    telemetry::warn!("telemetry test: second");
    assert!(telemetry::warnings_total() >= before + 2);
}
