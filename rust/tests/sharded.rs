//! Integration: sharded serving over real `ooc-build` output — the
//! manifest round-trip, the global-id invariants the merge maintains,
//! and recall parity between the sharded scatter-gather path and the
//! monolithic index over the same assembled graph.

use std::collections::HashSet;
use std::path::PathBuf;

use gnnd::config::Metric;
use gnnd::dataset::io;
use gnnd::dataset::{groundtruth, synth};
use gnnd::gnnd::{GnndParams, NativeEngine};
use gnnd::graph::KnnGraph;
use gnnd::merge::outofcore::{
    build_out_of_core, quantize_store, OutOfCoreConfig, ResidencyMode, ShardManifest, ShardStore,
    MANIFEST_FILE, STATS_FILE,
};
use gnnd::search::sharded::ShardedIndex;
use gnnd::search::{AnnIndex, EntryStrategy, SearchIndex, SearchParams};
use gnnd::util::json::Json;

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "gnnd-sharded-{tag}-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn manifest_roundtrip() {
    let dir = tmpdir("manifest");
    let store = ShardStore::new(&dir).unwrap();
    let m = ShardManifest {
        shards: 3,
        total: 300,
        d: 4,
        k: 8,
        metric: Metric::L2,
        offsets: vec![0, 100, 200],
        centroids: vec![
            vec![0.5, 1.0, -2.25, 3.0],
            vec![0.1, -0.2, 0.3, -0.4],
            vec![7.75, 0.0, -1.5, 2.125],
        ],
        route_centroids: vec![
            vec![vec![0.5, 1.0, -2.25, 3.0], vec![0.25, 0.5, -1.0, 1.5]],
            vec![vec![0.1, -0.2, 0.3, -0.4]],
            vec![],
        ],
    };
    store.save_manifest(&m).unwrap();
    let back = store.load_manifest().unwrap();
    assert_eq!(back, m);
    // a manifest written before route_centroids existed still loads,
    // defaulting to one empty centroid list per shard
    let text = std::fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();
    let mut j = Json::parse(&text).unwrap();
    if let Json::Obj(fields) = &mut j {
        fields.retain(|(k, _)| k != "route_centroids");
    }
    std::fs::write(dir.join(MANIFEST_FILE), j.to_string()).unwrap();
    let old = store.load_manifest().unwrap();
    assert_eq!(old.route_centroids, vec![Vec::<Vec<f32>>::new(); 3]);
    assert_eq!(old.centroids, m.centroids);
    // a manifest missing a required field is rejected with a useful error
    let mut j = Json::parse(&text).unwrap();
    if let Json::Obj(fields) = &mut j {
        fields.retain(|(k, _)| k != "offsets");
    }
    std::fs::write(dir.join(MANIFEST_FILE), j.to_string()).unwrap();
    let err = store.load_manifest().unwrap_err().to_string();
    assert!(err.contains("offsets"), "unhelpful error: {err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn ooc_build_persists_manifest_stats_and_global_id_invariants() {
    let ds = synth::clustered(480, 8, 41);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("invariants");
    let (_g, stats) = build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    // stats.json persisted for bench trajectories
    let text = std::fs::read_to_string(dir.join(STATS_FILE)).unwrap();
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("merges").and_then(Json::as_usize), Some(stats.merges));
    assert_eq!(j.get("rounds").and_then(Json::as_usize), Some(stats.rounds));
    assert!(j.get("merge_secs").and_then(Json::as_f64).unwrap() >= 0.0);

    // manifest describes the shard geometry
    let store = ShardStore::new(&dir).unwrap();
    let m = store.load_manifest().unwrap();
    assert_eq!(m.shards, 4);
    assert_eq!(m.total, 480);
    assert_eq!(m.d, 8);
    assert_eq!(m.k, 10);
    assert_eq!(m.offsets.len(), 4);
    assert_eq!(m.centroids.len(), 4);
    assert_eq!(m.offsets[0], 0);
    assert!(m.centroids.iter().all(|c| c.len() == 8));

    // global-id invariants of every merged shard graph: every neighbor
    // id lives inside the global space, no self-loops, no duplicates
    for s in 0..m.shards {
        let g = store.load_graph(s).unwrap();
        let off = m.offsets[s] as u32;
        for u in 0..g.n() {
            let gid = off + u as u32;
            let mut seen = HashSet::new();
            for e in g.list(u) {
                if e.is_empty() {
                    break;
                }
                assert!(
                    (e.id as usize) < m.total,
                    "shard {s} u={u}: id {} >= total {}",
                    e.id,
                    m.total
                );
                assert_ne!(e.id, gid, "shard {s} u={u}: self loop");
                assert!(seen.insert(e.id), "shard {s} u={u}: duplicate id {}", e.id);
            }
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

fn recall_over(index: &dyn AnnIndex, qids: &[usize], truth: &[Vec<u32>], k: usize) -> f64 {
    let mut scratch = index.make_scratch();
    let mut out = Vec::new();
    let mut hit = 0usize;
    let mut total = 0usize;
    for (row, &q) in truth.iter().zip(qids) {
        let qv = index.vector(q as u32).to_vec();
        index.search_ef_into_excluding(&qv, k, 0, q as u32, &mut scratch, &mut out);
        let set: HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
        hit += row.iter().take(k).filter(|id| set.contains(id)).count();
        total += row.len().min(k);
    }
    hit as f64 / total as f64
}

#[test]
fn sharded_recall_parity_with_monolithic() {
    // The acceptance shape: serving the shard directory must be within
    // 2 recall points of serving the assembled monolithic graph at the
    // same ef.
    let ds = synth::clustered(600, 8, 42);
    let params = GnndParams::default().with_k(12).with_p(6).with_iters(8);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("parity");
    let (g, _) = build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    let sp = SearchParams::default().with_ef(64);
    let mono = SearchIndex::new(&ds, &g, sp.clone()).unwrap();
    let sharded = ShardedIndex::open(&dir, sp, 0).unwrap();
    assert_eq!(sharded.len(), ds.len());
    assert_eq!(sharded.dim(), ds.d);
    assert_eq!(sharded.shards(), 4);

    let (qids, truth) = groundtruth::sampled_truth(&ds, 150, 10, 7);
    let r_mono = recall_over(&mono, &qids, &truth, 10);
    let r_sharded = recall_over(&sharded, &qids, &truth, 10);
    assert!(
        r_sharded >= r_mono - 0.02,
        "sharded recall {r_sharded} more than 2 points below monolithic {r_mono}"
    );
    assert!(r_sharded > 0.8, "sharded recall {r_sharded} too low outright");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn sharded_results_are_sorted_dedup_and_deterministic() {
    let ds = synth::clustered(400, 6, 43);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 3, workers: 1, params };
    let dir = tmpdir("results");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    let sp = SearchParams::default().with_ef(48);
    let index = ShardedIndex::open(&dir, sp.clone(), 0).unwrap();
    let again = ShardedIndex::open(&dir, sp, 0).unwrap();
    let mut scratch = index.make_scratch();
    let mut scratch2 = again.make_scratch();
    let mut out = Vec::new();
    let mut out2 = Vec::new();
    for q in (0..ds.len()).step_by(37) {
        index.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut scratch, &mut out);
        assert!(!out.is_empty());
        assert!(out.len() <= 10);
        assert!(out.iter().all(|&(_, id)| id != q as u32), "self in results of {q}");
        assert!(out.iter().all(|&(_, id)| (id as usize) < ds.len()));
        for w in out.windows(2) {
            assert!(w[0].0 <= w[1].0, "unsorted results for {q}");
        }
        let ids: HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids.len(), out.len(), "duplicate ids for {q}");
        again.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut scratch2, &mut out2);
        assert_eq!(out2, out, "nondeterministic for {q}");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn probing_fewer_shards_is_monotone_in_recall() {
    // Probing a subset of shards searches a subset of candidates, so
    // recall at probe=all dominates recall at probe=1; both answer.
    let ds = synth::clustered(500, 8, 44);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("probe");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    let sp = SearchParams::default().with_ef(48);
    let all = ShardedIndex::open(&dir, sp.clone(), 0).unwrap();
    let one = ShardedIndex::open(&dir, sp, 1).unwrap();
    assert_eq!(all.probe(), 4);
    assert_eq!(one.probe(), 1);

    let (qids, truth) = groundtruth::sampled_truth(&ds, 100, 10, 9);
    let r_all = recall_over(&all, &qids, &truth, 10);
    let r_one = recall_over(&one, &qids, &truth, 10);
    assert!(r_all >= r_one - 1e-9, "probe=all recall {r_all} below probe=1 recall {r_one}");
    let hits = one.search(ds.vec(3), 5);
    assert_eq!(hits.len(), 5, "probe=1 must still fill k");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn opening_without_manifest_fails_cleanly() {
    let dir = tmpdir("nomanifest");
    let err = ShardedIndex::open(&dir, SearchParams::default(), 0).unwrap_err().to_string();
    assert!(err.contains("manifest"), "unhelpful error: {err}");
    std::fs::remove_dir_all(dir).ok();
}

/// Results under a byte budget that fits only 1 of 4 shards must be
/// *bit-identical* to the unbounded index (same seeds): the scoring
/// universe is the probed set, never "what happened to be resident".
#[test]
fn budget_constrained_results_match_unbounded_exactly() {
    let ds = synth::clustered(480, 8, 45);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("budget");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    let sp = SearchParams::default().with_ef(48);
    let unbounded = ShardedIndex::open(&dir, sp.clone(), 0).unwrap();
    // total resident bytes of the store, via the manifest estimate
    let store = ShardStore::new(&dir).unwrap();
    let manifest = store.load_manifest().unwrap();
    let budget = manifest.shard_bytes(0); // fits ~1 of 4 shards
    assert!(budget * 3 < manifest.estimated_resident_bytes());
    let tight = ShardedIndex::open_with(&dir, sp, 0, budget, 1).unwrap();
    assert_eq!(tight.store().budget_bytes(), budget);

    let mut s1 = unbounded.make_scratch();
    let mut s2 = tight.make_scratch();
    let (mut o1, mut o2) = (Vec::new(), Vec::new());
    for q in (0..ds.len()).step_by(23) {
        unbounded.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s1, &mut o1);
        tight.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s2, &mut o2);
        assert_eq!(o1, o2, "budget changed the results of query {q}");
        assert_eq!(s1.dist_evals, s2.dist_evals, "budget changed the walk of query {q}");
    }
    let res = tight.residency();
    assert!(res.evictions > 0, "1-of-4 budget must evict: {res:?}");
    assert!(res.misses > res.hits, "1-of-4 budget at probe=all must mostly miss: {res:?}");
    // unpinned cache respects the budget once the last query's pins drop
    tight.store().evict_to_budget();
    assert!(tight.residency().resident_bytes <= budget);
    std::fs::remove_dir_all(dir).ok();
}

/// Pool-based scatter is bit-identical to sequential scatter across
/// the (search_threads, probe_shards) grid — including more pool
/// participants than probed shards and a residency budget that fits
/// only half the store. The gather sort is order-independent and every
/// per-shard walk is independent, so neither the pool fan-out nor the
/// cache state may change a single bit of output.
#[test]
fn pool_scatter_parity_across_threads_probe_and_budget() {
    let ds = synth::clustered(480, 8, 48);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("poolparity");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    let manifest = ShardStore::new(&dir).unwrap().load_manifest().unwrap();
    let half = manifest.estimated_resident_bytes() / 2;

    let sp = SearchParams::default().with_ef(48);
    for probe in [0usize, 1, 2, 3] {
        let seq = ShardedIndex::open_with(&dir, sp.clone(), probe, 0, 1).unwrap();
        assert_eq!(seq.pool_workers(), 0, "sequential index must not spawn a pool");
        let mut s_seq = seq.make_scratch();
        let mut o_seq = Vec::new();
        for threads in [2usize, 4, 8] {
            for budget in [0usize, half] {
                let par = ShardedIndex::open_with(&dir, sp.clone(), probe, budget, threads)
                    .unwrap();
                // pool size is search_threads - 1 capped at shards - 1:
                // a participant beyond the shard count can never claim
                // work, so no thread is spawned to park forever
                assert_eq!(
                    par.pool_workers(),
                    (threads - 1).min(par.shards() - 1),
                    "wrong pool size for search_threads={threads}"
                );
                let mut s_par = par.make_scratch();
                let mut o_par = Vec::new();
                for q in (0..ds.len()).step_by(41) {
                    seq.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_seq,
                        &mut o_seq,
                    );
                    par.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_par,
                        &mut o_par,
                    );
                    assert_eq!(
                        o_seq, o_par,
                        "pool scatter diverged (threads={threads} probe={probe} \
                         budget={budget}) on query {q}"
                    );
                    assert_eq!(
                        s_seq.dist_evals, s_par.dist_evals,
                        "eval counts diverged (threads={threads} probe={probe} \
                         budget={budget}) on query {q}"
                    );
                    assert_eq!(
                        s_seq.hops, s_par.hops,
                        "hop counts diverged (threads={threads} probe={probe} \
                         budget={budget}) on query {q}"
                    );
                }
            }
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Parallel scatter (`--search-threads`) is bit-identical to the
/// sequential scatter — the gather sort is order-independent and every
/// per-shard walk is independent.
#[test]
fn parallel_scatter_matches_sequential() {
    let ds = synth::clustered(480, 8, 46);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("parscatter");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    let sp = SearchParams::default().with_ef(48);
    let seq = ShardedIndex::open_with(&dir, sp.clone(), 0, 0, 1).unwrap();
    let par = ShardedIndex::open_with(&dir, sp, 0, 0, 4).unwrap();
    assert_eq!(seq.scatter_threads(), 1);
    assert_eq!(par.scatter_threads(), 4);
    let mut s1 = seq.make_scratch();
    let mut s2 = par.make_scratch();
    let (mut o1, mut o2) = (Vec::new(), Vec::new());
    for q in (0..ds.len()).step_by(31) {
        seq.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s1, &mut o1);
        par.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s2, &mut o2);
        assert_eq!(o1, o2, "parallel scatter diverged on query {q}");
        assert_eq!(s1.dist_evals, s2.dist_evals, "eval counts diverged on query {q}");
        assert_eq!(s1.hops, s2.hops, "hop counts diverged on query {q}");
    }
    std::fs::remove_dir_all(dir).ok();
}

/// The tentpole acceptance grid: block-granular paged serving is
/// *bit-identical* to the owned (whole-shard, unbounded) path across
/// probe x budget x threads — including budgets smaller than a single
/// shard, a configuration whole-shard residency could not serve
/// without pinning past the budget on every query.
#[test]
fn paged_parity_with_owned_across_probe_budget_threads() {
    let ds = synth::clustered(480, 8, 49);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("pagedparity");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    let manifest = ShardStore::new(&dir).unwrap().load_manifest().unwrap();
    let sub_shard = manifest.shard_bytes(0) / 3; // smaller than ONE shard
    let half = manifest.estimated_resident_bytes() / 2;

    let sp = SearchParams::default().with_ef(48);
    for probe in [0usize, 1, 2] {
        let owned = ShardedIndex::open_with(&dir, sp.clone(), probe, 0, 1).unwrap();
        let mut s_own = owned.make_scratch();
        let mut o_own = Vec::new();
        for budget in [0usize, sub_shard, half] {
            for threads in [1usize, 4] {
                let paged = ShardedIndex::open_with_residency(
                    &dir,
                    sp.clone(),
                    probe,
                    budget,
                    threads,
                    ResidencyMode::block(),
                )
                .unwrap();
                let mut s_pg = paged.make_scratch();
                let mut o_pg = Vec::new();
                for q in (0..ds.len()).step_by(29) {
                    owned.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_own,
                        &mut o_own,
                    );
                    paged.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_pg,
                        &mut o_pg,
                    );
                    assert_eq!(
                        o_own, o_pg,
                        "paged serving diverged (probe={probe} budget={budget} \
                         threads={threads}) on query {q}"
                    );
                    assert_eq!(
                        s_own.dist_evals, s_pg.dist_evals,
                        "eval counts diverged (probe={probe} budget={budget} \
                         threads={threads}) on query {q}"
                    );
                }
                let res = paged.residency();
                assert_eq!(res.mode, "block");
                assert!(res.block_fetches > 0, "no blocks paged in: {res:?}");
            }
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

/// The partial-read acceptance shape: a low-probe serve run over a
/// block-residency store must read strictly fewer bytes off disk than
/// the store's total payload — whole-shard residency had to read
/// everything the probe touched; block residency reads only the rows
/// the walks visit.
#[test]
fn block_residency_reads_less_than_total_bytes() {
    let ds = synth::clustered(600, 8, 50);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("partialread");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    let manifest = ShardStore::new(&dir).unwrap().load_manifest().unwrap();
    // total on-disk payload (vectors + graph entries) across shards
    let total: u64 = (0..manifest.shards)
        .map(|s| (manifest.shard_len(s) * (manifest.d * 4 + manifest.k * 8)) as u64)
        .sum();

    // small blocks so reads track visited rows closely; probe=1 keeps
    // each query inside its nearest shard
    let sp = SearchParams::default().with_ef(32);
    let index = ShardedIndex::open_with_residency(
        &dir,
        sp,
        1,
        256 * 1024,
        1,
        ResidencyMode::Block { block_bytes: 1024 },
    )
    .unwrap();
    let mut scratch = index.make_scratch();
    let mut out = Vec::new();
    // two queries at probe=1 touch at most 2 of the 4 shards' blocks,
    // so even a walk that visits a whole shard stays under the total
    for q in [0usize, 400] {
        index.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut scratch, &mut out);
        assert!(!out.is_empty());
    }
    let res = index.residency();
    assert!(res.block_fetches > 0);
    assert!(
        res.bytes_read < total,
        "low-probe block serving read {} bytes >= total payload {total} — \
         partial-shard reads are not happening: {res:?}",
        res.bytes_read
    );
    std::fs::remove_dir_all(dir).ok();
}

/// Legacy v1 shard files under `--residency block` fall back to owned
/// loads per shard and still return results identical to a v2 store.
#[test]
fn block_residency_serves_v1_stores_identically() {
    let ds = synth::clustered(400, 6, 51);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(5);
    let cfg = OutOfCoreConfig { shards: 3, workers: 1, params };
    let dir = tmpdir("v1compat");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    let sp = SearchParams::default().with_ef(48);
    let v2 = ShardedIndex::open_with_residency(&dir, sp.clone(), 0, 0, 1, ResidencyMode::block())
        .unwrap();
    let mut s2 = v2.make_scratch();
    let mut o2 = Vec::new();
    let mut answers = Vec::new();
    for q in (0..ds.len()).step_by(43) {
        v2.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s2, &mut o2);
        answers.push(o2.clone());
    }
    drop(v2);

    // rewrite every shard pair in the legacy v1 layouts
    for s in 0..3 {
        let shard = io::read_dsb(dir.join(format!("shard_{s}.dsb"))).unwrap();
        io::write_dsb_v1(&shard, dir.join(format!("shard_{s}.dsb"))).unwrap();
        let g = KnnGraph::load(dir.join(format!("graph_{s}.knng"))).unwrap();
        g.save_v1(dir.join(format!("graph_{s}.knng"))).unwrap();
    }
    let v1 = ShardedIndex::open_with_residency(&dir, sp, 0, 0, 1, ResidencyMode::block()).unwrap();
    let mut s1 = v1.make_scratch();
    let mut o1 = Vec::new();
    for (row, q) in (0..ds.len()).step_by(43).enumerate() {
        v1.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s1, &mut o1);
        assert_eq!(o1, answers[row], "v1 fallback diverged on query {q}");
    }
    // v1 files cannot page: no block traffic, everything owned
    assert_eq!(v1.residency().block_fetches, 0);
    std::fs::remove_dir_all(dir).ok();
}

/// Recall with the *original* f32 rows as queries (unlike
/// [`recall_over`], which replays `index.vector(q)` — on a quantized
/// index that would be the dequantized row, muddying the comparison
/// against an f32 baseline).
fn recall_with_f32_queries(
    index: &dyn AnnIndex,
    ds: &gnnd::dataset::Dataset,
    qids: &[usize],
    truth: &[Vec<u32>],
    k: usize,
) -> f64 {
    let mut scratch = index.make_scratch();
    let mut out = Vec::new();
    let mut hit = 0usize;
    let mut total = 0usize;
    for (row, &q) in truth.iter().zip(qids) {
        index.search_ef_into_excluding(ds.vec(q), k, 0, q as u32, &mut scratch, &mut out);
        let set: HashSet<u32> = out.iter().map(|&(_, id)| id).collect();
        hit += row.iter().take(k).filter(|id| set.contains(id)).count();
        total += row.len().min(k);
    }
    hit as f64 / total as f64
}

/// Quantized code-space distances preserve the f32 neighbor ordering:
/// over sampled candidate pairs whose f32 distances differ by more
/// than the quantization noise floor, the code distance agrees on the
/// order — the rank correlation that lets a quantized beam plus exact
/// rerank recover f32 recall.
#[test]
fn quant_rank_correlation_with_f32() {
    let ds = synth::clustered(300, 8, 52);
    let qds = ds.quantize();
    let mut qcodes = Vec::new();
    let mut lut = Vec::new();
    let (mut concordant, mut pairs) = (0usize, 0usize);
    for q in (0..ds.len()).step_by(11) {
        let qv = ds.vec(q).to_vec();
        assert!(
            qds.prepare_query(&qv, &mut qcodes, &mut lut),
            "quantized dataset must own a code space"
        );
        for i in (0..ds.len()).step_by(7) {
            let j = (i * 131 + 17) % ds.len();
            let (di, dj) = (ds.dist_to(i, &qv), ds.dist_to(j, &qv));
            // near-ties may legitimately flip inside the quantization
            // step; the property is about pairs with a real gap
            if (di - dj).abs() <= 0.05 * di.abs().max(dj.abs()).max(1e-6) {
                continue;
            }
            let qi = qds.dist_to_quant(i, &qv, &qcodes, &lut);
            let qj = qds.dist_to_quant(j, &qv, &qcodes, &lut);
            pairs += 1;
            if (di < dj) == (qi < qj) {
                concordant += 1;
            }
        }
    }
    assert!(pairs > 500, "tie filter ate the sample: only {pairs} pairs");
    let frac = concordant as f64 / pairs as f64;
    assert!(frac >= 0.9, "rank concordance {frac:.3} over {pairs} pairs too low");
}

/// The quantized serving grid: Shard-owned and Block-paged residency
/// are *bit-identical* across probe x budget x rerank (same codes,
/// same exact-rerank rows, order-independent gather sort), and
/// `rerank=4` recovers to within 2 recall points of the f32 index
/// over the same shard directory.
#[test]
fn quantized_parity_grid_and_rerank_recall() {
    let ds = synth::clustered(480, 8, 54);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("quantgrid");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    quantize_store(&dir).unwrap();
    let manifest = ShardStore::new(&dir).unwrap().load_manifest().unwrap();
    let half = manifest.estimated_resident_bytes() / 2;

    let (qids, truth) = groundtruth::sampled_truth(&ds, 120, 10, 13);
    let f32_recall = {
        let idx = ShardedIndex::open(&dir, SearchParams::default().with_ef(48), 0).unwrap();
        recall_with_f32_queries(&idx, &ds, &qids, &truth, 10)
    };

    for rerank in [1usize, 4] {
        let sp = SearchParams::default().with_ef(48).with_rerank(rerank);
        for probe in [0usize, 2] {
            for budget in [0usize, half] {
                let owned = ShardedIndex::from_store(
                    ShardStore::with_options(&dir, budget, ResidencyMode::Shard, true).unwrap(),
                    sp.clone(),
                    probe,
                    1,
                )
                .unwrap();
                let paged = ShardedIndex::from_store(
                    ShardStore::with_options(&dir, budget, ResidencyMode::block(), true).unwrap(),
                    sp.clone(),
                    probe,
                    1,
                )
                .unwrap();
                assert!(
                    owned.describe().contains("u8-quantized"),
                    "describe must surface the backing: {}",
                    owned.describe()
                );
                let mut s_own = owned.make_scratch();
                let mut s_pg = paged.make_scratch();
                let (mut o_own, mut o_pg) = (Vec::new(), Vec::new());
                for q in (0..ds.len()).step_by(37) {
                    owned.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_own,
                        &mut o_own,
                    );
                    paged.search_ef_into_excluding(
                        ds.vec(q),
                        10,
                        0,
                        q as u32,
                        &mut s_pg,
                        &mut o_pg,
                    );
                    assert_eq!(
                        o_own, o_pg,
                        "quantized residency modes diverged (rerank={rerank} probe={probe} \
                         budget={budget}) on query {q}"
                    );
                    assert_eq!(
                        s_own.dist_evals, s_pg.dist_evals,
                        "code-space eval counts diverged on query {q}"
                    );
                    assert_eq!(
                        s_own.rerank_evals, s_pg.rerank_evals,
                        "rerank eval counts diverged on query {q}"
                    );
                    if rerank == 1 {
                        assert_eq!(s_own.rerank_evals, 0, "rerank=1 must skip the exact pass");
                    } else {
                        assert!(
                            s_own.rerank_evals > 0 && s_own.rerank_evals <= 10 * rerank,
                            "rerank pass must score at most rerank*k candidates: {}",
                            s_own.rerank_evals
                        );
                    }
                }
            }
        }
        let idx = ShardedIndex::from_store(
            ShardStore::with_options(&dir, 0, ResidencyMode::Shard, true).unwrap(),
            SearchParams::default().with_ef(48).with_rerank(rerank),
            0,
            1,
        )
        .unwrap();
        let r = recall_with_f32_queries(&idx, &ds, &qids, &truth, 10);
        if rerank == 4 {
            assert!(
                r >= f32_recall - 0.02,
                "quantized rerank=4 recall {r} more than 2 points below f32 {f32_recall}"
            );
        } else {
            assert!(r > 0.5, "quantized rerank=1 recall collapsed outright: {r}");
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Under the same block-residency budget and block size, serving the
/// quantized codes pages in fewer blocks than serving f32 rows: a u8
/// code block holds 4x the rows, so the same walks touch ~1/4 the
/// data blocks (graph traffic is identical in both runs).
#[test]
fn quantized_block_store_fetches_fewer_blocks() {
    let ds = synth::clustered(600, 8, 55);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("quantfetch");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    quantize_store(&dir).unwrap();

    let fetches = |quantized: bool| {
        let mode = ResidencyMode::Block { block_bytes: 1024 };
        let store = ShardStore::with_options(&dir, 256 * 1024, mode, quantized).unwrap();
        let idx =
            ShardedIndex::from_store(store, SearchParams::default().with_ef(32), 1, 1).unwrap();
        let mut scratch = idx.make_scratch();
        let mut out = Vec::new();
        for q in (0..ds.len()).step_by(17) {
            idx.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut scratch, &mut out);
            assert!(!out.is_empty());
        }
        idx.residency().block_fetches
    };
    let f = fetches(false);
    let q = fetches(true);
    assert!(q < f, "quantized block serving fetched {q} blocks, f32 fetched {f}");
    std::fs::remove_dir_all(dir).ok();
}

/// Adaptive routing invariants: with `route_slack` disabled the route
/// phase is bit-identical to the fixed-probe ranking, a manifest
/// stripped of `route_centroids` (a pre-routing store) serves the same
/// results through the single-centroid fallback, and an effectively
/// infinite slack degenerates to probing the full cap.
#[test]
fn adaptive_routing_zero_slack_and_old_manifest_parity() {
    let ds = synth::clustered(480, 8, 56);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("routeparity");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    let store = ShardStore::new(&dir).unwrap();
    let manifest = store.load_manifest().unwrap();
    assert!(
        manifest.route_centroids.iter().all(|c| !c.is_empty()),
        "ooc-build must fit route centroids per shard"
    );

    let fixed = ShardedIndex::open(&dir, SearchParams::default().with_ef(48), 2).unwrap();
    let loose = ShardedIndex::open(
        &dir,
        SearchParams::default().with_ef(48).with_route_slack(1e9),
        2,
    )
    .unwrap();
    let mut s_fix = fixed.make_scratch();
    let mut s_loose = loose.make_scratch();
    let (mut o_fix, mut o_loose) = (Vec::new(), Vec::new());
    for q in (0..ds.len()).step_by(29) {
        fixed.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s_fix, &mut o_fix);
        assert_eq!(s_fix.shards_probed, 2, "slack=0 must probe exactly the cap");
        loose.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s_loose, &mut o_loose);
        assert_eq!(s_loose.shards_probed, 2, "huge slack must degenerate to the cap");
        assert_eq!(o_fix, o_loose, "huge slack diverged from fixed probe on query {q}");
    }

    // the empty-route_centroids fallback routes by the mean centroid:
    // a manifest carrying exactly [[mean]] per shard and a manifest
    // stripped of route_centroids must rank (and serve) identically
    let mut single = manifest.clone();
    single.route_centroids = single.centroids.iter().map(|c| vec![c.clone()]).collect();
    store.save_manifest(&single).unwrap();
    let explicit = ShardedIndex::open(&dir, SearchParams::default().with_ef(48), 2).unwrap();
    let mut stripped = manifest.clone();
    stripped.route_centroids = vec![Vec::new(); stripped.shards];
    store.save_manifest(&stripped).unwrap();
    let old = ShardedIndex::open(&dir, SearchParams::default().with_ef(48), 2).unwrap();
    let mut s_exp = explicit.make_scratch();
    let mut s_old = old.make_scratch();
    let (mut o_exp, mut o_old) = (Vec::new(), Vec::new());
    for q in (0..ds.len()).step_by(29) {
        explicit.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s_exp, &mut o_exp);
        old.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut s_old, &mut o_old);
        assert_eq!(o_exp, o_old, "centroid fallback diverged on query {q}");
        assert_eq!(s_exp.dist_evals, s_old.dist_evals, "fallback walk diverged on query {q}");
    }
    std::fs::remove_dir_all(dir).ok();
}

/// A tight slack prunes: per-query probed counts stay within [1, cap],
/// and the adaptive index still fills k from whatever it probes.
#[test]
fn adaptive_slack_probes_within_bounds() {
    let ds = synth::clustered(500, 8, 57);
    let params = GnndParams::default().with_k(10).with_p(5).with_iters(6);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("slackbounds");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    let sp = SearchParams::default().with_ef(48).with_route_slack(1.0);
    let idx = ShardedIndex::open(&dir, sp, 0).unwrap();
    let mut scratch = idx.make_scratch();
    let mut out = Vec::new();
    let mut min_probed = usize::MAX;
    for q in (0..ds.len()).step_by(23) {
        idx.search_ef_into_excluding(ds.vec(q), 10, 0, q as u32, &mut scratch, &mut out);
        assert!(
            (1..=4).contains(&scratch.shards_probed),
            "query {q} probed {} shards",
            scratch.shards_probed
        );
        min_probed = min_probed.min(scratch.shards_probed);
        assert_eq!(out.len(), 10, "adaptive probe must still fill k for {q}");
    }
    assert!(min_probed < 4, "slack=1.0 never pruned a shard — cutoff is inert");
    std::fs::remove_dir_all(dir).ok();
}

/// Hierarchy entries over a shard store: per-shard `hier_<s>.bin`
/// sidecars are written once, reused byte-identically on reopen, and
/// serving with hierarchy entries stays within 2 recall points of the
/// flat k-means entries over the same store.
#[test]
fn sharded_hierarchy_sidecars_persist_and_hold_recall() {
    let ds = synth::clustered(600, 8, 58);
    let params = GnndParams::default().with_k(12).with_p(6).with_iters(8);
    let cfg = OutOfCoreConfig { shards: 4, workers: 2, params };
    let dir = tmpdir("hiershard");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();

    let flat_sp = SearchParams::default().with_ef(64);
    let hier_sp = SearchParams::default()
        .with_ef(64)
        .with_entries(EntryStrategy::Hierarchy, 16);
    let flat = ShardedIndex::open(&dir, flat_sp, 0).unwrap();
    let hier = ShardedIndex::open(&dir, hier_sp.clone(), 0).unwrap();
    let sidecars: Vec<Vec<u8>> = (0..4)
        .map(|s| std::fs::read(dir.join(format!("hier_{s}.bin"))).unwrap())
        .collect();

    let (qids, truth) = groundtruth::sampled_truth(&ds, 120, 10, 11);
    let r_flat = recall_over(&flat, &qids, &truth, 10);
    let r_hier = recall_over(&hier, &qids, &truth, 10);
    assert!(
        r_hier >= r_flat - 0.02,
        "hierarchy recall {r_hier} more than 2 points below flat {r_flat}"
    );
    drop(hier);

    // reopen: sidecars load (not rebuild) and stay byte-identical
    let again = ShardedIndex::open(&dir, hier_sp, 0).unwrap();
    for (s, bytes) in sidecars.iter().enumerate() {
        let back = std::fs::read(dir.join(format!("hier_{s}.bin"))).unwrap();
        assert_eq!(&back, bytes, "hier_{s}.bin changed across opens");
    }
    let mut s1 = again.make_scratch();
    let mut out = Vec::new();
    again.search_ef_into_excluding(ds.vec(5), 10, 0, 5, &mut s1, &mut out);
    assert_eq!(out.len(), 10);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn probe_clamp_is_reported() {
    use gnnd::search::sharded::clamp_probe;
    assert_eq!(clamp_probe(99, 4), (4, true));
    assert_eq!(clamp_probe(4, 4), (4, false));
    assert_eq!(clamp_probe(0, 4), (0, false));
    // the index itself also tolerates an oversized probe
    let ds = synth::clustered(300, 6, 47);
    let params = GnndParams::default().with_k(8).with_p(4).with_iters(4);
    let cfg = OutOfCoreConfig { shards: 3, workers: 1, params };
    let dir = tmpdir("probeclamp");
    build_out_of_core(&ds, &dir, &cfg, &NativeEngine).unwrap();
    let idx = ShardedIndex::open(&dir, SearchParams::default(), 99).unwrap();
    assert_eq!(idx.probe(), 3);
    assert_eq!(idx.search(ds.vec(1), 5).len(), 5);
    std::fs::remove_dir_all(dir).ok();
}
